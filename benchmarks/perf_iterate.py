import os
if "xla_force_host_platform_device_count" not in os.environ.get("XLA_FLAGS", ""):
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Perf hillclimb harness: compile one cell under a named variant and diff
its roofline terms against the stored baseline artifact.

  PYTHONPATH=src:. python benchmarks/perf_iterate.py \
      --arch smollm-135m --shape train_4k --variant dp_only

Variants encode the §Perf candidate changes; each writes a tagged artifact
next to the baseline so EXPERIMENTS.md §Perf can cite both.
"""
import argparse     # noqa: E402
import json         # noqa: E402
import pathlib      # noqa: E402

from repro.core.sharding import (FSDP_RULES, LONG_CONTEXT_RULES,  # noqa: E402
                                 TP_DP_RULES)
from repro.launch.dryrun import run_cell   # noqa: E402
from repro.optim import AdamWConfig        # noqa: E402

# batch fully sharded over BOTH axes (model axis becomes extra DP) — for
# small archs whose attention cannot use TP.
DP_ONLY_RULES = TP_DP_RULES.replace(
    batch=("pod", "data", "model"), heads=(), kv_heads=(), mlp=(),
    experts=(), vocab=(), zero1=("pod", "data", "model"))

# flash-decode: KV cache sharded along *sequence* over the model axis —
# for GQA archs whose kv_heads < model_ways the cache would otherwise be
# replicated 16x and all-gathered every step.  q (1 token) replicates;
# the softmax runs distributed (psum of partial max/sum).
DECODE_SEQ_RULES = TP_DP_RULES.replace(
    kv_seq=("model",), heads=(), kv_heads=())

VARIANTS = {
    "baseline": {},
    "decode_seq": {"rules": DECODE_SEQ_RULES},
    "decode_seq_bf16": {"rules": DECODE_SEQ_RULES,
                        "cfg_overrides": {"param_dtype": "bfloat16"}},
    "dp_only": {"rules": DP_ONLY_RULES},
    "fsdp": {"rules": FSDP_RULES},
    "tp_dp": {"rules": TP_DP_RULES},
    "ce_chunk": {"cfg_overrides": {"ce_chunk": 512}},
    "ce_chunk_1k": {"cfg_overrides": {"ce_chunk": 1024}},
    "attn_chunk_2k": {"cfg_overrides": {"attn_chunk": 2048}},
    "attn_chunk_512": {"cfg_overrides": {"attn_chunk": 512}},
    "accum_2": {"accum": 2},
    "accum_4": {"accum": 4},
    "accum_16": {"accum": 16},
    "no_zero1": {"opt_cfg": AdamWConfig(zero1=False)},
    "grad_bf16": {"opt_cfg": AdamWConfig(grad_reduce_dtype="bfloat16")},
    "remat_dots": {"cfg_overrides": {"remat": "dots"}},
    "ssd_chunk_1k": {"cfg_overrides": {"ssd_chunk": 1024}},
    "dp_only_ce": {"rules": DP_ONLY_RULES,
                   "cfg_overrides": {"ce_chunk": 512}},
    "dp_only_dots": {"rules": DP_ONLY_RULES,
                     "cfg_overrides": {"remat": "dots"}},
    "dp_only_dots_ce": {"rules": DP_ONLY_RULES,
                        "cfg_overrides": {"remat": "dots",
                                          "ce_chunk": 1024}},
}


def show(rec, label):
    if rec.get("status") != "ok":
        print(f"{label}: {rec.get('status')} {rec.get('error', '')[:200]}")
        return None
    rl = rec["roofline"]
    mem = rec["memory"]
    print(f"{label:>16s}: compute={rl['compute_s']*1e3:9.2f}ms "
          f"memory={rl['memory_s']*1e3:9.2f}ms "
          f"coll={rl['collective_s']*1e3:9.2f}ms "
          f"dom={rl['dominant']:<10s} mfu={rl['mfu']:.4f} "
          f"useful={rl['useful_ratio']:.2f} "
          f"temp={mem['temp_size_in_bytes']/1e9:.1f}GB")
    return rl


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--shape", required=True)
    ap.add_argument("--variant", required=True)
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--out", default="artifacts/perf")
    args = ap.parse_args()

    out = pathlib.Path(args.out)
    base_path = pathlib.Path("artifacts/dryrun") / (
        f"{args.arch}__{args.shape}__"
        f"{'pod2x16x16' if args.multi_pod else 'pod16x16'}.json")
    base = json.loads(base_path.read_text()) if base_path.exists() else None
    if base:
        show(base, "baseline")

    spec = dict(VARIANTS[args.variant])
    rec = run_cell(args.arch, args.shape, args.multi_pod, out,
                   verbose=False, tag=args.variant, **spec)
    rl = show(rec, args.variant)
    if base and rl and base.get("status") == "ok":
        b = base["roofline"]
        for k in ("compute_s", "memory_s", "collective_s", "step_s"):
            delta = (rl[k] - b[k]) / b[k] * 100 if b[k] else 0.0
            print(f"   {k:>13s}: {b[k]*1e3:9.2f} -> {rl[k]*1e3:9.2f} ms "
                  f"({delta:+.1f}%)")
        print(f"   {'mfu':>13s}: {b['mfu']:.4f} -> {rl['mfu']:.4f}")


if __name__ == "__main__":
    main()
