"""Table 4 + Figs. 4/5: throughput evaluation, 50-400 jobs, fixed vs
flexible (preferred mode, as in the paper's §7.5).

Runs on the event-driven engine (``repro.rms.engine``); pass ``policy`` to
re-derive the table under any registered scheduling policy, and
``artifact`` to emit the rows in the versioned sweep schema shared with
``benchmarks/trace_replay.py`` and ``benchmarks/policy_zoo.py``.
"""
from __future__ import annotations

from benchmarks.common import run_sim
from repro.rms.sweep import artifact, report_row, row_key, write_artifact


def main(quick: bool = False, policy: str = "easy",
         artifact_path: str = None):
    sizes = (50, 100) if quick else (50, 100, 200, 400)
    print(f"# Table 4 + Fig4/5: workloads, fixed vs flexible (preferred, "
          f"{policy} scheduling policy)")
    print("jobs,version,util_rate_pct,job_waiting_s,job_exec_s,"
          "job_completion_s,makespan_s,makespan_gain_pct,wait_gain_pct")
    out = {}
    rows = []
    for n in sizes:
        base = run_sim(n, flexible=False, policy=policy)
        flex = run_sim(n, flexible=True, policy=policy)
        out[n] = (base, flex)
        for flexible, rep in ((False, base), (True, flex)):
            rows.append(report_row(
                rep, trace=f"feitelson-{n}", policy=policy,
                mix=(0.0, 0.0, 1.0, 0.0), flexible=flexible))
        bw, be, bc = base.averages()
        fw, fe, fc = flex.averages()
        for name, rep, (w, e, c) in (("fixed", base, (bw, be, bc)),
                                     ("flexible", flex, (fw, fe, fc))):
            gain = (base.makespan - rep.makespan) / base.makespan * 100
            wgain = (bw - w) / bw * 100 if bw else 0.0
            print(f"{n},{name},{rep.utilization()[0]:.2f},{w:.1f},{e:.1f},"
                  f"{c:.1f},{rep.makespan:.0f},{gain:.1f},{wgain:.1f}")
    n0 = sizes[0]
    base, flex = out[n0]
    checks = [
        ("flexible lowers allocation rate ~30% (Table 4)",
         flex.utilization()[0] < base.utilization()[0] - 10),
        ("waiting time reduced (Fig. 5)",
         flex.averages()[0] < base.averages()[0]),
        ("execution time increases (shrunk jobs)",
         flex.averages()[1] > base.averages()[1]),
        ("completion time improves (Fig. 4)",
         flex.averages()[2] < base.averages()[2]),
    ]
    for name, ok in checks:
        print(f"# claim[{name}]: {ok}")
    if artifact_path:
        grid = {"traces": [f"feitelson-{n}" for n in sizes],
                "policies": [policy], "mixes": [[0.0, 0.0, 1.0, 0.0]],
                "flexibles": [False, True], "num_nodes": 64, "seed": 7}
        # canonical row order: the schema promises row_key-sorted results
        write_artifact(artifact_path,
                       artifact(sorted(rows, key=row_key), grid))
        print(f"# wrote {artifact_path} ({len(rows)} rows)")
    return out


if __name__ == "__main__":
    import argparse

    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--policy", default="easy")
    ap.add_argument("--artifact", default=None)
    a = ap.parse_args()
    main(quick=a.quick, policy=a.policy, artifact_path=a.artifact)
