"""Table 4 + Figs. 4/5: throughput evaluation, 50-400 jobs, fixed vs
flexible (preferred mode, as in the paper's §7.5).

Runs on the event-driven engine (``repro.rms.engine``); pass ``policy`` to
re-derive the table under any registered scheduling policy.
"""
from __future__ import annotations

from benchmarks.common import run_sim


def main(quick: bool = False, policy: str = "easy"):
    sizes = (50, 100) if quick else (50, 100, 200, 400)
    print(f"# Table 4 + Fig4/5: workloads, fixed vs flexible (preferred, "
          f"{policy} scheduling policy)")
    print("jobs,version,util_rate_pct,job_waiting_s,job_exec_s,"
          "job_completion_s,makespan_s,makespan_gain_pct,wait_gain_pct")
    out = {}
    for n in sizes:
        base = run_sim(n, flexible=False, policy=policy)
        flex = run_sim(n, flexible=True, policy=policy)
        out[n] = (base, flex)
        bw, be, bc = base.averages()
        fw, fe, fc = flex.averages()
        for name, rep, (w, e, c) in (("fixed", base, (bw, be, bc)),
                                     ("flexible", flex, (fw, fe, fc))):
            gain = (base.makespan - rep.makespan) / base.makespan * 100
            wgain = (bw - w) / bw * 100 if bw else 0.0
            print(f"{n},{name},{rep.utilization()[0]:.2f},{w:.1f},{e:.1f},"
                  f"{c:.1f},{rep.makespan:.0f},{gain:.1f},{wgain:.1f}")
    n0 = sizes[0]
    base, flex = out[n0]
    checks = [
        ("flexible lowers allocation rate ~30% (Table 4)",
         flex.utilization()[0] < base.utilization()[0] - 10),
        ("waiting time reduced (Fig. 5)",
         flex.averages()[0] < base.averages()[0]),
        ("execution time increases (shrunk jobs)",
         flex.averages()[1] > base.averages()[1]),
        ("completion time improves (Fig. 4)",
         flex.averages()[2] < base.averages()[2]),
    ]
    for name, ok in checks:
        print(f"# claim[{name}]: {ok}")
    return out


if __name__ == "__main__":
    main()
