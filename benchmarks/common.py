"""Shared helpers for the paper-reproduction benchmarks.

All benchmarks drive the event-driven engine (``repro.rms.engine``) through
``ClusterSimulator``; ``run_sim`` exposes the scheduling-policy registry so
any table can be re-derived under fcfs / easy / conservative / malleable.
"""
from __future__ import annotations

import dataclasses
import sys
from typing import Dict, List

import numpy as np

from repro.rms import (ClusterSimulator, PAPER_APPS, SchedulerConfig,
                       SimConfig)
from repro.workload import make_workload

WIDE_APPS = {k: dataclasses.replace(v, preferred=None)
             for k, v in PAPER_APPS.items()}


def run_sim(n_jobs: int, *, flexible: bool, scheduling: str = "sync",
            wide: bool = False, seed: int = 7, policy: str = "easy", **kw):
    apps = WIDE_APPS if wide else None
    jobs = make_workload(n_jobs, seed=seed, apps=apps)
    cfg = SimConfig(num_nodes=64, flexible=flexible,
                    scheduling=scheduling,
                    sched=SchedulerConfig(policy=policy), **kw)
    return ClusterSimulator(jobs, cfg, apps=apps).run()


def action_stats(actions, kind: str) -> Dict[str, float]:
    xs = [a.decide_s + a.apply_s for a in actions if a.action == kind]
    if not xs:
        return {"min": 0.0, "max": 0.0, "avg": 0.0, "std": 0.0, "n": 0}
    arr = np.array(xs)
    return {"min": float(arr.min()), "max": float(arr.max()),
            "avg": float(arr.mean()), "std": float(arr.std()),
            "n": len(xs)}


def emit(rows: List[dict], header: List[str], file=None):
    file = file or sys.stdout
    print(",".join(header), file=file)
    for r in rows:
        print(",".join(str(r.get(h, "")) for h in header), file=file)
