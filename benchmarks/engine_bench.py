"""Engine-throughput benchmark: events/sec over the synthetic corpus.

The sweep scale-out work (ROADMAP "sweep scale-out + engine raw speed")
needs the inner engine's dispatch rate pinned PR-over-PR the same way the
golden traces pin semantics: this benchmark replays a fixed set of
scenarios from the deterministic synthetic SWF corpus
(``tests/synthetic_swf.py``) through :class:`ClusterSimulator` and reports

- **deterministic fields** — dispatched engine events, recorded actions,
  completed jobs, makespan — which must match the committed trajectory
  artifact ``BENCH_engine.json`` exactly (CI fails on drift: a semantics
  change must be intentional and regenerate the artifact), and
- **timings** — wall seconds and events/sec — which are machine-dependent
  and *informative only*: they are recorded in the trajectory so speedups
  and regressions are visible in review, but never byte-compared.

Trajectory artifact schema (``BENCH_engine.json``)::

    {"schema": "repro.bench.engine", "version": 1,
     "workload": {"n_jobs": ..., "num_nodes": ..., "seed": ...,
                  "time_scale": ...},
     "entries": [{"label": "...",
                  "deterministic": {"<scenario>": {"dispatched": ...,
                      "actions": ..., "completed": ..., "makespan_s": ...},
                      "total_dispatched": ...},
                  "timings": {"<scenario>": {"wall_s": ...,
                      "events_per_sec": ...},
                      "total_wall_s": ..., "events_per_sec": ...,
                      "sanitize_overhead_x": ..., "obs_overhead_x": ...}}]}

The ``sanitize_sjf_mixed_sync`` scenario replays ``sjf_mixed_sync`` in
checked mode (``SimConfig(sanitize=True)``); its deterministic fields
must equal the twin's and the bench fails if the wall-time overhead
reaches 3x.  ``trace_sjf_mixed_sync`` replays the same twin under the
observability recorder (:class:`repro.obs.recorder.TraceRecorder`) with
the same identical-semantics requirement and a 2x overhead budget.

``entries`` is append-only history (oldest first); CI checks the *last*
entry's deterministic fields against a fresh run.

Usage::

    PYTHONPATH=src python benchmarks/engine_bench.py            # print only
    PYTHONPATH=src python benchmarks/engine_bench.py \\
        --append BENCH_engine.json --label "PR 6"               # record
    PYTHONPATH=src python benchmarks/engine_bench.py \\
        --check BENCH_engine.json                               # CI smoke
"""
from __future__ import annotations

import argparse
import json
import os
import sys
import time
from typing import Dict, List, Optional, Tuple

_REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

SCHEMA_ID = "repro.bench.engine"
SCHEMA_VERSION = 1

#: Canonical workload parameters — the committed trajectory is only
#: comparable across entries because these never vary per run.
WORKLOAD = {"n_jobs": 1000, "num_nodes": 64, "seed": 7, "time_scale": 0.05}

#: (label, policy, (rigid, moldable, malleable, evolving), scheduling,
#: variant).  Chosen to cover the hot paths: sync + async DMR checks,
#: backfill, evolving phase churn, and the preemption channel.  Variants
#: replay an existing scenario under an engine monitor: ``"sanitize"``
#: installs the invariant sanitizer (:mod:`repro.rms.sanitizer`),
#: ``"trace"`` the observability recorder
#: (:class:`repro.obs.recorder.TraceRecorder`, finalize included in the
#: timed region).  A variant's deterministic fields must be identical to
#: its plain twin's, and its wall-time ratio to the twin is recorded as
#: ``timings["sanitize_overhead_x"]`` / ``timings["obs_overhead_x"]``
#: and pinned below :data:`SANITIZE_OVERHEAD_MAX` /
#: :data:`OBS_OVERHEAD_MAX`.
SCENARIOS: Tuple[Tuple[str, str, Tuple[float, float, float, float], str,
                       str], ...] = (
    ("easy_all_malleable_sync", "easy", (0.0, 0.0, 1.0, 0.0), "sync", ""),
    ("sjf_mixed_sync", "sjf", (0.25, 0.15, 0.3, 0.3), "sync", ""),
    ("malleable_async", "malleable", (0.0, 0.0, 1.0, 0.0), "async", ""),
    ("preempt_mixed_sync", "preempt", (0.2, 0.2, 0.6, 0.0), "sync", ""),
    ("sanitize_sjf_mixed_sync", "sjf", (0.25, 0.15, 0.3, 0.3), "sync",
     "sanitize"),
    ("trace_sjf_mixed_sync", "sjf", (0.25, 0.15, 0.3, 0.3), "sync",
     "trace"),
)

#: The monitored twins used for the overhead ratios.
SANITIZE_TWIN = ("sanitize_sjf_mixed_sync", "sjf_mixed_sync")
SANITIZE_OVERHEAD_MAX = 3.0
OBS_TWIN = ("trace_sjf_mixed_sync", "sjf_mixed_sync")
OBS_OVERHEAD_MAX = 2.0

ROUND_DIGITS = 6


def _synthetic_trace():
    tests_dir = os.path.join(_REPO, "tests")
    if tests_dir not in sys.path:
        sys.path.insert(0, tests_dir)
    import synthetic_swf
    from repro.workload.swf import parse_swf
    lines, _ = synthetic_swf.synthetic_swf(WORKLOAD["n_jobs"])
    return parse_swf(lines)


def _build_sim(trace, policy: str, mix, scheduling: str,
               sanitize: bool = False):
    from repro.rms.scheduler import SchedulerConfig
    from repro.rms.simulator import ClusterSimulator, SimConfig
    from repro.workload.swf import MalleabilityMix, jobs_from_swf

    jobs, apps = jobs_from_swf(
        trace, num_nodes=WORKLOAD["num_nodes"], mix=MalleabilityMix(*mix),
        seed=WORKLOAD["seed"], time_scale=WORKLOAD["time_scale"])
    cfg = SimConfig(num_nodes=WORKLOAD["num_nodes"], flexible=True,
                    scheduling=scheduling, seed=WORKLOAD["seed"],
                    sanitize=sanitize, sched=SchedulerConfig(policy=policy))
    return ClusterSimulator(jobs, cfg, apps=apps)


def run_scenario(trace, policy: str, mix, scheduling: str, repeats: int,
                 variant: str = ""
                 ) -> Tuple[Dict[str, object], Dict[str, float]]:
    """Returns ``(deterministic, timings)`` for one scenario.

    The wall time is the best of ``repeats`` full replays (kernel-bench
    style: the minimum is the least noisy location statistic for
    wall-clock micro-measurements).
    """
    from repro.rms.job import JobState

    best_wall = None
    det: Dict[str, object] = {}
    for _ in range(max(repeats, 1)):
        sim = _build_sim(trace, policy, mix, scheduling,
                         sanitize=variant == "sanitize")
        recorder = None
        if variant == "trace":
            from repro.obs.recorder import TraceRecorder
            recorder = TraceRecorder(sim).install()
        t0 = time.perf_counter()
        report = sim.run()
        if recorder is not None:
            recorder.finalize(report)   # recording cost includes finalize
        wall = time.perf_counter() - t0
        det = {
            "dispatched": sim.engine.dispatched,
            "actions": len(report.actions),
            "completed": sum(1 for j in report.jobs
                             if j.state is JobState.COMPLETED),
            "makespan_s": round(float(report.makespan), ROUND_DIGITS),
        }
        if best_wall is None or wall < best_wall:
            best_wall = wall
    timings = {"wall_s": round(best_wall, 6),
               "events_per_sec": round(det["dispatched"] / best_wall, 1)}
    return det, timings


def run_bench(repeats: int = 3, verbose: bool = True
              ) -> Tuple[Dict[str, object], Dict[str, object]]:
    """Run every scenario; returns ``(deterministic, timings)`` blocks."""
    trace = _synthetic_trace()
    deterministic: Dict[str, object] = {}
    timings: Dict[str, object] = {}
    total_events, total_wall = 0, 0.0
    if verbose:
        print("# engine bench: synthetic corpus "
              f"({WORKLOAD['n_jobs']} jobs, {len(SCENARIOS)} scenarios, "
              f"best of {repeats})")
        print("scenario,dispatched,actions,completed,makespan_s,"
              "wall_s,events_per_sec")
    for label, policy, mix, scheduling, variant in SCENARIOS:
        det, tim = run_scenario(trace, policy, mix, scheduling, repeats,
                                variant)
        deterministic[label] = det
        timings[label] = tim
        total_events += det["dispatched"]
        total_wall += tim["wall_s"]
        if verbose:
            print(f"{label},{det['dispatched']},{det['actions']},"
                  f"{det['completed']},{det['makespan_s']},"
                  f"{tim['wall_s']},{tim['events_per_sec']}")
    deterministic["total_dispatched"] = total_events
    timings["total_wall_s"] = round(total_wall, 6)
    timings["events_per_sec"] = round(total_events / total_wall, 1)
    for twin_key, (checked, twin) in (("sanitize_overhead_x",
                                       SANITIZE_TWIN),
                                      ("obs_overhead_x", OBS_TWIN)):
        if deterministic[checked] != deterministic[twin]:
            raise RuntimeError(
                f"monitor perturbed simulation semantics: {checked} "
                f"{deterministic[checked]} != {twin} "
                f"{deterministic[twin]}")
        overhead = timings[checked]["wall_s"] / timings[twin]["wall_s"]
        timings[twin_key] = round(overhead, 2)
    if verbose:
        print(f"total,{total_events},,,,{timings['total_wall_s']},"
              f"{timings['events_per_sec']}")
        print(f"# sanitize overhead: {timings['sanitize_overhead_x']}x "
              f"(limit {SANITIZE_OVERHEAD_MAX}x)")
        print(f"# obs overhead: {timings['obs_overhead_x']}x "
              f"(limit {OBS_OVERHEAD_MAX}x)")
    return deterministic, timings


# ---------------------------------------------------------------------------
# Trajectory artifact
# ---------------------------------------------------------------------------

def load_trajectory(path: str) -> Dict[str, object]:
    with open(path) as fh:
        doc = json.load(fh)
    if doc.get("schema") != SCHEMA_ID:
        raise ValueError(f"not an engine-bench trajectory: "
                         f"schema={doc.get('schema')!r}")
    if doc.get("version") != SCHEMA_VERSION:
        raise ValueError(f"engine-bench trajectory version "
                         f"{doc.get('version')} != {SCHEMA_VERSION}")
    if doc.get("workload") != WORKLOAD:
        raise ValueError("engine-bench trajectory workload mismatch: "
                         f"{doc.get('workload')} != {WORKLOAD} "
                         "(the canonical parameters changed — start a "
                         "fresh trajectory)")
    return doc


def dumps_trajectory(doc: Dict[str, object]) -> str:
    return json.dumps(doc, sort_keys=True, indent=2) + "\n"


def append_entry(path: str, label: str, deterministic: Dict[str, object],
                 timings: Dict[str, object]) -> Dict[str, object]:
    if os.path.exists(path):
        doc = load_trajectory(path)
    else:
        doc = {"schema": SCHEMA_ID, "version": SCHEMA_VERSION,
               "workload": dict(WORKLOAD), "entries": []}
    doc["entries"].append({"label": label, "deterministic": deterministic,
                           "timings": timings})
    with open(path, "w") as fh:
        fh.write(dumps_trajectory(doc))
    return doc


def check_against(path: str, deterministic: Dict[str, object]) -> List[str]:
    """Compare a fresh run's deterministic block against the trajectory's
    last entry; returns human-readable drift messages (empty: clean)."""
    doc = load_trajectory(path)
    if not doc["entries"]:
        return [f"{path}: empty trajectory (no entries to check against)"]
    want = doc["entries"][-1]["deterministic"]
    drift = []
    for key in sorted(set(want) | set(deterministic)):
        if want.get(key) != deterministic.get(key):
            drift.append(f"{key}: committed {want.get(key)!r} != "
                         f"measured {deterministic.get(key)!r}")
    return drift


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--repeats", type=int, default=3,
                    help="full replays per scenario; wall time is the best")
    ap.add_argument("--append", default=None, metavar="PATH",
                    help="append this run as a new trajectory entry")
    ap.add_argument("--label", default="dev",
                    help="entry label used with --append")
    ap.add_argument("--check", default=None, metavar="PATH",
                    help="fail (exit 1) if deterministic fields drift from "
                         "the trajectory's last entry")
    args = ap.parse_args(argv)

    deterministic, timings = run_bench(repeats=args.repeats)
    if timings["sanitize_overhead_x"] >= SANITIZE_OVERHEAD_MAX:
        print(f"# FAIL sanitize overhead {timings['sanitize_overhead_x']}x "
              f">= {SANITIZE_OVERHEAD_MAX}x budget")
        return 1
    if timings["obs_overhead_x"] >= OBS_OVERHEAD_MAX:
        print(f"# FAIL obs overhead {timings['obs_overhead_x']}x "
              f">= {OBS_OVERHEAD_MAX}x budget")
        return 1
    if args.append:
        append_entry(args.append, args.label, deterministic, timings)
        print(f"# appended entry {args.label!r} to {args.append}")
    if args.check:
        drift = check_against(args.check, deterministic)
        if drift:
            print(f"# DRIFT against {args.check} (deterministic fields "
                  f"changed — regenerate only for intentional semantics "
                  f"changes):")
            for line in drift:
                print(f"#   {line}")
            return 1
        print(f"# deterministic fields match {args.check}")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
