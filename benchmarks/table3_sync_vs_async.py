"""Table 3: cluster + per-job measures, sync vs async (async dismissal).

Runs on the event-driven engine (``repro.rms.engine``); pass ``policy`` to
re-derive the table under any registered scheduling policy.
"""
from __future__ import annotations

import numpy as np

from benchmarks.common import run_sim


def gains(base, rep):
    bm, fm = base.job_metrics(), rep.job_metrics()
    out = []
    for jid in bm:
        if jid not in fm:
            continue
        b, f = bm[jid], fm[jid]
        out.append([(b[i] - f[i]) / b[i] * 100 if b[i] else 0.0
                    for i in range(3)])
    return np.array(out)


def main(quick: bool = False, policy: str = "easy"):
    n = 100 if quick else 400
    print(f"# Table 3: cluster and job measures of the {n}-job workloads "
          f"(wide-opt mode, {policy} scheduling policy)")
    print("measure,fixed,sync,async")
    base = run_sim(n, flexible=False, wide=True, policy=policy)
    sync = run_sim(n, flexible=True, scheduling="sync", wide=True,
                   policy=policy)
    asyn = run_sim(n, flexible=True, scheduling="async", wide=True,
                   policy=policy)
    u = {k: r.utilization() for k, r in
         (("fixed", base), ("sync", sync), ("async", asyn))}
    print(f"utilization_avg_pct,{u['fixed'][0]:.2f},{u['sync'][0]:.2f},"
          f"{u['async'][0]:.2f}")
    print(f"utilization_std_pct,{u['fixed'][1]:.2f},{u['sync'][1]:.2f},"
          f"{u['async'][1]:.2f}")
    gs, ga = gains(base, sync), gains(base, asyn)
    for i, name in enumerate(("waiting", "execution", "completion")):
        print(f"{name}_gain_avg_pct,-,{gs[:, i].mean():.2f},"
              f"{ga[:, i].mean():.2f}")
        print(f"{name}_gain_std_pct,-,{gs[:, i].std():.2f},"
              f"{ga[:, i].std():.2f}")
    print(f"# claim[sync utilization steadier]: std sync="
          f"{u['sync'][1]:.1f} < std async={u['async'][1]:.1f}: "
          f"{u['sync'][1] < u['async'][1]}")
    to = sum(1 for a in asyn.actions if a.timed_out)
    print(f"# claim[async pathological]: {to} expand timeouts vs 0 in sync")
    return {"fixed": base, "sync": sync, "async": asyn}


if __name__ == "__main__":
    main()
