"""Replay an SWF trace through the RMS: fixed vs flexible, per policy.

The scenario-diversity axis of the malleability claim: instead of the
paper's five synthetic apps, ingest a real (or sampled) Standard Workload
Format trace, annotate a fraction of jobs as malleable, and compare the
fixed and flexible configurations under several scheduling policies.

Runs on the parallel sweep driver (:mod:`repro.rms.sweep`) and shares its
versioned artifact schema (``--artifact``).

  PYTHONPATH=src python benchmarks/trace_replay.py \\
      [--trace tests/data/sample.swf] [--nodes 64] \\
      [--policies easy,fcfs] [--malleable 0.6] [--moldable 0.2] \\
      [--evolving 0.0] [--time-scale 1.0] [--max-jobs N] [--workers 4] \\
      [--artifact out.json]
"""
from __future__ import annotations

import argparse
import os

from repro.rms.sweep import (artifact, build_grid, run_sweep, write_artifact)
from repro.workload import parse_swf

DEFAULT_TRACE = os.path.join(os.path.dirname(__file__), "..", "tests",
                             "data", "sample.swf")


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--trace", default=os.path.normpath(DEFAULT_TRACE))
    ap.add_argument("--nodes", type=int, default=64)
    ap.add_argument("--policies", default="easy,fcfs")
    ap.add_argument("--malleable", type=float, default=0.6)
    ap.add_argument("--moldable", type=float, default=0.2)
    ap.add_argument("--evolving", type=float, default=0.0)
    ap.add_argument("--time-scale", type=float, default=1.0)
    ap.add_argument("--max-jobs", type=int, default=None)
    ap.add_argument("--seed", type=int, default=7)
    ap.add_argument("--workers", type=int, default=0)
    ap.add_argument("--artifact", default=None,
                    help="write the versioned sweep JSON artifact here")
    args = ap.parse_args(argv)

    mix = (max(0.0, 1.0 - args.malleable - args.moldable - args.evolving),
           args.moldable, args.malleable, args.evolving)
    policies = [p.strip() for p in args.policies.split(",") if p.strip()]
    trace = parse_swf(args.trace)
    print(f"# trace: {args.trace} ({len(trace.jobs)} jobs, "
          f"{trace.skipped_lines} skipped lines, "
          f"MaxNodes={trace.max_nodes})")
    print(f"# mix: rigid={mix[0]:.2f} moldable={mix[1]:.2f} "
          f"malleable={mix[2]:.2f} evolving={mix[3]:.2f}")
    points = build_grid([args.trace], policies, [mix], (False, True),
                        num_nodes=args.nodes, seed=args.seed,
                        time_scale=args.time_scale, max_jobs=args.max_jobs)
    rows = run_sweep(points, workers=args.workers)
    by_key = {(r["policy"], r["flexible"]): r for r in rows}
    print("policy,version,makespan_s,util_avg_pct,util_std_pct,"
          "avg_wait_s,avg_completion_s,reconfigs")
    for policy in policies:
        for flexible in (False, True):
            r = by_key[(policy, flexible)]
            name = "flexible" if flexible else "fixed"
            nrec = r["expands"] + r["shrinks"]
            print(f"{policy},{name},{r['makespan_s']:.0f},"
                  f"{r['util_avg_pct']:.2f},{r['util_std_pct']:.2f},"
                  f"{r['avg_wait_s']:.1f},{r['avg_completion_s']:.1f},"
                  f"{nrec}")
    for policy in policies:
        base = by_key[(policy, False)]
        flex = by_key[(policy, True)]
        gain = ((base["makespan_s"] - flex["makespan_s"])
                / base["makespan_s"] * 100 if base["makespan_s"] else 0.0)
        print(f"# claim[{policy}: flexible makespan <= fixed]: "
              f"{flex['makespan_s'] <= base['makespan_s']} "
              f"(gain {gain:.1f}%)")
    if args.artifact:
        grid = {"traces": [os.path.basename(args.trace)],
                "policies": policies, "mixes": [list(mix)],
                "flexibles": [False, True], "num_nodes": args.nodes,
                "seed": args.seed}
        write_artifact(args.artifact, artifact(rows, grid))
        print(f"# wrote {args.artifact} ({len(rows)} rows)")
    return rows


if __name__ == "__main__":
    main()
