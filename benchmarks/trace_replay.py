"""Replay an SWF trace through the RMS: fixed vs flexible, per policy.

The scenario-diversity axis of the malleability claim: instead of the
paper's five synthetic apps, ingest a real (or sampled) Standard Workload
Format trace, annotate a fraction of jobs as malleable, and compare the
fixed and flexible configurations under several scheduling policies.

  PYTHONPATH=src python benchmarks/trace_replay.py \\
      [--trace tests/data/sample.swf] [--nodes 64] \\
      [--policies easy,fcfs] [--malleable 0.6] [--moldable 0.2] \\
      [--time-scale 1.0] [--max-jobs N]
"""
from __future__ import annotations

import argparse
import os

from repro.rms import ClusterSimulator, SchedulerConfig, SimConfig
from repro.workload import MalleabilityMix, SWFTrace, jobs_from_swf, \
    parse_swf

DEFAULT_TRACE = os.path.join(os.path.dirname(__file__), "..", "tests",
                             "data", "sample.swf")


def replay(trace, *, num_nodes: int, policy: str, flexible: bool,
           mix: MalleabilityMix, time_scale: float = 1.0,
           max_jobs=None, seed: int = 7):
    """`trace` is a path or an already-parsed SWFTrace."""
    if not isinstance(trace, SWFTrace):
        trace = parse_swf(trace)
    jobs, apps = jobs_from_swf(trace, num_nodes=num_nodes, mix=mix,
                               seed=seed, max_jobs=max_jobs,
                               time_scale=time_scale)
    cfg = SimConfig(num_nodes=num_nodes, flexible=flexible,
                    sched=SchedulerConfig(policy=policy))
    return ClusterSimulator(jobs, cfg, apps=apps).run()


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--trace", default=DEFAULT_TRACE)
    ap.add_argument("--nodes", type=int, default=64)
    ap.add_argument("--policies", default="easy,fcfs")
    ap.add_argument("--malleable", type=float, default=0.6)
    ap.add_argument("--moldable", type=float, default=0.2)
    ap.add_argument("--time-scale", type=float, default=1.0)
    ap.add_argument("--max-jobs", type=int, default=None)
    ap.add_argument("--seed", type=int, default=7)
    args = ap.parse_args(argv)

    mix = MalleabilityMix(
        rigid=max(0.0, 1.0 - args.malleable - args.moldable),
        moldable=args.moldable, malleable=args.malleable)
    trace = parse_swf(args.trace)
    print(f"# trace: {args.trace} ({len(trace.jobs)} jobs, "
          f"{trace.skipped_lines} skipped lines, "
          f"MaxNodes={trace.max_nodes})")
    print(f"# mix: rigid={mix.rigid:.2f} moldable={mix.moldable:.2f} "
          f"malleable={mix.malleable:.2f}")
    print("policy,version,makespan_s,util_avg_pct,util_std_pct,"
          "avg_wait_s,avg_completion_s,reconfigs")
    out = {}
    for policy in args.policies.split(","):
        policy = policy.strip()
        for flexible in (False, True):
            rep = replay(trace, num_nodes=args.nodes, policy=policy,
                         flexible=flexible, mix=mix,
                         time_scale=args.time_scale,
                         max_jobs=args.max_jobs, seed=args.seed)
            out[(policy, flexible)] = rep
            u, us = rep.utilization()
            w, _, c = rep.averages()
            nrec = sum(1 for a in rep.actions
                       if a.action in ("expand", "shrink"))
            name = "flexible" if flexible else "fixed"
            print(f"{policy},{name},{rep.makespan:.0f},{u:.2f},{us:.2f},"
                  f"{w:.1f},{c:.1f},{nrec}")
    for policy in args.policies.split(","):
        policy = policy.strip()
        base, flex = out[(policy, False)], out[(policy, True)]
        gain = ((base.makespan - flex.makespan) / base.makespan * 100
                if base.makespan else 0.0)
        print(f"# claim[{policy}: flexible makespan <= fixed]: "
              f"{flex.makespan <= base.makespan} (gain {gain:.1f}%)")
    return out


if __name__ == "__main__":
    main()
