"""Roofline report: reads dry-run artifacts, prints the 40-cell table."""
from __future__ import annotations

import json
import pathlib

from repro.configs import list_archs
from repro.launch.shapes import SHAPES, applicable

ART = pathlib.Path("artifacts/dryrun")


def load(mesh="pod16x16"):
    rows = []
    for arch in list_archs():
        for shape in SHAPES:
            path = ART / f"{arch}__{shape}__{mesh}.json"
            ok, why = applicable(arch, shape)
            if not ok:
                rows.append({"arch": arch, "shape": shape, "mesh": mesh,
                             "status": "skipped", "reason": why})
                continue
            if not path.exists():
                rows.append({"arch": arch, "shape": shape, "mesh": mesh,
                             "status": "missing"})
                continue
            rows.append(json.loads(path.read_text()))
    return rows


def main(quick: bool = False):
    print("# Roofline (single-pod 16x16, v5e: 197TF bf16 / 819GB/s HBM / "
          "50GB/s link)")
    print("arch,shape,status,compute_ms,memory_ms,collective_ms,dominant,"
          "mfu,useful_ratio,hbm_fit,temp_gb")
    n_ok = n_skip = n_other = 0
    for r in load():
        if r.get("status") == "ok":
            rl = r["roofline"]
            mem = r["memory"]
            temp = mem["temp_size_in_bytes"] / 1e9
            args = mem["argument_size_in_bytes"] / 1e9
            fit = (temp + args) <= 16.0
            print(f"{r['arch']},{r['shape']},ok,"
                  f"{rl['compute_s']*1e3:.2f},{rl['memory_s']*1e3:.2f},"
                  f"{rl['collective_s']*1e3:.2f},{rl['dominant']},"
                  f"{rl['mfu']:.4f},{rl['useful_ratio']:.3f},"
                  f"{fit},{temp:.2f}")
            n_ok += 1
        elif r.get("status") == "skipped":
            print(f"{r['arch']},{r['shape']},skipped({r['reason'][:40]})"
                  ",,,,,,,,")
            n_skip += 1
        else:
            print(f"{r['arch']},{r['shape']},{r.get('status')},,,,,,,,")
            n_other += 1
    print(f"# {n_ok} ok, {n_skip} skipped, {n_other} missing/error")
    # multi-pod pass/fail summary
    multi = [r for r in load("pod2x16x16")]
    ok2 = sum(1 for r in multi if r.get("status") == "ok")
    sk2 = sum(1 for r in multi if r.get("status") == "skipped")
    print(f"# multi-pod (2x16x16): {ok2} ok, {sk2} skipped, "
          f"{len(multi)-ok2-sk2} missing/error")
    return n_other


if __name__ == "__main__":
    main()
