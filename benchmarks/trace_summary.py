"""Trace-summary CLI: load factor, inter-arrival stats, size histogram.

First slice of the ROADMAP "Trace corpus" item: before replaying a trace
(or committing a new one to the corpus), summarize what load it actually
carries — the malleability literature's conclusions move with exactly
these statistics.  Works on any SWF file; ``--synthetic`` additionally
summarizes the deterministic ~200-job generated corpus the tests use
(``tests/synthetic_swf.py``).

Usage::

    PYTHONPATH=src python benchmarks/trace_summary.py \\
        tests/data/sample.swf [more.swf ...] [--synthetic] [--nodes 64]
"""
from __future__ import annotations

import argparse
import os
import sys
from typing import Dict, List, Optional

import numpy as np

from repro.workload.swf import SWFTrace, parse_swf

_REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _pow2_bucket(n: int) -> int:
    p = 1
    while p * 2 <= n:
        p *= 2
    return p


def _pct(xs: np.ndarray, q: float) -> float:
    return float(np.percentile(xs, q)) if len(xs) else 0.0


def summarize(trace: SWFTrace, label: str,
              nodes: Optional[int] = None) -> Dict[str, object]:
    """Aggregate statistics of one parsed trace."""
    jobs = trace.jobs
    submits = np.array(sorted(j.submit_time for j in jobs))
    runs = np.array([j.run_time for j in jobs], dtype=float)
    procs = np.array([j.procs for j in jobs], dtype=float)
    capacity = nodes or trace.max_nodes or int(procs.max(initial=1))
    # Span: first submission to the last recorded completion.
    end = max((j.submit_time + max(j.wait_time, 0.0) + j.run_time
               for j in jobs), default=0.0)
    span = max(end - (submits[0] if len(submits) else 0.0), 1.0)
    inter = np.diff(submits)
    hist: Dict[int, int] = {}
    for j in jobs:
        b = _pow2_bucket(j.procs)
        hist[b] = hist.get(b, 0) + 1
    return {
        "trace": label, "jobs": len(jobs),
        "skipped_lines": trace.skipped_lines,
        "capacity_nodes": capacity, "span_s": round(span, 1),
        # Offered load: node-seconds demanded over capacity node-seconds.
        "load_factor": round(float(np.sum(procs * runs))
                             / (capacity * span), 4),
        "interarrival_mean_s": round(float(inter.mean())
                                     if len(inter) else 0.0, 1),
        "interarrival_p50_s": round(_pct(inter, 50), 1),
        "interarrival_p90_s": round(_pct(inter, 90), 1),
        "runtime_mean_s": round(float(runs.mean()) if len(runs) else 0.0, 1),
        "runtime_p50_s": round(_pct(runs, 50), 1),
        "runtime_p90_s": round(_pct(runs, 90), 1),
        "size_mean": round(float(procs.mean()) if len(procs) else 0.0, 2),
        "size_hist": hist,
    }


def synthetic_trace() -> SWFTrace:
    """Parse the deterministic test corpus (tests/synthetic_swf.py)."""
    tests_dir = os.path.join(_REPO, "tests")
    if tests_dir not in sys.path:
        sys.path.insert(0, tests_dir)
    import synthetic_swf
    lines, _ = synthetic_swf.synthetic_swf()
    return parse_swf(lines)


COLS = ("trace", "jobs", "skipped_lines", "capacity_nodes", "span_s",
        "load_factor", "interarrival_mean_s", "interarrival_p50_s",
        "interarrival_p90_s", "runtime_mean_s", "runtime_p50_s",
        "runtime_p90_s", "size_mean")


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("traces", nargs="*",
                    default=None, help="SWF trace files")
    ap.add_argument("--synthetic", action="store_true",
                    help="also summarize the deterministic test corpus")
    ap.add_argument("--nodes", type=int, default=None,
                    help="capacity override (default: trace header "
                         "MaxNodes/MaxProcs, else max job size)")
    args = ap.parse_args(argv)

    targets: List[Dict[str, object]] = []
    paths = args.traces or ([] if args.synthetic else
                            [os.path.join(_REPO, "tests", "data",
                                          "sample.swf")])
    for path in paths:
        targets.append(summarize(parse_swf(path), os.path.basename(path),
                                 args.nodes))
    if args.synthetic:
        targets.append(summarize(synthetic_trace(), "synthetic-corpus",
                                 args.nodes))

    print("# trace summary (offered load, arrivals, sizes)")
    print(",".join(COLS))
    for s in targets:
        print(",".join(str(s[c]) for c in COLS))
    for s in targets:
        buckets = sorted(s["size_hist"])
        line = " ".join(f"{b}:{s['size_hist'][b]}" for b in buckets)
        print(f"# {s['trace']} size histogram (pow2 buckets): {line}")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
