"""Benchmark driver: one function per paper table/figure.

Usage: PYTHONPATH=src python -m benchmarks.run [--quick]
Prints CSV blocks per benchmark; claim checks inline as comments.
"""
from __future__ import annotations

import sys
import time


def main() -> None:
    quick = "--quick" in sys.argv
    from benchmarks import (fig3_reconfig_overhead, fig6_trace,
                            kernel_bench, lm_cluster, roofline_report,
                            table2_actions, table3_sync_vs_async,
                            table4_throughput)
    benches = [
        ("fig3_reconfig_overhead", fig3_reconfig_overhead.main),
        ("table2_actions", table2_actions.main),
        ("table3_sync_vs_async", table3_sync_vs_async.main),
        ("table4_throughput", table4_throughput.main),
        ("fig6_trace", fig6_trace.main),
        ("lm_cluster", lm_cluster.main),
        ("kernel_bench", kernel_bench.main),
        ("roofline_report", roofline_report.main),
    ]
    for name, fn in benches:
        print(f"\n===== {name} =====")
        t0 = time.perf_counter()
        fn(quick=quick)
        print(f"# [{name} took {time.perf_counter()-t0:.1f}s]")


if __name__ == "__main__":
    main()
