"""Kernel microbenches (CPU): XLA chunked-attention path + interpret-mode
kernel sanity timings.  Absolute numbers are CPU-only; the TPU story lives
in the roofline report."""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp


def timeit(fn, *args, n=5):
    fn(*args).block_until_ready() if hasattr(fn(*args), "block_until_ready") \
        else jax.block_until_ready(fn(*args))
    t0 = time.perf_counter()
    for _ in range(n):
        jax.block_until_ready(fn(*args))
    return (time.perf_counter() - t0) / n * 1e6


def main(quick: bool = False):
    from repro.models.attention import chunked_attention
    from repro.models.config import ModelConfig
    print("# kernel/xla-path microbenches (CPU wall time)")
    print("name,us_per_call,derived")
    cfg = ModelConfig(name="bench", family="dense", num_layers=1,
                      d_model=256, num_heads=4, num_kv_heads=2, d_ff=512,
                      vocab_size=128, attn_chunk=128)
    key = jax.random.PRNGKey(0)
    b, s, h, kv, d = 1, 512, 4, 2, 64
    q = jax.random.normal(key, (b, s, h, d), jnp.bfloat16)
    k = jax.random.normal(key, (b, s, kv, d), jnp.bfloat16)
    v = jax.random.normal(key, (b, s, kv, d), jnp.bfloat16)

    @jax.jit
    def xla_attn(q, k, v):
        return chunked_attention(q, k, v, cfg, causal=True, window=None)
    us = timeit(xla_attn, q, k, v)
    flops = 2 * 2 * b * h * d * s * s / 2
    print(f"chunked_attention_xla_b{b}s{s},{us:.0f},"
          f"{flops/us*1e-3:.2f}GFLOP/s")

    from repro.models.ssm import ssd_chunked
    x = jax.random.normal(key, (1, 512, 4, 32))
    dt = jax.nn.softplus(jax.random.normal(key, (1, 512, 4)))
    a_log = jax.random.normal(key, (4,)) * 0.5
    bb = jax.random.normal(key, (1, 512, 64))
    cc = jax.random.normal(key, (1, 512, 64))

    @jax.jit
    def ssd(x, dt, bb, cc):
        return ssd_chunked(x, dt, a_log, bb, cc, 128)[0]
    us = timeit(ssd, x, dt, bb, cc)
    print(f"ssd_chunked_xla_s512,{us:.0f},tokens/s={512/us*1e6:.0f}")

    from repro.models.rglru import rglru_scan
    a = jax.nn.sigmoid(jax.random.normal(key, (1, 512, 256)))
    bvec = jax.random.normal(key, (1, 512, 256))

    @jax.jit
    def lru(a, bvec):
        return rglru_scan(a, bvec)
    us = timeit(lru, a, bvec)
    print(f"rglru_assoc_scan_s512,{us:.0f},tokens/s={512/us*1e6:.0f}")
    return True


if __name__ == "__main__":
    main()
