"""Beyond-paper: the DMR policy on a cluster of elastic LLM training jobs.

The paper evaluated CG/Jacobi/N-body; the same machinery schedules modern
LLM training: each job is an elastic data-parallel training run (one node
= one 16-chip mesh slice), sized from the assigned architectures, with
per-step times from the v5e roofline model and resize costs from the
factor-based redistribution plans over ICI (params+optimizer state moved).

Reports fixed vs flexible completion/waiting on a 64-slice (1024-chip)
cluster — the large-scale scenario DESIGN.md §5 targets.
"""
from __future__ import annotations

import numpy as np

from repro.configs import get_config
from repro.rms import ClusterSimulator, SimConfig, lm_app_model
from repro.rms.job import Job
from repro.workload.feitelson import poisson_arrivals

# preferred=None: LM training scales near-linearly with DP slices, so the
# productive policy is wide optimization — shrink *only* when that starts a
# queued job, expand when spare slices cannot serve the queue.  (With eager
# preferred-mode shrinking the cluster loses throughput: recorded as a
# negative ablation in EXPERIMENTS.md.)
ARCH_JOBS = [
    # (arch, steps, min, max, preferred)
    ("smollm-135m", 4000, 1, 8, None),
    ("granite-3-2b", 1500, 2, 16, None),
    ("qwen3-4b", 1000, 2, 16, None),
    ("recurrentgemma-9b", 600, 4, 32, None),
    ("deepseek-moe-16b", 500, 4, 32, None),
    ("gemma2-27b", 300, 8, 32, None),
]


def make_lm_apps():
    apps = {}
    for arch, steps, mn, mx, pref in ARCH_JOBS:
        cfg = get_config(arch)
        step_flops = 6.0 * cfg.active_param_count() * 4096 * 256
        apps[f"lm:{arch}"] = lm_app_model(
            arch, params=cfg.param_count(), step_flops=step_flops,
            iterations=steps, min_nodes=mn, max_nodes=mx, preferred=pref)
    return apps


def make_jobs(n, apps, seed=11):
    rng = np.random.default_rng(seed)
    names = list(apps)
    arrivals = poisson_arrivals(rng, n, scale_s=60.0)
    jobs = []
    for i in range(n):
        app = apps[names[rng.integers(len(names))]]
        jobs.append(Job(job_id=i, app=app.name, submit_time=float(arrivals[i]),
                        work=float(app.iterations), min_nodes=app.min_nodes,
                        max_nodes=app.max_nodes, preferred=app.preferred,
                        factor=2, malleable=True,
                        check_period_s=app.check_period_s,
                        requested_nodes=app.max_nodes,
                        data_bytes=app.data_bytes))
    return jobs


def main(quick: bool = False):
    n = 30 if quick else 60
    apps = make_lm_apps()
    print(f"# beyond-paper: {n} elastic LLM training jobs on 64 slices "
          f"(1024 chips)")
    print("version,makespan_s,util_pct,wait_s,exec_s,completion_s")
    reps = {}
    for flexible in (False, True):
        jobs = make_jobs(n, apps)
        cfg = SimConfig(num_nodes=64, flexible=flexible,
                        cost=__import__("repro.rms.costmodel",
                                        fromlist=["ReconfigCostModel"])
                        .ReconfigCostModel(link_bw=50e9))
        rep = ClusterSimulator(jobs, cfg, apps=apps).run()
        reps[flexible] = rep
        w, e, c = rep.averages()
        name = "flexible" if flexible else "fixed"
        print(f"{name},{rep.makespan:.0f},{rep.utilization()[0]:.1f},"
              f"{w:.0f},{e:.0f},{c:.0f}")
    gain = (reps[False].makespan - reps[True].makespan) \
        / reps[False].makespan * 100
    resizes = [a for a in reps[True].actions if a.action != "no_action"]
    mean_resize = np.mean([a.apply_s for a in resizes]) if resizes else 0
    print(f"# makespan gain {gain:.1f}%; {len(resizes)} resizes, mean "
          f"state-move {mean_resize:.2f}s (params+moments over ICI)")
    return reps


if __name__ == "__main__":
    main()
