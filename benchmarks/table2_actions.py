"""Table 2: per-action statistics in a 400-job workload, sync vs async.

Wide-optimization mode (no preferred sizes) — the configuration consistent
with the paper's §7.3/7.4 overhead study (frequent expansions; async
expand waits dominated by the resizer-job timeout).
"""
from __future__ import annotations

import numpy as np

from benchmarks.common import action_stats, run_sim


def main(quick: bool = False):
    n = 100 if quick else 400
    print(f"# Table 2: actions in a {n}-job workload (wide-opt mode)")
    print("mode,action,min_s,max_s,avg_s,std_s,quantity,actions_per_job")
    out = {}
    for mode in ("sync", "async"):
        rep = run_sim(n, flexible=True, scheduling=mode, wide=True)
        out[mode] = rep
        for kind in ("no_action", "expand", "shrink"):
            s = action_stats(rep.actions, kind)
            print(f"{mode},{kind},{s['min']:.4f},{s['max']:.4f},"
                  f"{s['avg']:.4f},{s['std']:.4f},{s['n']},"
                  f"{s['n'] / n:.3f}")
        if rep.policy_wall_s:
            w = np.array(rep.policy_wall_s)
            print(f"# {mode}: measured in-process policy latency "
                  f"avg={w.mean()*1e6:.1f}us max={w.max()*1e6:.1f}us")
    async_exp = [a for a in out["async"].actions if a.action == "expand"]
    timeouts = sum(1 for a in async_exp if a.timed_out)
    print(f"# claim[async expand timeout pathology]: timeouts={timeouts}, "
          f"max wait={max((a.apply_s for a in async_exp), default=0):.1f}s "
          f"(paper: max 40.4s, avg 8.8s, high sigma)")
    return out


if __name__ == "__main__":
    main()
