"""Table 2: per-action statistics in a 400-job workload, sync vs async.

Wide-optimization mode (no preferred sizes) — the configuration consistent
with the paper's §7.3/7.4 overhead study (frequent expansions; async
expand waits dominated by the resizer-job timeout).

``--calibration <artifact>`` replays the workload under a measured
reconfiguration-cost model (:mod:`repro.calib`) instead of the hand-fit
Table 2 / Fig. 3 constants; absent, the paper-fit defaults apply.
"""
from __future__ import annotations

import argparse
from typing import Optional

import numpy as np

from benchmarks.common import action_stats, run_sim


def main(quick: bool = False, calibration: Optional[str] = None):
    n = 100 if quick else 400
    sim_kw = {}
    if calibration:
        from repro.rms.costmodel import ReconfigCostModel
        cost = ReconfigCostModel.from_artifact(calibration)
        sim_kw["cost"] = cost
        print(f"# using calibration {cost.calibration_id} "
              f"(link_bw={cost.link_bw:.4g} B/s)")
    print(f"# Table 2: actions in a {n}-job workload (wide-opt mode)")
    print("mode,action,min_s,max_s,avg_s,std_s,quantity,actions_per_job")
    out = {}
    for mode in ("sync", "async"):
        rep = run_sim(n, flexible=True, scheduling=mode, wide=True,
                      **sim_kw)
        out[mode] = rep
        for kind in ("no_action", "expand", "shrink"):
            s = action_stats(rep.actions, kind)
            print(f"{mode},{kind},{s['min']:.4f},{s['max']:.4f},"
                  f"{s['avg']:.4f},{s['std']:.4f},{s['n']},"
                  f"{s['n'] / n:.3f}")
        if rep.policy_wall_s:
            w = np.array(rep.policy_wall_s)
            print(f"# {mode}: measured in-process policy latency "
                  f"avg={w.mean()*1e6:.1f}us max={w.max()*1e6:.1f}us")
    async_exp = [a for a in out["async"].actions if a.action == "expand"]
    timeouts = sum(1 for a in async_exp if a.timed_out)
    print(f"# claim[async expand timeout pathology]: timeouts={timeouts}, "
          f"max wait={max((a.apply_s for a in async_exp), default=0):.1f}s "
          f"(paper: max 40.4s, avg 8.8s, high sigma)")
    return out


if __name__ == "__main__":
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--calibration", default=None,
                    help="repro.calib artifact (default: paper-fit "
                         "constants)")
    args = ap.parse_args()
    main(quick=args.quick, calibration=args.calibration)
