"""Figs. 6/7/8: 50-job workload traces and per-job time differences."""
from __future__ import annotations

import numpy as np

from benchmarks.common import run_sim


def main(quick: bool = False):
    base = run_sim(50, flexible=False)
    flex = run_sim(50, flexible=True)
    print("# Fig6: evolution in time (sampled every 120s)")
    print("t_s,alloc_fixed,run_fixed,done_fixed,alloc_flex,run_flex,"
          "done_flex")
    t_end = max(base.makespan, flex.makespan)
    for t in np.arange(0, t_end, 120.0):
        row = [f"{t:.0f}"]
        for rep in (base, flex):
            ts = [e[0] for e in rep.timeline]
            i = max(0, np.searchsorted(ts, t, side="right") - 1)
            _, alloc, running, done = rep.timeline[i]
            row += [str(alloc), str(running), str(done)]
        print(",".join(row))
    print("# Fig7/8: per-job diffs (fixed - flexible), grouped by app")
    print("job_id,app,wait_diff_s,exec_diff_s,completion_diff_s")
    bm, fm = base.job_metrics(), flex.job_metrics()
    apps = {j.job_id: j.app for j in base.jobs}
    n_exec_worse = n_compl_better = 0
    for jid in sorted(bm):
        b, f = bm[jid], fm[jid]
        wd, ed, cd = b[0] - f[0], b[1] - f[1], b[2] - f[2]
        n_exec_worse += ed < 0
        n_compl_better += cd > 0
        print(f"{jid},{apps[jid]},{wd:.1f},{ed:.1f},{cd:.1f}")
    print(f"# claim[Fig8: exec diff below zero for most jobs]: "
          f"{n_exec_worse}/{len(bm)}")
    print(f"# claim[Fig8: completion driven by waiting gain]: "
          f"{n_compl_better}/{len(bm)} jobs complete earlier")
    return base, flex


if __name__ == "__main__":
    main()
