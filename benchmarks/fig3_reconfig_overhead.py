"""Fig. 3: time to reconfigure from/to N processes (Flexible Sleep, 1 GB).

Left chart (a): RMS scheduling time — measured from the real policy code
plus the calibrated Slurm-transaction model.  Right chart (b): data-
redistribution time from the factor-based transfer plans over per-node
links.  Reproduces both paper observations: more participants => faster
resize; shrinks pay extra synchronization.

``--calibration <artifact>`` swaps the hand-wired paper-fit constants for
the parameters fitted from measured redistribute runs
(:mod:`repro.calib`), and appends a measured-vs-fitted-vs-paper
comparison block from the artifact's samples.  Without it the paper-fit
defaults are used, as before.
"""
from __future__ import annotations

import argparse
import time
from typing import Optional

from repro.core.actions import Action
from repro.rms import Cluster, ReconfigPolicy
from repro.rms.costmodel import GiB, ReconfigCostModel
from repro.rms.job import Job, JobState

SIZES = [1, 2, 4, 8, 16, 32]


def rows(cost: Optional[ReconfigCostModel] = None):
    cost = cost if cost is not None else ReconfigCostModel()
    pol = ReconfigPolicy()
    out = []
    for p in SIZES:
        q = p * 2
        # measured policy latency (the in-process part of scheduling time)
        cluster = Cluster(128)
        job = Job(job_id=0, app="fs", submit_time=0, work=2, min_nodes=1,
                  max_nodes=128, preferred=None, requested_nodes=p)
        job.state = JobState.RUNNING
        job.nodes = p
        cluster.allocate(0, p)
        t0 = time.perf_counter()
        for _ in range(100):
            pol.decide(cluster, [], job, minimum=q, maximum=q, factor=2)
        wall_us = (time.perf_counter() - t0) / 100 * 1e6
        sched_expand = cost.schedule_time(Action.EXPAND, q)
        sched_shrink = cost.schedule_time(Action.SHRINK, q)
        # resize_time is what the simulator charges (spawn + busiest-link
        # drain + shrink sync) — the same quantity the calibration
        # comparison block and the artifact samples report.
        t_expand = cost.resize_time(p, q, GiB)
        t_shrink = cost.resize_time(q, p, GiB)
        out.append({"action": "expand", "from": p, "to": q,
                    "policy_us": round(wall_us, 1),
                    "sched_s": round(sched_expand, 4),
                    "resize_s": round(t_expand, 4)})
        out.append({"action": "shrink", "from": q, "to": p,
                    "policy_us": round(wall_us, 1),
                    "sched_s": round(sched_shrink, 4),
                    "resize_s": round(t_shrink, 4)})
    return out


def main(quick: bool = False, calibration: Optional[str] = None):
    cost = ReconfigCostModel()
    if calibration:
        cost = ReconfigCostModel.from_artifact(calibration)
        print(f"# using calibration {cost.calibration_id} "
              f"(link_bw={cost.link_bw:.4g} B/s, spawn_s={cost.spawn_s}, "
              f"shrink_sync_s={cost.shrink_sync_s})")
    rs = rows(cost)
    print("# Fig3: reconfiguration scheduling + resize times (FS, 1 GiB)")
    print("action,from,to,policy_us,sched_s,resize_s")
    for r in rs:
        print(f"{r['action']},{r['from']},{r['to']},{r['policy_us']},"
              f"{r['sched_s']},{r['resize_s']}")
    # paper claims
    exp = {r["from"]: r["resize_s"] for r in rs if r["action"] == "expand"}
    shr = {r["from"]: r["resize_s"] for r in rs if r["action"] == "shrink"}
    print(f"# claim[more participants faster]: resize(1->2)={exp[1]}s "
          f"> resize(32->64)={exp[32]}s: {exp[1] > exp[32]}")
    print(f"# claim[shrink sync overhead]: shrink(64->32)={shr[64]}s > "
          f"expand(32->64)={exp[32]}s: {shr[64] > exp[32]}")
    if calibration:
        from repro.calib import fit_report_rows, load_calibration
        doc = load_calibration(calibration)
        print(f"# measured vs fitted vs paper-fit "
              f"(backend={doc['backend']}, "
              f"residual rms={doc['residuals']['resize_rms_s']}s)")
        print("action,from,to,bytes,measured_s,fitted_s,paper_s")
        for c in fit_report_rows(doc):
            print(f"{c['action']},{c['from']},{c['to']},{c['bytes']},"
                  f"{c['measured_s']},{c['fitted_s']},{c['paper_s']}")
    return rs


if __name__ == "__main__":
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--calibration", default=None,
                    help="repro.calib artifact (default: paper-fit "
                         "constants)")
    args = ap.parse_args()
    main(quick=args.quick, calibration=args.calibration)
