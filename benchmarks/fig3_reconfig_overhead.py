"""Fig. 3: time to reconfigure from/to N processes (Flexible Sleep, 1 GB).

Left chart (a): RMS scheduling time — measured from the real policy code
plus the calibrated Slurm-transaction model.  Right chart (b): data-
redistribution time from the factor-based transfer plans over per-node
links.  Reproduces both paper observations: more participants => faster
resize; shrinks pay extra synchronization.
"""
from __future__ import annotations

import time

from repro.core import expand_plan, shrink_plan, transfer_time_s
from repro.core.actions import Action
from repro.rms import Cluster, ReconfigPolicy
from repro.rms.costmodel import GiB, ReconfigCostModel
from repro.rms.job import Job, JobState

SIZES = [1, 2, 4, 8, 16, 32]


def rows():
    cost = ReconfigCostModel()
    pol = ReconfigPolicy()
    out = []
    for p in SIZES:
        q = p * 2
        # measured policy latency (the in-process part of scheduling time)
        cluster = Cluster(128)
        job = Job(job_id=0, app="fs", submit_time=0, work=2, min_nodes=1,
                  max_nodes=128, preferred=None, requested_nodes=p)
        job.state = JobState.RUNNING
        job.nodes = p
        cluster.allocate(0, p)
        t0 = time.perf_counter()
        for _ in range(100):
            pol.decide(cluster, [], job, minimum=q, maximum=q, factor=2)
        wall_us = (time.perf_counter() - t0) / 100 * 1e6
        sched_expand = cost.schedule_time(Action.EXPAND, q)
        sched_shrink = cost.schedule_time(Action.SHRINK, q)
        t_expand = transfer_time_s(expand_plan(p, q, GiB),
                                   link_bw=cost.link_bw)
        t_shrink = transfer_time_s(
            shrink_plan(q, p, GiB), link_bw=cost.link_bw,
            sync_s_per_participant=cost.shrink_sync_s)
        out.append({"action": "expand", "from": p, "to": q,
                    "policy_us": round(wall_us, 1),
                    "sched_s": round(sched_expand, 4),
                    "resize_s": round(t_expand, 4)})
        out.append({"action": "shrink", "from": q, "to": p,
                    "policy_us": round(wall_us, 1),
                    "sched_s": round(sched_shrink, 4),
                    "resize_s": round(t_shrink, 4)})
    return out


def main(quick: bool = False):
    rs = rows()
    print("# Fig3: reconfiguration scheduling + resize times (FS, 1 GiB)")
    print("action,from,to,policy_us,sched_s,resize_s")
    for r in rs:
        print(f"{r['action']},{r['from']},{r['to']},{r['policy_us']},"
              f"{r['sched_s']},{r['resize_s']}")
    # paper claims
    exp = {r["from"]: r["resize_s"] for r in rs if r["action"] == "expand"}
    shr = {r["from"]: r["resize_s"] for r in rs if r["action"] == "shrink"}
    print(f"# claim[more participants faster]: resize(1->2)={exp[1]}s "
          f"> resize(32->64)={exp[32]}s: {exp[1] > exp[32]}")
    print(f"# claim[shrink sync overhead]: shrink(64->32)={shr[64]}s > "
          f"expand(32->64)={exp[32]}s: {shr[64] > exp[32]}")
    return rs


if __name__ == "__main__":
    main()
