"""Policy zoo: which scheduling policy wins per malleability mix?

Replays an SWF trace across *every* registered scheduling policy × a set of
rigid/moldable/malleable/evolving mixes via the parallel sweep driver
(:mod:`repro.rms.sweep`), then reports the winner (lowest makespan) per
mix — the Chadha/Zojer-style policy-grid study the ROADMAP "policy zoo"
item asks for, now including evolving-heavy workloads (§2 EVOLVING).

Winner tables report two objective axes per policy: the winning metric
(makespan by default) *and* node-hours — on an elastic cluster (--churn)
a policy that finishes marginally later while letting the power manager
park more capacity can be the cheaper choice.

With ``--serving`` the mixes include an SLO-bound SERVING share and a
second winner table is printed per mix: the makespan winner next to the
slo_violations winner.  The two routinely disagree — a policy that
packs batch jobs tightest (moldable's start-size optimizer) starves
serving jobs of expansion headroom and pays for its makespan in SLO
violations — which is the batch-vs-serving co-scheduling trade-off this
zoo exists to surface.

  PYTHONPATH=src python benchmarks/policy_zoo.py \\
      [--trace tests/data/sample.swf] [--nodes 64] [--workers 4] \\
      [--mixes 1:0:0:0,0.2:0.2:0.6:0,0.2:0.1:0.4:0.3] \\
      [--metric makespan_s] [--churn smoke] [--serving] \\
      [--artifact zoo.json]
"""
from __future__ import annotations

import argparse
import os

from repro.rms import POLICY_REGISTRY
from repro.rms.capacity import CHURN_SCENARIOS
from repro.rms.sweep import (artifact, build_grid, csv_lines, parse_mixes,
                             run_sweep, winners_by_mix, write_artifact)

DEFAULT_TRACE = os.path.join(os.path.dirname(__file__), "..", "tests",
                             "data", "sample.swf")
DEFAULT_MIXES = "1:0:0:0,0.2:0.2:0.6:0,0:0:1:0,0.2:0.1:0.4:0.3,0:0:0.3:0.7"
#: ``--serving`` default: batch/serving co-scheduling mixes (the last
#: field is the SERVING share of jobs).
SERVING_MIXES = "0:0:0.7:0:0.3,0.25:0:0.25:0.2:0.3,0:0:0.4:0:0.6"


def run_zoo(trace: str, *, num_nodes: int = 64, workers: int = 0,
            mixes=None, seed: int = 7, metric: str = "makespan_s",
            churn=None, trace_dir=None):
    """Returns (rows, winners): sweep rows + winning policy keyed by
    ``(trace, rigid, moldable, malleable, evolving, serving)``.

    ``trace_dir`` replays every zoo point under a ``TraceRecorder`` and
    drops its ``repro.obs`` artifacts there (rows are unchanged — the
    observer-effect guarantee)."""
    mixes = mixes or parse_mixes(DEFAULT_MIXES)
    policies = sorted(POLICY_REGISTRY)
    if trace_dir:
        os.makedirs(trace_dir, exist_ok=True)
    points = build_grid([trace], policies, mixes, (True,),
                        num_nodes=num_nodes, seed=seed, churn=churn,
                        trace_dir=trace_dir)
    rows = run_sweep(points, workers=workers)
    return rows, winners_by_mix(rows, metric=metric)


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--trace", default=os.path.normpath(DEFAULT_TRACE))
    ap.add_argument("--nodes", type=int, default=64)
    ap.add_argument("--workers", type=int, default=0)
    ap.add_argument("--seed", type=int, default=7)
    ap.add_argument("--mixes", default=DEFAULT_MIXES)
    ap.add_argument("--metric", default="makespan_s",
                    help="winner criterion (any numeric row column)")
    ap.add_argument("--churn", default=None,
                    choices=sorted(CHURN_SCENARIOS),
                    help="run the zoo on an elastic cluster: named "
                         "capacity-churn scenario (drains/joins + power "
                         "management)")
    ap.add_argument("--serving", action="store_true",
                    help="co-schedule SLO-bound serving jobs with the "
                         "batch mix (default mixes gain a serving share) "
                         "and print the makespan-vs-SLO winner table")
    ap.add_argument("--trace-dir", default=None, metavar="DIR",
                    help="write repro.obs span/metrics/Perfetto trace "
                         "artifacts for every zoo point into DIR")
    ap.add_argument("--artifact", default=None,
                    help="write the versioned JSON artifact here")
    args = ap.parse_args(argv)

    if args.serving and args.mixes == DEFAULT_MIXES:
        args.mixes = SERVING_MIXES
    mixes = parse_mixes(args.mixes)
    policies = sorted(POLICY_REGISTRY)
    print(f"# policy zoo: {os.path.basename(args.trace)}, "
          f"{len(policies)} policies x {len(mixes)} mixes "
          f"({args.workers or 1} workers"
          + (f", churn={args.churn}" if args.churn else "") + ")")
    rows, winners = run_zoo(args.trace, num_nodes=args.nodes,
                            workers=args.workers, mixes=mixes,
                            seed=args.seed, metric=args.metric,
                            churn=args.churn, trace_dir=args.trace_dir)
    for line in csv_lines(rows):
        print(line)

    # Winner keys carry the trace (a multi-trace zoo has one table per
    # trace — keying by mix alone used to collapse them into one).
    by_key = {}
    for row in rows:
        by_key.setdefault((row["trace"], row["rigid"], row["moldable"],
                           row["malleable"], row["evolving"],
                           row["serving"]), []).append(row)
    print(f"\n# winner per trace x mix (lowest {args.metric}; "
          f"cells are {args.metric}/node_hours):")
    print(f"{'trace':<20} {'rigid':>6} {'mold':>6} {'mall':>6} {'evol':>6} "
          f"{'serv':>6}  "
          f"{'winner':<12} " + " ".join(f"{p:>16}" for p in policies))
    for key in sorted(by_key):
        trace, rigid, mold, mall, evol, serv = key
        vals = {r["policy"]: (float(r[args.metric]),
                              float(r.get("node_hours", 0.0)))
                for r in by_key[key]}
        cells = " ".join(
            f"{vals[p][0]:9.0f}/{vals[p][1]:6.0f}" if p in vals
            else f"{'-':>16}" for p in policies)
        print(f"{trace:<20} {rigid:6.2f} {mold:6.2f} {mall:6.2f} "
              f"{evol:6.2f} {serv:6.2f}  {winners[key]:<12} {cells}")

    if args.serving:
        slo_winners = winners_by_mix(rows, metric="slo_violations")
        print("\n# makespan vs SLO winner per trace x mix "
              "('*' = they disagree: the winner on makespan pays for it "
              "in SLO violations):")
        print(f"{'trace':<20} {'serv':>6}  {'makespan winner':<28} "
              f"{'slo winner':<28}")
        for key in sorted(by_key):
            vals = {r["policy"]: (float(r["makespan_s"]),
                                  int(r["slo_violations"]))
                    for r in by_key[key]}
            mk, sl = winners[key], slo_winners[key]
            mark = " *" if mk != sl else ""
            print(f"{key[0]:<20} {key[5]:6.2f}  "
                  f"{mk} ({vals[mk][0]:.0f}s, {vals[mk][1]} viol)"
                  f"{'':<4} {sl} ({vals[sl][0]:.0f}s, {vals[sl][1]} viol)"
                  f"{mark}")

    if args.artifact:
        grid = {"traces": [os.path.basename(args.trace)],
                "policies": policies, "mixes": [list(m) for m in mixes],
                "flexibles": [True], "num_nodes": args.nodes,
                "seed": args.seed}
        if args.churn:
            grid["churn"] = args.churn
        write_artifact(args.artifact, artifact(rows, grid))
        print(f"# wrote {args.artifact} ({len(rows)} rows)")
    return rows, winners


if __name__ == "__main__":
    main()
