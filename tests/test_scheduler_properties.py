"""Property-based scheduler invariants (policy registry, EASY backfill)."""
import random

try:
    from hypothesis import given, settings, strategies as st
except ImportError:                              # container has no hypothesis
    from _hypothesis_stub import given, settings, strategies as st

import pytest

from repro.rms import (POLICY_REGISTRY, Cluster, Job, JobState, Scheduler,
                       SchedulerConfig)


def make_jobs(sizes, submit_times=None, state=JobState.PENDING):
    submit_times = submit_times or [float(i) for i in range(len(sizes))]
    jobs = []
    for i, (n, t) in enumerate(zip(sizes, submit_times)):
        j = Job(job_id=i, app="cg", submit_time=t, work=100.0,
                min_nodes=1, max_nodes=n, preferred=None,
                requested_nodes=n)
        j.state = state
        if state is JobState.RUNNING:
            j.nodes = n
        jobs.append(j)
    return jobs


def occupy(cluster, running):
    for j in running:
        cluster.allocate(j.job_id + 1000, j.nodes)


def rand_case(seed, num_nodes=32):
    """Deterministic random queue + running mix from a seed."""
    rng = random.Random(seed)
    n_run = rng.randint(0, 4)
    run_sizes = [rng.choice([1, 2, 4, 8]) for _ in range(n_run)]
    while sum(run_sizes) > num_nodes:
        run_sizes.pop()
    n_pend = rng.randint(1, 8)
    pend_sizes = [rng.choice([1, 2, 4, 8, 16, 32]) for _ in range(n_pend)]
    running = make_jobs(run_sizes, state=JobState.RUNNING)
    for i, j in enumerate(running):
        j.job_id += 100
    pending = make_jobs(pend_sizes,
                        [float(rng.randint(0, 50)) for _ in pend_sizes])
    estimates = {j.job_id: float(rng.randint(10, 500))
                 for j in running + pending}
    return num_nodes, running, pending, estimates


@settings(max_examples=40, deadline=None)
@given(st.integers(0, 10_000), st.sampled_from(sorted(POLICY_REGISTRY)))
def test_starts_never_exceed_free_nodes(seed, policy):
    num_nodes, running, pending, est = rand_case(seed)
    cluster = Cluster(num_nodes)
    occupy(cluster, running)
    sched = Scheduler(cluster, SchedulerConfig(policy=policy))
    starts = sched.schedule(pending, running, now=60.0,
                            runtime_estimate=lambda j: est[j.job_id])
    # the preempt policy may free victim nodes before the starts apply
    freed = sum(v.nodes - max(new, 0)
                for v, new in sched.pop_preemptions())
    assert sum(n for _, n in starts) <= cluster.free_nodes + freed
    assert cluster.free_nodes + cluster.allocated_nodes == num_nodes


@settings(max_examples=40, deadline=None)
@given(st.integers(0, 10_000), st.sampled_from(sorted(POLICY_REGISTRY)))
def test_starts_are_pending_and_unique(seed, policy):
    num_nodes, running, pending, est = rand_case(seed)
    cluster = Cluster(num_nodes)
    occupy(cluster, running)
    sched = Scheduler(cluster, SchedulerConfig(policy=policy))
    starts = sched.schedule(pending, running, now=60.0,
                            runtime_estimate=lambda j: est[j.job_id])
    ids = [j.job_id for j, _ in starts]
    assert len(ids) == len(set(ids))
    pend_ids = {j.job_id for j in pending}
    assert all(i in pend_ids for i in ids)
    for j, n in starts:
        if policy == "moldable":
            # start-size optimizer: any size within the job's range
            assert max(j.min_nodes, 1) <= n <= j.max_nodes
        else:
            assert n == j.requested_nodes


def head_reservation_time(free, head_need, releases):
    """Earliest t where `head_need` nodes are available."""
    avail = free
    if avail >= head_need:
        return 0.0
    for t, n in sorted(releases):
        avail += n
        if avail >= head_need:
            return t
    return float("inf")


@settings(max_examples=40, deadline=None)
@given(st.integers(0, 10_000))
def test_easy_backfill_never_delays_head_reservation(seed):
    """Backfilled jobs must leave the blocked head startable no later than
    its reservation computed before backfilling."""
    num_nodes, running, pending, est = rand_case(seed)
    cluster = Cluster(num_nodes)
    occupy(cluster, running)
    now = 60.0
    sched = Scheduler(cluster, SchedulerConfig(policy="easy"))
    order = sched.order(pending, now)
    starts = sched.schedule(pending, running, now,
                            runtime_estimate=lambda j: est[j.job_id])
    started = {j.job_id for j, _ in starts}
    blocked = [j for j in order if j.job_id not in started]
    if not blocked:
        return
    head = blocked[0]
    head_pos = [j.job_id for j in order].index(head.job_id)
    prefix = [(j, n) for j, n in starts
              if [x.job_id for x in order].index(j.job_id) < head_pos]
    backfills = [(j, n) for j, n in starts if (j, n) not in prefix]
    # Reservation as seen when the head blocked: prefix starts consumed.
    free_at_head = cluster.free_nodes - sum(n for _, n in prefix)
    releases0 = [(now + est[j.job_id], j.nodes) for j in running] + \
                [(now + est[j.job_id], n) for j, n in prefix]
    t_resv = head_reservation_time(free_at_head, head.requested_nodes,
                                   releases0)
    # After backfilling: less free now, but backfills also release later.
    free1 = free_at_head - sum(n for _, n in backfills)
    releases1 = releases0 + [(now + est[j.job_id], n) for j, n in backfills]
    t_after = head_reservation_time(free1, head.requested_nodes, releases1)
    assert t_after <= t_resv + 1e-9


@settings(max_examples=40, deadline=None)
@given(st.integers(0, 10_000))
def test_priority_order_is_total_and_stable_under_ties(seed):
    rng = random.Random(seed)
    num_nodes = 64
    cluster = Cluster(num_nodes)
    sched = Scheduler(cluster, SchedulerConfig())
    # Several jobs share (size, submit) => identical priority; job_id breaks
    # the tie, so any input permutation must produce the same order.
    sizes = [rng.choice([4, 8]) for _ in range(10)]
    submits = [float(rng.choice([0, 10])) for _ in range(10)]
    jobs = make_jobs(sizes, submits)
    now = 100.0
    ref = sched.order(list(jobs), now)
    for _ in range(5):
        shuffled = list(jobs)
        rng.shuffle(shuffled)
        assert [j.job_id for j in sched.order(shuffled, now)] == \
            [j.job_id for j in ref]
    # total order: strictly sorted by the sort key
    keys = [(-sched.priority(j, now), j.submit_time, j.job_id) for j in ref]
    assert keys == sorted(keys)
    assert len({j.job_id for j in ref}) == len(ref)


def test_boost_dominates_priority():
    cluster = Cluster(64)
    sched = Scheduler(cluster, SchedulerConfig())
    jobs = make_jobs([4, 4], [0.0, 1000.0])
    jobs[1].priority_boost = 1e12
    order = sched.order(jobs, now=2000.0)
    assert order[0].job_id == 1


def test_unknown_policy_raises():
    with pytest.raises(ValueError):
        Scheduler(Cluster(8), SchedulerConfig(policy="nope"))


def test_fcfs_blocks_behind_head():
    """FCFS: a job that fits must NOT start if a higher-priority job is
    blocked ahead of it."""
    cluster = Cluster(8)
    # Head needs 16 (> 8): nothing behind it may start under fcfs.  The
    # head's age dwarfs the small job's size bonus, so it tops the queue.
    jobs = make_jobs([16, 2], [0.0, 9_900.0])
    jobs[0].requested_nodes = 16
    sched = Scheduler(cluster, SchedulerConfig(policy="fcfs"))
    starts = sched.schedule(jobs, [], now=10_000.0,
                            runtime_estimate=lambda j: 100.0)
    assert starts == []
    easy = Scheduler(cluster, SchedulerConfig(policy="easy"))
    starts = easy.schedule(jobs, [], now=10_000.0,
                           runtime_estimate=lambda j: 100.0)
    assert [j.job_id for j, _ in starts] == [1]   # EASY backfills it


@settings(max_examples=40, deadline=None)
@given(st.integers(0, 10_000))
def test_conservative_backfill_false_is_fcfs(seed):
    """``SchedulerConfig.backfill=False`` must be honored by conservative
    (regression: it used to be silently ignored): without backfill no job
    may start ahead of a blocked higher-priority job — fcfs semantics."""
    num_nodes, running, pending, est = rand_case(seed)

    def starts_for(policy, backfill=True):
        cluster = Cluster(num_nodes)
        occupy(cluster, running)
        sched = Scheduler(cluster, SchedulerConfig(policy=policy,
                                                   backfill=backfill))
        return sched.schedule(pending, running, now=60.0,
                              runtime_estimate=lambda j: est[j.job_id])

    cons = starts_for("conservative", backfill=False)
    fcfs = starts_for("fcfs")
    assert [(j.job_id, n) for j, n in cons] == \
        [(j.job_id, n) for j, n in fcfs]


def test_conservative_backfill_false_blocks_behind_head():
    """Pin the honored behavior on the fcfs blocking scenario."""
    cluster = Cluster(8)
    jobs = make_jobs([16, 2], [0.0, 9_900.0])
    jobs[0].requested_nodes = 16
    sched = Scheduler(cluster, SchedulerConfig(policy="conservative",
                                               backfill=False))
    starts = sched.schedule(jobs, [], now=10_000.0,
                            runtime_estimate=lambda j: 100.0)
    assert starts == []                  # head blocks; nothing leapfrogs
    with_bf = Scheduler(cluster, SchedulerConfig(policy="conservative"))
    starts = with_bf.schedule(jobs, [], now=10_000.0,
                              runtime_estimate=lambda j: 100.0)
    assert [j.job_id for j, _ in starts] == [1]   # backfill reserves + fills


def test_conservative_skips_job_that_can_never_fit():
    """A request larger than the cluster gets no reservation and must not
    be started (regression: the fallback used to over-allocate)."""
    cluster = Cluster(4)
    jobs = make_jobs([8, 2], [0.0, 1.0])
    jobs[0].requested_nodes = 8
    sched = Scheduler(cluster, SchedulerConfig(policy="conservative"))
    starts = sched.schedule(jobs, [], now=10.0,
                            runtime_estimate=lambda j: 100.0)
    assert [j.job_id for j, _ in starts] == [1]
    assert all(n <= 4 for _, n in starts)


def test_malleable_releases_conserve_held_nodes():
    """The shrinkable split must not double-count a job's nodes
    (regression: phantom release was added on top of the full one)."""
    cluster = Cluster(64)
    runner = make_jobs([32], state=JobState.RUNNING)[0]
    runner.malleable = True
    runner.min_nodes = 4
    runner.check_period_s = 15.0
    cluster.allocate(runner.job_id, 32)
    pol = Scheduler(cluster, SchedulerConfig(policy="malleable")).policy
    releases = pol._releases([runner], 0.0, lambda j: 1000.0)
    assert sum(n for _, n in releases) == 32
    assert releases == [(15.0, 16), (1000.0, 16)]


def test_malleable_policy_reserves_earlier():
    """A malleable running job's shrinkable nodes count as an early release,
    so the malleable policy can refuse a long backfill that EASY accepts."""
    cluster = Cluster(16)
    runner = make_jobs([16], state=JobState.RUNNING)[0]
    runner.job_id = 99
    runner.malleable = True
    runner.min_nodes = 4
    runner.check_period_s = 15.0
    cluster.allocate(runner.job_id, 16)
    # Head needs 8; a long 4-node job could backfill under plain EASY
    # (reservation at runner's end) but would delay the earlier
    # malleability-aware reservation.
    # Head is much older than the filler so it tops the priority order.
    head = make_jobs([8], [0.0])[0]
    filler = make_jobs([4], [95.0])[0]
    filler.job_id = 1
    est = {99: 1000.0, 0: 500.0, 1: 900.0}
    easy = Scheduler(cluster, SchedulerConfig(policy="easy"))
    mall = Scheduler(cluster, SchedulerConfig(policy="malleable"))
    # no free nodes at all => neither starts anything; free 4 nodes first
    cluster.resize(99, 12)
    runner.nodes = 12
    est_fn = lambda j: est[j.job_id]
    s_easy = easy.schedule([head, filler], [runner], 100.0, est_fn)
    s_mall = mall.schedule([head, filler], [runner], 100.0, est_fn)
    assert [j.job_id for j, _ in s_easy] == [1]
    assert s_mall == []   # spare nodes held back for the sooner reservation
