"""Property tests: the serving traffic model is open-loop and seeded.

The load-bearing property of :mod:`repro.workload.traffic` is that the
arrival process is a pure function of ``(seed, rate curve)`` — nothing
the simulator does (allocation decisions, query times, query *order*)
can change how many requests arrive.  A closed-loop generator would let
a policy "reduce load" by shrinking a job, corrupting every
policy-comparison row the sweep produces.

Runs under real hypothesis when installed, the deterministic
boundary-example stub otherwise (the container has no hypothesis).
"""
import math

try:
    from hypothesis import given, settings, strategies as st
except ImportError:
    from _hypothesis_stub import given, settings, strategies as st

import pytest

from repro.workload.traffic import DiurnalCurve, TrafficGenerator, TrafficSpec


def make_spec(seed=7, base_rps=4.0, amplitude=0.5, noise=0.1,
              duration=900.0, bursts=()):
    curve = DiurnalCurve(base_rps=base_rps, amplitude=amplitude,
                         period_s=duration / 2.0, phase_s=duration / 8.0,
                         bursts=tuple(bursts))
    return TrafficSpec(curve=curve, seed=seed, t0=100.0,
                       duration_s=duration, bucket_s=30.0, noise=noise)


def probe_times(spec, n=40):
    """Deterministic probe grid covering before/inside/after the window."""
    lo, hi = spec.t0 - 50.0, spec.end + 50.0
    return [lo + (hi - lo) * i / (n - 1) for i in range(n)]


# -- determinism ------------------------------------------------------------

@settings(max_examples=25, deadline=None)
@given(seed=st.integers(min_value=0, max_value=10_000),
       base=st.floats(min_value=0.0, max_value=50.0),
       amplitude=st.floats(min_value=0.0, max_value=1.0),
       noise=st.floats(min_value=0.0, max_value=0.9))
def test_same_seed_generators_identical(seed, base, amplitude, noise):
    """Two generators built from equal specs agree bit-for-bit at every
    probe — arrivals are a function of the spec alone."""
    spec = make_spec(seed=seed, base_rps=base, amplitude=amplitude,
                     noise=noise)
    a, b = TrafficGenerator(spec), TrafficGenerator(spec)
    for t in probe_times(spec):
        assert a.arrivals_until(t) == b.arrivals_until(t)
        assert a.rate(t) == b.rate(t)
    assert a.total() == b.total()


@settings(max_examples=25, deadline=None)
@given(seed=st.integers(min_value=0, max_value=10_000),
       noise=st.floats(min_value=0.0, max_value=0.9))
def test_query_order_cannot_change_arrivals(seed, noise):
    """The open-loop property, mechanically: the simulator queries the
    generator at whatever times its allocation decisions produce, so a
    reversed / interleaved query schedule must return bit-identical
    values to a forward scan (the lazy bucket extension must not leak
    query history into results)."""
    spec = make_spec(seed=seed, noise=noise)
    forward, backward = TrafficGenerator(spec), TrafficGenerator(spec)
    times = probe_times(spec)
    want = [forward.arrivals_until(t) for t in times]
    got = {t: backward.arrivals_until(t) for t in reversed(times)}
    assert [got[t] for t in times] == want
    # interleaved re-queries (an engine revisiting earlier timestamps
    # after a requeue) don't perturb anything either
    mixed = TrafficGenerator(spec)
    order = times[::3] + times[1::3] + list(reversed(times)) + times
    for t in order:
        assert mixed.arrivals_until(t) == want[times.index(t)]


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(min_value=0, max_value=1_000))
def test_different_seeds_differ(seed):
    """Noise is seeded per (seed, bucket): distinct seeds give distinct
    arrival counts (almost surely — boundary-true for these params)."""
    a = TrafficGenerator(make_spec(seed=seed, noise=0.5))
    b = TrafficGenerator(make_spec(seed=seed + 1, noise=0.5))
    assert a.total() != b.total()


# -- conservation / shape ---------------------------------------------------

@settings(max_examples=25, deadline=None)
@given(seed=st.integers(min_value=0, max_value=10_000),
       amplitude=st.floats(min_value=0.0, max_value=1.0),
       noise=st.floats(min_value=0.0, max_value=0.9))
def test_cumulative_monotone_and_clamped(seed, amplitude, noise):
    spec = make_spec(seed=seed, amplitude=amplitude, noise=noise)
    gen = TrafficGenerator(spec)
    times = probe_times(spec)
    vals = [gen.arrivals_until(t) for t in times]
    assert all(b >= a for a, b in zip(vals, vals[1:]))
    assert gen.arrivals_until(spec.t0 - 1.0) == 0.0
    assert gen.arrivals_until(spec.end + 1.0) == gen.total()
    assert all(gen.rate(t) >= 0.0 for t in times)
    assert gen.rate(spec.t0 - 1.0) == 0.0 == gen.rate(spec.end + 1.0)


@settings(max_examples=25, deadline=None)
@given(seed=st.integers(min_value=0, max_value=10_000),
       noise=st.floats(min_value=0.0, max_value=0.9),
       cut=st.floats(min_value=0.1, max_value=0.9))
def test_partition_sums_to_total(seed, noise, cut):
    """arrivals_between telescopes: splitting the window at any point
    conserves the request count (no request created or lost at a cut —
    the property the sanitizer's serving_conservation invariant leans
    on)."""
    spec = make_spec(seed=seed, noise=noise)
    gen = TrafficGenerator(spec)
    mid = spec.t0 + cut * spec.duration_s
    left = gen.arrivals_between(spec.t0, mid)
    right = gen.arrivals_between(mid, spec.end)
    assert left >= 0.0 and right >= 0.0
    assert (left + right) == pytest.approx(gen.total(), rel=1e-12, abs=1e-9)


def test_zero_noise_matches_closed_form_integral():
    """With noise off the fluid arrivals are exactly the curve integral."""
    spec = make_spec(noise=0.0, amplitude=0.4)
    gen = TrafficGenerator(spec)
    for t in probe_times(spec):
        lo = min(max(t, spec.t0), spec.end)
        want = spec.curve.integral(spec.t0, lo)
        assert gen.arrivals_until(t) == pytest.approx(want, rel=1e-12,
                                                      abs=1e-9)


def test_bursts_add_load_inside_their_window_only():
    quiet = TrafficGenerator(make_spec(noise=0.0))
    spec = make_spec(noise=0.0, bursts=[(400.0, 100.0, 6.0)])
    bursty = TrafficGenerator(spec)
    assert bursty.arrivals_until(400.0) == quiet.arrivals_until(400.0)
    assert bursty.arrivals_between(400.0, 500.0) == pytest.approx(
        quiet.arrivals_between(400.0, 500.0) + 600.0, rel=1e-12)
    assert bursty.rate(450.0) == pytest.approx(quiet.rate(450.0) + 6.0)
    assert bursty.rate(550.0) == pytest.approx(quiet.rate(550.0))


def test_curve_rate_is_periodic_and_nonnegative():
    curve = DiurnalCurve(base_rps=2.0, amplitude=1.0, period_s=120.0,
                         phase_s=13.0)
    for t in range(0, 600, 7):
        assert curve.rate(float(t)) >= 0.0
        assert curve.rate(float(t)) == pytest.approx(
            curve.rate(float(t) + 120.0), rel=1e-12, abs=1e-12)
    assert curve.rate(13.0) == pytest.approx(4.0)     # crest: base*(1+amp)


def test_spec_validation():
    curve = DiurnalCurve(base_rps=1.0)
    with pytest.raises(ValueError):
        DiurnalCurve(base_rps=-1.0)
    with pytest.raises(ValueError):
        DiurnalCurve(base_rps=1.0, amplitude=1.5)
    with pytest.raises(ValueError):
        DiurnalCurve(base_rps=1.0, period_s=0.0)
    with pytest.raises(ValueError):
        TrafficSpec(curve=curve, seed=1, duration_s=0.0)
    with pytest.raises(ValueError):
        TrafficSpec(curve=curve, seed=1, noise=1.0)
    with pytest.raises(ValueError):
        TrafficSpec(curve=curve, seed=1, bucket_s=0.0)


# -- open loop at the simulator level ---------------------------------------

def test_allocation_decisions_cannot_change_served_totals():
    """End-to-end open-loop check: the same serving workload replayed
    under schedulers with opposite incentives (moldable squeezes start
    sizes for makespan, fcfs never backfills) must serve *exactly* the
    same number of requests — policies redistribute when requests are
    served, never how many arrive."""
    import os

    from repro.rms.simulator import ClusterSimulator, SimConfig
    from repro.rms.scheduler import SchedulerConfig
    from repro.workload.swf import MalleabilityMix, jobs_from_swf, parse_swf

    trace = parse_swf(os.path.join(os.path.dirname(__file__), "data",
                                   "sample.swf"))
    mix = MalleabilityMix(rigid=0.0, moldable=0.0, malleable=0.5,
                          evolving=0.0, serving=0.5)
    totals = {}
    for policy in ("moldable", "fcfs"):
        jobs, apps = jobs_from_swf(trace, num_nodes=64, mix=mix, seed=11,
                                   max_jobs=12)
        cfg = SimConfig(num_nodes=64, seed=11,
                        sched=SchedulerConfig(policy=policy))
        rep = ClusterSimulator(jobs, cfg, apps=apps).run()
        totals[policy] = rep.served_requests()
        assert rep.served_requests() > 0.0
        assert math.isfinite(rep.p99_latency())
    assert totals["moldable"] == totals["fcfs"]
