"""Degenerate `hypothesis` fallback so tier-1 collects without the package.

Provides just the surface the test-suite uses — ``given``, ``settings``,
and ``strategies.{integers,floats,sampled_from,booleans,lists}`` — with
strategies that enumerate a handful of fixed boundary examples instead of
searching.  With real hypothesis installed the test modules never import
this; the stub exists so `python -m pytest` runs everywhere (the container
has no hypothesis) while CI with `requirements-dev.txt` gets the real
property-based search.

Usage in a test module:

    try:
        from hypothesis import given, settings, strategies as st
    except ImportError:
        from _hypothesis_stub import given, settings, strategies as st
"""
from __future__ import annotations

import itertools

#: Cap on example tuples per test; keeps the fallback cheap.
MAX_EXAMPLES = 6


class Strategy:
    """A fixed, ordered set of examples standing in for a search space."""

    def __init__(self, examples):
        self.examples = list(examples)
        if not self.examples:
            raise ValueError("stub strategy needs at least one example")

    # hypothesis API subset some suites touch
    def map(self, fn):
        return Strategy([fn(x) for x in self.examples])

    def filter(self, pred):
        kept = [x for x in self.examples if pred(x)]
        return Strategy(kept or self.examples[:1])


class _Strategies:
    """Stand-in for the `hypothesis.strategies` module."""

    @staticmethod
    def integers(min_value=0, max_value=None):
        if max_value is None:
            max_value = min_value + 100
        mid = (min_value + max_value) // 2
        vals = sorted({min_value, mid, max_value})
        return Strategy(vals)

    @staticmethod
    def floats(min_value=0.0, max_value=1.0, **_kw):
        mid = (min_value + max_value) / 2.0
        vals = []
        for v in (min_value, mid, max_value):
            if v not in vals:
                vals.append(v)
        return Strategy(vals)

    @staticmethod
    def sampled_from(seq):
        seq = list(seq)
        picks = [seq[0], seq[len(seq) // 2], seq[-1]]
        uniq = []
        for p in picks:
            if p not in uniq:
                uniq.append(p)
        return Strategy(uniq)

    @staticmethod
    def booleans():
        return Strategy([False, True])

    @staticmethod
    def lists(elements, min_size=0, max_size=None):
        base = elements.examples
        out = []
        if min_size == 0:
            out.append([])
        out.append(base[: max(min_size, 1)])
        if max_size is None or len(base) <= max_size:
            out.append(list(base))
        return Strategy([x for x in out if len(x) >= min_size] or [[]])

    @staticmethod
    def just(value):
        return Strategy([value])


strategies = _Strategies()


def _combos(arg_strats, kw_strats):
    """A small deterministic sample of the example cross-product.

    Takes the "diagonal" first (i-th example of every strategy, cycling),
    which covers each strategy's boundary values without exploding
    combinatorially, then pads from the full product up to MAX_EXAMPLES.
    """
    names = list(kw_strats)
    spaces = [s.examples for s in arg_strats] + \
             [kw_strats[n].examples for n in names]
    if not spaces:
        return [((), {})]
    depth = max(len(sp) for sp in spaces)
    seen, combos = set(), []

    def add(tup):
        if tup not in seen and len(combos) < MAX_EXAMPLES:
            seen.add(tup)
            combos.append(tup)

    for i in range(depth):
        add(tuple(sp[i % len(sp)] for sp in spaces))
    for tup in itertools.product(*spaces):
        if len(combos) >= MAX_EXAMPLES:
            break
        add(tup)
    n_pos = len(arg_strats)
    return [(tup[:n_pos], dict(zip(names, tup[n_pos:])))
            for tup in combos]


def given(*arg_strats, **kw_strats):
    """Run the test once per sampled example tuple (no shrinking/search)."""

    def deco(fn):
        # No functools.wraps: copying __wrapped__ would let pytest unwrap
        # to the original signature and demand fixtures for strategy args.
        def wrapper():
            for pos, kw in _combos(arg_strats, kw_strats):
                try:
                    fn(*pos, **kw)
                except _AssumptionFailed:
                    continue
        wrapper.__name__ = fn.__name__
        wrapper.__doc__ = fn.__doc__
        wrapper.__module__ = fn.__module__
        return wrapper
    return deco


def settings(*_args, **_kwargs):
    """`@settings(...)` no-op: example budget is fixed by the stub."""

    def deco(fn):
        return fn
    return deco


class HealthCheck:
    """Placeholder attributes for `suppress_health_check=[...]` usages."""
    too_slow = data_too_large = filter_too_much = None
    function_scoped_fixture = differing_executors = None


class _AssumptionFailed(Exception):
    pass


def assume(condition) -> bool:
    """Reject the current example (the `given` wrapper moves on)."""
    if not condition:
        raise _AssumptionFailed
    return True
