"""Golden-trace regression: the event engine is deterministic and stable.

Two guarantees:

1. Two runs with the same seed produce byte-identical serialized
   ``ActionRecord`` sequences and the same makespan.
2. The serialization matches the committed golden JSON
   (``tests/data/golden_engine_trace.json``) — any engine refactor that
   changes dispatch order, cost modelling, or handler semantics fails
   loudly here instead of silently shifting the paper's tables.

Regenerate the golden file (after an *intentional* semantic change) with:

    PYTHONPATH=src:tests python -c \\
        "import test_engine_determinism as t; t.write_golden()"
"""
import json
import os

from repro.rms import ClusterSimulator, SimConfig
from repro.workload import make_workload

GOLDEN = os.path.join(os.path.dirname(__file__), "data",
                      "golden_engine_trace.json")


def scenario():
    """Small but event-rich: reconfigs + a node failure + a straggler."""
    jobs = make_workload(12, seed=7)
    cfg = SimConfig(num_nodes=32, flexible=True, seed=7,
                    failures=((400.0, 0),),
                    stragglers=((200.0, 1, 3.0),))
    return ClusterSimulator(jobs, cfg)


def serialize(report) -> dict:
    return {
        "makespan": round(report.makespan, 6),
        "actions": [
            {"t": round(a.t, 6), "job_id": a.job_id, "action": a.action,
             "decide_s": round(a.decide_s, 6),
             "apply_s": round(a.apply_s, 6),
             "from_nodes": a.from_nodes, "to_nodes": a.to_nodes,
             "timed_out": a.timed_out, "reason": a.reason}
            for a in report.actions],
    }


def run_bytes():
    rep = scenario().run()
    doc = serialize(rep)
    return json.dumps(doc, indent=1, sort_keys=True).encode(), doc


def write_golden():
    data, _ = run_bytes()
    with open(GOLDEN, "wb") as fh:
        fh.write(data + b"\n")


def test_two_runs_byte_identical():
    a, doc_a = run_bytes()
    b, doc_b = run_bytes()
    assert a == b
    assert doc_a["makespan"] == doc_b["makespan"]


def test_matches_committed_golden_trace():
    data, doc = run_bytes()
    with open(GOLDEN) as fh:
        golden = json.load(fh)
    assert doc["makespan"] == golden["makespan"]
    assert len(doc["actions"]) == len(golden["actions"])
    for got, want in zip(doc["actions"], golden["actions"]):
        assert got == want


def test_checkpoint_chain_not_duplicated_after_requeue():
    """A rigid job requeued by a node failure restarts its CheckpointTick
    chain; the stale chain must die at the epoch guard instead of
    accumulating (regression: ticks used to multiply per restart)."""
    from repro.rms import CheckpointTick

    jobs = make_workload(4, seed=3, malleable=False)
    cfg = SimConfig(num_nodes=64, flexible=False, seed=3,
                    checkpoint_period_s=50.0, failures=((100.0, 0),))
    sim = ClusterSimulator(jobs, cfg)
    ticks = {}
    sim.engine.on(CheckpointTick, lambda ev: ticks.setdefault(
        ev.job_id, []).append((ev.epoch, ev.t)))
    rep = sim.run()
    assert any(a.action == "failure_requeue" for a in rep.actions)
    for job_id, evs in ticks.items():
        by_epoch = {}
        for epoch, t in evs:
            by_epoch.setdefault(epoch, []).append(t)
        for epoch, ts in by_epoch.items():
            # within a live chain, ticks are exactly one period apart
            for a, b in zip(ts, ts[1:]):
                assert abs((b - a) - cfg.checkpoint_period_s) < 1e-6
            # a superseded chain dies: at most one tick fires at or after
            # the successor epoch's first tick
            nxt = by_epoch.get(epoch + 1)
            if nxt:
                assert sum(1 for t in ts if t >= nxt[0]) <= 1


def test_reconfig_chain_not_duplicated_after_requeue():
    """A malleable job requeued (node failure with too few survivors) and
    restarted within one check period must get a *fresh* ReconfigPoint
    chain; the stale chain dies at the epoch guard instead of doubling the
    DMR check frequency (regression: preempt/failure requeues used to leave
    the old chain live)."""
    from repro.rms import AppModel, Job, ReconfigPoint

    app = AppModel("x", iterations=100, t1_iter_s=4.0, serial_frac=0.0,
                   data_bytes=1 << 20, min_nodes=4, max_nodes=4,
                   preferred=None, check_period_s=5.0)
    job = Job(job_id=0, app="x", submit_time=0.0, work=100.0,
              min_nodes=4, max_nodes=4, preferred=None, factor=2,
              malleable=True, check_period_s=5.0, requested_nodes=4,
              data_bytes=1 << 20)
    # Failing one of the job's nodes leaves 3 survivors < min_nodes=4, so
    # the job requeues — and restarts immediately on the 4+ free nodes.
    cfg = SimConfig(num_nodes=8, flexible=True, checkpoint_period_s=0.0,
                    failures=((7.0, 0),))
    sim = ClusterSimulator([job], cfg, apps={"x": app})
    ticks = []
    sim.engine.on(ReconfigPoint, lambda ev: ticks.append((ev.t, ev.epoch)))
    rep = sim.run()
    assert any(a.action == "failure_requeue" for a in rep.actions)
    assert job.end_time > 0                      # restarted and finished
    epochs = {e for _, e in ticks}
    assert epochs == {1, 2}                      # exactly one restart
    t_restart = min(t for t, e in ticks if e == 2)
    # the superseded chain fires at most once after the new chain starts
    stale = [t for t, e in ticks if e == 1 and t >= 7.0]
    assert len(stale) <= 1
    # and the live chain ticks exactly one period apart
    live = sorted(t for t, e in ticks if e == 2)
    assert t_restart == live[0]
    for a, b in zip(live, live[1:]):
        assert abs((b - a) - 5.0) < 1e-6


def test_trace_exercises_failure_and_reconfig_paths():
    """The golden scenario must stay event-rich, or the regression test
    degrades into a trivial check."""
    _, doc = run_bytes()
    kinds = {a["action"] for a in doc["actions"]}
    assert "shrink" in kinds or "expand" in kinds
    assert any(a["action"].startswith("failure_") for a in doc["actions"])
