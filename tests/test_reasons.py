"""Closed reason vocabulary (:mod:`repro.rms.reasons`) regression.

Every ``ActionRecord.reason`` the simulator emits must parse to a code in
``REASON_CODES`` — the observability ledger groups by code, so an
out-of-vocabulary emission (or a code with varying data baked in, like
the historical ``phase{i}``/``node{n}``) silently fragments the audit.
The scenario battery below walks every emission family: DMR policy
decisions, async pathologies, preemption, EVOLVING phases, faults and
stragglers, capacity churn and power management, and serving SLO bands.
"""
import pytest

import test_capacity
import test_engine_determinism
import test_evolving
import test_serving_rms
from repro.rms import (MAX_PRIORITY, AppModel, CapacityConfig,
                       ClusterSimulator, Job, SimConfig)
from repro.rms.reasons import (REASON_CODES, is_known_reason, make_reason,
                               reason_code, reason_detail)
from repro.rms.scheduler import SchedulerConfig
from repro.workload import make_workload


# ---------------------------------------------------------------------------
# vocabulary primitives
# ---------------------------------------------------------------------------

def test_make_reason_validates_code():
    assert make_reason("node-failed") == "node-failed"
    assert make_reason("node-failed", 3) == "node-failed:3"
    with pytest.raises(ValueError):
        make_reason("node3-failed")          # varying data baked in
    with pytest.raises(ValueError):
        make_reason("")


def test_reason_code_and_detail_roundtrip():
    assert reason_code("node-failed:3") == "node-failed"
    assert reason_detail("node-failed:3") == "3"
    assert reason_code("at-preferred") == "at-preferred"
    assert reason_detail("at-preferred") == ""
    # detail may itself contain colons (e.g. joined node lists)
    assert reason_detail("power-off:1,2:3") == "1,2:3"


def test_is_known_reason():
    assert is_known_reason("slo-expand")
    assert is_known_reason("drain-vacate:9")
    assert not is_known_reason("")
    assert not is_known_reason("node3-failed")


def test_codes_never_embed_varying_data():
    """Codes are enum-like: lowercase words and dashes only — any digit
    in a code is smuggled detail (the pre-vocabulary bug)."""
    for code in REASON_CODES:
        assert code, "empty code"
        assert not any(ch.isdigit() for ch in code), code
        assert code == code.lower(), code
        assert ":" not in code, code


# ---------------------------------------------------------------------------
# every emission across the scenario battery is in-vocabulary
# ---------------------------------------------------------------------------

def preempt_scenario():
    """A malleable victim at min size is requeued for a max-priority
    head — the §4.3 ``head-reservation-slip`` path end to end."""
    apps = {
        "vic": AppModel("vic", iterations=1000, t1_iter_s=8.0,
                        serial_frac=0.0, data_bytes=1 << 20, min_nodes=8,
                        max_nodes=8, preferred=None, check_period_s=15.0),
        "big": AppModel("big", iterations=100, t1_iter_s=16.0,
                        serial_frac=0.0, data_bytes=0, min_nodes=16,
                        max_nodes=16, preferred=None, check_period_s=0.0),
    }
    victim = Job(job_id=0, app="vic", submit_time=0.0, work=1000.0,
                 min_nodes=8, max_nodes=8, preferred=None, malleable=True,
                 check_period_s=15.0, requested_nodes=8,
                 data_bytes=1 << 20)
    head = Job(job_id=1, app="big", submit_time=20.0, work=100.0,
               min_nodes=16, max_nodes=16, preferred=None, malleable=False,
               requested_nodes=16)
    head.priority_boost = MAX_PRIORITY
    cfg = SimConfig(num_nodes=16, flexible=True, checkpoint_period_s=0.0,
                    sched=SchedulerConfig(policy="preempt",
                                          preempt_grace_s=5.0,
                                          preempt_requeue=True))
    sim = ClusterSimulator([victim, head], cfg)
    sim.apps = apps
    return sim


def straggler_scenario():
    """One malleable job with healthy free nodes available — the scan
    must swap the slow slice out (``slice-migration``)."""
    job = Job(job_id=0, app="cg", submit_time=0.0, work=600.0,
              min_nodes=4, max_nodes=4, preferred=None, malleable=False,
              requested_nodes=4, data_bytes=1 << 20)
    cfg = SimConfig(num_nodes=8, flexible=False, checkpoint_period_s=0.0,
                    stragglers=((30.0, 0, 4.0),))
    return ClusterSimulator([job], cfg)


def power_scenario():
    """CLUES power cycling: parked after the idle dwell, booted back on
    demand (``power-off`` / ``power-on``)."""
    a = Job(job_id=0, app="cg", submit_time=0.0, work=50.0, min_nodes=1,
            max_nodes=1, preferred=None, requested_nodes=1)
    b = Job(job_id=1, app="cg", submit_time=60.0, work=10.0, min_nodes=3,
            max_nodes=3, preferred=None, requested_nodes=3)
    cfg = SimConfig(num_nodes=4, flexible=False, checkpoint_period_s=0.0,
                    capacity=CapacityConfig(enabled=True,
                                            idle_power_off_s=30.0,
                                            min_free=1,
                                            power_up_delay_s=10.0))
    return ClusterSimulator([a, b], cfg)


def evolving_scenario():
    job, apps = test_evolving.two_phase_job()
    cfg = SimConfig(num_nodes=8, flexible=True, checkpoint_period_s=0.0)
    return ClusterSimulator([job], cfg, apps=apps)


def async_scenario():
    jobs = make_workload(12, seed=7)
    cfg = SimConfig(num_nodes=16, flexible=True, seed=7,
                    scheduling="async", expand_timeout_s=30.0)
    return ClusterSimulator(jobs, cfg)


SCENARIOS = {
    "engine": test_engine_determinism.scenario,   # failures + stragglers
    "churn": test_capacity.churn_scenario,        # joins/drains
    "serving": test_serving_rms.serving_scenario, # SLO negotiation
    "preempt": preempt_scenario,                  # head-reservation slips
    "straggler": straggler_scenario,              # slice migration
    "power": power_scenario,                      # CLUES power cycling
    "evolving": evolving_scenario,                # phase boundaries
    "async": async_scenario,                      # stale grants/timeouts
}


@pytest.mark.parametrize("name", sorted(SCENARIOS))
def test_every_emitted_reason_is_in_vocabulary(name):
    rep = SCENARIOS[name]().run()
    assert rep.actions, f"{name}: scenario emitted no actions"
    bad = sorted({a.reason for a in rep.actions
                  if not is_known_reason(a.reason)})
    assert not bad, f"{name}: out-of-vocabulary reasons {bad}"


def test_battery_exercises_the_vocabulary_families():
    """The battery must stay event-rich: one representative code per
    emission family has to actually appear, or the closed-vocabulary
    test above degrades to vacuity."""
    seen = set()
    for build in SCENARIOS.values():
        rep = build().run()
        seen |= {reason_code(a.reason) for a in rep.actions}
    required = {
        "toward-preferred",            # DMR policy decisions
        "slo-expand",                  # serving SLO band
        "node-failed",                 # faults
        "slice-migration",             # stragglers
        "node-join", "drain-vacate",   # capacity churn
        "power-off",                   # power manager
        "phase-entered",               # EVOLVING phases
        "head-reservation-slip",       # preemption
    }
    missing = required - seen
    assert not missing, f"battery no longer emits {sorted(missing)}"
    assert seen <= REASON_CODES
