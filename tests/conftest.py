"""Test fixtures. Tests must see the real single CPU device — only
launch/dryrun.py sets the 512-device placeholder flag."""
import os

import jax
import pytest

assert "xla_force_host_platform_device_count" not in \
    os.environ.get("XLA_FLAGS", ""), \
    "tests must not run with placeholder devices"


@pytest.fixture(scope="session")
def single_device():
    return jax.devices()[0]
