"""Pallas kernels vs pure-jnp oracles (interpret mode), shape/dtype sweeps."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
try:
    from hypothesis import given, settings, strategies as st
except ImportError:                              # container has no hypothesis
    from _hypothesis_stub import given, settings, strategies as st

from repro.kernels.flash_attention.ops import flash_attention_op
from repro.kernels.flash_attention.ref import attention_ref
from repro.kernels.rglru.ops import rglru_op
from repro.kernels.rglru.ref import rglru_ref
from repro.kernels.ssd.ops import ssd_op
from repro.kernels.ssd.ref import ssd_ref

KEY = jax.random.PRNGKey(0)


def rand(key, shape, dtype):
    return jax.random.normal(key, shape, jnp.float32).astype(dtype)


FLASH_CASES = [
    # (b, h, kv, s, d, causal, window, softcap, dtype, tol)
    (2, 4, 2, 256, 64, True, None, None, jnp.float32, 2e-6),
    (1, 4, 1, 512, 128, True, 128, None, jnp.float32, 2e-6),
    (2, 2, 2, 256, 64, True, None, 50.0, jnp.float32, 2e-6),
    (1, 8, 4, 256, 32, False, None, None, jnp.float32, 2e-6),
    (1, 2, 1, 256, 64, True, None, None, jnp.bfloat16, 2e-2),
    (2, 3, 3, 384, 64, True, 256, 30.0, jnp.float32, 2e-6),
]


@pytest.mark.parametrize(
    "b,h,kv,s,d,causal,window,softcap,dtype,tol", FLASH_CASES)
def test_flash_attention_matches_ref(b, h, kv, s, d, causal, window,
                                     softcap, dtype, tol):
    ks = jax.random.split(KEY, 3)
    q = rand(ks[0], (b, h, s, d), dtype)
    k = rand(ks[1], (b, kv, s, d), dtype)
    v = rand(ks[2], (b, kv, s, d), dtype)
    out = flash_attention_op(q, k, v, causal=causal, window=window,
                             softcap=softcap, block_q=128, block_k=128,
                             impl="interpret")
    ref = attention_ref(q, k, v, causal=causal, window=window,
                        softcap=softcap)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32),
                               atol=tol, rtol=tol * 10)


SSD_CASES = [
    (2, 64, 3, 16, 32, 16, jnp.float32, 1e-5),
    (1, 128, 2, 32, 64, 32, jnp.float32, 1e-5),
    (1, 64, 2, 16, 32, 64, jnp.float32, 1e-5),   # chunk > seq clamps
    (2, 64, 2, 16, 32, 16, jnp.bfloat16, 5e-2),
]


@pytest.mark.parametrize("b,s,h,p,n,chunk,dtype,tol", SSD_CASES)
def test_ssd_matches_ref(b, s, h, p, n, chunk, dtype, tol):
    ks = jax.random.split(KEY, 5)
    x = rand(ks[0], (b, s, h, p), dtype)
    dt = jax.nn.softplus(rand(ks[1], (b, s, h), jnp.float32))
    a_log = rand(ks[2], (h,), jnp.float32) * 0.5
    bb = rand(ks[3], (b, s, n), dtype)
    cc = rand(ks[4], (b, s, n), dtype)
    out = ssd_op(x, dt, a_log, bb, cc, chunk=chunk, impl="interpret")
    ref = ssd_ref(x, dt, a_log, bb, cc)
    scale = np.abs(np.asarray(ref, np.float32)).max() + 1e-6
    np.testing.assert_allclose(np.asarray(out, np.float32) / scale,
                               np.asarray(ref, np.float32) / scale,
                               atol=tol)


@settings(max_examples=10, deadline=None)
@given(st.integers(1, 3), st.sampled_from([32, 64, 128]),
       st.sampled_from([64, 128, 256]))
def test_rglru_matches_ref_property(b, s, w):
    ks = jax.random.split(jax.random.PRNGKey(s * w + b), 2)
    a = jax.nn.sigmoid(jax.random.normal(ks[0], (b, s, w))) * 0.99
    bb = jax.random.normal(ks[1], (b, s, w))
    out = rglru_op(a, bb, chunk=min(32, s), block_w=min(64, w),
                   impl="interpret")
    ref = rglru_ref(a, bb)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               atol=1e-5, rtol=1e-4)


def test_flash_attention_grid_skips_are_exact():
    """Causal + window: masked-out blocks must not change results."""
    ks = jax.random.split(KEY, 3)
    q = rand(ks[0], (1, 2, 512, 64), jnp.float32)
    k = rand(ks[1], (1, 2, 512, 64), jnp.float32)
    v = rand(ks[2], (1, 2, 512, 64), jnp.float32)
    for window in (64, 128, 256):
        out = flash_attention_op(q, k, v, causal=True, window=window,
                                 block_q=64, block_k=64, impl="interpret")
        ref = attention_ref(q, k, v, causal=True, window=window)
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                                   atol=2e-6, rtol=1e-5)


def test_model_ssd_uses_same_math_as_kernel():
    """The model's chunked SSD and the Pallas kernel agree."""
    from repro.models.ssm import ssd_chunked
    ks = jax.random.split(KEY, 5)
    b, s, h, p, n = 2, 64, 2, 16, 32
    x = rand(ks[0], (b, s, h, p), jnp.float32)
    dt = jax.nn.softplus(rand(ks[1], (b, s, h), jnp.float32))
    a_log = rand(ks[2], (h,), jnp.float32) * 0.5
    bb = rand(ks[3], (b, s, n), jnp.float32)
    cc = rand(ks[4], (b, s, n), jnp.float32)
    y_model, _ = ssd_chunked(x * 1.0, dt, a_log, bb, cc, 16)
    y_kernel = ssd_op(x, dt, a_log, bb, cc, chunk=16, impl="interpret")
    # model multiplies x by dt inside; kernel does the same
    np.testing.assert_allclose(np.asarray(y_model), np.asarray(y_kernel),
                               atol=1e-5, rtol=1e-4)
