"""Optimizer: AdamW vs numpy reference, schedule, clipping, compression."""
import jax
import jax.numpy as jnp
import numpy as np
try:
    from hypothesis import given, settings, strategies as st
except ImportError:                              # container has no hypothesis
    from _hypothesis_stub import given, settings, strategies as st

from repro.optim import (AdamWConfig, apply_updates, global_norm, init_state,
                         schedule)
from repro.optim.compression import _dequantize, _quantize


def test_adamw_matches_numpy_reference():
    cfg = AdamWConfig(lr=0.1, beta1=0.9, beta2=0.99, eps=1e-8,
                      weight_decay=0.0, clip_norm=None, warmup_steps=0,
                      total_steps=1000, min_lr_ratio=1.0)
    p = {"w": jnp.array([[1.0, -2.0], [0.5, 3.0]])}
    g = {"w": jnp.array([[0.1, 0.2], [-0.3, 0.4]])}
    st_ = init_state(p)
    new_p, st1, _ = apply_updates(cfg, p, g, st_)
    # numpy reference
    mu = 0.1 * np.asarray(g["w"])
    nu = 0.01 * np.asarray(g["w"]) ** 2
    mh, nh = mu / (1 - 0.9), nu / (1 - 0.99)
    ref = np.asarray(p["w"]) - 0.1 * mh / (np.sqrt(nh) + 1e-8)
    np.testing.assert_allclose(np.asarray(new_p["w"]), ref, rtol=1e-5)
    assert int(st1["step"]) == 1


def test_weight_decay_only_on_matrices():
    cfg = AdamWConfig(lr=0.1, weight_decay=1.0, clip_norm=None,
                      warmup_steps=0, min_lr_ratio=1.0)
    p = {"w": jnp.ones((2, 2)), "b": jnp.ones((2,))}
    g = {"w": jnp.zeros((2, 2)), "b": jnp.zeros((2,))}
    new_p, _, _ = apply_updates(cfg, p, g, init_state(p))
    assert float(jnp.abs(new_p["w"] - 1.0).max()) > 0   # decayed
    np.testing.assert_allclose(np.asarray(new_p["b"]), 1.0)  # not decayed


def test_clipping_bounds_update():
    cfg = AdamWConfig(clip_norm=1.0, warmup_steps=0)
    p = {"w": jnp.zeros((4,))}
    g = {"w": jnp.full((4,), 100.0)}
    _, _, metrics = apply_updates(cfg, p, g, init_state(p))
    assert float(metrics["grad_norm"]) == 200.0


def test_schedule_warmup_and_cosine():
    cfg = AdamWConfig(lr=1.0, warmup_steps=10, total_steps=100,
                      min_lr_ratio=0.1)
    assert float(schedule(cfg, jnp.int32(0))) == 0.0
    assert abs(float(schedule(cfg, jnp.int32(10))) - 1.0) < 1e-6
    assert abs(float(schedule(cfg, jnp.int32(100))) - 0.1) < 1e-6


@settings(max_examples=30, deadline=None)
@given(st.integers(1, 2000), st.floats(0.01, 100.0))
def test_quantize_roundtrip_bounded_error(n, scale):
    x = (np.random.RandomState(n).randn(n) * scale).astype(np.float32)
    q, s = _quantize(jnp.asarray(x))
    out = np.asarray(_dequantize(q, s, (n,), n))
    # per-block max-abs scaling bounds error by scale/127 per element
    blocks = np.abs(x).reshape(-1)
    assert np.abs(out - x).max() <= (np.abs(x).max() / 127.0) + 1e-6


def test_global_norm():
    t = {"a": jnp.ones((4,)), "b": jnp.full((3,), 2.0)}
    assert abs(float(global_norm(t)) - np.sqrt(4 + 12)) < 1e-6
