"""Golden-artifact regression for the parallel sweep driver.

Three locks:

1. The serial smoke-grid sweep (2 policies × 2 mixes on ``sample.swf``)
   byte-matches the committed ``tests/data/golden_sweep.json``.
2. The 2-worker parallel run byte-matches the serial run — worker fan-out
   must never change results or their order.
3. The artifact schema (version, row columns, canonical serialization) is
   stable; loading rejects foreign schemas/versions.

Regenerate the golden file (after an *intentional* semantic change) with:

    PYTHONPATH=src:tests python -c \\
        "import test_sweep_golden as t; t.write_golden()"
"""
import json
import os

import pytest

from repro.rms import sweep

DATA = os.path.join(os.path.dirname(__file__), "data")
TRACE = os.path.join(DATA, "sample.swf")
GOLDEN = os.path.join(DATA, "golden_sweep.json")


def smoke_bytes(workers: int) -> str:
    points, grid = sweep.smoke_grid(TRACE)
    rows = sweep.run_sweep(points, workers=workers)
    return sweep.dumps_artifact(sweep.artifact(rows, grid))


def write_golden():
    with open(GOLDEN, "w") as fh:
        fh.write(smoke_bytes(0))


def golden_bytes() -> str:
    """Canonical bytes of the golden artifact *after* schema upgrade —
    the committed file deliberately stays at v4 on disk so every golden
    comparison (here and in the CI ``--check`` step) also exercises the
    v4 → v5 auto-upgrade path against a fresh v5 run."""
    return sweep.dumps_artifact(sweep.load_artifact(GOLDEN))


def test_serial_sweep_matches_golden_artifact():
    assert smoke_bytes(0) == golden_bytes()


def test_parallel_two_workers_byte_matches_serial_and_golden():
    """The acceptance lock: 2-worker fan-out is bit-identical to serial."""
    par = smoke_bytes(2)
    assert par == smoke_bytes(0)
    assert par == golden_bytes()


def test_artifact_schema_versioned_and_complete():
    doc = json.loads(golden_bytes())
    assert doc["schema"] == sweep.SCHEMA_ID
    assert doc["version"] == sweep.SCHEMA_VERSION
    assert len(doc["results"]) == \
        len(sweep.SMOKE_POLICIES) * len(sweep.SMOKE_MIXES)
    for row in doc["results"]:
        assert set(sweep.COLUMNS) <= set(row), \
            f"row missing columns: {set(sweep.COLUMNS) - set(row)}"
        assert row["trace"] == "sample.swf"     # label, not a path
        assert row["completed"] == row["jobs"] == 24
        # golden grid runs under the hand-fit constants (provenance v3)
        assert row["calibration_id"] == sweep.PAPER_FIT_ID
    # rows sorted by the canonical key
    keys = [sweep.row_key(r) for r in doc["results"]]
    assert keys == sorted(keys)


def test_csv_lines_follow_column_order():
    doc = json.loads(golden_bytes())
    lines = sweep.csv_lines(doc["results"])
    assert lines[0] == ",".join(sweep.COLUMNS)
    assert len(lines) == 1 + len(doc["results"])
    first = lines[1].split(",")
    assert first[0] == "sample.swf"
    assert len(first) == len(sweep.COLUMNS)


def test_load_artifact_round_trip_and_rejections(tmp_path):
    doc = sweep.load_artifact(GOLDEN)           # accepts the golden file
    # upgraded doc re-serializes and re-loads as a fixed point
    out = tmp_path / "upgraded.json"
    out.write_text(sweep.dumps_artifact(doc))
    assert sweep.dumps_artifact(sweep.load_artifact(str(out))) == \
        sweep.dumps_artifact(doc)
    bad_schema = tmp_path / "bad_schema.json"
    bad_schema.write_text(json.dumps({"schema": "nope", "version": 1}))
    with pytest.raises(ValueError, match="not a sweep artifact"):
        sweep.load_artifact(str(bad_schema))
    bad_version = tmp_path / "bad_version.json"
    bad_version.write_text(json.dumps(
        {"schema": sweep.SCHEMA_ID, "version": sweep.SCHEMA_VERSION + 1}))
    with pytest.raises(ValueError, match="version"):
        sweep.load_artifact(str(bad_version))


def test_winners_by_mix_deterministic_tiebreak():
    rows = [
        {"trace": "t.swf", "rigid": 0.0, "moldable": 0.0, "malleable": 1.0,
         "evolving": 0.0, "policy": "b", "makespan_s": 100.0},
        {"trace": "t.swf", "rigid": 0.0, "moldable": 0.0, "malleable": 1.0,
         "evolving": 0.0, "policy": "a", "makespan_s": 100.0},
        {"trace": "t.swf", "rigid": 1.0, "moldable": 0.0, "malleable": 0.0,
         "evolving": 0.0, "policy": "c", "makespan_s": 50.0},
        # a v1 row (no evolving key) lands in the zero-evolving bucket
        {"trace": "t.swf", "rigid": 1.0, "moldable": 0.0, "malleable": 0.0,
         "policy": "b", "makespan_s": 40.0},
    ]
    winners = sweep.winners_by_mix(rows)
    # tie -> lexical
    assert winners[("t.swf", 0.0, 0.0, 1.0, 0.0, 0.0)] == "a"
    assert winners[("t.swf", 1.0, 0.0, 0.0, 0.0, 0.0)] == "b"


def test_winners_by_mix_keyed_per_trace():
    """Regression: keying by mix alone collapsed a multi-trace sweep into
    one winner table — the trace with the globally smallest metric won
    every mix.  Each trace must get its own winner."""
    mix = {"rigid": 0.0, "moldable": 0.0, "malleable": 1.0, "evolving": 0.0}
    rows = [
        dict(mix, trace="small.swf", policy="easy", makespan_s=10.0),
        dict(mix, trace="small.swf", policy="sjf", makespan_s=20.0),
        dict(mix, trace="big.swf", policy="easy", makespan_s=900.0),
        dict(mix, trace="big.swf", policy="sjf", makespan_s=800.0),
    ]
    winners = sweep.winners_by_mix(rows)
    assert winners[("small.swf", 0.0, 0.0, 1.0, 0.0, 0.0)] == "easy"
    # pre-fix this bucket did not exist: big.swf's rows lost to small.swf's
    # globally smaller makespans and the table crowned "easy" for all
    assert winners[("big.swf", 0.0, 0.0, 1.0, 0.0, 0.0)] == "sjf"
    assert len(winners) == 2


def test_csv_lines_quote_hostile_trace_names():
    """Regression: csv_lines joined raw ``str(value)`` on commas, so a
    trace name containing a comma shifted every later column.  Under
    csv-module quoting the hostile name must round-trip exactly."""
    import csv as csv_mod
    import io

    doc = json.loads(golden_bytes())
    row = dict(doc["results"][0])
    hostile = 'evil, "trace"\nname.swf'
    row["trace"] = hostile
    lines = sweep.csv_lines([row])
    parsed = list(csv_mod.reader(io.StringIO("\n".join(lines))))
    assert parsed[0] == list(sweep.COLUMNS)
    rec = parsed[1]
    assert len(rec) == len(sweep.COLUMNS)
    assert rec[sweep.COLUMNS.index("trace")] == hostile
    assert rec[sweep.COLUMNS.index("policy")] == str(row["policy"])
    assert rec[sweep.COLUMNS.index("makespan_s")] == str(row["makespan_s"])


def test_smoke_grid_includes_evolving_mix():
    """The golden grid must keep exercising the evolving workload class."""
    points, grid = sweep.smoke_grid(TRACE)
    assert any(m[3] > 0 for m in grid["mixes"])
    assert all(len(p.mix) == 5 for p in points)
    doc = json.loads(golden_bytes())
    assert any(row["evolving"] > 0 and row["phase_changes"] > 0
               for row in doc["results"])


def test_load_artifact_upgrades_v1(tmp_path):
    """Pre-evolving (v1) artifacts stay loadable: rows gain evolving=0.0
    and phase_changes=0, grid mixes widen to 5 fractions."""
    v1 = {"schema": sweep.SCHEMA_ID, "version": 1,
          "grid": {"mixes": [[0.0, 0.0, 1.0]]},
          "results": [{"trace": "t.swf", "policy": "easy", "rigid": 0.0,
                       "moldable": 0.0, "malleable": 1.0, "flexible": True,
                       "scheduling": "sync", "num_nodes": 64, "seed": 7,
                       "time_scale": 1.0, "makespan_s": 10.0}]}
    path = tmp_path / "v1.json"
    path.write_text(json.dumps(v1))
    doc = sweep.load_artifact(str(path))
    assert doc["version"] == sweep.SCHEMA_VERSION
    row = doc["results"][0]
    assert row["evolving"] == 0.0
    assert row["phase_changes"] == 0
    # the v1 → v2 → v3 → v4 → v5 chain lands at the current schema
    assert row["calibration_id"] == sweep.PAPER_FIT_ID
    assert row["churn"] == ""
    assert row["serving"] == 0.0
    assert row["slo_violations"] == 0
    assert doc["grid"]["mixes"] == [[0.0, 0.0, 1.0, 0.0, 0.0]]
    # upgraded rows sort with the current key
    assert sweep.row_key(row)


def test_load_artifact_upgrades_v2(tmp_path):
    """Pre-calibration (v2) artifacts stay loadable: rows gain the
    paper-fit calibration_id provenance."""
    v2 = {"schema": sweep.SCHEMA_ID, "version": 2,
          "grid": {"mixes": [[0.1, 0.2, 0.4, 0.3]]},
          "results": [{"trace": "t.swf", "policy": "sjf", "rigid": 0.1,
                       "moldable": 0.2, "malleable": 0.4, "evolving": 0.3,
                       "flexible": True, "scheduling": "sync",
                       "num_nodes": 64, "seed": 7, "time_scale": 1.0,
                       "phase_changes": 3, "makespan_s": 10.0}]}
    path = tmp_path / "v2.json"
    path.write_text(json.dumps(v2))
    doc = sweep.load_artifact(str(path))
    assert doc["version"] == sweep.SCHEMA_VERSION
    row = doc["results"][0]
    assert row["calibration_id"] == sweep.PAPER_FIT_ID
    assert row["evolving"] == 0.3            # v2 fields untouched
    assert sweep.row_key(row)[-2] == sweep.PAPER_FIT_ID
    assert sweep.row_key(row)[-1] == ""      # churn lands last in the key


def test_load_artifact_upgrades_v3(tmp_path):
    """Pre-elastic (v3) artifacts stay loadable: fixed-capacity rows gain
    churn="", node_hours = capacity × makespan (exact for a cluster that
    never churned), zero powered-off hours and zero capacity events —
    and the upgraded doc round-trips through the canonical serializer."""
    v3 = {"schema": sweep.SCHEMA_ID, "version": 3,
          "grid": {"mixes": [[0.1, 0.2, 0.4, 0.3]]},
          "results": [{"trace": "t.swf", "policy": "sjf", "rigid": 0.1,
                       "moldable": 0.2, "malleable": 0.4, "evolving": 0.3,
                       "flexible": True, "scheduling": "sync",
                       "num_nodes": 64, "seed": 7, "time_scale": 1.0,
                       "phase_changes": 3, "makespan_s": 3600.0,
                       "calibration_id": sweep.PAPER_FIT_ID}]}
    path = tmp_path / "v3.json"
    path.write_text(json.dumps(v3))
    doc = sweep.load_artifact(str(path))
    assert doc["version"] == sweep.SCHEMA_VERSION
    row = doc["results"][0]
    assert row["churn"] == ""
    assert row["node_hours"] == 64.0         # 64 nodes × 1 h
    assert row["powered_off_hours"] == 0.0
    assert row["drains"] == row["joins"] == 0
    assert row["power_offs"] == row["power_ons"] == 0
    assert row["phase_changes"] == 3         # v3 fields untouched
    # upgraded artifact re-loads as the native version (round-trip)
    out = tmp_path / "v4.json"
    out.write_text(sweep.dumps_artifact(doc))
    again = sweep.load_artifact(str(out))
    assert sweep.dumps_artifact(again) == sweep.dumps_artifact(doc)
