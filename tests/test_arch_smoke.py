"""Per-architecture smoke tests (deliverable f): reduced same-family config,
one forward + one train step on CPU, asserting shapes + finiteness."""
import dataclasses

import jax
import jax.numpy as jnp
import pytest

from repro.configs import list_archs
from repro.models import build_model, get_model, reduced_config
from repro.optim import AdamWConfig, apply_updates, init_state

KEY = jax.random.PRNGKey(0)


def make_inputs(cfg, B=2, S=32):
    toks = jax.random.randint(KEY, (B, S - cfg.frontend_tokens), 0,
                              cfg.vocab_size)
    batch = {"tokens": toks, "labels": toks}
    if cfg.family == "encdec":
        batch = {"tokens": jax.random.randint(KEY, (B, S // 2), 0,
                                              cfg.vocab_size),
                 "labels": jax.random.randint(KEY, (B, S // 2), 0,
                                              cfg.vocab_size),
                 "frontend": jax.random.normal(KEY, (B, S // 2,
                                                     cfg.d_model))}
    elif cfg.frontend:
        batch["frontend"] = jax.random.normal(
            KEY, (B, cfg.frontend_tokens, cfg.d_model))
    return batch


@pytest.mark.parametrize("arch", list_archs())
def test_forward_shapes_and_finite(arch):
    _, full = get_model(arch)
    cfg = reduced_config(full)
    model = build_model(cfg)
    params = model.init(KEY)
    B, S = 2, 32
    batch = make_inputs(cfg, B, S)
    if cfg.family == "encdec":
        logits, _ = model.forward(params, batch["frontend"],
                                  batch["tokens"])
        assert logits.shape == (B, S // 2, cfg.vocab_size)
    else:
        logits, _ = model.forward(params, batch["tokens"],
                                  batch.get("frontend"))
        exp = S if cfg.frontend else S
        assert logits.shape == (B, exp, cfg.vocab_size)
    assert bool(jnp.isfinite(logits).all())


@pytest.mark.parametrize("arch", list_archs())
def test_one_train_step(arch):
    _, full = get_model(arch)
    cfg = reduced_config(full)
    model = build_model(cfg)
    params = model.init(KEY)
    batch = make_inputs(cfg)
    opt_cfg = AdamWConfig(lr=1e-3, warmup_steps=1, total_steps=10)
    opt = init_state(params)

    @jax.jit
    def step(params, opt, batch):
        (loss, _), grads = jax.value_and_grad(
            model.loss, has_aux=True)(params, batch)
        params, opt, metrics = apply_updates(opt_cfg, params, grads, opt)
        return params, opt, loss

    p1, o1, loss = step(params, opt, batch)
    assert bool(jnp.isfinite(loss))
    # params actually changed
    delta = sum(float(jnp.abs(a - b).sum())
                for a, b in zip(jax.tree.leaves(params),
                                jax.tree.leaves(p1)))
    assert delta > 0
    # no NaNs anywhere in the new state
    for leaf in jax.tree.leaves(p1):
        assert bool(jnp.isfinite(leaf).all())


@pytest.mark.parametrize("arch", list_archs())
def test_exact_config_matches_assignment(arch):
    """The full (non-reduced) configs carry the assigned hyperparameters."""
    _, cfg = get_model(arch)
    expected = {
        "smollm-135m": (30, 576, 9, 3, 1536, 49152),
        "granite-3-2b": (40, 2048, 32, 8, 8192, 49155),
        "qwen3-4b": (36, 2560, 32, 8, 9728, 151936),
        "gemma2-27b": (46, 4608, 32, 16, 36864, 256000),
        "recurrentgemma-9b": (38, 4096, 16, 1, 12288, 256000),
        "deepseek-moe-16b": (28, 2048, 16, 16, 1408, 102400),
        "phi3.5-moe-42b-a6.6b": (32, 4096, 32, 8, 6400, 32064),
        "seamless-m4t-medium": (12, 1024, 16, 16, 4096, 256206),
        "mamba2-130m": (24, 768, 0, 0, 0, 50280),
        "paligemma-3b": (18, 2048, 8, 1, 16384, 257216),
    }[cfg.name]
    got = (cfg.num_layers, cfg.d_model, cfg.num_heads, cfg.num_kv_heads,
           cfg.d_ff, cfg.vocab_size)
    assert got == expected
    if cfg.name == "deepseek-moe-16b":
        assert (cfg.num_experts, cfg.top_k, cfg.num_shared_experts) == \
            (64, 6, 2)
    if cfg.name == "phi3.5-moe-42b-a6.6b":
        assert (cfg.num_experts, cfg.top_k) == (16, 2)
    if cfg.name == "mamba2-130m":
        assert cfg.ssm_state == 128
