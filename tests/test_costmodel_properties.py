"""Property tests for the Fig. 3 reconfiguration cost model.

Pins the paper's shape claims on ``transfer_time_s`` / ``resize_time``
across the parameter space (works under the no-hypothesis stub too):

- Fig. 3b: more participants ⇒ faster redistribution at fixed bytes;
- shrinks cost at least expands at equal geometry (the §5.2.2 per-
  participant sync term);
- no-op resizes (same size, or nothing to move) are free;
- the ``schedule_time`` jitter path (``rng is not None``) respects its
  distribution floor ``>= 0.2 * base`` (previously untested).
"""
import numpy as np
try:
    from hypothesis import given, settings, strategies as st
except ImportError:                              # container has no hypothesis
    from _hypothesis_stub import given, settings, strategies as st

from repro.core import expand_plan, plan_stats, shrink_plan, transfer_time_s
from repro.core.actions import Action
from repro.rms.costmodel import ReconfigCostModel

sizes = st.sampled_from([1, 2, 4, 8, 16, 32])
byte_exps = st.integers(20, 33)          # 1 MiB .. 8 GiB


@settings(max_examples=40, deadline=None)
@given(sizes, byte_exps)
def test_transfer_time_monotone_decreasing_in_participants(p, log_bytes):
    """Fig. 3b: at fixed bytes, an expand involving more slices is never
    slower — the per-link chunks shrink as the participant count grows."""
    nbytes = 2 ** log_bytes
    times = [transfer_time_s(expand_plan(q, 2 * q, nbytes), link_bw=5e9)
             for q in (p, 2 * p, 4 * p)]
    assert times[0] >= times[1] >= times[2]
    assert times[0] > times[2]           # strictly faster across a 4x jump


@settings(max_examples=40, deadline=None)
@given(sizes, byte_exps)
def test_shrink_costs_at_least_expand_at_equal_geometry(p, log_bytes):
    """q→p shrink ≥ p→q expand at equal bytes: the shrink moves the same
    per-link maximum but pays the per-participant sync barrier."""
    nbytes = 2 ** log_bytes
    q = 2 * p
    model = ReconfigCostModel()
    expand = model.resize_time(p, q, nbytes)
    shrink = model.resize_time(q, p, nbytes)
    assert shrink >= expand
    assert shrink > expand               # default sync term is positive


@settings(max_examples=20, deadline=None)
@given(sizes, byte_exps)
def test_noop_resize_is_free(p, log_bytes):
    model = ReconfigCostModel()
    assert model.resize_time(p, p, 2 ** log_bytes) == 0.0
    assert model.resize_time(p, 2 * p, 0) == 0.0     # nothing to move


@settings(max_examples=20, deadline=None)
@given(sizes, byte_exps)
def test_plan_stats_matches_transfer_time_features(p, log_bytes):
    """plan_stats (the calibration fitter's feature extractor) agrees with
    what transfer_time_s charges."""
    nbytes = 2 ** log_bytes
    plan = shrink_plan(2 * p, p, nbytes)
    participants, busiest = plan_stats(plan)
    t = transfer_time_s(plan, link_bw=5e9, sync_s_per_participant=0.004)
    assert t == busiest / 5e9 + 0.004 * participants
    assert participants == 2 * p         # every old rank takes part


# -- schedule_time jitter path (previously untested) -------------------------

def test_schedule_time_jitter_floor_and_spread():
    """rng path: multiplicative jitter is clipped at 0.2x base, actually
    varies, and stays distributed around the base."""
    model = ReconfigCostModel()
    base = model.schedule_time(Action.EXPAND, 16)           # rng=None
    rng = np.random.default_rng(42)
    draws = np.array([model.schedule_time(Action.EXPAND, 16, rng=rng)
                      for _ in range(2000)])
    assert float(draws.min()) >= 0.2 * base                 # the pinned floor
    assert float(draws.std()) > 0.0                         # it does jitter
    # mean of max(0.2, 1 + 0.15 N) is ~1: within 2% at n=2000, seed 42
    assert abs(float(draws.mean()) - base) <= 0.02 * base
    assert float(draws.max()) <= 2.0 * base                 # sane upper tail


def test_schedule_time_jitter_clips_extreme_draws_to_floor():
    """A normal draw below -16/3 sigma must clip exactly to 0.2x base."""

    class _FloorRng:
        @staticmethod
        def standard_normal():
            return -1000.0

    model = ReconfigCostModel()
    base = model.schedule_time(Action.SHRINK, 8)
    assert model.schedule_time(Action.SHRINK, 8, rng=_FloorRng()) == \
        0.2 * base


def test_schedule_time_jitter_deterministic_under_seed():
    model = ReconfigCostModel()
    a = [model.schedule_time(Action.EXPAND, 4,
                             rng=np.random.default_rng(7))
         for _ in range(3)]
    b = [model.schedule_time(Action.EXPAND, 4,
                             rng=np.random.default_rng(7))
         for _ in range(3)]
    assert a == b


def test_noaction_schedule_time_jitters_too():
    model = ReconfigCostModel()
    rng = np.random.default_rng(0)
    draws = {model.schedule_time(Action.NO_ACTION, 1, rng=rng)
             for _ in range(32)}
    assert len(draws) > 1
    assert min(draws) >= 0.2 * model.noaction_s
