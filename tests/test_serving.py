"""Batched serving loop: continuous batching, greedy decoding."""
import dataclasses

import jax
import numpy as np

from repro.models import build_model, get_model, reduced_config
from repro.runtime import Request, Server

KEY = jax.random.PRNGKey(0)


def make_server(batch=2, max_len=64):
    _, full = get_model("smollm-135m")
    cfg = dataclasses.replace(reduced_config(full), dtype="float32")
    model = build_model(cfg)
    params = model.init(KEY)
    return Server(model, params, batch=batch, max_len=max_len), cfg


def test_serves_batched_requests():
    server, cfg = make_server()
    rng = np.random.default_rng(0)
    reqs = [Request(rid=i, prompt=rng.integers(0, cfg.vocab_size, 5),
                    max_new_tokens=4) for i in range(4)]
    done = server.run(reqs)
    assert set(done) == {0, 1, 2, 3}
    assert all(len(v) == 4 for v in done.values())


def test_slots_are_reused():
    server, cfg = make_server(batch=1)
    rng = np.random.default_rng(1)
    reqs = [Request(rid=i, prompt=rng.integers(0, cfg.vocab_size, 3),
                    max_new_tokens=2) for i in range(3)]
    done = server.run(reqs)
    assert len(done) == 3
