"""Batched serving loop: continuous batching, greedy decoding."""
import dataclasses

import jax
import numpy as np
import pytest

from repro.models import build_model, get_model, reduced_config
from repro.runtime import Request, Server

KEY = jax.random.PRNGKey(0)


def make_server(batch=2, max_len=64):
    _, full = get_model("smollm-135m")
    cfg = dataclasses.replace(reduced_config(full), dtype="float32")
    model = build_model(cfg)
    params = model.init(KEY)
    return Server(model, params, batch=batch, max_len=max_len), cfg


def test_serves_batched_requests():
    server, cfg = make_server()
    rng = np.random.default_rng(0)
    reqs = [Request(rid=i, prompt=rng.integers(0, cfg.vocab_size, 5),
                    max_new_tokens=4) for i in range(4)]
    done = server.run(reqs)
    assert set(done) == {0, 1, 2, 3}
    assert all(len(v) == 4 for v in done.values())


def test_slots_are_reused():
    server, cfg = make_server(batch=1)
    rng = np.random.default_rng(1)
    reqs = [Request(rid=i, prompt=rng.integers(0, cfg.vocab_size, 3),
                    max_new_tokens=2) for i in range(3)]
    done = server.run(reqs)
    assert len(done) == 3


def test_slot_freed_on_completion_and_reassigned():
    """The slot a finished request held must come back to free_slots and
    be handed to the next request."""
    server, cfg = make_server(batch=2)
    rng = np.random.default_rng(2)
    a = Request(rid=0, prompt=rng.integers(0, cfg.vocab_size, 3),
                max_new_tokens=1)
    b = Request(rid=1, prompt=rng.integers(0, cfg.vocab_size, 3),
                max_new_tokens=8)
    assert server.add(a) and server.add(b)
    slot_a = server.slot_of[0]
    assert server.free_slots() == []
    server.serve_step()                   # finishes a (1-token budget)
    assert 0 not in server.active
    assert server.free_slots() == [slot_a]
    c = Request(rid=2, prompt=rng.integers(0, cfg.vocab_size, 3),
                max_new_tokens=1)
    assert server.add(c)
    assert server.slot_of[2] == slot_a    # lowest free slot is recycled


def test_free_slots_accounting():
    server, cfg = make_server(batch=3)
    rng = np.random.default_rng(3)
    assert server.free_slots() == [0, 1, 2]
    for i in range(3):
        assert server.add(Request(rid=i,
                                  prompt=rng.integers(0, cfg.vocab_size, 2),
                                  max_new_tokens=4))
        assert len(server.free_slots()) == 2 - i
    assert not server.add(Request(rid=9,
                                  prompt=rng.integers(0, cfg.vocab_size, 2)))
    while server.active:
        server.serve_step()
    assert server.free_slots() == [0, 1, 2]


def test_max_len_evicts_at_cache_end():
    """A request whose decode reaches the end of the KV cache finishes
    early instead of writing past max_len: with a 3-token prompt and an
    8-entry cache the decode positions 2..7 emit exactly 6 tokens even
    under a much larger token budget."""
    server, cfg = make_server(batch=1, max_len=8)
    rng = np.random.default_rng(4)
    req = Request(rid=0, prompt=rng.integers(0, cfg.vocab_size, 3),
                  max_new_tokens=100)
    done = server.run([req])
    assert len(done[0]) == 8 - 3 + 1
    assert server.free_slots() == [0]     # the slot came back


def test_add_rejects_prompt_longer_than_cache():
    server, cfg = make_server(batch=1, max_len=4)
    rng = np.random.default_rng(5)
    with pytest.raises(ValueError, match="max_len"):
        server.add(Request(rid=0, prompt=rng.integers(0, cfg.vocab_size, 5)))
    assert server.free_slots() == [0]     # nothing was claimed
