"""DMR API semantics: sync/async, inhibitor, expand-timeout abort (§5.1)."""
import time

from repro.core import DMR, Action, Decision


class FakeRMS:
    def __init__(self, decisions, grant=True, wait_s=0.0):
        self.decisions = list(decisions)
        self.grant = grant
        self.wait_s = wait_s
        self.queries = 0

    def request_reconfig(self, job_id, *, current, minimum, maximum,
                         factor, preferred):
        self.queries += 1
        if self.decisions:
            return self.decisions.pop(0)
        return Decision(Action.NO_ACTION, current)

    def confirm_resize(self, job_id, decision, timeout_s):
        return self.grant, self.wait_s


def test_sync_expand_applies():
    rms = FakeRMS([Decision(Action.EXPAND, 8)])
    dmr = DMR(rms, 0, current_slices=4)
    action, n, handler = dmr.check_status(minimum=1, maximum=16, factor=2)
    assert action is Action.EXPAND and n == 8
    assert handler.old_slices == 4 and handler.new_slices == 8
    assert dmr.current_slices == 8


def test_expand_timeout_aborts():
    """§5.2.1: RJ cancelled on timeout; action aborted."""
    rms = FakeRMS([Decision(Action.EXPAND, 8)], grant=False, wait_s=30.0)
    dmr = DMR(rms, 0, current_slices=4)
    action, n, handler = dmr.check_status(minimum=1, maximum=16)
    assert action is Action.NO_ACTION and n == 4
    assert dmr.current_slices == 4
    assert dmr.history[-1].timed_out


def test_inhibitor_suppresses_calls():
    rms = FakeRMS([Decision(Action.SHRINK, 2),
                   Decision(Action.EXPAND, 8)])
    dmr = DMR(rms, 0, current_slices=4, inhibitor_s=100.0)
    dmr.check_status(minimum=1, maximum=16)
    assert rms.queries == 1
    action, n, _ = dmr.check_status(minimum=1, maximum=16)
    assert action is Action.NO_ACTION     # inhibited, no RMS contact
    assert rms.queries == 1


def test_async_returns_previous_decision():
    rms = FakeRMS([Decision(Action.SHRINK, 2)])
    dmr = DMR(rms, 0, current_slices=4)
    a1, n1, _ = dmr.icheck_status(minimum=1, maximum=16)
    assert a1 is Action.NO_ACTION          # first call: nothing ready yet
    time.sleep(0.2)                        # let the background query land
    a2, n2, _ = dmr.icheck_status(minimum=1, maximum=16)
    assert a2 is Action.SHRINK and n2 == 2
    dmr.close()


def test_history_records_all_actions():
    rms = FakeRMS([Decision(Action.SHRINK, 2), Decision(Action.EXPAND, 4)])
    dmr = DMR(rms, 0, current_slices=4)
    dmr.check_status(minimum=1, maximum=16)
    dmr.check_status(minimum=1, maximum=16)
    assert [h.action for h in dmr.history] == [Action.SHRINK, Action.EXPAND]
