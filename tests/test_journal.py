"""Resumable-sweep journal: kill-safety, resume, and shard determinism.

Extends the golden determinism contract to journals: an artifact built
from any combination of kills, resumes, and shard merges must be
byte-identical to a fresh serial run of the same grid.
"""
import json
import os
import signal
import subprocess
import sys
import time

import pytest

from repro.rms import sweep
from repro.rms.journal import GridJournal, JournalMismatch, parse_shard

DATA = os.path.join(os.path.dirname(__file__), "data")
TRACE = os.path.join(DATA, "sample.swf")
REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def smoke_points():
    points, grid = sweep.smoke_grid(TRACE)
    return points, grid


def artifact_bytes(rows, grid):
    return sweep.dumps_artifact(sweep.artifact(rows, grid))


# ---------------------------------------------------------------------------
# GridJournal primitives
# ---------------------------------------------------------------------------

def test_journal_append_load_round_trip(tmp_path):
    path = str(tmp_path / "j.jsonl")
    with GridJournal(path) as j:
        j.append("k1", {"a": 1}, {"fp": "x"})
        j.append("k2", {"a": 2})
    entries = GridJournal.load(path)
    assert set(entries) == {"k1", "k2"}
    assert entries["k1"]["row"] == {"a": 1}
    assert entries["k1"]["point"] == {"fp": "x"}
    assert "point" not in entries["k2"]


def test_journal_missing_file_is_empty(tmp_path):
    assert GridJournal.load(str(tmp_path / "nope.jsonl")) == {}


def test_journal_tolerates_truncated_tail(tmp_path):
    """A kill can cut the last line mid-write; earlier entries survive and
    the cut point simply re-runs on resume."""
    path = str(tmp_path / "j.jsonl")
    with GridJournal(path) as j:
        j.append("k1", {"a": 1})
        j.append("k2", {"a": 2})
    with open(path, "rb") as fh:
        blob = fh.read()
    cut = blob[:-9]                       # chop into the final JSON line
    assert not cut.endswith(b"\n")
    with open(path, "wb") as fh:
        fh.write(cut)
    entries = GridJournal.load(path)
    assert set(entries) == {"k1"}
    # ... and appending after the truncation still loads: the writer
    # terminates the partial line on reopen, so it stays isolated (and
    # skipped) instead of swallowing the next entry
    with GridJournal(path) as j:
        j.append("k3", {"a": 3})
    entries = GridJournal.load(path)
    assert "k1" in entries and "k3" in entries


def test_journal_duplicate_key_last_wins(tmp_path):
    path = str(tmp_path / "j.jsonl")
    with GridJournal(path) as j:
        j.append("k", {"a": 1})
        j.append("k", {"a": 2})
    assert GridJournal.load(path)["k"]["row"] == {"a": 2}


def test_journal_rejects_foreign_header(tmp_path):
    path = tmp_path / "alien.jsonl"
    path.write_text(json.dumps({"journal": "other.schema", "version": 1})
                    + "\n")
    with pytest.raises(JournalMismatch, match="not a sweep journal"):
        GridJournal.load(str(path))


def test_parse_shard():
    assert parse_shard("0/2") == [0, 2]
    assert parse_shard("3/4") == [3, 4]
    for bad in ("2/2", "-1/2", "0/0", "1", "a/b"):
        with pytest.raises(ValueError):
            parse_shard(bad)


# ---------------------------------------------------------------------------
# Resume semantics
# ---------------------------------------------------------------------------

def test_resume_skips_journaled_points(tmp_path, monkeypatch):
    """A resumed sweep re-runs only the missing points and still returns
    the full, canonically sorted row set."""
    points, grid = smoke_points()
    jpath = str(tmp_path / "j.jsonl")
    fresh = sweep.run_sweep(points)

    ran = []
    real = sweep.run_point

    def counting(point):
        ran.append(point)
        return real(point)

    monkeypatch.setattr(sweep, "run_point", counting)
    partial = sweep.run_sweep(points[:4], journal=jpath)
    assert len(ran) == 4 and len(partial) == 4

    ran.clear()
    resumed = sweep.run_sweep(points, journal=jpath, resume_from=(jpath,))
    assert len(ran) == len(points) - 4        # journaled points not re-run
    assert artifact_bytes(resumed, grid) == artifact_bytes(fresh, grid)

    ran.clear()                               # second resume: fully cached
    again = sweep.run_sweep(points, resume_from=(jpath,))
    assert ran == []
    assert artifact_bytes(again, grid) == artifact_bytes(fresh, grid)


def test_resume_rejects_fingerprint_mismatch(tmp_path):
    """A journal written under a different grid (same row key, different
    max_jobs) must fail loudly, not serve wrong rows."""
    points, _ = smoke_points()
    jpath = str(tmp_path / "j.jsonl")
    sweep.run_sweep(points[:1], journal=jpath)
    import dataclasses
    altered = dataclasses.replace(points[0], max_jobs=3)
    assert sweep.point_journal_key(altered) == \
        sweep.point_journal_key(points[0])    # key alone cannot tell
    with pytest.raises(JournalMismatch, match="different grid point"):
        sweep.run_sweep([altered], resume_from=(jpath,))


def test_colliding_grid_points_rejected(tmp_path):
    points, _ = smoke_points()
    import dataclasses
    twin = dataclasses.replace(points[0], max_jobs=3)
    with pytest.raises(ValueError, match="collide"):
        sweep.run_sweep([points[0], twin],
                        journal=str(tmp_path / "j.jsonl"))


def test_point_key_matches_row_key():
    """The key computed from a point up front must equal the row_key of
    the row that point produces — that equality is what lets resume skip
    without running."""
    points, _ = smoke_points()
    point = points[0]
    row = sweep.run_point(point)
    assert sweep.point_journal_key(point) == \
        json.dumps(sweep.row_key(row))


# ---------------------------------------------------------------------------
# Shard partitioning
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("n_shards", [2, 3, 5])
def test_shard_union_equals_full_grid(n_shards):
    """Shards are disjoint and their union is the full grid, whatever N."""
    points, _ = smoke_points()
    shards = [points[i::n_shards] for i in range(n_shards)]
    keys = [sweep.point_journal_key(p) for p in points]
    shard_keys = [[sweep.point_journal_key(p) for p in s] for s in shards]
    flat = [k for ks in shard_keys for k in ks]
    assert sorted(flat) == sorted(keys)
    assert len(set(flat)) == len(flat)


def test_shard_journals_merge_to_serial_bytes(tmp_path):
    """Run each shard with its own journal, merge via resume: artifact
    bytes equal the fresh serial run's."""
    points, grid = smoke_points()
    fresh = sweep.run_sweep(points)
    jpaths = []
    for i in range(2):
        jpath = str(tmp_path / f"shard{i}.jsonl")
        jpaths.append(jpath)
        sweep.run_sweep(points[i::2], journal=jpath)
    merged = sweep.run_sweep(points, resume_from=jpaths)
    assert artifact_bytes(merged, grid) == artifact_bytes(fresh, grid)


# ---------------------------------------------------------------------------
# Kill -> resume through the real CLI
# ---------------------------------------------------------------------------

def _sweep_cli(tmp, *extra):
    env = dict(os.environ, PYTHONPATH=os.path.join(REPO, "src"))
    return subprocess.Popen(
        [sys.executable, "-m", "repro.rms.sweep", "--trace", TRACE,
         "--smoke", *extra],
        cwd=str(tmp), env=env,
        stdout=subprocess.DEVNULL, stderr=subprocess.DEVNULL)


def test_cli_kill_resume_byte_identical(tmp_path):
    """The acceptance lock: SIGKILL a journaled sweep mid-grid, resume it,
    and the final artifact byte-matches a fresh serial run."""
    serial = tmp_path / "serial.json"
    proc = _sweep_cli(tmp_path, "--out", str(serial))
    assert proc.wait(timeout=300) == 0

    jpath = tmp_path / "run.jsonl"
    proc = _sweep_cli(tmp_path, "--journal", str(jpath))
    deadline = time.monotonic() + 300
    while time.monotonic() < deadline:       # wait for >=1 durable row
        if jpath.exists() and len(GridJournal.load(str(jpath))) >= 1:
            break
        if proc.poll() is not None:
            break                            # finished before we killed it
        time.sleep(0.02)
    if proc.poll() is None:
        proc.send_signal(signal.SIGKILL)
        proc.wait(timeout=60)

    resumed = tmp_path / "resumed.json"
    proc = _sweep_cli(tmp_path, "--journal", str(jpath), "--resume",
                      "--out", str(resumed))
    assert proc.wait(timeout=300) == 0
    assert resumed.read_bytes() == serial.read_bytes()
