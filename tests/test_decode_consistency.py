"""Prefill + decode must agree with the full forward pass (fp32)."""
import dataclasses

import jax
import jax.numpy as jnp
import pytest

from repro.configs import list_archs
from repro.models import build_model, get_model, reduced_config

KEY = jax.random.PRNGKey(1)


@pytest.mark.parametrize("arch", list_archs())
def test_prefill_decode_matches_forward(arch):
    _, full = get_model(arch)
    cfg = dataclasses.replace(reduced_config(full), dtype="float32")
    model = build_model(cfg)
    params = model.init(KEY)
    B, S = 2, 32
    toks = jax.random.randint(KEY, (B, S), 0, cfg.vocab_size)
    nf = cfg.frontend_tokens
    if cfg.family == "encdec":
        frames = jax.random.normal(KEY, (B, 16, cfg.d_model))
        full_logits, _ = model.forward(params, frames, toks)
        pre, cache = model.prefill(params, frames, toks[:, :S - 1],
                                   max_len=S)
        dec, _ = model.decode_step(params, cache, toks[:, S - 1:S],
                                   jnp.int32(S - 1))
        off = 0
    elif cfg.frontend:
        fr = jax.random.normal(KEY, (B, nf, cfg.d_model))
        full_logits, _ = model.forward(params, toks, extra_embeds=fr)
        pre, cache = model.prefill(params, toks[:, :S - 1], max_len=S + nf,
                                   extra_embeds=fr)
        dec, _ = model.decode_step(params, cache, toks[:, S - 1:S],
                                   jnp.int32(S - 1 + nf))
        off = nf
    else:
        full_logits, _ = model.forward(params, toks)
        pre, cache = model.prefill(params, toks[:, :S - 1], max_len=S)
        dec, _ = model.decode_step(params, cache, toks[:, S - 1:S],
                                   jnp.int32(S - 1))
        off = 0
    scale = float(jnp.abs(full_logits).max()) + 1e-6
    err_pre = float(jnp.abs(pre[:, 0] - full_logits[:, off + S - 2]).max())
    err_dec = float(jnp.abs(dec[:, 0] - full_logits[:, off + S - 1]).max())
    assert err_pre / scale < 1e-4, f"prefill diverges: {err_pre}"
    assert err_dec / scale < 1e-4, f"decode diverges: {err_dec}"


def test_multi_token_decode_chain():
    """Greedy decode over several steps stays consistent with forward."""
    _, full = get_model("smollm-135m")
    cfg = dataclasses.replace(reduced_config(full), dtype="float32")
    model = build_model(cfg)
    params = model.init(KEY)
    B, S, T = 1, 16, 5
    toks = jax.random.randint(KEY, (B, S + T), 0, cfg.vocab_size)
    full_logits, _ = model.forward(params, toks)
    _, cache = model.prefill(params, toks[:, :S], max_len=S + T)
    for t in range(T):
        dec, cache = model.decode_step(params, cache, toks[:, S + t:S + t + 1],
                                       jnp.int32(S + t))
        err = float(jnp.abs(dec[:, 0] - full_logits[:, S + t]).max())
        assert err < 1e-3, f"step {t}: {err}"
