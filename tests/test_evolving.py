"""EVOLVING jobs (§2 taxonomy): PhaseChange events + phase-aware DMR.

Covers the ISSUE-3 tentpole and its satellite bugfixes:

- deterministic phase schedules from the SWF adapter,
- live band updates visible to the scheduler/DMR check after a
  ``PhaseChange``,
- epoch invalidation of phase predictions across requeues,
- the size-band clamp (``min <= preferred <= max <= cluster``) for trace
  jobs whose recorded size dwarfs the simulated cluster,
- structural invalidation of pending ``ExpandTimeout`` chains on requeue
  (regression: a requeued job's stale resizer-job wait used to survive
  until the next scheduler pass, so the stale timeout could fire and
  record a spurious timed-out action against a job at 0 nodes).
"""
import os

import pytest

from repro.rms import (AppModel, ClusterSimulator, Job, JobPhase, JobState,
                       JobSubmit, PhaseChange, SchedulerConfig, SimConfig)
from repro.workload import (EVOLVING, MalleabilityMix, clamp_band,
                            jobs_from_swf, make_workload, parse_swf)
from synthetic_swf import EVOLVING_MIX, evolving_corpus_jobs

DATA = os.path.join(os.path.dirname(__file__), "data", "sample.swf")


# ---------------------------------------------------------------------------
# Phase schedules from workload generation
# ---------------------------------------------------------------------------

def evolving_mix():
    return MalleabilityMix(*EVOLVING_MIX)


def test_phase_schedules_deterministic():
    trace = parse_swf(DATA)
    a, apps_a = jobs_from_swf(trace, num_nodes=64, mix=evolving_mix(),
                              seed=7)
    b, apps_b = jobs_from_swf(trace, num_nodes=64, mix=evolving_mix(),
                              seed=7)
    assert any(j.phases for j in a)
    for ja, jb in zip(a, b):
        assert ja.phases == jb.phases
        assert apps_a[ja.app].phases == apps_b[jb.app].phases


def test_evolving_jobs_get_consistent_phases():
    trace = parse_swf(DATA)
    jobs, apps = jobs_from_swf(trace, num_nodes=64, mix=evolving_mix(),
                               seed=7)
    evolving = [j for j in jobs if j.phases]
    n = len(jobs)
    assert abs(len(evolving) - EVOLVING_MIX[3] * n) <= 1
    for j in evolving:
        app = apps[j.app]
        assert j.malleable
        assert j.phases == app.phases
        assert 2 <= len(j.phases) <= 4
        # phase works sum to the job's total work
        assert sum(ph.work for ph in j.phases) == pytest.approx(j.work)
        for ph in j.phases:
            assert 1 <= ph.min_nodes <= ph.preferred <= ph.max_nodes <= 64
        # the live band starts at phase 0; the app holds the envelope
        ph0 = j.phases[0]
        assert (j.min_nodes, j.max_nodes, j.preferred) == \
            (ph0.min_nodes, ph0.max_nodes, ph0.preferred)
        assert app.min_nodes == min(ph.min_nodes for ph in j.phases)
        assert app.max_nodes == max(ph.max_nodes for ph in j.phases)


def test_make_workload_evolving_fraction():
    jobs = make_workload(40, seed=7, evolving_fraction=0.5)
    evolving = [j for j in jobs if j.phases]
    assert 5 < len(evolving) < 35          # ~50% by coin flip
    for j in evolving:
        assert j.malleable
        assert sum(ph.work for ph in j.phases) == pytest.approx(j.work)
    # the historic draws are untouched: fraction 0 reproduces the old
    # workload bit-for-bit
    base = make_workload(40, seed=7)
    again = make_workload(40, seed=7, evolving_fraction=0.0)
    assert [(j.submit_time, j.app, j.user) for j in base] == \
        [(j.submit_time, j.app, j.user) for j in again]


# ---------------------------------------------------------------------------
# PhaseChange handler: live band + forced DMR check
# ---------------------------------------------------------------------------

def two_phase_job(*, work=200.0, p0=(4, 4, 4), p1=(1, 2, 2)):
    phases = (JobPhase(work=work / 2, min_nodes=p0[0], max_nodes=p0[1],
                       preferred=p0[2], serial_frac=0.0),
              JobPhase(work=work / 2, min_nodes=p1[0], max_nodes=p1[1],
                       preferred=p1[2], serial_frac=0.0))
    app = AppModel("evo", iterations=int(work), t1_iter_s=4.0,
                   serial_frac=0.0, data_bytes=1 << 20, min_nodes=1,
                   max_nodes=4, preferred=None, check_period_s=5.0,
                   phases=phases)
    job = Job(job_id=0, app="evo", submit_time=0.0, work=work,
              min_nodes=p0[0], max_nodes=p0[1], preferred=p0[2], factor=2,
              malleable=True, check_period_s=5.0, requested_nodes=p0[2],
              data_bytes=1 << 20, phases=phases)
    return job, {"evo": app}


def test_phase_change_updates_live_band_and_forces_shrink():
    """Entering a phase whose max is below the current allocation must
    update the live band and trigger an immediate DMR shrink (§4.1
    requested-shrink semantics), not wait for the next periodic check."""
    job, apps = two_phase_job()
    sim = ClusterSimulator([job], SimConfig(num_nodes=8, flexible=True,
                                            checkpoint_period_s=0.0),
                           apps=apps)
    rep = sim.run()
    assert job.state is JobState.COMPLETED
    pcs = [a for a in rep.actions if a.action == "phase_change"]
    assert len(pcs) == 1                       # one boundary, applied once
    t_pc = pcs[0].t
    # live band rewritten to phase 1
    assert (job.min_nodes, job.max_nodes, job.preferred) == (1, 2, 2)
    assert job.requested_nodes <= 2            # requeue restart stays in band
    # the forced check shrank the job out of the out-of-band size 4
    shrinks = [a for a in rep.actions
               if a.action == "shrink" and a.t >= t_pc]
    assert shrinks and shrinks[0].t == pytest.approx(t_pc)
    assert shrinks[0].from_nodes == 4 and shrinks[0].to_nodes == 2
    assert shrinks[0].reason == "requested-shrink"


def test_phase_change_expand_demand_met_when_free():
    """A phase that raises the demand floor above the current size expands
    at the forced check when nodes are free."""
    job, apps = two_phase_job(p0=(2, 2, 2), p1=(4, 8, 8))
    sim = ClusterSimulator([job], SimConfig(num_nodes=8, flexible=True,
                                            checkpoint_period_s=0.0),
                           apps=apps)
    rep = sim.run()
    assert job.state is JobState.COMPLETED
    t_pc = next(a.t for a in rep.actions if a.action == "phase_change")
    expands = [a for a in rep.actions
               if a.action == "expand" and not a.timed_out and a.t >= t_pc]
    assert expands and expands[0].from_nodes == 2
    assert expands[0].to_nodes == 4            # one factor step toward min
    assert expands[0].reason == "requested-expand"


def test_phase_band_visible_to_scheduler_next_pass():
    """After a shrinking phase change, the freed nodes start a queued job
    on the very next pass — the scheduler saw the live band, not the
    submission-time one."""
    job, apps = two_phase_job()
    rigid_app = AppModel("r6", iterations=50, t1_iter_s=6.0,
                         serial_frac=0.0, data_bytes=0, min_nodes=6,
                         max_nodes=6, preferred=None, check_period_s=0.0)
    apps["r6"] = rigid_app
    queued = Job(job_id=1, app="r6", submit_time=1.0, work=50.0,
                 min_nodes=6, max_nodes=6, preferred=None, malleable=False,
                 requested_nodes=6)
    sim = ClusterSimulator([job, queued],
                           SimConfig(num_nodes=8, flexible=True,
                                     checkpoint_period_s=0.0), apps=apps)
    rep = sim.run()
    assert all(j.state is JobState.COMPLETED for j in rep.jobs)
    t_pc = next(a.t for a in rep.actions if a.action == "phase_change")
    # 8 nodes: evolving job holds 4, queued needs 6 -> blocked until the
    # phase-1 shrink to 2 frees capacity at the forced check
    assert queued.start_time >= t_pc
    assert queued.start_time == pytest.approx(t_pc, abs=1.0)


def test_phase_epoch_invalidated_after_requeue():
    """A requeue mid-phase kills the in-flight PhaseChange prediction; the
    restart re-predicts from preserved progress, and each boundary is still
    applied exactly once."""
    job, apps = two_phase_job()
    # fail one of the job's nodes early: survivors 3 >= min 4 is false ->
    # requeue + checkpoint restart
    cfg = SimConfig(num_nodes=8, flexible=True, checkpoint_period_s=0.0,
                    failures=((20.0, 0),))
    sim = ClusterSimulator([job], cfg, apps=apps)
    fired = []
    sim.engine.on(PhaseChange, lambda ev: fired.append(
        (ev.t, ev.epoch, ev.phase)))
    rep = sim.run()
    assert any(a.action in ("failure_requeue", "failure_shrink")
               for a in rep.actions)
    assert job.state is JobState.COMPLETED
    applied = [a for a in rep.actions if a.action == "phase_change"]
    assert len(applied) == 1                   # boundary applied exactly once
    # stale predictions (scheduled pre-requeue) fired but died at the
    # epoch guard: every *applied* event's epoch was live at dispatch
    assert len(fired) >= 1
    assert job.phase_index == 1


def test_phase_change_not_applied_before_boundary_reached():
    """A straggler slows the job after the PhaseChange prediction is made
    (StragglerOnset reschedules nothing); the stale event must re-predict
    instead of entering the phase with phase-0 work remaining."""
    job, apps = two_phase_job(p0=(4, 4, 4), p1=(1, 2, 2))
    # 4-node cluster: the job owns every node, so the straggler cannot be
    # swapped out and the 1/3 rate persists
    cfg = SimConfig(num_nodes=4, flexible=True, checkpoint_period_s=0.0,
                    stragglers=((50.0, 0, 3.0),))
    sim = ClusterSimulator([job], cfg, apps=apps)
    rep = sim.run()
    assert job.state is JobState.COMPLETED
    pcs = [a for a in rep.actions if a.action == "phase_change"]
    assert len(pcs) == 1
    # unslowed prediction lands ~t=101; the real boundary (49 work done by
    # t=50, then 51 more at 1/3 rate) is ~t=203
    assert pcs[0].t > 150.0


def test_requeue_checkpoint_rewind_resyncs_phase():
    """A checkpoint restore that rewinds work into an earlier phase must
    also rewind the live phase, and the skipped transition re-fires as the
    replayed work crosses the boundary again.

    The bands are identical so the phase change triggers no resize — a
    resize would refresh the restore point and defeat the rewind; the
    phases differ in serial fraction only (rate changes per phase).
    """
    phases = (JobPhase(work=100.0, min_nodes=4, max_nodes=4, preferred=4,
                       serial_frac=0.0),
              JobPhase(work=100.0, min_nodes=4, max_nodes=4, preferred=4,
                       serial_frac=0.5))
    app = AppModel("evo2", iterations=200, t1_iter_s=4.0, serial_frac=0.0,
                   data_bytes=1 << 20, min_nodes=4, max_nodes=4,
                   preferred=None, check_period_s=5.0, phases=phases)
    job = Job(job_id=0, app="evo2", submit_time=0.0, work=200.0,
              min_nodes=4, max_nodes=4, preferred=4, factor=2,
              malleable=True, check_period_s=5.0, requested_nodes=4,
              data_bytes=1 << 20, phases=phases)
    # no checkpoint refresh (period 0, no resizes): the restore point stays
    # at start (work 0); failing one of the job's 4 nodes after the phase-1
    # boundary leaves 3 survivors < min 4 -> requeue + full rewind
    cfg = SimConfig(num_nodes=8, flexible=True, checkpoint_period_s=0.0,
                    failures=((150.0, 0),))
    sim = ClusterSimulator([job], cfg, apps={"evo2": app})
    rep = sim.run()
    requeues = [a for a in rep.actions if a.action == "failure_requeue"]
    assert requeues, "scenario must exercise the requeue path"
    t_rq = requeues[0].t
    pcs = [a for a in rep.actions if a.action == "phase_change"]
    # boundary crossed once before the failure and again after the rewind
    assert len(pcs) == 2
    assert pcs[0].t < t_rq < pcs[1].t
    assert job.state is JobState.COMPLETED
    assert job.phase_index == 1


# ---------------------------------------------------------------------------
# Satellite: size-band clamp (min <= preferred <= max <= cluster)
# ---------------------------------------------------------------------------

def test_clamp_band_pins_invariant():
    assert clamp_band(64, 32, 48, 32) == (32, 32, 32)   # inverted input
    assert clamp_band(2, 8, 16, 64) == (2, 8, 8)        # preferred above max
    assert clamp_band(0, 0, None, 64) == (1, 1, None)   # degenerate
    lo, hi, pref = clamp_band(1, 512, 256, 48)
    assert 1 <= lo <= pref <= hi <= 48


@pytest.mark.parametrize("num_nodes", [3, 20, 48, 64])
def test_trace_bands_never_invert_on_small_clusters(num_nodes):
    """Regression (ISSUE 3 satellite): trace jobs whose recorded size
    exceeds the simulated cluster (e.g. 256 procs replayed on 48 nodes)
    must still get a satisfiable band for every annotation kind."""
    lines = ["; MaxNodes: 512"]
    for i, procs in enumerate([1, 5, 48, 96, 256, 300, 512], start=1):
        lines.append(f"{i} {10 * i} 0 600 {procs} -1 -1 {procs} 900 -1 1 "
                     f"{i} 1 1 1 1 -1 -1")
    trace = parse_swf(lines)
    mix = MalleabilityMix(rigid=0.25, moldable=0.25, malleable=0.25,
                          evolving=0.25)
    jobs, apps = jobs_from_swf(trace, num_nodes=num_nodes, mix=mix, seed=3)
    for j in jobs:
        app = apps[j.app]
        pref = j.preferred if j.preferred is not None else j.requested_nodes
        assert 1 <= j.min_nodes <= pref <= j.max_nodes <= num_nodes
        assert j.min_nodes <= j.requested_nodes <= j.max_nodes
        assert app.min_nodes <= app.max_nodes <= num_nodes
        for ph in j.phases:
            assert 1 <= ph.min_nodes <= ph.preferred <= ph.max_nodes \
                <= num_nodes


# ---------------------------------------------------------------------------
# Satellite: requeue structurally invalidates pending ExpandTimeouts
# ---------------------------------------------------------------------------

def test_requeue_invalidates_pending_expand_timeout():
    """Regression: requeueing a job with a pending resizer-job wait must
    void the wait *and* its scheduled ExpandTimeout.  Pre-fix, the wait
    entry survived until the next scheduler pass, so the stale timeout
    matched it and recorded a spurious timed-out action against a job that
    holds zero nodes."""
    apps = {
        "grow": AppModel("grow", iterations=300, t1_iter_s=2.0,
                         serial_frac=0.0, data_bytes=1 << 20, min_nodes=2,
                         max_nodes=8, preferred=8, check_period_s=5.0),
        "wall": AppModel("wall", iterations=200, t1_iter_s=6.0,
                         serial_frac=0.0, data_bytes=0, min_nodes=6,
                         max_nodes=6, preferred=None, check_period_s=0.0),
    }
    grower = Job(job_id=0, app="grow", submit_time=0.0, work=300.0,
                 min_nodes=2, max_nodes=8, preferred=8, malleable=True,
                 check_period_s=5.0, requested_nodes=2, data_bytes=1 << 20)
    wall = Job(job_id=1, app="wall", submit_time=8.0, work=200.0,
               min_nodes=6, max_nodes=6, preferred=None, malleable=False,
               requested_nodes=6)
    cfg = SimConfig(num_nodes=8, flexible=True, scheduling="async",
                    checkpoint_period_s=0.0, expand_timeout_s=40.0)
    sim = ClusterSimulator([grower, wall], cfg, apps=apps)
    # drive the engine manually (instead of sim.run()) so the requeue can
    # land at the pathological moment: wait pending, timeout scheduled
    for j in sim.jobs:
        sim.engine.schedule(JobSubmit(j.submit_time, j.job_id))
    guard = 0
    while not sim._waiting_expands:
        assert sim.engine.step(), "never reached a waiting expand"
        guard += 1
        assert guard < 10_000
    t_requeue = sim.now
    # the preemption path's requeue (what _apply_preemption does for a
    # victim stuck at its minimum size)
    sim._requeue(grower, "preempt_requeue", grower.nodes,
                 "head-reservation-slip")
    # the resizer-job reservation is dropped immediately, not next pass
    assert sim.cluster.allocation(-(grower.job_id + 1)) == 0
    assert not sim._waiting_expands
    sim.engine.run()
    # no spurious timeout fired against the requeued (0-node) job
    spurious = [a for a in sim.actions
                if a.timed_out and a.t > t_requeue and a.from_nodes == 0]
    assert spurious == []
    # and the workload still drains: the grower restarted and finished
    assert grower.state is JobState.COMPLETED
    assert wall.state is JobState.COMPLETED


# ---------------------------------------------------------------------------
# End-to-end: evolving corpus drains under every policy
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("policy", ["easy", "malleable", "preempt",
                                    "moldable", "fairshare"])
def test_evolving_corpus_replay_completes(policy):
    jobs, apps = evolving_corpus_jobs(40)
    rep = ClusterSimulator(
        jobs, SimConfig(num_nodes=64, flexible=True,
                        sched=SchedulerConfig(policy=policy)),
        apps=apps).run()
    assert all(j.state is JobState.COMPLETED for j in rep.jobs)
    assert any(a.action == "phase_change" for a in rep.actions)
    assert max(e[1] for e in rep.timeline) <= 64
