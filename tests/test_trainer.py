"""Elastic trainer: loss descends, checkpoint-restart resumes."""
import dataclasses

import jax
import pytest

from repro.data import DataConfig
from repro.models import build_model, get_model, reduced_config
from repro.optim import AdamWConfig
from repro.runtime import ElasticTrainer, TrainerConfig


def make(steps=60, **kw):
    _, full = get_model("smollm-135m")
    cfg = reduced_config(full)
    model = build_model(cfg)
    data = DataConfig(vocab_size=cfg.vocab_size, seq_len=64, global_batch=8)
    opt = AdamWConfig(lr=3e-3, warmup_steps=5, total_steps=steps)
    return ElasticTrainer(model, opt, data,
                          TrainerConfig(steps=steps, model_ways=1,
                                        max_slices=1, log_period=10, **kw))


@pytest.mark.slow
def test_loss_descends():
    tr = make(steps=120)
    tr.train()
    first = tr.metrics[0]["loss"]
    last = tr.metrics[-1]["loss"]
    assert last < first - 0.3, (first, last)


@pytest.mark.slow
def test_checkpoint_resume(tmp_path):
    tr = make(steps=40, ckpt_dir=str(tmp_path), ckpt_period=20)
    state = tr.train()
    assert tr.store.latest_step() == 40
    # resume into a new trainer from the checkpoint
    tr2 = make(steps=50, ckpt_dir=str(tmp_path), ckpt_period=20)
    template = tr2.init_state()
    restored = tr2.store.restore(40, template,
                                 tr2._state_shardings(tr2.mesh))
    assert int(restored["step"]) == 40
    out = tr2.train(state=restored)
    assert int(out["step"]) == 50


@pytest.mark.slow
def test_grad_accum_equivalence():
    """accum=2 must match accum=1 on the same global batch (fp32)."""
    import jax.numpy as jnp
    _, full = get_model("smollm-135m")
    cfg = dataclasses.replace(reduced_config(full), dtype="float32")
    model = build_model(cfg)
    data = DataConfig(vocab_size=cfg.vocab_size, seq_len=32, global_batch=8)
    opt = AdamWConfig(lr=1e-3, warmup_steps=0, total_steps=10)

    def run(accum):
        tr = ElasticTrainer(model, opt, data,
                            TrainerConfig(steps=5, model_ways=1,
                                          max_slices=1, grad_accum=accum,
                                          log_period=1))
        tr.train()
        return [m["loss"] for m in tr.metrics]

    l1, l2 = run(1), run(2)
    assert max(abs(a - b) for a, b in zip(l1, l2)) < 5e-3
