"""Runtime sanitizer (:mod:`repro.rms.sanitizer`): mutation suite.

Each mutation test monkeypatches one deliberate bug into the simulator or
cluster — a bug class the sanitizer exists to catch — runs a small
scenario in checked mode, and asserts a :class:`SanitizerError` naming
exactly the violated invariant:

1. double-decrement node accounting on failure -> ``node_conservation``
2. recycling a quarantined (slow) node into the free list
   -> ``quarantine_routing``
3. reusing a stale check-chain epoch after a requeue
   -> ``duplicate_check_chain``
4. corrupting fairshare node-second billing -> ``fairshare_billing``
5. inverting a phase band on application -> ``band_order``
6. scheduling a completion without bumping the version
   -> ``completion_version``
7. leaking serving backlog on a preemption shrink
   -> ``serving_conservation``
8. reusing a stale traffic-tick epoch after a serving requeue
   -> ``duplicate_check_chain``

Plus the clean-mode contract: a sanitized run of the capacity-churn
golden scenario reports zero violations and produces byte-identical
artifacts to the unsanitized run, and the fairshare shadow ledger stays
in agreement through a serving job's SLO-driven resizes.
"""
import dataclasses
import json

import pytest

import test_capacity
from repro.rms.cluster import Cluster
from repro.rms.engine import JobFinish
from repro.rms.job import Job, JobPhase
from repro.rms.costmodel import AppModel
from repro.rms.sanitizer import SanitizerError, SimSanitizer
from repro.rms.scheduler import FairSharePolicy, SchedulerConfig
from repro.rms.simulator import ClusterSimulator, SimConfig
from repro.workload.traffic import DiurnalCurve, TrafficSpec


def make_app(name, lo, hi, preferred=None, check_period_s=15.0, phases=()):
    return AppModel(name, iterations=400, t1_iter_s=2.0, serial_frac=0.0,
                    data_bytes=1 << 20, min_nodes=lo, max_nodes=hi,
                    preferred=preferred, check_period_s=check_period_s,
                    phases=phases)


def make_job(n, *, lo=None, hi=None, work=400.0, submit=0.0, job_id=0,
             malleable=False, user=0, phases=()):
    lo = n if lo is None else lo
    hi = n if hi is None else hi
    return Job(job_id=job_id, app="app", submit_time=submit, work=work,
               min_nodes=lo, max_nodes=hi, preferred=None, factor=2,
               malleable=malleable, check_period_s=15.0,
               requested_nodes=n, data_bytes=1 << 20, user=user,
               phases=phases)


def make_traffic(base_rps, *, duration=120.0, bursts=(), noise=0.0,
                 amplitude=0.0, seed=5):
    curve = DiurnalCurve(base_rps=base_rps, amplitude=amplitude,
                         period_s=duration, phase_s=0.0,
                         bursts=tuple(bursts))
    return TrafficSpec(curve=curve, seed=seed, t0=0.0, duration_s=duration,
                       slo_p99_s=2.0, bucket_s=30.0, noise=noise)


def make_serving_job(n, spec, *, lo=2, hi=8, job_id=0, user=0):
    return Job(job_id=job_id, app="api", submit_time=0.0, work=0.0,
               min_nodes=lo, max_nodes=hi, preferred=n, factor=2,
               malleable=True, check_period_s=5.0, requested_nodes=n,
               data_bytes=1 << 20, user=user, traffic=spec)


def make_serving_app(lo=2, hi=8):
    # drains ~1 req/s per node (t1_iter_s=1, perfectly parallel)
    return AppModel("api", iterations=1, t1_iter_s=1.0, serial_frac=0.0,
                    data_bytes=1 << 20, min_nodes=lo, max_nodes=hi,
                    preferred=None, check_period_s=5.0)


def run_sanitized(jobs, cfg, apps):
    cfg = dataclasses.replace(cfg, sanitize=True)
    sim = ClusterSimulator(jobs, cfg, apps=apps)
    assert sim.sanitizer is not None
    sim.run()
    return sim


# ---------------------------------------------------------------------------
# Mutation 1: lose a node on failure accounting
# ---------------------------------------------------------------------------

def test_catches_node_conservation_break(monkeypatch):
    inner = Cluster.fail_node

    def leaky_fail(self, node):
        out = inner(self, node)
        if self.free:
            self.free.pop()        # bug: a second node silently vanishes
        return out

    monkeypatch.setattr(Cluster, "fail_node", leaky_fail)
    cfg = SimConfig(num_nodes=4, flexible=False, sanitize=True,
                    failures=((10.0, 3),))
    sim = ClusterSimulator([make_job(2)], cfg, apps={"app": make_app(
        "app", 2, 2)})
    with pytest.raises(SanitizerError) as err:
        sim.run()
    assert err.value.invariant == "node_conservation"
    # the error is structured: event, sim time, and detail ride along
    assert err.value.t == pytest.approx(10.0)
    assert type(err.value.event).__name__ == "NodeFail"
    assert "nodes_ever_joined" in err.value.detail


# ---------------------------------------------------------------------------
# Mutation 2: recycle a quarantined node into the free list
# ---------------------------------------------------------------------------

def test_catches_slow_node_in_free_pool(monkeypatch):
    def careless_route(self, nodes):
        for node in nodes:
            self._drain_pending.discard(node)
            self.free.append(node)   # bug: ignores quarantine routing

    monkeypatch.setattr(Cluster, "_route_released", careless_route)
    cfg = SimConfig(num_nodes=4, flexible=False, sanitize=True,
                    stragglers=((20.0, 1, 2.0),))
    sim = ClusterSimulator([make_job(2)], cfg, apps={"app": make_app(
        "app", 2, 2)})
    with pytest.raises(SanitizerError) as err:
        sim.run()
    assert err.value.invariant == "quarantine_routing"


# ---------------------------------------------------------------------------
# Mutation 3: requeue forgets to retire the check-chain epoch
# ---------------------------------------------------------------------------

def test_catches_duplicate_check_chain_after_requeue(monkeypatch):
    inner = ClusterSimulator._requeue

    def stale_epoch_requeue(self, job, action, from_nodes, reason):
        inner(self, job, action, from_nodes, reason)
        # bug: roll the epoch back so the restart re-derives the epoch of
        # the still-pending chain instead of a fresh one
        self._reconfig_epoch[job.job_id] -= 1

    monkeypatch.setattr(ClusterSimulator, "_requeue", stale_epoch_requeue)
    # min == nodes: one failed node forces a requeue; 7 survivors in the
    # pool let the scheduler restart the job within the same event, which
    # schedules a second ReconfigPoint chain under the stale epoch.
    cfg = SimConfig(num_nodes=8, flexible=True, sanitize=True,
                    failures=((10.0, 0),))
    sim = ClusterSimulator([make_job(4, malleable=True)], cfg,
                           apps={"app": make_app("app", 4, 4)})
    with pytest.raises(SanitizerError) as err:
        sim.run()
    assert err.value.invariant == "duplicate_check_chain"


# ---------------------------------------------------------------------------
# Mutation 4: fairshare billing corruption
# ---------------------------------------------------------------------------

def test_catches_fairshare_billing_drift(monkeypatch):
    monkeypatch.setattr(FairSharePolicy, "_node_seconds",
                        staticmethod(lambda job, a, b: 0.0))  # bills nothing
    cfg = SimConfig(num_nodes=8, flexible=False, sanitize=True,
                    sched=SchedulerConfig(policy="fairshare"))
    jobs = [make_job(2, work=100.0, job_id=0, user=0),
            make_job(2, work=100.0, submit=30.0, job_id=1, user=1)]
    sim = ClusterSimulator(jobs, cfg, apps={"app": make_app("app", 2, 2)})
    with pytest.raises(SanitizerError) as err:
        sim.run()
    assert err.value.invariant == "fairshare_billing"


# ---------------------------------------------------------------------------
# Mutation 5: phase band applied inverted
# ---------------------------------------------------------------------------

def test_catches_inverted_phase_band(monkeypatch):
    inner = ClusterSimulator._apply_phase_band

    def inverted_band(self, job, phase_idx, min_nodes, max_nodes,
                      preferred):
        inner(self, job, phase_idx, min_nodes, max_nodes, preferred)
        job.min_nodes, job.max_nodes = job.max_nodes, job.min_nodes

    monkeypatch.setattr(ClusterSimulator, "_apply_phase_band",
                        inverted_band)
    phases = (JobPhase(work=100.0, min_nodes=4, max_nodes=4, preferred=4,
                       serial_frac=0.0),
              JobPhase(work=100.0, min_nodes=1, max_nodes=2, preferred=2,
                       serial_frac=0.0))
    app = make_app("app", 1, 4, check_period_s=5.0, phases=phases)
    job = make_job(4, lo=4, hi=4, work=200.0, malleable=True,
                   phases=phases)
    cfg = SimConfig(num_nodes=4, flexible=True, sanitize=True)
    sim = ClusterSimulator([job], cfg, apps={"app": app})
    with pytest.raises(SanitizerError) as err:
        sim.run()
    assert err.value.invariant == "band_order"


# ---------------------------------------------------------------------------
# Mutation 6: completion rescheduled without a version bump
# ---------------------------------------------------------------------------

def test_catches_missing_completion_version_bump(monkeypatch):
    def unversioned_completion(self, job):
        remaining = max(job.work - job.work_done, 0.0)
        t0 = max(self.now, job.paused_until)
        self.engine.schedule(JobFinish(t0 + remaining / self._rate(job),
                                       job.job_id, job.completion_version))
        self._schedule_phase_change(job, t0)

    monkeypatch.setattr(ClusterSimulator, "_schedule_completion",
                        unversioned_completion)
    # the failure shrink re-schedules completion: without the bump the old
    # pending JobFinish shares the new one's version
    cfg = SimConfig(num_nodes=8, flexible=True, sanitize=True,
                    failures=((10.0, 0),))
    sim = ClusterSimulator([make_job(4, lo=2, hi=4, malleable=True)], cfg,
                           apps={"app": make_app("app", 2, 4)})
    with pytest.raises(SanitizerError) as err:
        sim.run()
    assert err.value.invariant == "completion_version"


# ---------------------------------------------------------------------------
# Mutation 7: serving backlog leaks on a preemption shrink
# ---------------------------------------------------------------------------

def test_catches_serving_backlog_leak_on_shrink(monkeypatch):
    inner = ClusterSimulator._apply_preemption

    def leaky_preempt(self, job, new):
        inner(self, job, new)
        if job.traffic is not None and new > 0:
            self._backlog[job.job_id] *= 0.5   # bug: requests vanish

    monkeypatch.setattr(ClusterSimulator, "_apply_preemption",
                        leaky_preempt)
    # Sustained overload (10 rps vs 8 nodes x 1 rps) piles up backlog;
    # the 6-node batch head submitted at t=10 outranks the serving job
    # (size bias beats 10 s of age) and its reservation slips past the
    # grace window, so the preempt policy shrinks the serving job 8 -> 4
    # mid-backlog.  The leak breaks arrivals == backlog + served at the
    # very next checked event.
    spec = make_traffic(10.0, duration=300.0)
    serving = make_serving_job(8, spec)
    head = make_job(6, submit=10.0, job_id=1, work=150.0)
    cfg = SimConfig(num_nodes=10, flexible=True, sanitize=True,
                    sched=SchedulerConfig(policy="preempt"))
    sim = ClusterSimulator([serving, head], cfg,
                           apps={"api": make_serving_app(),
                                 "app": make_app("app", 6, 6)})
    with pytest.raises(SanitizerError) as err:
        sim.run()
    assert err.value.invariant == "serving_conservation"
    assert "arrivals" in err.value.detail


# ---------------------------------------------------------------------------
# Mutation 8: serving requeue leaves the traffic-tick chain epoch live
# ---------------------------------------------------------------------------

def test_catches_stale_traffic_tick_chain_after_requeue(monkeypatch):
    inner = ClusterSimulator._requeue

    def stale_tick_requeue(self, job, action, from_nodes, reason):
        inner(self, job, action, from_nodes, reason)
        if job.traffic is not None:
            # bug: roll back both the requeue bump and (pre-compensating)
            # the restart's bump, so the restarted TrafficTick chain
            # re-derives the epoch of the still-pending old chain
            self._traffic_epoch[job.job_id] -= 2

    monkeypatch.setattr(ClusterSimulator, "_requeue", stale_tick_requeue)
    # min == nodes: the t=7 failure forces a requeue before the first
    # traffic tick (t=10) fires; survivors let the restart happen within
    # the same event, scheduling a second tick chain under the stale
    # epoch — two live chains for one job.
    spec = make_traffic(2.0)
    serving = make_serving_job(4, spec, lo=4, hi=4)
    cfg = SimConfig(num_nodes=8, flexible=True, sanitize=True,
                    failures=((7.0, 0),))
    sim = ClusterSimulator([serving], cfg,
                           apps={"api": make_serving_app(4, 4)})
    with pytest.raises(SanitizerError) as err:
        sim.run()
    assert err.value.invariant == "duplicate_check_chain"
    assert "traffic" in err.value.detail


# ---------------------------------------------------------------------------
# Clean mode: zero violations, byte-identical artifacts
# ---------------------------------------------------------------------------

def test_clean_churn_run_has_zero_violations_and_identical_bytes(
        monkeypatch):
    plain, _ = test_capacity.run_bytes()
    monkeypatch.setenv("REPRO_SANITIZE", "1")
    sim = test_capacity.churn_scenario()
    assert sim.sanitizer is not None
    report = sim.run()
    checked = json.dumps(test_capacity.serialize(report), indent=1,
                         sort_keys=True).encode()
    assert sim.sanitizer.checks == sim.engine.dispatched
    assert checked == plain


def test_sanitize_opt_in_paths(monkeypatch):
    monkeypatch.delenv("REPRO_SANITIZE", raising=False)
    jobs = [make_job(2, work=10.0)]
    apps = {"app": make_app("app", 2, 2)}
    off = ClusterSimulator(jobs, SimConfig(num_nodes=4), apps=apps)
    assert off.sanitizer is None and off.engine.monitor is None
    flag = ClusterSimulator([make_job(2, work=10.0)],
                            SimConfig(num_nodes=4, sanitize=True),
                            apps=apps)
    assert isinstance(flag.sanitizer, SimSanitizer)
    assert flag.engine.monitor is flag.sanitizer
    monkeypatch.setenv("REPRO_SANITIZE", "0")   # explicit off
    zero = ClusterSimulator([make_job(2, work=10.0)],
                            SimConfig(num_nodes=4), apps=apps)
    assert zero.sanitizer is None
    monkeypatch.setenv("REPRO_SANITIZE", "1")
    env = ClusterSimulator([make_job(2, work=10.0)],
                           SimConfig(num_nodes=4), apps=apps)
    assert isinstance(env.sanitizer, SimSanitizer)


def test_fairshare_clean_run_under_sanitizer():
    """The shadow ledger must agree with the real one on a healthy run
    (several passes, a resize-free mixed workload, two users)."""
    cfg = SimConfig(num_nodes=8, flexible=False, sanitize=True,
                    sched=SchedulerConfig(policy="fairshare"))
    jobs = [make_job(2, work=100.0, job_id=0, user=0),
            make_job(2, work=100.0, submit=30.0, job_id=1, user=1),
            make_job(4, work=50.0, submit=60.0, job_id=2, user=0)]
    sim = ClusterSimulator(jobs, cfg, apps={"app": make_app("app", 2, 4)})
    sim.run()                      # no SanitizerError
    assert sim.sanitizer.checks > 0
    assert sim.scheduler.policy._usage    # billing actually happened


def test_fairshare_clean_run_with_serving_resizes():
    """The shadow ledger must also track a serving job through its
    SLO-driven resizes: every expand/shrink changes the node-seconds
    slope mid-flight, which is exactly where billing drift would hide.
    Two users (serving vs batch) keep the fairshare penalty live."""
    spec = make_traffic(2.5, duration=600.0, amplitude=0.2, noise=0.1,
                        bursts=((90.0, 60.0, 6.0),))
    jobs = [make_serving_job(4, spec, user=0),
            make_job(2, work=100.0, submit=30.0, job_id=1, user=1),
            make_job(4, work=50.0, submit=60.0, job_id=2, user=1)]
    cfg = SimConfig(num_nodes=10, flexible=True,
                    sched=SchedulerConfig(policy="fairshare"))
    sim = run_sanitized(jobs, cfg,
                        {"api": make_serving_app(),
                         "app": make_app("app", 2, 4)})
    assert sim.sanitizer.checks > 0
    assert sim.scheduler.policy._usage
    # the serving job actually resized under the sanitizer's eye
    assert any(a.job_id == 0 and a.action in ("expand", "shrink")
               for a in sim.actions)
