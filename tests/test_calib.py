"""Measured-cost calibration subsystem: measure → fit → artifact → consume.

The acceptance lock: re-measuring the deterministic CI grid, re-fitting,
and re-serializing reproduces ``tests/data/golden_calibration.json``
byte-for-byte, and a sweep run under the fitted model records the
artifact's ``calibration_id`` in its (schema v3) rows.

Regenerate the golden file (after an *intentional* grid/fitter change):

    PYTHONPATH=src:tests python -c \\
        "import test_calib as t; t.write_golden()"
"""
import copy
import json
import os

import pytest

from repro.calib import (FitError, MeasureConfig, calibrate,
                         dumps_calibration, fit_samples, load_calibration,
                         measure_grid, validate_calibration)
from repro.calib.artifact import content_id
from repro.calib.measure import (MiB, PLAN_NOISE_SIGMA, TRUE_PARAMS,
                                 resize_features)
from repro.rms.costmodel import ReconfigCostModel

DATA = os.path.join(os.path.dirname(__file__), "data")
GOLDEN = os.path.join(DATA, "golden_calibration.json")


def golden_doc():
    return load_calibration(GOLDEN)


def write_golden():
    from repro.calib import write_calibration
    write_calibration(GOLDEN, calibrate(MeasureConfig()))


# -- the golden round trip ---------------------------------------------------

def test_measure_fit_reproduces_golden_artifact_bytes():
    """Acceptance lock #1: the CI grid round trip is byte-deterministic."""
    doc = calibrate(MeasureConfig())
    with open(GOLDEN) as fh:
        assert dumps_calibration(doc) == fh.read()


def test_refit_from_golden_samples_reproduces_fitted_params():
    """Fitting the *stored* samples reproduces the stored fit exactly —
    the artifact is self-consistent, not just a cached pair."""
    doc = golden_doc()
    fitted, residuals, checks = fit_samples(doc["samples"])
    assert fitted == doc["fitted"]
    assert residuals == doc["residuals"]
    assert checks == doc["checks"]


def test_fit_recovers_hidden_truth_within_tolerance():
    """The plan backend's noise is 3%: the fit must land within 5% of the
    ground-truth parameters it was generated from (so it cannot be just
    echoing the paper defaults, which are further away)."""
    f = golden_doc()["fitted"]
    for key, tol in (("link_bw", 0.05), ("spawn_s", 0.05),
                     ("shrink_sync_s", 0.10), ("sched_base_s", 0.05),
                     ("sched_per_node_s", 0.25)):
        rel = abs(f[key] - TRUE_PARAMS[key]) / TRUE_PARAMS[key]
        assert rel <= tol, f"{key}: fitted {f[key]} vs true " \
                           f"{TRUE_PARAMS[key]} (rel err {rel:.3f})"


def test_golden_checks_and_diagnostics():
    doc = golden_doc()
    assert doc["backend"] == "plan"
    assert all(doc["checks"].values())
    assert doc["residuals"]["resize_r2"] > 0.99
    assert doc["residuals"]["n_resize"] > 0
    assert doc["paper_defaults"]["link_bw"] == ReconfigCostModel().link_bw


# -- artifact schema / integrity ---------------------------------------------

def test_load_rejects_foreign_schema_version_and_tampering(tmp_path):
    doc = golden_doc()
    bad = copy.deepcopy(doc)
    bad["schema"] = "nope"
    with pytest.raises(ValueError, match="not a calibration artifact"):
        validate_calibration(bad)
    bad = copy.deepcopy(doc)
    bad["version"] = 99
    with pytest.raises(ValueError, match="version"):
        validate_calibration(bad)
    # hand-editing any part of the body invalidates the content hash:
    # a sample, the fit, or the backend label (a plan run must not be
    # relabelable as a hardware measurement)
    for key, value in (("samples", None), ("fitted", None),
                       ("backend", "jax"), ("residuals", {"resize_r2": 1.0})):
        bad = copy.deepcopy(doc)
        if key == "samples":
            bad["samples"][0]["seconds"] = 123.0
        elif key == "fitted":
            bad["fitted"]["link_bw"] = 1e12
        else:
            bad[key] = value
        with pytest.raises(ValueError, match="calibration_id"):
            validate_calibration(bad)


def test_calibration_id_is_content_derived():
    doc = golden_doc()
    assert doc["calibration_id"] == content_id(doc)
    perturbed = copy.deepcopy(doc)
    perturbed["samples"][0]["seconds"] += 1e-6
    assert content_id(perturbed) != doc["calibration_id"]
    relabeled = copy.deepcopy(doc)
    relabeled["backend"] = "jax"
    assert content_id(relabeled) != doc["calibration_id"]


def test_fit_error_on_bandwidth_free_samples():
    """All-equal busiest bytes ⇒ no bandwidth signal ⇒ explicit FitError,
    not a silently absurd model."""
    samples = [{"kind": "expand", "old": 1, "new": 2, "bytes": 64,
                "participants": 2, "busiest_bytes": 32,
                "seconds": 0.05 + i * 0.01} for i in range(4)]
    with pytest.raises(FitError):
        fit_samples(samples)


# -- consumption -------------------------------------------------------------

def test_from_artifact_builds_tagged_model():
    doc = golden_doc()
    model = ReconfigCostModel.from_artifact(GOLDEN)
    assert model.calibration_id == doc["calibration_id"]
    assert model.link_bw == doc["fitted"]["link_bw"]
    assert model.spawn_s == doc["fitted"]["spawn_s"]
    assert model.shrink_sync_s == doc["fitted"]["shrink_sync_s"]
    # loading from the parsed doc is equivalent
    assert ReconfigCostModel.from_artifact(doc) == model
    # the un-fitted constant keeps its paper default
    assert model.noaction_s == ReconfigCostModel().noaction_s


def test_fitted_model_keeps_fig3b_shape():
    model = ReconfigCostModel.from_artifact(GOLDEN)
    assert model.resize_time(1, 2, 1 << 30) > \
        model.resize_time(32, 64, 1 << 30)
    assert model.resize_time(64, 32, 1 << 30) >= \
        model.resize_time(32, 64, 1 << 30)


def test_sweep_rows_record_calibration_provenance():
    """Acceptance lock #2: a sweep point run under the fitted model
    carries the artifact's calibration_id in its schema-v3 row."""
    from repro.rms import sweep

    trace = os.path.join(DATA, "sample.swf")
    point = sweep.SweepPoint(trace=trace, policy="easy",
                             mix=(0.0, 0.0, 1.0, 0.0), max_jobs=8,
                             calibration=GOLDEN)
    row = sweep.run_point(point)
    assert row["calibration_id"] == golden_doc()["calibration_id"]
    assert "calibration_id" in sweep.COLUMNS
    # without an artifact the row records the paper-fit constants
    base = sweep.run_point(sweep.SweepPoint(
        trace=trace, policy="easy", mix=(0.0, 0.0, 1.0, 0.0), max_jobs=8))
    assert base["calibration_id"] == sweep.PAPER_FIT_ID


def test_scheduler_moldable_uses_threaded_cost_model():
    """The calibrated model reaches the moldable start-size optimizer."""
    from repro.rms.cluster import Cluster
    from repro.rms.scheduler import SchedulerConfig, Scheduler

    model = ReconfigCostModel.from_artifact(GOLDEN)
    sched = Scheduler(Cluster(64), SchedulerConfig(policy="moldable"),
                      cost=model)
    assert sched.policy.cost is model
    # default stays the paper fit
    plain = Scheduler(Cluster(64), SchedulerConfig(policy="moldable"))
    assert plain.policy.cost.calibration_id is None


# -- measurement harness -----------------------------------------------------

def test_plan_measurement_grid_shape_and_determinism():
    cfg = MeasureConfig(geometries=((1, 2), (2, 4)),
                        data_bytes=(MiB,), repeats=2, seed=5)
    samples, env = measure_grid(cfg)
    again, _ = measure_grid(cfg)
    assert samples == again                       # fully seeded
    resize = [s for s in samples if s["kind"] in ("expand", "shrink")]
    sched = [s for s in samples if s["kind"] == "sched"]
    assert len(resize) == 2 * 2 * 2               # geoms x dirs x repeats
    assert len(sched) == len(cfg.sched_nodes) * 2
    assert env["backend"] == "plan"
    assert env["noise_sigma"] == PLAN_NOISE_SIGMA
    for s in resize:
        parts, busiest = resize_features(s["kind"], s["old"], s["new"],
                                         s["bytes"])
        assert (s["participants"], s["busiest_bytes"]) == (parts, busiest)
        assert s["seconds"] > 0


def test_jax_backend_smoke_fits_positive_bandwidth():
    """Real-timing smoke on whatever devices exist (single-device CI uses
    the host→device link proxy): the fit must produce a finite, positive
    bandwidth and pass the shape checks."""
    import math

    cfg = MeasureConfig(backend="jax", geometries=((1, 2), (2, 4)),
                        data_bytes=(4 * MiB, 16 * MiB), repeats=1)
    doc = calibrate(cfg)
    assert doc["backend"] == "jax"
    bw = doc["fitted"]["link_bw"]
    assert math.isfinite(bw) and bw > 0
    assert doc["checks"]["link_bw_positive"]
    assert doc["checks"]["more_participants_faster"]
    validate_calibration(doc)                     # id consistent
