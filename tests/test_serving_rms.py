"""SERVING job class end-to-end: SLO-driven malleability golden trace.

A hand-built scenario locks the full serving story byte-for-byte
(``tests/data/golden_serving_trace.json``): a diurnal burst drives the
SLO negotiation to expand the serving job; a batch job submitted at the
peak has to wait; when traffic ebbs the serving job releases nodes step
by step and the batch job backfills into them — the co-scheduling
dynamic the DMR band negotiation exists to produce.

Locks:

1. The trace byte-matches the committed golden file, twice over (two
   fresh runs are byte-identical).
2. A sanitized run (``REPRO_SANITIZE=1`` machinery) is byte-identical
   to the plain run and reports zero violations.
3. One serving grid point re-simulated from scratch byte-matches its
   row in ``tests/data/golden_serving_sweep.json`` and a journal resume
   reuses it without re-running (serial == parallel == resume for the
   full serving grid is locked by the CI serving smoke step).

Regenerate the golden file (after an *intentional* semantic change)
with:

    PYTHONPATH=src:tests python -c \\
        "import test_serving_rms as t; t.write_golden()"
"""
import json
import os

from repro.rms.costmodel import AppModel
from repro.rms.job import Job, JobState
from repro.rms.simulator import ClusterSimulator, SimConfig
from repro.workload.traffic import DiurnalCurve, TrafficSpec

DATA = os.path.join(os.path.dirname(__file__), "data")
GOLDEN = os.path.join(DATA, "golden_serving_trace.json")


def serving_scenario() -> ClusterSimulator:
    """One serving job under a diurnal burst + a batch job at the peak.

    The serving app drains ~1 req/s per node; the curve crests near
    t=60 s and a burst on [90, 150) pushes demand past what 4 nodes
    clear, so SLO pressure expands 4 → 8.  The 6-node batch job lands
    mid-burst when only 2 nodes are free and must wait until the ebb
    lets the serving job shrink back down.
    """
    apps = {
        "api": AppModel("api", iterations=1, t1_iter_s=1.0,
                        serial_frac=0.0, data_bytes=1 << 20, min_nodes=2,
                        max_nodes=8, preferred=4, check_period_s=5.0),
        "batch": AppModel("batch", iterations=1, t1_iter_s=2.0,
                          serial_frac=0.0, data_bytes=1 << 20, min_nodes=6,
                          max_nodes=6, preferred=None, check_period_s=0.0),
    }
    curve = DiurnalCurve(base_rps=2.5, amplitude=0.2, period_s=600.0,
                         phase_s=60.0, bursts=((90.0, 60.0, 6.0),))
    spec = TrafficSpec(curve=curve, seed=42, t0=0.0, duration_s=600.0,
                       slo_p99_s=2.0, bucket_s=30.0, noise=0.1)
    serving = Job(job_id=0, app="api", submit_time=0.0, work=0.0,
                  min_nodes=2, max_nodes=8, preferred=4, factor=2,
                  malleable=True, check_period_s=5.0, requested_nodes=4,
                  data_bytes=1 << 20, traffic=spec)
    batch = Job(job_id=1, app="batch", submit_time=120.0, work=450.0,
                min_nodes=6, max_nodes=6, preferred=None, malleable=False,
                requested_nodes=6, data_bytes=1 << 20)
    cfg = SimConfig(num_nodes=10, flexible=True, checkpoint_period_s=0.0)
    return ClusterSimulator([serving, batch], cfg, apps=apps)


def serialize(report) -> dict:
    return {
        "makespan": round(report.makespan, 6),
        "actions": [
            {"t": round(a.t, 6), "job_id": a.job_id, "action": a.action,
             "from_nodes": a.from_nodes, "to_nodes": a.to_nodes,
             "reason": a.reason}
            for a in report.actions if a.action != "no_action"],
        "serving_stats": {
            str(jid): {"slo_violations": viol,
                       "served": round(served, 6),
                       "p99": round(p99, 6)}
            for jid, (viol, served, p99)
            in sorted(report.serving_stats.items())},
        "job_ends": [round(j.end_time, 6) for j in report.jobs],
    }


def run_bytes():
    rep = serving_scenario().run()
    doc = serialize(rep)
    return json.dumps(doc, indent=1, sort_keys=True).encode(), doc


def write_golden():
    data, _ = run_bytes()
    with open(GOLDEN, "wb") as fh:
        fh.write(data + b"\n")


def test_serving_trace_matches_committed_golden():
    data, doc = run_bytes()
    with open(GOLDEN) as fh:
        golden = json.load(fh)
    assert doc["makespan"] == golden["makespan"]
    assert doc["serving_stats"] == golden["serving_stats"]
    assert len(doc["actions"]) == len(golden["actions"])
    for got, want in zip(doc["actions"], golden["actions"]):
        assert got == want
    assert doc["job_ends"] == golden["job_ends"]


def test_serving_trace_two_runs_byte_identical():
    assert run_bytes()[0] == run_bytes()[0]


def test_serving_trace_sanitized_byte_identical(monkeypatch):
    plain, _ = run_bytes()
    monkeypatch.setenv("REPRO_SANITIZE", "1")
    sim = serving_scenario()
    assert sim.sanitizer is not None
    rep = sim.run()
    checked = json.dumps(serialize(rep), indent=1, sort_keys=True).encode()
    assert sim.sanitizer.checks == sim.engine.dispatched
    assert checked == plain


def test_serving_trace_exercises_the_slo_negotiation():
    """The golden scenario must stay event-rich: a burst-forced
    slo-expand, an ebb shrink, and the batch job backfilling into the
    released nodes — plus exact request conservation at the end."""
    sim = serving_scenario()
    rep = sim.run()
    serving, batch = rep.jobs
    expands = [a for a in rep.actions
               if a.action == "expand" and a.reason == "slo-expand"]
    shrinks = [a for a in rep.actions
               if a.action == "shrink" and a.reason == "slo-shrink"]
    assert expands and shrinks
    assert max(a.to_nodes for a in expands) == 8       # rode out the burst
    assert min(a.to_nodes for a in shrinks) <= 4       # gave nodes back
    # the batch job could not start at submit (peak held 8 of 10 nodes);
    # it backfilled only after an ebb shrink released capacity
    assert batch.start_time > batch.submit_time
    assert any(a.t <= batch.start_time and a.action == "shrink"
               for a in rep.actions)
    assert all(j.state is JobState.COMPLETED for j in rep.jobs)
    # conservation: every generated request was served, exactly
    assert serving.work_done == serving.work == rep.served_requests()
    assert rep.slo_violations() > 0                    # the burst hurt
    assert rep.p99_latency() > 0.0
    # serving completion cannot precede its traffic window
    assert serving.end_time >= 600.0


def test_serving_sweep_row_matches_golden_artifact(tmp_path):
    """One serving grid point re-simulated from scratch must byte-match
    its row in the committed golden serving artifact, and a journal
    resume must serve it back without re-running."""
    from repro.rms import sweep

    golden = sweep.load_artifact(os.path.join(
        DATA, "golden_serving_sweep.json"))
    points, _ = sweep.smoke_grid(os.path.join(DATA, "sample.swf"),
                                 serving=True)
    point = next(p for p in points
                 if p.policy == "easy" and
                 p.mix == (0.0, 0.0, 0.4, 0.0, 0.6))
    row = sweep.run_point(point)
    assert row["serving"] == 0.6
    assert row["served_requests"] > 0.0
    assert row["slo_violations"] > 0
    want = [r for r in golden["results"]
            if sweep.row_key(r) == sweep.row_key(row)]
    assert len(want) == 1
    assert row == want[0]
    journal = str(tmp_path / "serving.jsonl")
    sweep.run_sweep([point], journal=journal)
    again = sweep.run_sweep([point], resume_from=(journal,))
    assert again == [row]
