"""Feitelson workload generator (paper §7.1)."""
import numpy as np

from repro.workload import feitelson_sizes, make_workload, poisson_arrivals


def test_deterministic_given_seed():
    a = make_workload(20, seed=5)
    b = make_workload(20, seed=5)
    assert [j.app for j in a] == [j.app for j in b]
    assert [j.submit_time for j in a] == [j.submit_time for j in b]


def test_arrivals_monotone_and_scaled():
    rng = np.random.default_rng(0)
    t = poisson_arrivals(rng, 1000, scale_s=10.0)
    assert (np.diff(t) >= 0).all()
    gaps = np.diff(t)
    assert 5.0 < gaps.mean() < 20.0       # exponential(10) mean


def test_jobs_launched_at_maximum():
    for j in make_workload(30, seed=1):
        assert j.requested_nodes == j.max_nodes


def test_sizes_within_bounds():
    rng = np.random.default_rng(0)
    sizes = feitelson_sizes(rng, 500, 32)
    assert sizes.min() >= 1 and sizes.max() <= 32
    # biased toward small sizes
    assert np.median(sizes) <= 8
