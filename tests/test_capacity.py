"""Elastic cluster capacity: lifecycle states, churn events, power manager.

Pins the three pre-existing capacity bugs this subsystem replaced:

1. double-fail double-count — ``_on_failure`` decremented
   ``cluster.num_nodes`` on *every* NodeFail, so failing the same node
   twice charged two nodes of capacity;
2. stale denominators — ``SimReport.utilization()`` divided by
   ``config.num_nodes`` and ``_apply_phase_band`` clamped phase bands to
   ``config.num_nodes`` after failures/drains shrank the real cluster;
3. straggler recycling — ``swap_straggler`` returned the known-slow node
   to the head-allocatable free list, so the next allocate handed it
   straight to a fresh job.

Plus the new invariants: capacity conservation under any op interleaving,
the deterministic capacity-churn golden trace (drain forces a DMR shrink
/ migration, join grants a waiting expand), CLUES-style power-cycle
hysteresis, and churn-sweep byte determinism.

Regenerate the golden file (after an *intentional* semantic change) with:

    PYTHONPATH=src:tests python -c \\
        "import test_capacity as t; t.write_golden()"
"""
import json
import os
import random

try:
    from hypothesis import given, settings, strategies as st
except ImportError:                              # container has no hypothesis
    from _hypothesis_stub import given, settings, strategies as st

from repro.rms import (CapacityConfig, Cluster, Job, JobState,
                       MoldableStartPolicy)
from repro.rms.costmodel import AppModel
from repro.rms.simulator import ClusterSimulator, SimConfig

DATA = os.path.join(os.path.dirname(__file__), "data")
GOLDEN = os.path.join(DATA, "golden_capacity_trace.json")


# ---------------------------------------------------------------------------
# Satellite bugfix 1: idempotent failure accounting
# ---------------------------------------------------------------------------

def _one_job(n=1, work=50.0, submit=0.0, job_id=0, malleable=False):
    return Job(job_id=job_id, app="cg", submit_time=submit, work=work,
               min_nodes=n, max_nodes=n, preferred=None,
               malleable=malleable, requested_nodes=n)


def test_double_fail_costs_one_node_of_capacity():
    """Two NodeFail events on the same node must cost exactly one node —
    the pre-fix handler charged ``num_nodes -= 1`` once per event."""
    cfg = SimConfig(num_nodes=8, flexible=False, checkpoint_period_s=0.0,
                    failures=((10.0, 3), (20.0, 3)))
    sim = ClusterSimulator([_one_job(work=100.0)], cfg)
    sim.run()
    assert sim.cluster.live_capacity == 7
    assert sim.cluster.state_counts()["dead"] == 1
    # initial capacity is immutable; live capacity is derived state
    assert sim.cluster.num_nodes == 8


def test_fail_node_idempotent_and_unknown_safe():
    c = Cluster(4)
    owner = c.allocate(9, 2)
    assert c.fail_node(owner[0]) == 9
    assert c.fail_node(owner[0]) is None        # double fail: no-op
    assert c.fail_node(999) is None             # never-joined node: no-op
    assert c.live_capacity == 3
    assert sum(c.state_counts().values()) == c.nodes_ever_joined


# ---------------------------------------------------------------------------
# Satellite bugfix 2: live-capacity denominators
# ---------------------------------------------------------------------------

def test_utilization_normalized_by_live_capacity():
    """A job holding every *surviving* node is ~100% utilization — the
    pre-fix denominator (``config.num_nodes``) reported ~50% after half
    the cluster died."""
    cfg = SimConfig(num_nodes=8, flexible=False, checkpoint_period_s=0.0,
                    failures=((5.0, 4), (6.0, 5), (7.0, 6), (8.0, 7)))
    sim = ClusterSimulator([_one_job(n=4, work=2000.0)], cfg)
    rep = sim.run()
    assert sim.cluster.live_capacity == 4
    avg, _ = rep.utilization()
    assert avg > 95.0, f"stale denominator: {avg:.1f}%"


def test_phase_band_clamped_to_live_capacity():
    """A post-failure phase band must not exceed the real cluster (the
    pre-fix clamp to ``config.num_nodes`` let allocate() blow up)."""
    cfg = SimConfig(num_nodes=8, flexible=True)
    job = _one_job(malleable=True)
    sim = ClusterSimulator([job], cfg)
    for node in (4, 5, 6, 7):
        sim.cluster.fail_node(node)
    assert sim.cluster.live_capacity == 4
    sim._apply_phase_band(job, 0, 2, 8, 8)
    assert job.max_nodes == 4
    assert job.preferred == 4
    assert job.requested_nodes <= 4


def test_moldable_candidates_capped_by_live_capacity():
    job = Job(job_id=0, app="cg", submit_time=0.0, work=10.0,
              min_nodes=1, max_nodes=16, preferred=None, requested_nodes=8)
    # single-arg staticmethod call keeps working (back-compat surface)
    assert MoldableStartPolicy.candidate_sizes(job) == [1, 2, 4, 8, 16]
    assert MoldableStartPolicy.candidate_sizes(job, 6) == [1, 2, 4]


# ---------------------------------------------------------------------------
# Satellite bugfix 3: straggler quarantine
# ---------------------------------------------------------------------------

def test_swapped_straggler_not_reissued_while_healthy_nodes_exist():
    c = Cluster(4)
    c.allocate(1, 2)                            # nodes 0, 1
    c.set_straggler(1, 3.0)
    assert c.swap_straggler(1) == 1             # 1 swapped out for node 2
    assert 1 in c.quarantine and 1 not in c.free
    fresh = c.allocate(2, 1)                    # healthy node first
    assert fresh == [3]
    last = c.allocate(3, 1)                     # only now the slow node
    assert last == [1]
    assert sum(c.state_counts().values()) == c.nodes_ever_joined


def test_free_straggler_quarantined_and_healed_on_rejoin():
    c = Cluster(3)
    c.set_straggler(2, 2.0)                     # free node turns slow
    assert c.quarantine == [2] and 2 not in c.free
    assert c.allocate(1, 1) == [0]              # healthy-first
    c.drain_node(2)
    assert c.join_node(2) == 2                  # maintenance healed it
    assert 2 in c.free and 2 not in c.quarantine
    assert c.slow.get(2) is None


# ---------------------------------------------------------------------------
# Conservation invariant (property test)
# ---------------------------------------------------------------------------

def _apply_random_ops(c: Cluster, rng: random.Random, n_ops: int):
    jobs = [10, 11, 12]
    for _ in range(n_ops):
        op = rng.choice(("alloc", "resize", "release", "fail", "drain",
                         "join", "off", "on", "slow", "swap"))
        node = rng.randint(0, c.nodes_ever_joined + 1)
        job = rng.choice(jobs)
        if op == "alloc":
            n = rng.randint(1, 4)
            if n <= c.free_nodes:
                c.allocate(job, n)
        elif op == "resize":
            if c.allocation(job):
                want = rng.randint(1, c.allocation(job) + c.free_nodes)
                c.resize(job, want)
        elif op == "release":
            c.release(job)
        elif op == "fail":
            c.fail_node(node)
        elif op == "drain":
            c.drain_node(node)
        elif op == "join":
            c.join_node(node if rng.random() < 0.7 else None)
        elif op == "off":
            c.power_off_node(node)
        elif op == "on":
            c.power_on_node(node)
        elif op == "slow":
            c.set_straggler(node, rng.uniform(1.1, 4.0))
        elif op == "swap":
            c.swap_straggler(job)
        counts = c.state_counts()
        total = sum(counts.values())
        assert total == c.nodes_ever_joined, \
            f"conservation broken after {op}: {counts} != " \
            f"{c.nodes_ever_joined}"
        # pools are disjoint: no node appears in two states
        pools = (c.free + c.quarantine + c.draining + c.powered_off
                 + sorted(c.dead)
                 + [n for ns in c.owned.values() for n in ns])
        assert len(pools) == len(set(pools)), f"pool overlap after {op}"


@settings(max_examples=40, deadline=None)
@given(st.integers(0, 10_000))
def test_capacity_conservation_under_random_interleavings(seed):
    """free + allocated + draining + powered_off + dead ==
    nodes_ever_joined — for any interleaving of capacity ops."""
    rng = random.Random(seed)
    c = Cluster(rng.randint(1, 12))
    _apply_random_ops(c, rng, 60)


# ---------------------------------------------------------------------------
# Deterministic capacity-churn golden trace
# ---------------------------------------------------------------------------

def churn_scenario():
    """Drains force DMR shrinks / a slice migration off the doomed node;
    a mid-wait join grants a waiting async expand the moment it lands."""
    apps = {
        "grow": AppModel("grow", iterations=600, t1_iter_s=2.0,
                         serial_frac=0.0, data_bytes=1 << 20, min_nodes=2,
                         max_nodes=8, preferred=8, check_period_s=5.0),
        "wall": AppModel("wall", iterations=100, t1_iter_s=6.0,
                         serial_frac=0.0, data_bytes=0, min_nodes=6,
                         max_nodes=6, preferred=None, check_period_s=0.0),
    }
    grower = Job(job_id=0, app="grow", submit_time=0.0, work=600.0,
                 min_nodes=2, max_nodes=8, preferred=8, malleable=True,
                 check_period_s=5.0, requested_nodes=2, data_bytes=1 << 20)
    wall = Job(job_id=1, app="wall", submit_time=8.0, work=100.0,
               min_nodes=6, max_nodes=6, preferred=None, malleable=False,
               requested_nodes=6)
    cfg = SimConfig(num_nodes=8, flexible=True, scheduling="async",
                    checkpoint_period_s=0.0, expand_timeout_s=500.0,
                    joins=((40.0, -1), (41.0, -1), (200.0, -1)),
                    drains=((80.0, 9), (120.0, 2), (160.0, 3)))
    return ClusterSimulator([grower, wall], cfg, apps=apps)


def serialize(report) -> dict:
    return {
        "makespan": round(report.makespan, 6),
        "actions": [
            {"t": round(a.t, 6), "job_id": a.job_id, "action": a.action,
             "decide_s": round(a.decide_s, 6),
             "apply_s": round(a.apply_s, 6),
             "from_nodes": a.from_nodes, "to_nodes": a.to_nodes,
             "timed_out": a.timed_out, "reason": a.reason}
            for a in report.actions],
        "capacity_timeline": [
            [round(t, 6), live, off]
            for t, live, off in report.capacity_timeline],
        "node_hours": round(report.node_hours(), 6),
    }


def run_bytes():
    rep = churn_scenario().run()
    doc = serialize(rep)
    return json.dumps(doc, indent=1, sort_keys=True).encode(), doc


def write_golden():
    data, _ = run_bytes()
    with open(GOLDEN, "wb") as fh:
        fh.write(data + b"\n")


def test_churn_trace_matches_committed_golden():
    data, doc = run_bytes()
    with open(GOLDEN) as fh:
        golden = json.load(fh)
    assert doc["makespan"] == golden["makespan"]
    assert doc["capacity_timeline"] == golden["capacity_timeline"]
    assert len(doc["actions"]) == len(golden["actions"])
    for got, want in zip(doc["actions"], golden["actions"]):
        assert got == want
    assert doc["node_hours"] == golden["node_hours"]


def test_churn_trace_two_runs_byte_identical():
    assert run_bytes()[0] == run_bytes()[0]


def test_churn_trace_exercises_the_negotiation_paths():
    """The golden scenario must stay event-rich: a drain-forced DMR
    shrink, a drain slice-migration, join events, and — the §5.2.1 RJ
    pathology resolved by elasticity — a waiting expand granted exactly
    when a join lands (not at a periodic check)."""
    sim = churn_scenario()
    rep = sim.run()
    kinds = {a.action for a in rep.actions}
    assert {"node_join", "node_drain", "drain_shrink",
            "drain_migrate", "expand"} <= kinds
    join_ts = {a.t for a in rep.actions if a.action == "node_join"}
    granted = [a for a in rep.actions
               if a.action == "expand" and not a.timed_out
               and a.t in join_ts]
    assert granted, "no expand granted at a join instant"
    assert all(j.state is JobState.COMPLETED for j in rep.jobs)
    counts = sim.cluster.state_counts()
    assert sum(counts.values()) == sim.cluster.nodes_ever_joined
    assert counts["draining"] == 3              # all three drains landed
    # node-hours track the *lived* capacity curve, not initial × makespan
    fixed = 8 * rep.makespan / 3600.0
    assert abs(rep.node_hours() - fixed) > 1e-6


def test_drain_requeues_rigid_job_and_join_unblocks_it():
    """No free node + rigid owner => checkpoint requeue; the later join
    restores enough capacity for the restart to complete."""
    job = _one_job(n=4, work=600.0)
    cfg = SimConfig(num_nodes=4, flexible=False, checkpoint_period_s=0.0,
                    drains=((50.0, 2),), joins=((80.0, -1),))
    sim = ClusterSimulator([job], cfg)
    rep = sim.run()
    kinds = [a.action for a in rep.actions]
    assert "drain_requeue" in kinds
    assert job.state is JobState.COMPLETED
    assert job.end_time > 80.0                  # restarted after the join
    assert 2 in sim.cluster.draining


# ---------------------------------------------------------------------------
# CLUES-style power management
# ---------------------------------------------------------------------------

def test_power_cycle_parks_idle_nodes_and_boots_on_demand():
    a = _one_job(n=1, work=50.0, job_id=0)
    b = _one_job(n=3, work=10.0, submit=60.0, job_id=1)
    cfg = SimConfig(num_nodes=4, flexible=False, checkpoint_period_s=0.0,
                    capacity=CapacityConfig(enabled=True,
                                            idle_power_off_s=30.0,
                                            min_free=1,
                                            power_up_delay_s=10.0))
    sim = ClusterSimulator([a, b], cfg)
    rep = sim.run()
    offs = [x for x in rep.actions if x.action == "power_off"]
    ons = [x for x in rep.actions if x.action == "power_on"]
    assert offs and offs[0].t >= 30.0           # parked after the idle dwell
    assert ons and ons[0].t >= 70.0             # b's demand + boot delay
    assert b.state is JobState.COMPLETED
    assert b.start_time >= 70.0                 # waited for the boot
    assert rep.powered_off_hours() > 0.0
    assert rep.node_hours() < 4 * rep.makespan / 3600.0 - 1e-9


def test_power_off_hysteresis_cancelled_by_queue_pressure():
    """Pressure arriving inside the idle dwell disarms the park — the
    armed NodePowerOff re-validates at fire time (CLUES hysteresis)."""
    a = _one_job(n=1, work=100.0, job_id=0)
    blocked = _one_job(n=4, work=10.0, submit=20.0, job_id=1)
    cfg = SimConfig(num_nodes=4, flexible=False, checkpoint_period_s=0.0,
                    capacity=CapacityConfig(enabled=True,
                                            idle_power_off_s=30.0,
                                            min_free=1,
                                            power_up_delay_s=10.0))
    sim = ClusterSimulator([a, blocked], cfg)
    rep = sim.run()
    early = [x for x in rep.actions
             if x.action == "power_off" and x.t <= 100.0]
    assert not early, f"parked under pressure: {early}"
    assert blocked.state is JobState.COMPLETED


def test_join_of_live_node_is_idempotent():
    c = Cluster(3)
    assert c.join_node(1) == 1                  # already free: no-op
    assert c.nodes_ever_joined == 3
    assert len(c.free) == 3
    c.allocate(5, 1)
    assert c.join_node(c.owned[5][0]) == c.owned[5][0]
    assert c.nodes_ever_joined == 3             # still a member
    fresh = c.join_node()
    assert fresh == 3 and c.nodes_ever_joined == 4


# ---------------------------------------------------------------------------
# Churn through the sweep driver (schema v4 determinism)
# ---------------------------------------------------------------------------

def test_churn_sweep_row_matches_golden_artifact(tmp_path):
    """One churn grid point re-simulated from scratch must byte-match its
    row in the committed golden churn artifact, and a journal resume must
    reuse it without re-running (serial == parallel == resume is locked
    end-to-end by the CI capacity-churn smoke step)."""
    from repro.rms import sweep

    golden = sweep.load_artifact(os.path.join(
        DATA, "golden_capacity_sweep.json"))
    points, _ = sweep.smoke_grid(os.path.join(DATA, "sample.swf"),
                                 churn="smoke")
    point = next(p for p in points
                 if p.policy == "easy" and
                 p.mix == (0.0, 0.0, 1.0, 0.0, 0.0))
    row = sweep.run_point(point)
    assert row["churn"] == "smoke"
    assert row["drains"] > 0 and row["joins"] > 0
    want = [r for r in golden["results"]
            if sweep.row_key(r) == sweep.row_key(row)]
    assert len(want) == 1
    assert row == want[0]
    # journal resume serves the row without re-simulation
    journal = str(tmp_path / "churn.jsonl")
    sweep.run_sweep([point], journal=journal)
    again = sweep.run_sweep([point], resume_from=(journal,))
    assert again == [row]
