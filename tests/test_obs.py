"""Observability layer (:mod:`repro.obs`): recorder, export, audit CLI.

Three contracts:

1. **Observer effect is zero.**  A run traced by ``TraceRecorder`` (even
   stacked with the sanitizer) serializes byte-identically to a plain
   run, on both golden scenarios (capacity churn, serving SLO); and the
   recorder's private utilization recomputation equals the report's.
2. **Artifacts are byte-deterministic and audited.**  Two traced runs
   produce identical bytes; the committed golden artifact and rendered
   report pin the schema; the ledger accounts for *every* ActionRecord.
3. **Monitor fan-out preserves registration order** and the single-
   monitor fast path keeps ``engine.monitor is m`` identity.

Regenerate the goldens (after an *intentional* semantic change) with:

    PYTHONPATH=src:tests python -c "import test_obs as t; t.write_golden()"
"""
import json
import os

import pytest

import test_capacity
import test_serving_rms
from repro.obs import TraceRecorder, build_artifact, dumps_artifact
from repro.obs.export import chrome_trace, spans_jsonl, write_trace
from repro.obs.metrics import Counter, Gauge, Histogram, MetricsRegistry
from repro.obs.report import ledger_total, main as report_main, render_report
from repro.rms.engine import Event, JobSubmit, SimulationEngine

DATA = os.path.join(os.path.dirname(__file__), "data")
GOLDEN_TRACE = os.path.join(DATA, "golden_obs_trace.json")
GOLDEN_REPORT = os.path.join(DATA, "golden_obs_report.txt")


# ---------------------------------------------------------------------------
# traced golden scenario -> artifact bytes
# ---------------------------------------------------------------------------

def traced_churn():
    sim = test_capacity.churn_scenario()
    rec = TraceRecorder(sim, meta={"scenario": "capacity-churn"}).install()
    report = sim.run()
    rec.finalize(report)
    return sim, rec, report


def obs_bytes():
    _, rec, report = traced_churn()
    doc = build_artifact(rec)
    return dumps_artifact(doc), doc, report


def write_golden():
    data, doc, _ = obs_bytes()
    with open(GOLDEN_TRACE, "wb") as fh:
        fh.write(data)
    with open(GOLDEN_REPORT, "w", encoding="utf-8") as fh:
        fh.write(render_report(doc))


# ---------------------------------------------------------------------------
# satellite: multi-monitor fan-out
# ---------------------------------------------------------------------------

class _OrderProbe:
    def __init__(self, tag, log):
        self.tag, self.log = tag, log

    def on_schedule(self, event):
        self.log.append((self.tag, "schedule", type(event).__name__))

    def before_event(self, event):
        self.log.append((self.tag, "before", type(event).__name__))

    def after_event(self, event):
        self.log.append((self.tag, "after", type(event).__name__))


def test_fanout_preserves_registration_order():
    eng = SimulationEngine()
    eng.on(JobSubmit, lambda ev: None)
    log = []
    a, b = _OrderProbe("a", log), _OrderProbe("b", log)
    eng.add_monitor(a)
    eng.add_monitor(b)
    eng.schedule(JobSubmit(t=1.0, job_id=0))
    eng.run()
    assert log == [("a", "schedule", "JobSubmit"),
                   ("b", "schedule", "JobSubmit"),
                   ("a", "before", "JobSubmit"),
                   ("b", "before", "JobSubmit"),
                   ("a", "after", "JobSubmit"),
                   ("b", "after", "JobSubmit")]


def test_single_monitor_keeps_identity_and_add_is_idempotent():
    eng = SimulationEngine()
    probe = _OrderProbe("a", [])
    eng.add_monitor(probe)
    assert eng.monitor is probe          # no fan-out wrapper for one
    eng.add_monitor(probe)               # idempotent
    assert eng.monitor is probe
    eng.remove_monitor(probe)
    assert eng.monitor is None
    eng.remove_monitor(probe)            # no-op


def test_monitor_setter_replaces_the_whole_set():
    eng = SimulationEngine()
    log = []
    eng.add_monitor(_OrderProbe("a", log))
    eng.add_monitor(_OrderProbe("b", log))
    assert eng.monitor is not None and eng.monitor.monitors
    solo = _OrderProbe("c", log)
    eng.monitor = solo                   # back-compat single-slot surface
    assert eng.monitor is solo
    eng.monitor = None
    assert eng.monitor is None


def test_recorder_observes_every_event_alongside_sanitizer(monkeypatch):
    monkeypatch.setenv("REPRO_SANITIZE", "1")
    sim = test_capacity.churn_scenario()
    assert sim.sanitizer is not None
    rec = TraceRecorder(sim).install()
    events = []
    sim.engine.add_monitor(_OrderProbe("probe", events))
    rep = sim.run()
    rec.finalize(rep)
    assert sim.sanitizer.checks == sim.engine.dispatched
    n_after = sum(1 for e in events if e[1] == "after")
    assert n_after == sim.engine.dispatched
    assert ledger_total(build_artifact(rec)) == len(rep.actions)


# ---------------------------------------------------------------------------
# satellite: observer effect is zero
# ---------------------------------------------------------------------------

def test_traced_churn_run_byte_identical_to_plain(monkeypatch):
    plain, _ = test_capacity.run_bytes()
    monkeypatch.setenv("REPRO_SANITIZE", "1")    # stack all three monitors
    sim = test_capacity.churn_scenario()
    rec = TraceRecorder(sim).install()
    rep = sim.run()
    rec.finalize(rep)
    traced = json.dumps(test_capacity.serialize(rep), indent=1,
                        sort_keys=True).encode()
    assert traced == plain


def test_traced_serving_run_byte_identical_to_plain(monkeypatch):
    plain, _ = test_serving_rms.run_bytes()
    monkeypatch.setenv("REPRO_SANITIZE", "1")
    sim = test_serving_rms.serving_scenario()
    rec = TraceRecorder(sim).install()
    rep = sim.run()
    rec.finalize(rep)
    traced = json.dumps(test_serving_rms.serialize(rep), indent=1,
                        sort_keys=True).encode()
    assert traced == plain


def test_recorder_utilization_matches_report():
    _, rec, report = traced_churn()
    avg, std = report.utilization()
    r_avg, r_std = rec.utilization()
    assert abs(r_avg - avg) <= 1e-9
    assert abs(r_std - std) <= 1e-9


def test_ledger_accounts_for_every_action():
    """The audit property: ledger counts sum to the exact ActionRecord
    total — no action is dropped, none is double-counted."""
    _, rec, report = traced_churn()
    doc = build_artifact(rec)
    assert ledger_total(doc) == len(report.actions)
    # and per (action, code) the counts match a direct recount
    from repro.rms.reasons import reason_code
    want = {}
    for a in report.actions:
        key = (a.action, reason_code(a.reason))
        want[key] = want.get(key, 0) + 1
    got = {(r["action"], r["reason"]): r["count"] for r in doc["ledger"]}
    assert got == want


def test_serving_slo_samples_match_report():
    sim = test_serving_rms.serving_scenario()
    rec = TraceRecorder(sim).install()
    rep = sim.run()
    rec.finalize(rep)
    doc = build_artifact(rec)
    for jid, (viol, served, p99) in rep.serving_stats.items():
        s = doc["serving"][str(jid)]
        assert s["slo_violations"] == viol
        # the recorder's per-probe violation counter agrees with the
        # simulator's own total
        counter = rec.metrics.counter("slo_violations", job=jid)
        assert counter.value == viol
    slo_spans = [s for s in doc["spans"] if s["kind"] == "slo"]
    assert slo_spans, "serving scenario emitted no SLO probes"
    assert all(s["args"]["p99_s"] is not None for s in slo_spans)


# ---------------------------------------------------------------------------
# artifact determinism + committed goldens
# ---------------------------------------------------------------------------

def test_artifact_two_runs_byte_identical():
    assert obs_bytes()[0] == obs_bytes()[0]


def test_artifact_matches_committed_golden():
    data, doc, _ = obs_bytes()
    with open(GOLDEN_TRACE, "rb") as fh:
        golden_bytes = fh.read()
    golden = json.loads(golden_bytes)
    assert doc["schema"] == golden["schema"] == "repro.obs"
    assert doc["version"] == golden["version"] == 1
    assert doc["makespan"] == golden["makespan"]
    assert doc["jobs"] == golden["jobs"]
    assert doc["ledger"] == golden["ledger"]
    assert len(doc["spans"]) == len(golden["spans"])
    assert data == golden_bytes


def test_report_matches_committed_golden():
    _, doc, _ = obs_bytes()
    with open(GOLDEN_REPORT, "r", encoding="utf-8") as fh:
        golden = fh.read()
    assert render_report(doc) == golden


def test_job_breakdown_attribution_is_consistent():
    _, doc, _ = obs_bytes()
    assert doc["jobs"], "no per-job rows"
    for j in doc["jobs"]:
        assert j["queued_s"] >= 0 and j["run_s"] >= 0
        assert j["reconfig_s"] >= 0 and j["compute_s"] >= 0
        assert abs(j["compute_s"] + j["reconfig_s"] - j["run_s"]) < 1e-4
        if j["state"] == "completed":
            span = j["end_t"] - j["submit_t"]
            assert abs(j["queued_s"] + j["run_s"] - span) < 1e-4


# ---------------------------------------------------------------------------
# Perfetto / CLI surfaces
# ---------------------------------------------------------------------------

def test_chrome_trace_structure():
    _, doc, _ = obs_bytes()
    trace = chrome_trace(doc)
    events = trace["traceEvents"]
    assert trace["displayTimeUnit"] == "ms"
    phases = {e["ph"] for e in events}
    assert phases <= {"X", "i", "s", "f", "C", "M"}
    # every span landed as a duration or instant event
    n_spans = sum(1 for e in events if e["ph"] in ("X", "i"))
    assert n_spans == len(doc["spans"])
    # flow arrows are balanced: one start per finish
    assert sum(1 for e in events if e["ph"] == "s") == \
        sum(1 for e in events if e["ph"] == "f")
    # granted resizes produce arrows (churn scenario has real resizes)
    assert any(e["ph"] == "s" for e in events)
    assert all(e["dur"] >= 0 for e in events if e["ph"] == "X")
    assert any(e["ph"] == "C" for e in events)     # counter tracks
    names = {e["args"]["name"] for e in events
             if e["ph"] == "M" and e["name"] == "process_name"}
    assert names == {"jobs", "dmr", "cluster", "metrics"}


def test_write_trace_bundle_and_cli(tmp_path, capsys):
    sim, rec, report = traced_churn()
    paths = write_trace(str(tmp_path / "churn"), rec)
    for p in paths.values():
        assert os.path.exists(p)
    with open(paths["spans"], "rb") as fh:
        lines = fh.read().splitlines()
    doc = json.load(open(paths["obs"]))
    assert len(lines) == len(doc["spans"])
    json.loads(lines[0])                           # valid JSONL
    json.load(open(paths["perfetto"]))             # valid JSON

    assert report_main([paths["obs"]]) == 0
    out = capsys.readouterr().out
    assert "per-job time breakdown" in out
    assert "DMR action ledger" in out
    assert report_main([paths["obs"], "--section", "ledger"]) == 0


def test_cli_check_mode_detects_drift(tmp_path, capsys):
    assert report_main([GOLDEN_TRACE, "--check", GOLDEN_REPORT]) == 0
    capsys.readouterr()
    bad = tmp_path / "bad.txt"
    bad.write_text("not the report\n")
    assert report_main([GOLDEN_TRACE, "--check", str(bad)]) == 1
    assert "DRIFT" in capsys.readouterr().out


def test_load_artifact_rejects_foreign_schema(tmp_path):
    from repro.obs.report import load_artifact
    p = tmp_path / "x.json"
    p.write_text(json.dumps({"schema": "other", "version": 1}))
    with pytest.raises(ValueError):
        load_artifact(str(p))
    p.write_text(json.dumps({"schema": "repro.obs", "version": 99}))
    with pytest.raises(ValueError):
        load_artifact(str(p))


# ---------------------------------------------------------------------------
# metrics primitives
# ---------------------------------------------------------------------------

def test_counter_and_gauge_semantics():
    c = Counter()
    c.inc()
    c.inc(3)
    assert c.value == 4
    g = Gauge()
    g.set(0.0, 5)
    g.set(1.0, 5)                  # unchanged value: deduped
    g.set(2.0, 7)
    g.set(2.0, 9)                  # same-t rewrite replaces the sample
    assert g.samples == [(0.0, 5), (2.0, 9)]
    assert g.last == 9
    g.set(3.0, 5)
    assert g.integral(4.0) == pytest.approx(5 * 2.0 + 9 * 1.0 + 5 * 1.0)


def test_histogram_buckets_and_overflow():
    h = Histogram(bounds=(1.0, 10.0))
    for v in (0.5, 1.0, 5.0, 100.0):
        h.observe(v)
    assert h.counts == [2, 1, 1]   # <=1, <=10, overflow
    assert h.count == 4
    assert h.total == pytest.approx(106.5)


def test_registry_labels_and_kind_clash():
    m = MetricsRegistry()
    assert m.counter("x", job=1) is m.counter("x", job=1)
    assert m.counter("x", job=1) is not m.counter("x", job=2)
    with pytest.raises(TypeError):
        m.gauge("x", job=1)        # same name+labels, different kind
    doc = m.to_doc()
    assert sorted(doc) == ["counters", "gauges", "histograms"]


def test_metrics_doc_is_deterministic():
    def build():
        m = MetricsRegistry()
        m.counter("b").inc()
        m.counter("a", k=2).inc(2)
        m.gauge("g", job=1).set(1.0, 3)
        m.histogram("h").observe(0.2)
        return json.dumps(m.to_doc(), sort_keys=True)
    assert build() == build()


def test_recorder_requires_finalize_before_export():
    sim = test_capacity.churn_scenario()
    rec = TraceRecorder(sim).install()
    sim.run()
    with pytest.raises(RuntimeError):
        build_artifact(rec)
