"""Checkpoint store: roundtrip, atomicity, GC, async, elastic restore."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import CheckpointStore


@pytest.fixture
def state():
    return {"params": {"w": jnp.arange(12.0).reshape(3, 4),
                       "b": jnp.ones((4,), jnp.bfloat16)},
            "step": jnp.int32(7)}


def test_roundtrip(tmp_path, state):
    store = CheckpointStore(tmp_path)
    store.save(7, state)
    out = store.restore(7, state)
    for a, b in zip(jax.tree.leaves(state), jax.tree.leaves(out)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
        assert a.dtype == b.dtype


def test_latest_and_gc(tmp_path, state):
    store = CheckpointStore(tmp_path, keep=2)
    for s in (1, 2, 3, 4):
        store.save(s, state)
    assert store.latest_step() == 4
    assert len(list(tmp_path.glob("ckpt_*"))) == 2


def test_async_save(tmp_path, state):
    store = CheckpointStore(tmp_path)
    store.save_async(5, state)
    store.wait()
    assert store.latest_step() == 5
    out = store.restore(5, state)
    np.testing.assert_array_equal(np.asarray(out["params"]["w"]),
                                  np.asarray(state["params"]["w"]))


def test_no_partial_files_after_save(tmp_path, state):
    store = CheckpointStore(tmp_path)
    store.save(1, state)
    assert not list(tmp_path.glob("*.tmp"))


def test_elastic_restore_with_shardings(tmp_path, state):
    store = CheckpointStore(tmp_path)
    store.save(1, state)
    mesh = jax.make_mesh((1,), ("data",))
    sh = jax.tree.map(
        lambda _: jax.sharding.NamedSharding(
            mesh, jax.sharding.PartitionSpec()), state)
    out = store.restore(1, state, sh)
    assert out["params"]["w"].sharding.mesh.shape["data"] == 1
