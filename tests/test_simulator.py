"""Discrete-event simulator invariants + paper-claim validation."""
import dataclasses

import numpy as np
import pytest

from repro.rms import (ClusterSimulator, PAPER_APPS, SimConfig)
from repro.rms.job import JobState
from repro.workload import make_workload

WIDE = {k: dataclasses.replace(v, preferred=None)
        for k, v in PAPER_APPS.items()}


def run(n, flexible, sched="sync", apps=None, **kw):
    jobs = make_workload(n, seed=7, apps=apps)
    cfg = SimConfig(num_nodes=64, flexible=flexible, scheduling=sched, **kw)
    return ClusterSimulator(jobs, cfg, apps=apps).run()


@pytest.fixture(scope="module")
def runs():
    return {
        "fixed": run(50, False),
        "flex": run(50, True),
        "async": run(50, True, "async"),
    }


def test_all_jobs_complete(runs):
    for rep in runs.values():
        assert all(j.state is JobState.COMPLETED for j in rep.jobs)


def test_no_overallocation(runs):
    for rep in runs.values():
        assert max(e[1] for e in rep.timeline) <= rep.config.num_nodes


def test_allocation_never_negative(runs):
    for rep in runs.values():
        assert min(e[1] for e in rep.timeline) >= 0


def test_wait_exec_completion_consistent(runs):
    for rep in runs.values():
        for j in rep.jobs:
            assert j.wait_time >= 0
            assert j.exec_time > 0
            assert abs(j.completion_time
                       - (j.wait_time + j.exec_time)) < 1e-6


def test_flexible_improves_completion(runs):
    """Paper headline: flexible workloads complete earlier (Fig. 4)."""
    _, _, c_fixed = runs["fixed"].averages()
    _, _, c_flex = runs["flex"].averages()
    assert c_flex < c_fixed


def test_flexible_reduces_waiting(runs):
    w_fixed, _, _ = runs["fixed"].averages()
    w_flex, _, _ = runs["flex"].averages()
    assert w_flex < w_fixed


def test_flexible_increases_exec(runs):
    """Shrunk jobs run slower (paper §7.4: negative execution gain)."""
    _, e_fixed, _ = runs["fixed"].averages()
    _, e_flex, _ = runs["flex"].averages()
    assert e_flex > e_fixed


def test_fixed_jobs_never_resize(runs):
    assert not runs["fixed"].actions
    for j in runs["fixed"].jobs:
        sizes = {n for _, n in j.nodes_history if n > 0}
        assert len(sizes) == 1


def test_flexible_actions_logged(runs):
    kinds = {a.action for a in runs["flex"].actions}
    assert "shrink" in kinds
    assert all(a.decide_s >= 0 for a in runs["flex"].actions)


def test_async_timeout_pathology():
    """Table 2: async expands wait with a timeout ceiling (~40s)."""
    rep = run(200, True, "async", apps=WIDE)
    expands = [a for a in rep.actions if a.action == "expand"]
    assert expands
    assert max(a.apply_s for a in expands) <= rep.config.expand_timeout_s \
        + 1.0
    assert any(a.timed_out for a in rep.actions)


def test_sync_expand_has_no_waits():
    rep = run(200, True, "sync", apps=WIDE)
    expands = [a for a in rep.actions if a.action == "expand"
               and not a.timed_out]
    assert all(a.apply_s < 5.0 for a in expands)


def test_utilization_definition():
    rep = run(50, False)
    u, _ = rep.utilization()
    assert 0 < u <= 100.0


def test_node_failure_malleable_shrinks():
    jobs = make_workload(8, seed=3)
    cfg = SimConfig(num_nodes=64, flexible=True,
                    failures=((100.0, 0),))
    rep = ClusterSimulator(jobs, cfg).run()
    assert any(a.action in ("failure_shrink", "failure_requeue")
               for a in rep.actions)
    assert all(j.state is JobState.COMPLETED for j in rep.jobs)


def test_node_failure_rigid_requeues():
    jobs = make_workload(8, seed=3, malleable=False)
    cfg = SimConfig(num_nodes=64, flexible=False,
                    failures=((100.0, 0),))
    rep = ClusterSimulator(jobs, cfg).run()
    assert all(j.state is JobState.COMPLETED for j in rep.jobs)


def test_straggler_migration():
    jobs = make_workload(4, seed=3)
    cfg = SimConfig(num_nodes=64, flexible=True,
                    stragglers=((50.0, 0, 4.0),))
    rep = ClusterSimulator(jobs, cfg).run()
    assert all(j.state is JobState.COMPLETED for j in rep.jobs)
    # either migrated or the slow node was free
    assert any(a.action == "straggler_migrate" for a in rep.actions) or \
        rep.makespan > 0


def test_deterministic_given_seed():
    a = run(30, True)
    b = run(30, True)
    assert a.makespan == b.makespan
    assert len(a.actions) == len(b.actions)


# ---------------------------------------------------------------------------
# Event churn: a granted async expand schedules completion exactly once
# ---------------------------------------------------------------------------

def _grant_scenario():
    """A grower whose async expand must wait on a rigid wall job: the wall
    finishing hands its nodes to the resizer-job reservation, so the wait
    is *granted* (not timed out) mid-run."""
    from repro.rms.costmodel import AppModel
    from repro.rms.job import Job

    apps = {
        "grow": AppModel("grow", iterations=600, t1_iter_s=2.0,
                         serial_frac=0.0, data_bytes=1 << 20, min_nodes=2,
                         max_nodes=8, preferred=8, check_period_s=5.0),
        "wall": AppModel("wall", iterations=100, t1_iter_s=6.0,
                         serial_frac=0.0, data_bytes=0, min_nodes=6,
                         max_nodes=6, preferred=None, check_period_s=0.0),
    }
    grower = Job(job_id=0, app="grow", submit_time=0.0, work=600.0,
                 min_nodes=2, max_nodes=8, preferred=8, malleable=True,
                 check_period_s=5.0, requested_nodes=2, data_bytes=1 << 20)
    wall = Job(job_id=1, app="wall", submit_time=8.0, work=100.0,
               min_nodes=6, max_nodes=6, preferred=None, malleable=False,
               requested_nodes=6)
    cfg = SimConfig(num_nodes=8, flexible=True, scheduling="async",
                    checkpoint_period_s=0.0, expand_timeout_s=500.0)
    return ClusterSimulator([grower, wall], cfg, apps=apps), grower


def test_granted_expand_schedules_completion_exactly_once():
    """Regression (event churn): _grant_waiting_expands used to call
    _schedule_completion right after _apply — which had already
    rescheduled completion — so every granted expand bumped
    completion_version twice and left a dead JobFinish in the heap."""
    from repro.rms.engine import JobFinish, JobSubmit

    sim, grower = _grant_scenario()
    for j in sim.jobs:
        sim.engine.schedule(JobSubmit(j.submit_time, j.job_id))
    guard = 0
    while not sim._waiting_expands:            # reach the pending wait
        assert sim.engine.step(), "never reached a waiting expand"
        guard += 1
        assert guard < 10_000
    version_waiting = grower.completion_version
    while sim._waiting_expands:                # ... and its grant
        assert sim.engine.step(), "wait never granted"
        guard += 1
        assert guard < 10_000
    granted = [a for a in sim.actions if a.action == "expand"
               and not a.timed_out and a.apply_s > 0]
    assert granted, "scenario no longer exercises the granted-expand path"
    # exactly one completion (re)schedule for the grant ...
    assert grower.completion_version == version_waiting + 1
    # ... so the heap holds one JobFinish per version ever scheduled (the
    # pre-grant event is inherently dead — a resize invalidates, it cannot
    # unschedule) and exactly one carries the live version.  Pre-fix the
    # double reschedule left an *extra* dead finish per granted expand.
    finishes = [ev for (_, _, ev) in sim.engine._heap
                if isinstance(ev, JobFinish) and ev.job_id == grower.job_id]
    assert len(finishes) == grower.completion_version
    assert sum(1 for ev in finishes
               if ev.version == grower.completion_version) == 1
    sim.engine.run()
    assert all(j.state is JobState.COMPLETED for j in sim.jobs)


def test_granted_expand_trace_and_makespan_deterministic():
    """The churn fix must not change semantics: two fresh replays of the
    grant scenario produce identical action traces and makespans, and the
    engine dispatches no more events than scheduled completions require."""
    reports = []
    dispatched = []
    for _ in range(2):
        sim, _ = _grant_scenario()
        reports.append(sim.run())
        dispatched.append(sim.engine.dispatched)
    a, b = reports
    assert a.makespan == b.makespan
    assert [dataclasses.astuple(x) for x in a.actions] == \
        [dataclasses.astuple(x) for x in b.actions]
    assert dispatched[0] == dispatched[1]
