"""Discrete-event simulator invariants + paper-claim validation."""
import dataclasses

import numpy as np
import pytest

from repro.rms import (ClusterSimulator, PAPER_APPS, SimConfig)
from repro.rms.job import JobState
from repro.workload import make_workload

WIDE = {k: dataclasses.replace(v, preferred=None)
        for k, v in PAPER_APPS.items()}


def run(n, flexible, sched="sync", apps=None, **kw):
    jobs = make_workload(n, seed=7, apps=apps)
    cfg = SimConfig(num_nodes=64, flexible=flexible, scheduling=sched, **kw)
    return ClusterSimulator(jobs, cfg, apps=apps).run()


@pytest.fixture(scope="module")
def runs():
    return {
        "fixed": run(50, False),
        "flex": run(50, True),
        "async": run(50, True, "async"),
    }


def test_all_jobs_complete(runs):
    for rep in runs.values():
        assert all(j.state is JobState.COMPLETED for j in rep.jobs)


def test_no_overallocation(runs):
    for rep in runs.values():
        assert max(e[1] for e in rep.timeline) <= rep.config.num_nodes


def test_allocation_never_negative(runs):
    for rep in runs.values():
        assert min(e[1] for e in rep.timeline) >= 0


def test_wait_exec_completion_consistent(runs):
    for rep in runs.values():
        for j in rep.jobs:
            assert j.wait_time >= 0
            assert j.exec_time > 0
            assert abs(j.completion_time
                       - (j.wait_time + j.exec_time)) < 1e-6


def test_flexible_improves_completion(runs):
    """Paper headline: flexible workloads complete earlier (Fig. 4)."""
    _, _, c_fixed = runs["fixed"].averages()
    _, _, c_flex = runs["flex"].averages()
    assert c_flex < c_fixed


def test_flexible_reduces_waiting(runs):
    w_fixed, _, _ = runs["fixed"].averages()
    w_flex, _, _ = runs["flex"].averages()
    assert w_flex < w_fixed


def test_flexible_increases_exec(runs):
    """Shrunk jobs run slower (paper §7.4: negative execution gain)."""
    _, e_fixed, _ = runs["fixed"].averages()
    _, e_flex, _ = runs["flex"].averages()
    assert e_flex > e_fixed


def test_fixed_jobs_never_resize(runs):
    assert not runs["fixed"].actions
    for j in runs["fixed"].jobs:
        sizes = {n for _, n in j.nodes_history if n > 0}
        assert len(sizes) == 1


def test_flexible_actions_logged(runs):
    kinds = {a.action for a in runs["flex"].actions}
    assert "shrink" in kinds
    assert all(a.decide_s >= 0 for a in runs["flex"].actions)


def test_async_timeout_pathology():
    """Table 2: async expands wait with a timeout ceiling (~40s)."""
    rep = run(200, True, "async", apps=WIDE)
    expands = [a for a in rep.actions if a.action == "expand"]
    assert expands
    assert max(a.apply_s for a in expands) <= rep.config.expand_timeout_s \
        + 1.0
    assert any(a.timed_out for a in rep.actions)


def test_sync_expand_has_no_waits():
    rep = run(200, True, "sync", apps=WIDE)
    expands = [a for a in rep.actions if a.action == "expand"
               and not a.timed_out]
    assert all(a.apply_s < 5.0 for a in expands)


def test_utilization_definition():
    rep = run(50, False)
    u, _ = rep.utilization()
    assert 0 < u <= 100.0


def test_node_failure_malleable_shrinks():
    jobs = make_workload(8, seed=3)
    cfg = SimConfig(num_nodes=64, flexible=True,
                    failures=((100.0, 0),))
    rep = ClusterSimulator(jobs, cfg).run()
    assert any(a.action in ("failure_shrink", "failure_requeue")
               for a in rep.actions)
    assert all(j.state is JobState.COMPLETED for j in rep.jobs)


def test_node_failure_rigid_requeues():
    jobs = make_workload(8, seed=3, malleable=False)
    cfg = SimConfig(num_nodes=64, flexible=False,
                    failures=((100.0, 0),))
    rep = ClusterSimulator(jobs, cfg).run()
    assert all(j.state is JobState.COMPLETED for j in rep.jobs)


def test_straggler_migration():
    jobs = make_workload(4, seed=3)
    cfg = SimConfig(num_nodes=64, flexible=True,
                    stragglers=((50.0, 0, 4.0),))
    rep = ClusterSimulator(jobs, cfg).run()
    assert all(j.state is JobState.COMPLETED for j in rep.jobs)
    # either migrated or the slow node was free
    assert any(a.action == "straggler_migrate" for a in rep.actions) or \
        rep.makespan > 0


def test_deterministic_given_seed():
    a = run(30, True)
    b = run(30, True)
    assert a.makespan == b.makespan
    assert len(a.actions) == len(b.actions)
