"""Property-based invariants for the policy zoo (sjf / fairshare / preempt /
moldable) pinned by ISSUE 2: no starvation, usage-monotone priorities,
capacity-safe preemption, power-of-two moldable starts."""
import random

try:
    from hypothesis import given, settings, strategies as st
except ImportError:                              # container has no hypothesis
    from _hypothesis_stub import given, settings, strategies as st

from repro.rms import (Cluster, Job, JobState, Scheduler, SchedulerConfig)
from repro.rms.scheduler import (FairSharePolicy, MoldableStartPolicy,
                                 PreemptiveBackfillPolicy, SJFPolicy)


def make_job(job_id, size, submit=0.0, *, min_nodes=1, max_nodes=None,
             user=0, state=JobState.PENDING, malleable=True, factor=2):
    j = Job(job_id=job_id, app="cg", submit_time=submit, work=100.0,
            min_nodes=min_nodes, max_nodes=max_nodes or size,
            preferred=None, factor=factor, malleable=malleable,
            requested_nodes=size, user=user)
    j.state = state
    if state is JobState.RUNNING:
        j.nodes = size
    return j


# ---------------------------------------------------------------------------
# SJF: bounded-age queues never starve the old job
# ---------------------------------------------------------------------------

@settings(max_examples=40, deadline=None)
@given(st.integers(0, 10_000))
def test_sjf_aged_jobs_jump_every_younger_job(seed):
    """Bounded-age generator: every job past the starvation guard must be
    ordered ahead of every younger job, whatever the runtime estimates."""
    rng = random.Random(seed)
    now = 10_000.0
    guard = 500.0
    cfg = SchedulerConfig(policy="sjf", sjf_starvation_age_s=guard)
    sched = Scheduler(Cluster(64), cfg)
    pol = sched.policy
    assert isinstance(pol, SJFPolicy)
    jobs, est = [], {}
    n_aged = rng.randint(1, 3)
    for i in range(n_aged + rng.randint(1, 6)):
        # first n_aged are past the guard, the rest strictly younger
        age = (guard + rng.uniform(0, 400) if i < n_aged
               else rng.uniform(0, guard - 1))
        jobs.append(make_job(i, rng.choice([1, 2, 4, 8]), now - age))
        est[i] = rng.uniform(1.0, 5_000.0)   # bounded estimates
    rng.shuffle(jobs)
    pol._est = lambda j: est[j.job_id]
    try:
        order = pol.order(jobs, now)
    finally:
        pol._est = None
    seen_young = False
    for j in order:
        aged = now - j.submit_time >= guard
        if not aged:
            seen_young = True
        assert not (aged and seen_young), \
            f"aged job {j.job_id} ordered behind a younger one"


@settings(max_examples=20, deadline=None)
@given(st.integers(0, 10_000))
def test_sjf_prefers_shorter_estimates_at_equal_age(seed):
    rng = random.Random(seed)
    now = 100.0
    sched = Scheduler(Cluster(64), SchedulerConfig(policy="sjf"))
    pol = sched.policy
    jobs, est = [], {}
    for i in range(6):
        jobs.append(make_job(i, 4, submit=0.0))     # identical age/size
        est[i] = rng.uniform(1.0, 1000.0)
    pol._est = lambda j: est[j.job_id]
    try:
        order = pol.order(jobs, now)
    finally:
        pol._est = None
    ests = [est[j.job_id] for j in order]
    assert ests == sorted(ests)


def test_sjf_starved_job_starts_first_when_nodes_free():
    """End-to-end through schedule(): the aged job heads the starts."""
    sched = Scheduler(Cluster(64),
                      SchedulerConfig(policy="sjf",
                                      sjf_starvation_age_s=100.0))
    old = make_job(0, 8, submit=0.0)              # age 1000: starved
    quick = make_job(1, 2, submit=950.0)          # age 50, tiny estimate
    est = {0: 5000.0, 1: 1.0}
    starts = sched.schedule([quick, old], [], 1000.0,
                            lambda j: est[j.job_id])
    assert [j.job_id for j, _ in starts][0] == 0


# ---------------------------------------------------------------------------
# Fairshare: priority monotone (decreasing) in recorded usage
# ---------------------------------------------------------------------------

@settings(max_examples=40, deadline=None)
@given(st.integers(0, 10_000))
def test_fairshare_priority_monotone_in_usage(seed):
    rng = random.Random(seed)
    sched = Scheduler(Cluster(64), SchedulerConfig(policy="fairshare"))
    pol = sched.policy
    assert isinstance(pol, FairSharePolicy)
    job = make_job(0, 4, submit=0.0, user=1)
    now = 500.0
    usages = sorted(rng.uniform(0, 1e6) for _ in range(6))
    prios = []
    for u in usages:
        pol._usage = {1: u}
        prios.append(pol.priority(job, now))
    for (u1, p1), (u2, p2) in zip(zip(usages, prios),
                                  zip(usages[1:], prios[1:])):
        assert (p2 < p1) or (u2 == u1), \
            f"priority not decreasing: usage {u1}->{u2}, prio {p1}->{p2}"


def test_fairshare_heavy_user_sinks_below_light_user():
    sched = Scheduler(Cluster(64), SchedulerConfig(policy="fairshare"))
    pol = sched.policy
    heavy = make_job(0, 4, submit=0.0, user=1)
    light = make_job(1, 4, submit=0.0, user=2)
    pol.record_usage(1, 1e6)
    order = pol.order([heavy, light], now=100.0)
    assert [j.job_id for j in order] == [1, 0]


def test_fairshare_usage_decays_toward_zero():
    cfg = SchedulerConfig(policy="fairshare", fairshare_halflife_s=100.0)
    pol = Scheduler(Cluster(64), cfg).policy
    pol.record_usage(1, 1000.0)
    pol.observe([], now=0.0)          # anchor the clock
    pol.observe([], now=100.0)        # one half-life
    assert pol.usage(1) == 500.0
    pol.observe([], now=1100.0)       # ten more
    assert pol.usage(1) < 1.0


def test_fairshare_charges_completed_job_tail_interval():
    """A job that completed between two passes is charged up to its
    end_time (regression: completion passes run after the job leaves the
    running set, so short jobs used to accrue zero usage)."""
    import pytest

    pol = Scheduler(Cluster(64), SchedulerConfig(policy="fairshare")).policy
    j = make_job(0, 4, user=1, state=JobState.RUNNING)
    j.start_time = 0.0
    j.record_nodes(0.0)
    pol.observe([j], 0.0)
    j.state = JobState.COMPLETED
    j.end_time = 50.0
    j.record_nodes(50.0)
    pol.observe([], 100.0)
    assert pol.usage(1) == pytest.approx(4 * 50.0)


def test_fairshare_charges_job_seen_only_pending():
    """A job that starts AND completes with no intervening scheduler pass
    is still billed — tracking starts at first sight (pending) and charges
    from nodes_history (regression: it used to accrue zero usage)."""
    import pytest

    pol = Scheduler(Cluster(64), SchedulerConfig(policy="fairshare")).policy
    j = make_job(0, 4, user=1)                    # PENDING, no history yet
    pol.observe([j], 0.0)
    j.state = JobState.RUNNING                    # starts after the pass...
    j.start_time = 0.0
    j.nodes = 4
    j.record_nodes(0.0)
    j.state = JobState.COMPLETED                  # ...and finishes before
    j.end_time = 26.0                             # the next one
    j.record_nodes(26.0)
    pol.observe([], 26.0)
    assert pol.usage(1) == pytest.approx(4 * 26.0)


def test_fairshare_charges_requeued_job_partial_interval():
    """A failure/preemption requeue zeroes the allocation mid-interval; the
    held node-seconds before the requeue must still be billed."""
    import pytest

    pol = Scheduler(Cluster(64), SchedulerConfig(policy="fairshare")).policy
    j = make_job(0, 8, user=1, state=JobState.RUNNING)
    j.record_nodes(0.0)
    pol.observe([j], 0.0)
    j.state = JobState.PENDING                    # requeued at t=30
    j.nodes = 0
    j.record_nodes(30.0)
    pol.observe([j], 100.0)
    assert pol.usage(1) == pytest.approx(8 * 30.0)


def test_fairshare_no_overcharge_before_start():
    """A job that started mid-interval is charged only from its start."""
    import pytest

    pol = Scheduler(Cluster(64), SchedulerConfig(policy="fairshare")).policy
    pol.observe([], 0.0)
    j = make_job(0, 4, user=1, state=JobState.RUNNING)
    j.start_time = 80.0
    j.record_nodes(80.0)
    pol.observe([j], 100.0)
    assert pol.usage(1) == pytest.approx(4 * 20.0)


def test_fairshare_accrues_usage_in_simulation():
    """End-to-end: serial non-overlapping jobs must leave a non-empty
    usage ledger (regression: the ledger used to stay empty because every
    pass saw the job either not-yet-running or already completed)."""
    from repro.rms import ClusterSimulator, SimConfig
    from repro.rms.costmodel import PAPER_APPS

    jobs = []
    for i in range(3):
        jobs.append(Job(job_id=i, app="cg", submit_time=200.0 * i,
                        work=100.0, min_nodes=1, max_nodes=4,
                        preferred=None, malleable=False,
                        requested_nodes=4, user=1))
    sim = ClusterSimulator(
        jobs, SimConfig(num_nodes=64, flexible=False,
                        sched=SchedulerConfig(policy="fairshare")),
        apps=dict(PAPER_APPS))
    rep = sim.run()
    assert all(j.state is JobState.COMPLETED for j in rep.jobs)
    assert sum(sim.scheduler.policy._usage.values()) > 0


def test_fairshare_resize_and_finish_same_pass_billed_exactly():
    """A job that resizes *and* finishes between two passes is billed from
    its full nodes_history: 8 nodes for 30 s, then 4 nodes for 20 s."""
    import pytest

    pol = Scheduler(Cluster(64), SchedulerConfig(policy="fairshare")).policy
    j = make_job(0, 8, user=1, state=JobState.RUNNING)
    j.record_nodes(0.0)
    pol.observe([j], 0.0)
    j.nodes = 4                      # shrink at t=30 (no pass in between)
    j.record_nodes(30.0)
    j.state = JobState.COMPLETED     # finish at t=50, same upcoming pass
    j.end_time = 50.0
    j.record_nodes(50.0)
    pol.observe([], 60.0)
    assert pol.usage(1) == pytest.approx(8 * 30.0 + 4 * 20.0)


@settings(max_examples=8, deadline=None)
@given(st.integers(0, 10_000))
def test_fairshare_billing_exact_under_resizes_and_phase_changes(seed):
    """Property (ISSUE 3 billing audit): with decay disabled, the total
    billed fair-share usage equals the exact node-seconds integral of every
    job's allocation history — under DMR resizes, PhaseChange-forced
    resizes firing *between* passes, and resize+finish landing in the same
    pass."""
    import sys, os
    import pytest
    sys.path.insert(0, os.path.dirname(__file__))
    from synthetic_swf import synthetic_swf

    from repro.rms import ClusterSimulator, SimConfig
    from repro.workload import MalleabilityMix, jobs_from_swf, parse_swf

    rng = random.Random(seed)
    lines, _ = synthetic_swf()
    trace = parse_swf(lines)
    evolving = rng.choice([0.0, 0.3, 0.6])
    malleable = rng.choice([0.2, 0.4]) * (1.0 - evolving)
    rigid = 1.0 - malleable - evolving
    mix = MalleabilityMix(rigid=rigid, moldable=0.0, malleable=malleable,
                          evolving=evolving)
    jobs, apps = jobs_from_swf(trace, num_nodes=32, mix=mix,
                               seed=rng.randint(0, 99),
                               max_jobs=rng.randint(15, 30),
                               time_scale=0.15)
    cfg = SimConfig(num_nodes=32, flexible=True,
                    sched=SchedulerConfig(policy="fairshare",
                                          fairshare_halflife_s=1e15))
    sim = ClusterSimulator(jobs, cfg, apps=apps)
    rep = sim.run()
    assert all(j.state is JobState.COMPLETED for j in rep.jobs)
    exact = sum(j.node_seconds() for j in rep.jobs)
    billed = sum(sim.scheduler.policy._usage.values())
    assert billed == pytest.approx(exact, rel=1e-9)


def test_fairshare_boost_still_dominates():
    pol = Scheduler(Cluster(64), SchedulerConfig(policy="fairshare")).policy
    job = make_job(0, 4, user=1)
    job.priority_boost = 1e12
    pol.record_usage(1, 1e9)
    assert pol.priority(job, 100.0) == 1e12


# ---------------------------------------------------------------------------
# Preempt: capacity-safe, head never delayed, victims stay factor-valid
# ---------------------------------------------------------------------------

def preempt_case(seed, *, requeue=False, num_nodes=32):
    rng = random.Random(seed)
    cluster = Cluster(num_nodes)
    cfg = SchedulerConfig(policy="preempt", preempt_grace_s=10.0,
                          preempt_requeue=requeue)
    sched = Scheduler(cluster, cfg)
    running, est = [], {}
    for i in range(rng.randint(1, 4)):
        size = rng.choice([2, 4, 8, 16])
        if cluster.free_nodes < size:
            break
        j = make_job(100 + i, size, submit=rng.uniform(0, 5),
                     min_nodes=rng.choice([1, 2]),
                     state=JobState.RUNNING,
                     malleable=rng.random() < 0.8)
        cluster.allocate(j.job_id, size)
        est[j.job_id] = rng.uniform(500.0, 5000.0)   # far releases: slip
        running.append(j)
    pending = []
    for i in range(rng.randint(1, 5)):
        j = make_job(i, rng.choice([2, 4, 8, 16, 32]),
                     submit=rng.uniform(0, 40))
        est[j.job_id] = rng.uniform(10.0, 500.0)
        pending.append(j)
    return cluster, sched, running, pending, est


@settings(max_examples=40, deadline=None)
@given(st.integers(0, 10_000), st.booleans())
def test_preempt_never_exceeds_capacity(seed, requeue):
    cluster, sched, running, pending, est = preempt_case(seed,
                                                         requeue=requeue)
    free_before = cluster.free_nodes
    starts = sched.schedule(pending, running, 60.0,
                            lambda j: est[j.job_id])
    plan = sched.pop_preemptions()
    freed = sum(v.nodes - max(new, 0) for v, new in plan)
    assert sum(n for _, n in starts) <= free_before + freed
    # schedule() must not have touched the cluster
    assert cluster.free_nodes == free_before


@settings(max_examples=40, deadline=None)
@given(st.integers(0, 10_000))
def test_preempt_head_starts_when_preempting(seed):
    """If a preemption plan was emitted, the blocked head it was built for
    must be in the starts — preemption may never delay the head."""
    cluster, sched, running, pending, est = preempt_case(seed)
    now = 60.0
    order = sched.order(list(pending), now)
    starts = sched.schedule(pending, running, now,
                            lambda j: est[j.job_id])
    plan = sched.pop_preemptions()
    if not plan:
        return
    started = {j.job_id for j, _ in starts}
    # the head := first job in priority order not startable on free nodes
    free = cluster.free_nodes
    head = None
    for j in order:
        if j.requested_nodes <= free:
            free -= j.requested_nodes
        else:
            head = j
            break
    assert head is not None and head.job_id in started


@settings(max_examples=40, deadline=None)
@given(st.integers(0, 10_000), st.booleans())
def test_preempt_victims_shrink_factor_consistent(seed, requeue):
    cluster, sched, running, pending, est = preempt_case(seed,
                                                         requeue=requeue)
    sched.schedule(pending, running, 60.0, lambda j: est[j.job_id])
    for victim, new in sched.pop_preemptions():
        assert victim.malleable
        if new == 0:
            assert requeue               # requeue only when enabled
        else:
            assert new == victim.nodes // max(victim.factor, 2)
            assert new >= max(victim.min_nodes, 1)


def test_preempt_within_grace_falls_back_to_easy():
    """Head reservation lands inside the grace window: no preemption."""
    cluster = Cluster(16)
    runner = make_job(99, 16, state=JobState.RUNNING, min_nodes=1)
    cluster.allocate(99, 16)
    head = make_job(0, 8, submit=0.0)
    sched = Scheduler(cluster, SchedulerConfig(policy="preempt",
                                               preempt_grace_s=60.0))
    est = {99: 30.0, 0: 100.0}          # runner releases in 30 s < grace
    starts = sched.schedule([head], [runner], 1000.0,
                            lambda j: est[j.job_id])
    assert sched.pop_preemptions() == []
    assert starts == []


def test_preempt_simulation_respects_capacity_and_finishes():
    """End-to-end: a preempting replay never over-allocates the cluster."""
    from repro.rms import ClusterSimulator, SimConfig
    from repro.workload import MalleabilityMix, jobs_from_swf, parse_swf
    import os

    trace = parse_swf(os.path.join(os.path.dirname(__file__), "data",
                                   "sample.swf"))
    jobs, apps = jobs_from_swf(
        trace, num_nodes=32,
        mix=MalleabilityMix(rigid=0.0, moldable=0.0, malleable=1.0), seed=7)
    sim = ClusterSimulator(
        jobs, SimConfig(num_nodes=32, flexible=True,
                        sched=SchedulerConfig(policy="preempt",
                                              preempt_grace_s=5.0)),
        apps=apps)
    rep = sim.run()
    assert all(j.state is JobState.COMPLETED for j in rep.jobs)
    assert all(alloc <= 32 for _, alloc, _, _ in rep.timeline)


def test_preempt_requeue_simulation_preserves_progress():
    """End-to-end through the simulator's requeue branch: a victim at its
    minimum size is requeued (not shrunk) for a boosted head, restarts
    later, and its pre-requeue progress survives — both in work_done and in
    the checkpoint restore point (regression: restart used to reset
    _ckpt_work to 0, so a later failure erased the preserved progress)."""
    from repro.rms import AppModel, ClusterSimulator, SimConfig, MAX_PRIORITY

    apps = {
        # victim: malleable but already at min size -> only requeue frees it
        "vic": AppModel("vic", iterations=1000, t1_iter_s=8.0,
                        serial_frac=0.0, data_bytes=1 << 20, min_nodes=8,
                        max_nodes=8, preferred=None, check_period_s=15.0),
        # head: rigid, needs the whole cluster
        "big": AppModel("big", iterations=100, t1_iter_s=16.0,
                        serial_frac=0.0, data_bytes=0, min_nodes=16,
                        max_nodes=16, preferred=None, check_period_s=0.0),
    }
    victim = Job(job_id=0, app="vic", submit_time=0.0, work=1000.0,
                 min_nodes=8, max_nodes=8, preferred=None, malleable=True,
                 check_period_s=15.0, requested_nodes=8, data_bytes=1 << 20)
    head = Job(job_id=1, app="big", submit_time=20.0, work=100.0,
               min_nodes=16, max_nodes=16, preferred=None, malleable=False,
               requested_nodes=16)
    head.priority_boost = MAX_PRIORITY      # the §4.3 max-priority path
    sim = ClusterSimulator(
        [victim, head],
        SimConfig(num_nodes=16, flexible=True, checkpoint_period_s=0.0,
                  sched=SchedulerConfig(policy="preempt",
                                        preempt_grace_s=5.0,
                                        preempt_requeue=True)))
    sim.apps = apps
    rep = sim.run()
    assert any(a.action == "preempt_requeue" for a in rep.actions)
    assert all(j.state is JobState.COMPLETED for j in rep.jobs)
    assert all(alloc <= 16 for _, alloc, _, _ in rep.timeline)
    # ~19 work units were done before the requeue at t=20; the restart's
    # restore point must carry them instead of resetting to zero.
    assert sim._ckpt_work[0] > 0
    # and the victim's total span reflects the preserved progress: restart
    # at ~121 s + remaining ~981 iterations, well under a full re-run
    assert head.end_time < victim.end_time < 1115.0


# ---------------------------------------------------------------------------
# Moldable: power-of-two starts within [min, max]
# ---------------------------------------------------------------------------

@settings(max_examples=40, deadline=None)
@given(st.integers(0, 10_000))
def test_moldable_best_start_is_pow2_in_range(seed):
    rng = random.Random(seed)
    pol = Scheduler(Cluster(64), SchedulerConfig(policy="moldable")).policy
    assert isinstance(pol, MoldableStartPolicy)
    for _ in range(10):
        lo = rng.randint(1, 16)
        hi = rng.randint(lo, 64)
        size = rng.randint(lo, hi)
        job = make_job(0, size, min_nodes=lo, max_nodes=hi,
                       malleable=rng.random() < 0.5)
        job.data_bytes = rng.choice([0, 1 << 30])
        free = rng.randint(0, 64)
        s = pol.best_start(job, free, lambda j: 600.0)
        if s is None:
            # nothing viable: no pow2 in [lo, hi] fits free
            assert all(c > free
                       for c in pol.candidate_sizes(job)) \
                or not pol.candidate_sizes(job)
        else:
            assert s & (s - 1) == 0          # power of two
            assert max(job.min_nodes, 1) <= s <= job.max_nodes
            assert s <= free


@settings(max_examples=20, deadline=None)
@given(st.integers(0, 10_000))
def test_moldable_schedule_sizes_stay_in_range(seed):
    rng = random.Random(seed)
    cluster = Cluster(64)
    sched = Scheduler(cluster, SchedulerConfig(policy="moldable"))
    pending = []
    for i in range(rng.randint(1, 8)):
        lo = rng.choice([1, 2, 3])
        hi = rng.choice([4, 8, 16, 32])
        pending.append(make_job(i, rng.randint(lo, hi), min_nodes=lo,
                                max_nodes=hi,
                                submit=rng.uniform(0, 50)))
    starts = sched.schedule(pending, [], 60.0, lambda j: 600.0)
    total = 0
    for j, n in starts:
        total += n
        assert max(j.min_nodes, 1) <= n <= j.max_nodes
        if pol_has_pow2(j):
            assert n & (n - 1) == 0
    assert total <= 64


def pol_has_pow2(job):
    return bool(MoldableStartPolicy.candidate_sizes(job))


def test_moldable_prefers_larger_size_when_free():
    """With no reconfig penalty, a bigger power of two means a shorter
    estimated runtime, so the optimizer takes it."""
    pol = Scheduler(Cluster(64), SchedulerConfig(policy="moldable")).policy
    job = make_job(0, 8, min_nodes=1, max_nodes=32)
    job.data_bytes = 0
    assert pol.best_start(job, 64, lambda j: 600.0) == 32
    assert pol.best_start(job, 7, lambda j: 600.0) == 4


def test_moldable_reconfig_cost_pulls_toward_preferred():
    """When redistribution dominates the runtime gain (short job, huge
    state), overshooting the preferred size is a bad trade and the
    optimizer stays at the preferred size; with no state to move it takes
    the largest size instead."""
    pol = Scheduler(Cluster(64), SchedulerConfig(policy="moldable")).policy
    job = make_job(0, 8, min_nodes=1, max_nodes=32)
    job.preferred = 8
    job.malleable = True
    job.data_bytes = 1 << 45            # 32 TiB vs a 60 s runtime
    assert pol.best_start(job, 64, lambda j: 60.0) == 8
    job.data_bytes = 0
    assert pol.best_start(job, 64, lambda j: 60.0) == 32


def test_moldable_no_pow2_in_range_starts_as_requested():
    """A range with no power of two (e.g. [5, 7]) starts unchanged."""
    sched = Scheduler(Cluster(64), SchedulerConfig(policy="moldable"))
    job = make_job(0, 6, min_nodes=5, max_nodes=7)
    starts = sched.schedule([job], [], 10.0, lambda j: 600.0)
    assert starts == [(job, 6)]
