"""DMR reconfiguration policy (paper §4) unit tests."""
import pytest

from repro.core.actions import Action
from repro.rms.cluster import Cluster
from repro.rms.job import Job, JobState
from repro.rms.policy import ReconfigPolicy, factor_sizes


def make_job(jid, nodes, requested=None, state=JobState.RUNNING):
    j = Job(job_id=jid, app="cg", submit_time=0.0, work=100,
            min_nodes=2, max_nodes=32, preferred=8,
            requested_nodes=requested or nodes)
    j.state = state
    j.nodes = nodes
    return j


def cluster_with(jobs, num_nodes=64):
    c = Cluster(num_nodes)
    for j in jobs:
        if j.state is JobState.RUNNING:
            c.allocate(j.job_id, j.nodes)
    return c


def test_factor_sizes_single_step():
    # one factor step per action (Fig. 3 measures exactly these pairs)
    assert factor_sizes(8, 2, 1, 64) == [4, 16]
    assert factor_sizes(1, 2, 1, 64) == [2]
    assert factor_sizes(64, 2, 1, 64) == [32]
    assert factor_sizes(9, 2, 1, 64) == [18]  # 9 not divisible by 2


def test_mode1_requested_expand():
    pol = ReconfigPolicy()
    job = make_job(0, 8)
    c = cluster_with([job])
    d = pol.decide(c, [], job, minimum=16, maximum=32, factor=2)
    assert d.action is Action.EXPAND and d.new_slices == 16


def test_mode1_requested_expand_denied_when_full():
    pol = ReconfigPolicy()
    job = make_job(0, 8)
    other = make_job(1, 56)
    c = cluster_with([job, other])
    d = pol.decide(c, [], job, minimum=16, maximum=32, factor=2)
    assert d.action is Action.NO_ACTION


def test_mode1_requested_shrink():
    pol = ReconfigPolicy()
    job = make_job(0, 16)
    c = cluster_with([job])
    d = pol.decide(c, [], job, minimum=2, maximum=8, factor=2)
    assert d.action is Action.SHRINK and d.new_slices == 8


def test_mode2_at_preferred_no_action_under_queue():
    pol = ReconfigPolicy()
    job = make_job(0, 8)
    queued = make_job(1, 0, requested=32, state=JobState.PENDING)
    c = cluster_with([job])
    d = pol.decide(c, [queued], job, minimum=2, maximum=32, factor=2,
                   preferred=8)
    assert d.action is Action.NO_ACTION
    assert d.reason == "at-preferred"


def test_mode2_empty_queue_grows_to_max():
    pol = ReconfigPolicy()
    job = make_job(0, 8)
    c = cluster_with([job])
    d = pol.decide(c, [], job, minimum=2, maximum=32, factor=2, preferred=8)
    assert d.action is Action.EXPAND and d.new_slices == 16


def test_mode2_shrinks_toward_preferred_under_queue():
    pol = ReconfigPolicy()
    job = make_job(0, 32)
    queued = make_job(1, 0, requested=32, state=JobState.PENDING)
    c = cluster_with([job])
    d = pol.decide(c, [queued], job, minimum=2, maximum=32, factor=2,
                   preferred=8)
    assert d.action is Action.SHRINK and d.new_slices == 16  # one step


def test_mode3_wide_expand_only_if_queue_cannot_use():
    pol = ReconfigPolicy()
    job = make_job(0, 16)
    # queued job fits in free nodes -> no expansion
    small = make_job(1, 0, requested=16, state=JobState.PENDING)
    c = cluster_with([job])  # 48 free
    d = pol.decide(c, [small], job, minimum=2, maximum=32, factor=2)
    assert d.action is not Action.EXPAND
    # queued job too big for free nodes -> expansion allowed
    big = make_job(2, 0, requested=64, state=JobState.PENDING)
    d = pol.decide(c, [big], job, minimum=2, maximum=32, factor=2)
    assert d.action is Action.EXPAND


def test_mode3_wide_shrink_boosts_trigger_job():
    pol = ReconfigPolicy()
    a = make_job(0, 32)
    b = make_job(1, 24)
    queued = make_job(2, 0, requested=16, state=JobState.PENDING)
    c = cluster_with([a, b])  # 8 free; shrinking a 32->16 frees 16
    d = pol.decide(c, [queued], a, minimum=2, maximum=32, factor=2)
    assert d.action is Action.SHRINK and d.new_slices == 16
    assert d.boost_job_id == 2


def test_expansion_respects_free_nodes():
    pol = ReconfigPolicy()
    job = make_job(0, 32)
    other = make_job(1, 24)
    c = cluster_with([job, other])  # 8 free < 32 needed for 32->64
    d = pol.decide(c, [], job, minimum=2, maximum=64, factor=2)
    assert d.action is Action.NO_ACTION
