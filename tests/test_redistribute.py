"""Factor-based redistribution plans (Listing 3 / Fig. 2) + cost model."""
import numpy as np
try:
    from hypothesis import given, settings, strategies as st
except ImportError:                              # container has no hypothesis
    from _hypothesis_stub import given, settings, strategies as st

from repro.core import expand_plan, shrink_plan, transfer_time_s

sizes = st.sampled_from([1, 2, 4, 8, 16, 32, 64])


@settings(max_examples=60, deadline=None)
@given(sizes, st.integers(1, 3), st.integers(10, 30))
def test_expand_plan_conserves_bytes(p, log_f, log_bytes):
    q = p * (2 ** log_f)
    nbytes = (2 ** log_bytes)
    plan = expand_plan(p, q, nbytes)
    chunk = nbytes // q
    assert sum(t.nbytes for t in plan) == chunk * q
    # every destination receives exactly one chunk
    dsts = sorted(t.dst for t in plan)
    assert dsts == list(range(q))


@settings(max_examples=60, deadline=None)
@given(sizes, st.integers(1, 3), st.integers(10, 30))
def test_shrink_plan_folds_groups(p, log_f, log_bytes):
    f = 2 ** log_f
    if p % f:
        return
    q = p // f
    if q < 1:
        return
    plan = shrink_plan(p, q, 2 ** log_bytes)
    # Listing 3: receiver of group g is rank g*f + f-1, continuing as rank g
    for t in plan:
        assert t.dst == t.src // f
        if t.local:
            assert t.src % f == f - 1


def test_expand_reuses_original_nodes():
    plan = expand_plan(4, 8, 1024)
    local = [t for t in plan if t.local]
    assert len(local) == 4          # each old rank keeps one chunk


def test_more_participants_faster():
    """Fig. 3b: more processes involved => shorter resize."""
    t_small = transfer_time_s(expand_plan(1, 2, 1 << 30), link_bw=5e9)
    t_large = transfer_time_s(expand_plan(32, 64, 1 << 30), link_bw=5e9)
    assert t_large < t_small


def test_shrink_sync_overhead():
    """Shrinks pay synchronization per participant (paper §7.3)."""
    base = transfer_time_s(shrink_plan(64, 32, 1 << 30), link_bw=5e9)
    sync = transfer_time_s(shrink_plan(64, 32, 1 << 30), link_bw=5e9,
                           sync_s_per_participant=0.004)
    assert sync > base
