"""Deterministic synthetic SWF trace corpus (~200 jobs) for tests.

``tests/data/sample.swf`` is only 24 hand-written jobs; scheduler and sweep
tests that exercise queueing depth, backfill windows, and fair-share over
many users need a bigger, *generated* corpus so they stop over-fitting to
one tiny trace.  The generator is pure-numpy, fully seeded, and returns the
intended field values alongside the SWF text so the parser round-trip test
can compare them exactly (all numeric fields are integers).
"""
from __future__ import annotations

from typing import Dict, List, Tuple

import numpy as np

#: Generator defaults — one canonical corpus shared by the tests.
N_JOBS = 200
SEED = 1234
MAX_NODES = 64

#: Canonical evolving-heavy mix (rigid, moldable, malleable, evolving) used
#: by the evolving-job tests and the CI smoke grid.
EVOLVING_MIX = (0.2, 0.1, 0.4, 0.3)


def evolving_corpus_jobs(n_jobs: int = 60, *, seed: int = 7,
                         num_nodes: int = MAX_NODES,
                         time_scale: float = 0.2):
    """A deterministic evolving-heavy slice of the corpus, ready for
    ``ClusterSimulator``: returns ``(jobs, apps)`` with :data:`EVOLVING_MIX`
    annotation so tests exercise phase schedules over real queueing depth."""
    from repro.workload import MalleabilityMix, jobs_from_swf, parse_swf

    lines, _ = synthetic_swf()
    trace = parse_swf(lines)
    mix = MalleabilityMix(*EVOLVING_MIX)
    return jobs_from_swf(trace, num_nodes=num_nodes, mix=mix, seed=seed,
                         max_jobs=n_jobs, time_scale=time_scale)


def synthetic_swf(n_jobs: int = N_JOBS, *, seed: int = SEED,
                  max_nodes: int = MAX_NODES
                  ) -> Tuple[List[str], List[Dict[str, int]]]:
    """Returns ``(lines, records)``: SWF text lines (header + jobs) and the
    intended per-job field dicts (job_id, submit, run, procs, reqtime,
    user) for round-trip checks.

    Shape: Poisson-ish arrivals (mean 30 s), sizes biased to small powers
    of two with a ~25% non-power-of-two tail, log-normal runtimes clamped
    to [10 s, 4 h], 8 submitting users.
    """
    rng = np.random.default_rng(seed)
    lines = [
        "; Synthetic SWF corpus for tier-1 scheduler tests "
        f"({n_jobs} jobs, seed {seed})",
        "; Version: 2.2",
        f"; Computer: synthetic-{max_nodes}",
        f"; MaxJobs: {n_jobs}",
        f"; MaxNodes: {max_nodes}",
        f"; MaxProcs: {max_nodes}",
    ]
    records: List[Dict[str, int]] = []
    t = 0.0
    log_max = int(np.log2(max_nodes))
    for i in range(1, n_jobs + 1):
        t += float(rng.exponential(30.0))
        submit = int(round(t))
        size = int(2 ** rng.integers(0, log_max + 1))
        if rng.random() < 0.25 and size > 1:
            size = max(1, size - int(rng.integers(1, 3)))
        run = int(np.clip(round(rng.lognormal(5.5, 1.0)), 10, 4 * 3600))
        reqtime = int(round(run * float(rng.uniform(1.1, 3.0))))
        user = int(rng.integers(1, 9))
        rec = {"job_id": i, "submit": submit, "run": run, "procs": size,
               "reqtime": reqtime, "user": user}
        records.append(rec)
        lines.append(f"{i} {submit} 0 {run} {size} -1 -1 {size} {reqtime} "
                     f"-1 1 {user} 1 {1 + i % 4} 1 1 -1 -1")
    return lines, records
