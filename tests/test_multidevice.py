"""Multi-device semantics on CPU host devices (subprocess, 8 devices).

Validates for real what the dry-run only compiles: elastic resharding
across meshes of different sizes (values + Listing-3 ownership), slice
migration, and an elastic train loop that expands mid-run without changing
the math.
"""
import json
import os
import subprocess
import sys
import textwrap

import pytest

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def run_sub(code: str) -> dict:
    env = dict(os.environ,
               XLA_FLAGS="--xla_force_host_platform_device_count=8",
               PYTHONPATH=os.path.join(REPO, "src"),
               JAX_PLATFORMS="cpu")
    prelude = "import json, jax, jax.numpy as jnp, numpy as np\n"
    proc = subprocess.run([sys.executable, "-c",
                           prelude + textwrap.dedent(code)],
                          capture_output=True, text=True, env=env,
                          timeout=600)
    assert proc.returncode == 0, proc.stderr[-4000:]
    return json.loads(proc.stdout.strip().splitlines()[-1])


@pytest.mark.slow
def test_reshard_expand_preserves_values_and_layout():
    out = run_sub("""
    from repro.core import make_mesh, reshard, ownership_map
    from jax.sharding import NamedSharding, PartitionSpec as P
    x = jnp.arange(64.0).reshape(8, 8)
    m2 = make_mesh(2, 1)
    m4 = make_mesh(4, 1)
    x2 = jax.device_put(x, NamedSharding(m2, P("data")))
    x4 = reshard(x2, NamedSharding(m4, P("data")))
    own = ownership_map(x4)
    # Listing 3 expand: old rank r's rows split between new ranks 2r, 2r+1
    starts = sorted(idx[0].start or 0 for idx in own.values())
    print(json.dumps({
        "equal": bool((np.asarray(x4) == np.asarray(x)).all()),
        "ndev": len(own), "starts": starts}))
    """)
    assert out["equal"] and out["ndev"] == 4
    assert out["starts"] == [0, 2, 4, 6]


@pytest.mark.slow
def test_reshard_shrink_and_roundtrip():
    out = run_sub("""
    from repro.core import make_mesh, reshard
    from jax.sharding import NamedSharding, PartitionSpec as P
    x = jax.random.normal(jax.random.PRNGKey(0), (16, 4))
    m8, m2 = make_mesh(8, 1), make_mesh(2, 1)
    x8 = jax.device_put(x, NamedSharding(m8, P("data")))
    x2 = reshard(x8, NamedSharding(m2, P("data")))
    back = reshard(x2, NamedSharding(m8, P("data")))
    print(json.dumps({
        "shrink_ok": bool(np.allclose(np.asarray(x2), np.asarray(x))),
        "roundtrip_ok": bool(np.allclose(np.asarray(back),
                                         np.asarray(x)))}))
    """)
    assert out["shrink_ok"] and out["roundtrip_ok"]


@pytest.mark.slow
def test_migrate_slice_swaps_shards():
    out = run_sub("""
    from repro.core import make_mesh, migrate_slice
    from jax.sharding import NamedSharding, PartitionSpec as P
    m = make_mesh(4, 1)
    x = jnp.repeat(jnp.arange(4.0)[:, None], 3, axis=1)   # row i = i
    xs = jax.device_put(x, NamedSharding(m, P("data")))
    y = migrate_slice(xs, m, 0, 2)
    print(json.dumps({"rows": np.asarray(y)[:, 0].tolist()}))
    """)
    assert out["rows"] == [2.0, 1.0, 0.0, 3.0]


@pytest.mark.slow
def test_elastic_training_expand_matches_fixed():
    """A job that expands 2->4 slices mid-run must compute the same math
    (same loss trajectory) as one that never resizes."""
    out = run_sub("""
    import dataclasses
    from repro.core import Action, Decision
    from repro.models import build_model, get_model, reduced_config
    from repro.runtime import ElasticTrainer, TrainerConfig
    from repro.optim import AdamWConfig
    from repro.data import DataConfig

    class ScriptedRMS:
        def __init__(self, script):
            self.script = dict(script)
            self.calls = 0
        def request_reconfig(self, job_id, *, current, minimum, maximum,
                             factor, preferred):
            self.calls += 1
            return self.script.get(self.calls,
                                   Decision(Action.NO_ACTION, current))
        def confirm_resize(self, job_id, decision, timeout_s):
            return True, 0.0

    _, full = get_model("smollm-135m")
    cfg = dataclasses.replace(reduced_config(full), dtype="float32")
    model = build_model(cfg)
    data = DataConfig(vocab_size=cfg.vocab_size, seq_len=32, global_batch=8)
    opt = AdamWConfig(lr=1e-3, warmup_steps=2, total_steps=30)

    def run(rms, slices):
        tr = ElasticTrainer(model, opt, data,
                            TrainerConfig(steps=20, model_ways=1,
                                          max_slices=slices,
                                          check_period=5, log_period=5),
                            rms=rms)
        tr.slices = min(tr.slices, 2) if rms else tr.slices
        if rms:
            from repro.core import make_mesh
            tr.slices = 2
            tr.mesh = make_mesh(2, 1)
            tr.dmr.current_slices = 2
        tr.train()
        return [m["loss"] for m in tr.metrics], tr.resize_log

    base_losses, _ = run(None, 4)
    rms = ScriptedRMS({1: Decision(Action.EXPAND, 4)})
    el_losses, resizes = run(rms, 4)
    diffs = [abs(a - b) for a, b in zip(base_losses, el_losses)]
    print(json.dumps({"max_diff": max(diffs), "resizes": len(resizes)}))
    """)
    assert out["resizes"] == 1
    # resharding changes psum reduction topology -> float reassociation;
    # trajectories must agree to well under 1% of the loss scale (~7.6)
    assert out["max_diff"] < 0.05


@pytest.mark.slow
def test_compressed_allreduce_error_feedback_converges():
    """Single-shot int8 sync has bounded error; with error feedback the
    *running average* of synced gradients converges to the true mean —
    the property that preserves SGD convergence."""
    out = run_sub("""
    from repro.core import make_mesh
    from repro.optim.compression import compressed_psum_grads
    from jax.experimental.shard_map import shard_map
    from jax.sharding import NamedSharding, PartitionSpec as P
    mesh = make_mesh(4, 1)
    key = jax.random.PRNGKey(0)
    g_all = jax.random.normal(key, (4, 64))   # per-slice gradients

    def body(g):
        e = jnp.zeros_like(g[0])
        acc = jnp.zeros_like(g[0])
        first_err = None
        for t in range(12):
            mean, errs = compressed_psum_grads(
                {"g": g[0]}, mesh, axes=("data",), errors={"g": e})
            e = errs["g"]
            acc = acc + mean["g"]
            if t == 0:
                first_err = mean["g"]
        return first_err[None], (acc / 12)[None]

    fn = shard_map(body, mesh=mesh, in_specs=P("data"),
                   out_specs=(P("data"), P("data")), check_rep=False)
    first, avg = fn(g_all)
    truth = np.asarray(g_all).mean(axis=0)
    rel1 = np.abs(np.asarray(first)[0] - truth).max() / \
        (np.abs(truth).max() + 1e-9)
    relN = np.abs(np.asarray(avg)[0] - truth).max() / \
        (np.abs(truth).max() + 1e-9)
    print(json.dumps({"rel_single": float(rel1), "rel_avg": float(relN)}))
    """)
    assert out["rel_single"] < 0.25          # bounded single-shot error
    assert out["rel_avg"] < out["rel_single"]  # EF drives the bias down
    assert out["rel_avg"] < 0.05
