"""repro.lint determinism analyzer: per-rule fixtures + meta checks.

For every rule: a positive fixture (true finding), a negative fixture
(compliant code, no finding), and a pragma-suppressed fixture.  Plus:

- JSON report schema stability (``repro.lint/v1``, sorted findings, no
  timestamps — safe to golden-compare),
- the meta-check that the committed ``src/`` tree is lint-clean,
- regression pins for the true-positive findings fixed in this PR
  (shared default-config instances, winner-table ordering).
"""
import json
import os
import subprocess
import sys

import pytest

from repro.lint import (REGISTRY, SCHEMA, Finding, lint_paths, lint_source,
                        make_rules, render_json, to_json_doc)

SRC = os.path.join(os.path.dirname(os.path.dirname(
    os.path.abspath(__file__))), "src")


def rules_of(findings):
    return [f.rule for f in findings]


def lint_rms(source, **kw):
    """Lint a fixture as if it lived in a determinism-critical module."""
    return lint_source(source, path="src/repro/rms/fixture.py", **kw)


# ---------------------------------------------------------------------------
# DET001 — unordered iteration
# ---------------------------------------------------------------------------

def test_det001_positive_negative_pragma():
    pos = "for k, v in table.items():\n    emit(k, v)\n"
    assert rules_of(lint_rms(pos, select=["DET001"])) == ["DET001"]
    neg = "for k, v in sorted(table.items()):\n    emit(k, v)\n"
    assert lint_rms(neg, select=["DET001"]) == []
    sup = ("for k, v in table.items():   # lint: disable=DET001\n"
           "    emit(k, v)\n")
    assert lint_rms(sup, select=["DET001"]) == []


def test_det001_comprehension_and_set_literal():
    pos = "out = [v for v in table.values()]\n"
    assert rules_of(lint_rms(pos, select=["DET001"])) == ["DET001"]
    # order-insensitive consumer: the comprehension feeds sum/max/sorted
    neg = "out = sorted(v for v in table.values())\n"
    assert lint_rms(neg, select=["DET001"]) == []
    pos_set = "for x in {3, 1, 2}:\n    emit(x)\n"
    assert rules_of(lint_rms(pos_set, select=["DET001"])) == ["DET001"]


def test_det001_only_fires_in_critical_dirs():
    pos = "for k in table.items():\n    emit(k)\n"
    assert lint_source(pos, path="src/repro/cli/tool.py",
                       select=["DET001"]) == []


# ---------------------------------------------------------------------------
# DET002 — float accumulation over unordered iterables
# ---------------------------------------------------------------------------

def test_det002_positive_negative_pragma():
    pos = "total = sum(weights.values())\n"
    assert rules_of(lint_rms(pos, select=["DET002"])) == ["DET002"]
    neg = "total = sum(sorted(weights.values()))\n"
    assert lint_rms(neg, select=["DET002"]) == []
    # integer-valued accumulation is order-independent: len() elements
    neg_int = "total = sum(len(v) for v in table.values())\n"
    assert lint_rms(neg_int, select=["DET002"]) == []
    sup = "total = sum(weights.values())  # lint: disable=DET002\n"
    assert lint_rms(sup, select=["DET002"]) == []


# ---------------------------------------------------------------------------
# ENT001 — wall-clock / entropy calls
# ---------------------------------------------------------------------------

def test_ent001_positive_negative_pragma():
    pos = "import time\nt0 = time.time()\n"
    assert rules_of(lint_rms(pos, select=["ENT001"])) == ["ENT001"]
    neg = "import time\nt0 = time.perf_counter()\n"
    assert lint_rms(neg, select=["ENT001"]) == []
    sup = "import time\nt0 = time.time()  # lint: disable=ENT001\n"
    assert lint_rms(sup, select=["ENT001"]) == []


def test_ent001_rng_discipline():
    assert rules_of(lint_rms("x = random.random()\n",
                             select=["ENT001"])) == ["ENT001"]
    assert rules_of(lint_rms("rng = np.random.default_rng()\n",
                             select=["ENT001"])) == ["ENT001"]
    assert rules_of(lint_rms("x = np.random.rand(3)\n",
                             select=["ENT001"])) == ["ENT001"]
    assert lint_rms("rng = np.random.default_rng(seed)\n",
                    select=["ENT001"]) == []
    assert lint_rms("rng = random.Random(7)\n", select=["ENT001"]) == []


# ---------------------------------------------------------------------------
# ENT002 — ad-hoc output in library code
# ---------------------------------------------------------------------------

def test_ent002_positive_negative_pragma():
    pos = "def helper():\n    print('x')\n"
    assert rules_of(lint_rms(pos, select=["ENT002"])) == ["ENT002"]
    neg = "def main(argv=None):\n    print('x')\n"
    assert lint_rms(neg, select=["ENT002"]) == []
    sup = "def helper():\n    print('x')  # lint: disable=ENT002\n"
    assert lint_rms(sup, select=["ENT002"]) == []


def test_ent002_stream_writes():
    src = "import sys\ndef f():\n    sys.stderr.write('x')\n"
    assert rules_of(lint_rms(src, select=["ENT002"])) == ["ENT002"]
    src = "import sys\ndef f():\n    sys.stdout.writelines(['x'])\n"
    assert rules_of(lint_rms(src, select=["ENT002"])) == ["ENT002"]
    # writes to non-stream files are fine
    src = "def f(fh):\n    fh.write('x')\n"
    assert lint_rms(src, select=["ENT002"]) == []
    # main() is the sanctioned CLI surface, stream writes included
    src = "import sys\ndef main():\n    sys.stderr.write('x')\n"
    assert lint_rms(src, select=["ENT002"]) == []


def test_ent002_fires_in_obs_but_not_other_packages():
    src = "def helper():\n    print('x')\n"
    assert rules_of(lint_source(src, path="src/repro/obs/fixture.py",
                                select=["ENT002"])) == ["ENT002"]
    assert lint_source(src, path="src/repro/calib/fixture.py",
                       select=["ENT002"]) == []
    assert lint_source(src, path="benchmarks/fixture.py",
                       select=["ENT002"]) == []


# ---------------------------------------------------------------------------
# CAP001 — stale capacity reads
# ---------------------------------------------------------------------------

def test_cap001_positive_negative_pragma():
    pos = "denom = self.config.num_nodes\n"
    assert rules_of(lint_rms(pos, select=["CAP001"])) == ["CAP001"]
    neg = "denom = self.cluster.live_capacity\n"
    assert lint_rms(neg, select=["CAP001"]) == []
    sup = "denom = self.config.num_nodes  # lint: disable=CAP001\n"
    assert lint_rms(sup, select=["CAP001"]) == []


def test_cap001_exempts_cluster_py_and_other_packages():
    src = "cap = config.num_nodes\n"
    assert lint_source(src, path="src/repro/rms/cluster.py",
                       select=["CAP001"]) == []
    assert lint_source(src, path="src/repro/calib/measure.py",
                       select=["CAP001"]) == []


# ---------------------------------------------------------------------------
# ENG001 — event dataclasses must be frozen + slotted
# ---------------------------------------------------------------------------

def test_eng001_positive_negative_pragma():
    pos = ("import dataclasses\n"
           "@dataclasses.dataclass(frozen=True)\n"
           "class Ping(Event):\n"
           "    t: float\n")
    assert rules_of(lint_rms(pos, select=["ENG001"])) == ["ENG001"]
    neg = ("import dataclasses\n"
           "@dataclasses.dataclass(frozen=True, slots=True)\n"
           "class Ping(Event):\n"
           "    t: float\n")
    assert lint_rms(neg, select=["ENG001"]) == []
    sup = ("import dataclasses\n"
           "@dataclasses.dataclass(frozen=True)\n"
           "class Ping(Event):              # lint: disable=ENG001\n"
           "    t: float\n")
    assert lint_rms(sup, select=["ENG001"]) == []


# ---------------------------------------------------------------------------
# ENG002 — epoch-event handlers must guard on the epoch
# ---------------------------------------------------------------------------

def test_eng002_positive_negative_pragma():
    pos = "engine.on(ReconfigPoint, lambda ev: check(ev.job_id))\n"
    assert rules_of(lint_rms(pos, select=["ENG002"])) == ["ENG002"]
    neg = ("engine.on(ReconfigPoint,\n"
           "          lambda ev: check(ev.job_id, ev.epoch))\n")
    assert lint_rms(neg, select=["ENG002"]) == []
    sup = ("engine.on(ReconfigPoint,   # lint: disable=ENG002\n"
           "          lambda ev: check(ev.job_id))\n")
    assert lint_rms(sup, select=["ENG002"]) == []


def test_eng002_resolves_named_handlers():
    pos = ("def on_tick(ev):\n"
           "    run(ev.job_id)\n"
           "engine.on(CheckpointTick, on_tick)\n")
    assert rules_of(lint_rms(pos, select=["ENG002"])) == ["ENG002"]
    neg = ("def on_tick(ev):\n"
           "    if ev.epoch != live[ev.job_id]:\n"
           "        return\n"
           "engine.on(CheckpointTick, on_tick)\n")
    assert lint_rms(neg, select=["ENG002"]) == []
    # non-epoch events need no guard
    assert lint_rms("engine.on(JobSubmit, lambda ev: go(ev.job_id))\n",
                    select=["ENG002"]) == []


# ---------------------------------------------------------------------------
# MUT001 — mutable / constructor-call defaults
# ---------------------------------------------------------------------------

def test_mut001_positive_negative_pragma():
    pos = "def f(xs=[]):\n    xs.append(1)\n"
    assert rules_of(lint_rms(pos, select=["MUT001"])) == ["MUT001"]
    # the shared-default-config bug class: SimConfig() evaluated once
    pos_call = "def run(config=SimConfig()):\n    return config\n"
    assert rules_of(lint_rms(pos_call, select=["MUT001"])) == ["MUT001"]
    neg = "def f(xs=(), config=None):\n    return xs, config\n"
    assert lint_rms(neg, select=["MUT001"]) == []
    sup = "def f(xs=[]):   # lint: disable=MUT001\n    xs.append(1)\n"
    assert lint_rms(sup, select=["MUT001"]) == []


# ---------------------------------------------------------------------------
# MUT002 — module-level mutable state
# ---------------------------------------------------------------------------

def test_mut002_positive_negative_pragma():
    pos = "cache = {}\n"
    assert rules_of(lint_rms(pos, select=["MUT002"])) == ["MUT002"]
    neg = "REGISTRY = {}\n_limit = 3\n"       # ALL_CAPS registry is idiom
    assert lint_rms(neg, select=["MUT002"]) == []
    # function-local mutables are fine
    assert lint_rms("def f():\n    cache = {}\n    return cache\n",
                    select=["MUT002"]) == []
    sup = "cache = {}   # lint: disable=MUT002\n"
    assert lint_rms(sup, select=["MUT002"]) == []


# ---------------------------------------------------------------------------
# Framework: pragmas, selection, syntax errors, JSON schema
# ---------------------------------------------------------------------------

def test_pragma_all_suppresses_every_rule():
    src = "for k in table.items():   # lint: disable=all\n    emit(k)\n"
    assert lint_rms(src) == []


def test_unknown_rule_id_raises():
    with pytest.raises(ValueError, match="NOPE001"):
        lint_rms("x = 1\n", select=["NOPE001"])


def test_syntax_error_yields_e000():
    findings = lint_rms("def broken(:\n")
    assert rules_of(findings) == ["E000"]
    assert "syntax error" in findings[0].message


def test_registry_has_required_rules():
    assert {"DET001", "DET002", "ENT001", "ENT002", "CAP001", "ENG001",
            "ENG002", "MUT001", "MUT002"} <= set(REGISTRY)


def test_json_report_schema_stable():
    findings = lint_rms("t0 = time.time()\nfor k in d.items():\n"
                        "    emit(k, t0)\n")
    rules = make_rules()
    doc = to_json_doc(findings, rules)
    assert sorted(doc) == ["findings", "rules", "schema"]
    assert doc["schema"] == SCHEMA == "repro.lint/v1"
    assert set(doc["rules"]) == set(REGISTRY)
    keys = [(f["path"], f["line"], f["col"], f["rule"])
            for f in doc["findings"]]
    assert keys == sorted(keys)
    assert all(sorted(f) == ["col", "line", "message", "path", "rule"]
               for f in doc["findings"])
    # fully deterministic: same findings -> byte-identical report
    assert render_json(findings, rules) == render_json(
        list(findings), make_rules())
    json.loads(render_json(findings, rules))      # valid JSON


def test_finding_render_is_clickable():
    f = Finding("DET001", "src/repro/rms/x.py", 12, 4, "msg")
    assert f.render() == "src/repro/rms/x.py:12:4: DET001 msg"


# ---------------------------------------------------------------------------
# Meta: the committed tree is lint-clean, and the CLI agrees
# ---------------------------------------------------------------------------

def test_committed_src_tree_is_lint_clean():
    findings = lint_paths([SRC])
    assert findings == [], "\n".join(f.render() for f in findings)


def test_cli_check_mode_exit_codes(tmp_path):
    env = dict(os.environ, PYTHONPATH=SRC)
    clean = subprocess.run(
        [sys.executable, "-m", "repro.lint", SRC, "--check"],
        capture_output=True, text=True, env=env)
    assert clean.returncode == 0, clean.stdout + clean.stderr
    dirty = tmp_path / "rms"
    dirty.mkdir()
    (dirty / "bad.py").write_text("t0 = time.time()\n")
    run = subprocess.run(
        [sys.executable, "-m", "repro.lint", str(tmp_path), "--json"],
        capture_output=True, text=True, env=env)
    assert run.returncode == 1
    doc = json.loads(run.stdout)
    assert [f["rule"] for f in doc["findings"]] == ["ENT001"]


# ---------------------------------------------------------------------------
# Regression pins for this PR's true-positive fixes
# ---------------------------------------------------------------------------

def test_default_configs_are_not_shared_instances():
    """MUT001 fixes: ``def __init__(..., config=SimConfig())`` evaluated
    the default once per process — band edits in one sweep point leaked
    into every later point.  Defaults are None-sentinels now."""
    from repro.rms.policy import ReconfigPolicy
    from repro.rms.scheduler import Scheduler
    from repro.rms.simulator import ClusterSimulator
    from repro.rms.cluster import Cluster

    a = ClusterSimulator([])
    b = ClusterSimulator([])
    assert a.config is not b.config
    a.config.num_nodes = 3
    assert b.config.num_nodes != 3
    s1, s2 = Scheduler(Cluster(4)), Scheduler(Cluster(4))
    assert s1.config is not s2.config
    assert s1.policy.config is s1.config      # resolved config is threaded
    p1, p2 = ReconfigPolicy(), ReconfigPolicy()
    assert p1.config is not p2.config

    import inspect
    from repro.calib.measure import calibrate
    from repro.workload.swf import annotate_malleability
    assert inspect.signature(calibrate).parameters["config"].default is None
    assert inspect.signature(
        annotate_malleability).parameters["mix"].default is None


def test_winner_table_ordering_is_deterministic():
    """DET001 fix: winners_by_mix built its table in dict-insertion order
    (row order); the returned mapping is key-sorted now."""
    from repro.rms.sweep import winners_by_mix

    rows = [
        {"trace": "z", "rigid": 1.0, "moldable": 0.0, "malleable": 0.0,
         "evolving": 0.0, "policy": "easy", "makespan_s": 10.0},
        {"trace": "a", "rigid": 0.0, "moldable": 0.0, "malleable": 1.0,
         "evolving": 0.0, "policy": "sjf", "makespan_s": 5.0},
        {"trace": "a", "rigid": 0.0, "moldable": 0.0, "malleable": 1.0,
         "evolving": 0.0, "policy": "easy", "makespan_s": 7.0},
    ]
    winners = winners_by_mix(rows)
    assert list(winners) == sorted(winners)
    assert winners[("a", 0.0, 0.0, 1.0, 0.0, 0.0)] == "sjf"
    assert winners == winners_by_mix(list(reversed(rows)))
    assert list(winners) == list(winners_by_mix(list(reversed(rows))))
