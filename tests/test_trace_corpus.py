"""Synthetic ~200-job SWF corpus: parser round-trip + scheduler replay.

The corpus (``tests/synthetic_swf.py``) exists so scheduler/sweep tests
exercise real queueing depth instead of over-fitting to the 24-job
``sample.swf``.
"""
import pytest

from repro.rms import ClusterSimulator, JobState, SchedulerConfig, SimConfig
from repro.workload import MalleabilityMix, jobs_from_swf, parse_swf
from synthetic_swf import MAX_NODES, N_JOBS, synthetic_swf


def test_generator_is_deterministic():
    a_lines, a_recs = synthetic_swf()
    b_lines, b_recs = synthetic_swf()
    assert a_lines == b_lines
    assert a_recs == b_recs
    c_lines, _ = synthetic_swf(seed=999)
    assert a_lines != c_lines


def test_parser_round_trip():
    """Every generated record survives parse_swf field-for-field."""
    lines, records = synthetic_swf()
    trace = parse_swf(lines)
    assert trace.skipped_lines == 0
    assert trace.max_nodes == MAX_NODES
    assert len(trace.jobs) == N_JOBS == len(records)
    for job, rec in zip(trace.jobs, records):
        assert job.job_id == rec["job_id"]
        assert job.submit_time == rec["submit"]
        assert job.run_time == rec["run"]
        assert job.allocated_procs == rec["procs"]
        assert job.requested_procs == rec["procs"]
        assert job.requested_time == rec["reqtime"]
        assert job.user_id == rec["user"]
        assert job.procs == rec["procs"]


def test_corpus_shape_is_nontrivial():
    """The corpus must stay diverse, or downstream tests degrade."""
    _, records = synthetic_swf()
    sizes = {r["procs"] for r in records}
    users = {r["user"] for r in records}
    assert len(sizes) >= 8           # small and large, pow2 and not
    assert any(s & (s - 1) for s in sizes)     # non-power-of-two tail
    assert len(users) == 8
    assert max(r["procs"] for r in records) <= MAX_NODES
    submits = [r["submit"] for r in records]
    assert submits == sorted(submits)


def test_adapter_threads_users_and_bounds():
    lines, records = synthetic_swf()
    trace = parse_swf(lines)
    mix = MalleabilityMix(rigid=0.3, moldable=0.3, malleable=0.4)
    jobs, apps = jobs_from_swf(trace, num_nodes=MAX_NODES, mix=mix, seed=7)
    assert len(jobs) == N_JOBS
    assert {j.user for j in jobs} == {r["user"] for r in records}
    for j, rec in zip(jobs, records):
        assert j.user == rec["user"]
        assert 1 <= j.min_nodes <= j.requested_nodes <= j.max_nodes \
            <= MAX_NODES
        app = apps[j.app]
        assert (app.min_nodes, app.max_nodes) == (j.min_nodes, j.max_nodes)


@pytest.mark.parametrize("policy", ["easy", "sjf", "fairshare", "preempt",
                                    "moldable"])
def test_corpus_replay_completes(policy):
    """A 60-job slice of the corpus drains under every new policy."""
    lines, _ = synthetic_swf()
    trace = parse_swf(lines)
    mix = MalleabilityMix(rigid=0.2, moldable=0.2, malleable=0.6)
    jobs, apps = jobs_from_swf(trace, num_nodes=MAX_NODES, mix=mix, seed=7,
                               max_jobs=60, time_scale=0.2)
    rep = ClusterSimulator(
        jobs, SimConfig(num_nodes=MAX_NODES, flexible=True,
                        sched=SchedulerConfig(policy=policy)),
        apps=apps).run()
    assert all(j.state is JobState.COMPLETED for j in rep.jobs)
    assert rep.makespan > 0
