"""SWF trace parser + malleability annotation + Job adapter."""
import os

try:
    from hypothesis import given, settings, strategies as st
except ImportError:                              # container has no hypothesis
    from _hypothesis_stub import given, settings, strategies as st

import pytest

from repro.rms import ClusterSimulator, JobState, SimConfig
from repro.workload import (MALLEABLE, MOLDABLE, RIGID, MalleabilityMix,
                            annotate_malleability, jobs_from_swf, parse_swf)

DATA = os.path.join(os.path.dirname(__file__), "data", "sample.swf")

GOOD = "1 10 5 600 8 -1 -1 8 900 -1 1 3 1 2 1 1 -1 -1"


# -- parsing ----------------------------------------------------------------

def test_parse_sample_file():
    trace = parse_swf(DATA)
    assert len(trace.jobs) == 24
    assert trace.skipped_lines == 0
    assert trace.max_nodes == 64
    assert trace.header["Computer"] == "synthetic-64"
    first = trace.jobs[0]
    assert (first.job_id, first.submit_time, first.run_time,
            first.allocated_procs) == (1, 0.0, 620.0, 8)


def test_header_comments_parsed_and_non_kv_comments_ignored():
    trace = parse_swf(["; MaxNodes: 128", "; just a remark", GOOD])
    assert trace.max_nodes == 128
    assert len(trace.jobs) == 1


def test_blank_lines_ignored():
    trace = parse_swf(["", "   ", GOOD, ""])
    assert len(trace.jobs) == 1
    assert trace.skipped_lines == 0


def test_malformed_line_skipped_and_counted():
    trace = parse_swf([GOOD, "1 2 three 4 5 6 7 8 9", GOOD.replace("1 ", "2 ", 1)])
    assert len(trace.jobs) == 2
    assert trace.skipped_lines == 1


def test_truncated_line_skipped():
    trace = parse_swf(["1 10 5 600 8", GOOD])
    assert len(trace.jobs) == 1
    assert trace.skipped_lines == 1


def test_strict_mode_raises():
    with pytest.raises(ValueError, match="truncated"):
        parse_swf(["1 10 5 600 8"], strict=True)
    with pytest.raises(ValueError, match="non-numeric"):
        parse_swf(["1 2 three 4 5 6 7 8 9"], strict=True)


def test_zero_runtime_records_dropped():
    trace = parse_swf([GOOD.replace(" 600 ", " 0 ", 1), GOOD])
    assert len(trace.jobs) == 1
    assert trace.skipped_lines == 1


def test_allocated_falls_back_to_requested():
    line = "1 10 5 600 -1 -1 -1 16 900 -1 1 3 1 2 1 1 -1 -1"
    trace = parse_swf([line])
    assert trace.jobs[0].procs == 16


# -- malleability annotation ------------------------------------------------

def test_mix_validation():
    with pytest.raises(ValueError):
        MalleabilityMix(rigid=0.5, moldable=0.5, malleable=0.5)
    with pytest.raises(ValueError):
        MalleabilityMix(rigid=-0.2, moldable=0.4, malleable=0.8)


@settings(max_examples=20, deadline=None)
@given(st.sampled_from([0.0, 0.25, 0.5, 1.0]),
       st.sampled_from([0.0, 0.25, 0.5]),
       st.integers(0, 1000))
def test_annotation_fractions_round_trip(rigid, moldable, seed):
    if rigid + moldable > 1.0:
        return
    mix = MalleabilityMix(rigid=rigid, moldable=moldable,
                          malleable=1.0 - rigid - moldable)
    trace = parse_swf(DATA)
    kinds = annotate_malleability(trace.jobs, mix, seed=seed)
    n = len(kinds)
    assert n == len(trace.jobs)
    # exact quota split: realised counts within 1 job of requested
    for kind, frac in ((RIGID, mix.rigid), (MOLDABLE, mix.moldable),
                       (MALLEABLE, mix.malleable)):
        assert abs(kinds.count(kind) - frac * n) <= 1


def test_annotation_deterministic():
    trace = parse_swf(DATA)
    mix = MalleabilityMix(rigid=0.3, moldable=0.2, malleable=0.5)
    a = annotate_malleability(trace.jobs, mix, seed=11)
    b = annotate_malleability(trace.jobs, mix, seed=11)
    c = annotate_malleability(trace.jobs, mix, seed=12)
    assert a == b
    assert a != c   # different seed shuffles the assignment


# -- Job adapter ------------------------------------------------------------

def test_jobs_from_swf_basics():
    trace = parse_swf(DATA)
    jobs, apps = jobs_from_swf(trace, num_nodes=64)
    assert len(jobs) == 24
    assert {j.app for j in jobs} == set(apps)
    for j in jobs:
        app = apps[j.app]
        assert 1 <= j.min_nodes <= j.requested_nodes <= j.max_nodes <= 64
        # calibration: exec at the recorded size == recorded runtime
        rec = next(r for r in trace.jobs
                   if f"swf:{r.job_id}" == j.app)
        base = j.preferred if j.malleable else j.requested_nodes
        assert app.exec_time(base) == pytest.approx(rec.run_time, rel=0.01)


def test_rigid_annotation_pins_sizes():
    trace = parse_swf(DATA)
    jobs, _ = jobs_from_swf(
        trace, num_nodes=64,
        mix=MalleabilityMix(rigid=1.0, moldable=0.0, malleable=0.0))
    assert all(not j.malleable for j in jobs)
    assert all(j.min_nodes == j.max_nodes == j.requested_nodes
               for j in jobs)


def test_time_scale_compresses_arrivals():
    trace = parse_swf(DATA)
    full, _ = jobs_from_swf(trace, num_nodes=64, time_scale=1.0)
    tenth, _ = jobs_from_swf(trace, num_nodes=64, time_scale=0.1)
    assert max(j.submit_time for j in tenth) == pytest.approx(
        max(j.submit_time for j in full) * 0.1)


def test_trace_replay_end_to_end():
    """The sample trace runs through the engine; flexible <= fixed."""
    trace = parse_swf(DATA)
    mix = MalleabilityMix(rigid=0.2, moldable=0.2, malleable=0.6)
    makespans = {}
    for flexible in (False, True):
        jobs, apps = jobs_from_swf(trace, num_nodes=64, mix=mix, seed=7)
        rep = ClusterSimulator(
            jobs, SimConfig(num_nodes=64, flexible=flexible),
            apps=apps).run()
        assert all(j.state is JobState.COMPLETED for j in rep.jobs)
        makespans[flexible] = rep.makespan
    assert makespans[True] <= makespans[False]
