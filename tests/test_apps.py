"""Paper applications: CG converges, Jacobi relaxes, N-body is stable."""
import jax
import jax.numpy as jnp

from repro.apps import (FlexibleSleep, cg_init, cg_step, jacobi_init,
                        jacobi_step, laplacian_matvec, nbody_init,
                        nbody_step)


def test_cg_residual_decreases():
    s = cg_init(64)
    r0 = float(jnp.sqrt(s.rs))
    for _ in range(30):
        s = cg_step(s)
    assert float(jnp.sqrt(s.rs)) < 0.2 * r0


def test_cg_solves_system():
    s = cg_init(32)
    b = s.r + laplacian_matvec(s.x)
    for _ in range(200):
        s = cg_step(s)
    resid = jnp.linalg.norm(b - laplacian_matvec(s.x))
    assert float(resid) < 1e-2 * float(jnp.linalg.norm(b))


def test_jacobi_contracts():
    s = jacobi_init(32)
    s1 = jacobi_step(s)
    d_early = float(jnp.abs(s1["grid"] - s["grid"]).max())
    for _ in range(200):
        s = jacobi_step(s)
    nxt = jacobi_step(s)
    d_late = float(jnp.abs(nxt["grid"] - s["grid"]).max())
    assert d_late < 0.2 * d_early     # Jacobi relaxation is contracting


def test_nbody_finite_and_momentum():
    s = nbody_init(64)
    p0 = jnp.sum(s["vel"] * s["mass"][:, None], axis=0)
    for _ in range(10):
        s = nbody_step(s)
    assert bool(jnp.isfinite(s["pos"]).all())
    p1 = jnp.sum(s["vel"] * s["mass"][:, None], axis=0)
    # pairwise forces conserve momentum
    assert float(jnp.abs(p1 - p0).max()) < 1e-2


def test_flexible_sleep_state_size():
    fs = FlexibleSleep(nbytes=1 << 20, step_s=0.0)
    st = fs.init()
    assert st["data"].nbytes == 1 << 20
