"""Sharding rules: divisibility fallback, ZeRO-1, property tests."""
import jax
import numpy as np
try:
    from hypothesis import given, settings, strategies as st
except ImportError:                              # container has no hypothesis
    from _hypothesis_stub import given, settings, strategies as st
from jax.sharding import PartitionSpec as P

from repro.core import TP_DP_RULES, FSDP_RULES, LONG_CONTEXT_RULES, make_mesh
from repro.optim import zero1_logical


def mesh_2x2():
    # 1 real device: use (1,1); divisibility logic is tested symbolically
    return make_mesh(1, 1)


def test_divisibility_fallback_drops_axis():
    mesh = make_mesh(1, 1)
    # with model size 1, everything divides; symbolic check via spec on a
    # fake mesh is covered below with axis sizes from mesh.shape
    spec = TP_DP_RULES.spec_for(("embed", "heads", "head_dim"),
                                (576, 9, 64), mesh)
    assert spec == P(None, "model", None) or spec[1] in ("model", None)


def test_spec_never_uses_axis_twice():
    mesh = make_mesh(1, 1)
    spec = TP_DP_RULES.spec_for(("batch", "seq", "embed"), (8, 16, 32), mesh)
    used = [s for s in spec if s is not None]
    flat = []
    for s in used:
        flat.extend(s if isinstance(s, tuple) else [s])
    assert len(flat) == len(set(flat))


@settings(max_examples=50, deadline=None)
@given(st.integers(1, 64), st.integers(1, 64), st.integers(1, 64))
def test_spec_shapes_always_valid(a, b, c):
    mesh = make_mesh(1, 1)
    spec = TP_DP_RULES.spec_for(("batch", "heads", "mlp"), (a, b, c), mesh)
    sharding = jax.sharding.NamedSharding(mesh, spec)
    # a valid sharding must divide the shape on every sharded dim
    for dim, names in zip((a, b, c), spec):
        if names is None:
            continue
        names = names if isinstance(names, tuple) else (names,)
        ways = int(np.prod([mesh.shape[n] for n in names]))
        assert dim % ways == 0


def test_zero1_adds_data_axis():
    mesh = make_mesh(1, 1)
    lg = zero1_logical(("embed", "mlp"), (64, 128), mesh, TP_DP_RULES)
    assert "zero1" in lg


def test_zero1_skips_layers_dim():
    mesh = make_mesh(1, 1)
    lg = zero1_logical(("layers", "embed", "mlp"), (4, 64, 128),
                       mesh, TP_DP_RULES)
    assert lg[0] == "layers"


def test_long_context_rules_shard_kv_seq():
    assert LONG_CONTEXT_RULES.mesh_axes_for("kv_seq") == ("pod", "data")
    assert LONG_CONTEXT_RULES.mesh_axes_for("batch") == ()


def test_fsdp_rules_shard_embed():
    assert FSDP_RULES.mesh_axes_for("embed") == ("data",)
