"""Batched serving with continuous batching (decode-shape driver).

  PYTHONPATH=src python examples/serve.py [--requests 6] [--batch 3]
"""
import argparse
import dataclasses
import time

import jax
import numpy as np

from repro.models import build_model, get_model, reduced_config
from repro.runtime import Request, Server


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="smollm-135m")
    ap.add_argument("--requests", type=int, default=6)
    ap.add_argument("--batch", type=int, default=3)
    ap.add_argument("--new-tokens", type=int, default=16)
    args = ap.parse_args()

    _, full = get_model(args.arch)
    cfg = dataclasses.replace(reduced_config(full), dtype="float32")
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    server = Server(model, params, batch=args.batch, max_len=128)
    rng = np.random.default_rng(0)
    reqs = [Request(rid=i,
                    prompt=rng.integers(0, cfg.vocab_size, 8),
                    max_new_tokens=args.new_tokens)
            for i in range(args.requests)]
    t0 = time.perf_counter()
    done = server.run(reqs)
    dt = time.perf_counter() - t0
    total = sum(len(v) for v in done.values())
    print(f"arch={cfg.name} (reduced) batch={args.batch}")
    for rid in sorted(done):
        print(f"  req {rid}: {len(done[rid])} tokens -> "
              f"{done[rid][:8]}...")
    print(f"{total} tokens in {dt:.1f}s "
          f"({total/dt:.1f} tok/s, continuous batching over "
          f"{args.batch} slots)")


if __name__ == "__main__":
    main()
