"""Live elastic training: a malleable LM job expands and shrinks under
the DMR API against an in-process RMS, resharding its TrainState on the
fly (the paper's §5 protocol, end to end).

Needs >1 device, so this entry point (like the dry-run) requests CPU host
devices BEFORE jax initializes.

  PYTHONPATH=src python examples/elastic_train.py
"""
import os
os.environ.setdefault("XLA_FLAGS",
                      "--xla_force_host_platform_device_count=8")

import dataclasses  # noqa: E402

import jax  # noqa: E402

from repro.core import make_mesh  # noqa: E402
from repro.data import DataConfig  # noqa: E402
from repro.models import (build_model, get_model,  # noqa: E402
                          reduced_config)
from repro.optim import AdamWConfig  # noqa: E402
from repro.rms.job import Job, JobState  # noqa: E402
from repro.runtime import ElasticTrainer, LocalRMS, TrainerConfig  # noqa: E402


def main():
    print(f"devices: {len(jax.devices())}")
    rms = LocalRMS(num_nodes=8)
    # our job starts on 4 slices
    job = Job(job_id=0, app="lm:smollm", submit_time=0.0, work=1e9,
              min_nodes=1, max_nodes=8, preferred=None, requested_nodes=4)
    rms.submit(job, start=True)

    _, full = get_model("smollm-135m")
    cfg = dataclasses.replace(reduced_config(full), vocab_size=4096)
    model = build_model(cfg)
    data = DataConfig(vocab_size=cfg.vocab_size, seq_len=64,
                      global_batch=8)
    opt = AdamWConfig(lr=3e-3, warmup_steps=10, total_steps=120)
    trainer = ElasticTrainer(
        model, opt, data,
        TrainerConfig(steps=120, model_ways=1, min_slices=1, max_slices=8,
                      check_period=20, log_period=20),
        rms=rms, job_id=0)
    trainer.slices = 4
    trainer.mesh = make_mesh(4, 1)
    trainer.dmr.current_slices = 4

    # Script the cluster: at step ~40 a rival job takes nodes (we shrink
    # via wide-optimization); at step ~80 it finishes (we expand back).
    events = {40: "submit", 80: "finish"}
    rival = Job(job_id=1, app="lm:smollm", submit_time=0.0, work=1e9,
                min_nodes=4, max_nodes=4, preferred=None, requested_nodes=4)

    state = trainer.init_state()
    step = 0
    while step < 120:
        if step in events:
            if events[step] == "submit":
                rms.submit(rival)          # queued rival -> policy shrinks us
                print(f"[step {step}] rival job queued (wants 4 nodes)")
            else:
                for j in rms.jobs:
                    if j.job_id == 1 and j.state is JobState.RUNNING:
                        rms.finish(1)
                        print(f"[step {step}] rival finished, nodes free")
        if step > 0 and step % trainer.cfg.check_period == 0:
            before = trainer.slices
            state = trainer.maybe_reconfigure(state)
            if trainer.slices != before:
                print(f"[step {step}] DMR resize {before} -> "
                      f"{trainer.slices} slices "
                      f"(resize {trainer.resize_log[-1]['resize_s']*1e3:.0f}"
                      f" ms)")
                # a shrink frees nodes: the RMS can start the rival
                for j in rms.jobs:
                    if j.state is JobState.PENDING and \
                            j.requested_nodes <= rms.cluster.free_nodes:
                        rms.cluster.allocate(j.job_id, j.requested_nodes)
                        j.state = JobState.RUNNING
                        j.nodes = j.requested_nodes
                        print(f"[step {step}] rival job started on "
                              f"{j.nodes} nodes")
        batch = trainer.data.batch(step)
        fn = trainer.step_fn(trainer.mesh)
        with trainer.mesh:
            state, metrics = fn(state, batch)
        step += 1
        if step % 20 == 0:
            print(f"step {step:4d} loss {float(metrics['loss']):.4f} "
                  f"slices {trainer.slices}")
    print("\nresize log:", trainer.resize_log)
    assert any(r["action"] == "SHRINK" for r in trainer.resize_log)
    assert any(r["action"] == "EXPAND" for r in trainer.resize_log)
    print("OK: job shrank under queue pressure and expanded back — the "
          "paper's malleability loop, live.")


if __name__ == "__main__":
    main()
