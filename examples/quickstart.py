"""Quickstart: train a small llama-family model end-to-end on CPU.

Uses the full production stack — model zoo config, AdamW + ZeRO-1, the
synthetic data pipeline, checkpointing — at a width that trains a few
hundred steps in minutes on one CPU.  On a TPU pod the same script scales
by pointing --arch at any assigned config and raising model_ways.

  PYTHONPATH=src python examples/quickstart.py [--steps 300]
"""
import argparse
import dataclasses
import sys

from repro.data import DataConfig
from repro.models import build_model, get_model, reduced_config
from repro.optim import AdamWConfig
from repro.runtime import ElasticTrainer, TrainerConfig


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="smollm-135m")
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--full-size", action="store_true",
                    help="use the full config (TPU-scale!)")
    ap.add_argument("--ckpt", default="/tmp/repro_quickstart")
    args = ap.parse_args()

    _, full_cfg = get_model(args.arch)
    cfg = full_cfg if args.full_size else dataclasses.replace(
        reduced_config(full_cfg), d_model=256, num_layers=4, d_ff=1024,
        vocab_size=4096)
    model = build_model(cfg)
    print(f"arch={cfg.name} params~{cfg.param_count()/1e6:.1f}M "
          f"(full config: {full_cfg.param_count()/1e6:.0f}M)")

    data = DataConfig(vocab_size=cfg.vocab_size, seq_len=128,
                      global_batch=16,
                      frontend=cfg.frontend,
                      frontend_tokens=cfg.frontend_tokens,
                      d_model=cfg.d_model,
                      enc_dec=cfg.family == "encdec")
    opt = AdamWConfig(lr=3e-3, warmup_steps=20, total_steps=args.steps)
    trainer = ElasticTrainer(
        model, opt, data,
        TrainerConfig(steps=args.steps, model_ways=1, max_slices=1,
                      log_period=20, ckpt_dir=args.ckpt, ckpt_period=100))
    state = trainer.train()
    for m in trainer.metrics:
        print(f"step {m['step']:4d}  loss {m['loss']:.4f}  "
              f"lr {m['lr']:.2e}  grad_norm {m['grad_norm']:.2f}")
    first, last = trainer.metrics[0]["loss"], trainer.metrics[-1]["loss"]
    print(f"\nloss: {first:.3f} -> {last:.3f} "
          f"({'OK: learning' if last < first - 0.5 else 'WARN: too short'})")
    print(f"checkpoints in {args.ckpt}: latest step "
          f"{trainer.store.latest_step()}")
    return 0 if last < first else 1


if __name__ == "__main__":
    sys.exit(main())
