"""The paper's end-to-end scenario: process an adaptive workload.

Runs the same randomly-sorted CG/Jacobi/N-body workload — or any SWF
trace via ``--trace`` — through the event-driven RMS engine twice, fixed
vs flexible (malleable), and reports the paper's headline measures
(Table 4 / Figs. 4-6).

  PYTHONPATH=src python examples/workload_sim.py [--jobs 50] [--async]
      [--policy fcfs|easy|conservative|malleable|sjf|fairshare|preempt|moldable]
      [--trace tests/data/sample.swf]
"""
import argparse

from repro.rms import (POLICY_REGISTRY, ClusterSimulator, SchedulerConfig,
                       SimConfig)
from repro.workload import MalleabilityMix, jobs_from_swf, make_workload, \
    parse_swf


def bar(frac, width=40):
    return "#" * int(frac * width)


def build_jobs(args):
    """Returns a factory yielding fresh (jobs, apps) for each run."""
    if args.trace:
        trace = parse_swf(args.trace)
        mix = MalleabilityMix(rigid=0.2, moldable=0.2, malleable=0.6)

        def factory():
            return jobs_from_swf(trace, num_nodes=args.nodes, mix=mix,
                                 seed=7)
        return factory
    return lambda: (make_workload(args.jobs, seed=7), None)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--jobs", type=int, default=50)
    ap.add_argument("--nodes", type=int, default=64)
    ap.add_argument("--async", dest="async_", action="store_true")
    ap.add_argument("--policy", default="easy",
                    choices=sorted(POLICY_REGISTRY),
                    help="scheduling policy (the full registry zoo)")
    ap.add_argument("--trace", default=None,
                    help="replay an SWF trace instead of the synthetic mix")
    args = ap.parse_args()
    sched = "async" if args.async_ else "sync"
    factory = build_jobs(args)

    results = {}
    for flexible in (False, True):
        jobs, apps = factory()
        rep = ClusterSimulator(
            jobs, SimConfig(num_nodes=args.nodes, flexible=flexible,
                            scheduling=sched,
                            sched=SchedulerConfig(policy=args.policy)),
            apps=apps).run()
        results[flexible] = rep
        name = "flexible" if flexible else "fixed"
        w, e, c = rep.averages()
        u, us = rep.utilization()
        print(f"\n== {name} workload ({len(jobs)} jobs, {args.nodes} nodes,"
              f" {sched}, {args.policy}) ==")
        print(f"  makespan          {rep.makespan:10.0f} s")
        print(f"  utilization       {u:7.1f} +- {us:.1f} %")
        print(f"  avg waiting       {w:10.1f} s")
        print(f"  avg execution     {e:10.1f} s")
        print(f"  avg completion    {c:10.1f} s")
        print(f"  reconfigurations  {len([a for a in rep.actions if a.action != 'no_action']):6d}")
    base, flex = results[False], results[True]
    gain = (base.makespan - flex.makespan) / base.makespan * 100
    print(f"\nworkload completes {gain:.1f}% earlier with malleability")
    print("\nallocated nodes over time (fixed | flexible):")
    import numpy as np
    t_end = max(base.makespan, flex.makespan)
    for t in np.linspace(0, t_end, 18):
        row = []
        for rep in (base, flex):
            ts = [x[0] for x in rep.timeline]
            i = max(0, np.searchsorted(ts, t, "right") - 1)
            row.append(rep.timeline[i][1] / args.nodes)
        print(f"  t={t:7.0f}s |{bar(row[0]):<40s}|{bar(row[1]):<40s}|")


if __name__ == "__main__":
    main()
