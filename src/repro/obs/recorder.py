"""``TraceRecorder`` — the engine monitor behind the observability layer.

Installed via ``engine.add_monitor`` (so it composes with the sanitizer),
the recorder turns the simulator's existing audit surfaces into typed
spans and event-sampled metrics *without touching simulation state*:

- job lifecycle spans (submit -> queued -> run segments -> finish) from
  ``Job.nodes_history``, with per-job queue/compute/reconfig attribution;
- DMR negotiation spans from new ``ActionRecord`` entries (decision,
  band, vocabulary reason from :mod:`repro.rms.reasons`, duration);
- capacity/power/drain spans from the capacity-churn action records;
- SLO-pressure samples at every SERVING ``TrafficTick`` probe.

Observer-effect guarantee: every hook only *reads* simulator state and
appends to recorder-private structures, so a traced run's ``SimReport``
is byte-identical to a plain run (locked by ``tests/test_obs.py``).

Overhead: ``after_event`` is O(1) per event — it length-diffs the
simulator's append-only ``actions`` / ``timeline`` / ``capacity_timeline``
lists instead of scanning them, and the per-event metric updates are a
handful of dict lookups.  The budget is < 2x, pinned by the
``trace_sjf_mixed_sync`` twin in ``benchmarks/engine_bench.py``.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Tuple

import numpy as np

from repro.rms.engine import Event, TrafficTick
from repro.rms.reasons import reason_code

#: Actions that move a job's data (Fig. 3 costs): charged to the job's
#: reconfiguration time and observed by the duration histogram.
RESIZE_ACTIONS = frozenset({
    "expand", "shrink", "preempt_shrink", "failure_shrink",
    "drain_shrink", "drain_migrate", "straggler_migrate",
})

#: The §4 negotiation outcomes proper — the DMR span track.
DMR_ACTIONS = frozenset({"expand", "shrink", "no_action"})

#: Cluster-level capacity actions (``job_id == -1``).
CAPACITY_ACTIONS = frozenset({
    "node_join", "node_drain", "power_off", "power_on",
})


@dataclasses.dataclass(frozen=True, slots=True)
class Span:
    """One typed span: ``[t0, t0+dur]`` on a named track."""
    name: str      # e.g. "expand", "run", "queued", "node_drain"
    kind: str      # taxonomy: job | dmr | capacity | disruption | slo
    track: str     # e.g. "job/0", "dmr/job0", "cluster"
    t0: float
    dur: float
    args: dict


class TraceRecorder:
    """Engine monitor recording spans + metrics for one simulation run.

    Usage::

        sim = ClusterSimulator(jobs, cfg)
        rec = TraceRecorder(sim).install()
        report = sim.run()
        rec.finalize(report)
        write_trace("/tmp/run", rec)        # repro.obs.export

    Install *before* ``sim.run()`` — the engine hot loop hoists the
    monitor reference.  When no recorder is installed the engine path is
    exactly as before (zero overhead when disabled).
    """

    def __init__(self, sim, meta: Optional[dict] = None):
        from repro.obs.metrics import MetricsRegistry
        self.sim = sim
        self.engine = sim.engine
        self.meta = dict(meta or {})
        self.metrics = MetricsRegistry()
        self.spans: List[Span] = []
        self.jobs: List[dict] = []          # per-job breakdown (finalize)
        self.serving: Dict[int, dict] = {}  # per-job SLO totals (finalize)
        self.makespan = 0.0
        self._finalized = False
        # cursors into the simulator's append-only audit lists
        self._n_actions = 0
        self._n_timeline = 0
        self._n_capacity = 0
        # private copies for the utilization cross-check
        self._timeline: List[Tuple[float, int, int, int]] = []
        self._capacity: List[Tuple[float, int, int]] = []
        # ledger: (action, reason code) -> [count, decide_s, apply_s]
        self._ledger: Dict[Tuple[str, str], List[float]] = {}
        self._reconfig_s: Dict[int, float] = {}   # job -> charged seconds
        self._resizes: Dict[int, int] = {}        # job -> resize count
        self._p99_seen: Dict[int, int] = {}       # job -> samples consumed
        self._event_counters: Dict[str, object] = {}
        # hoisted gauges (touched every event)
        m = self.metrics
        self._g_alloc = m.gauge("allocated_nodes")
        self._g_running = m.gauge("running_jobs")
        self._g_done = m.gauge("completed_jobs")
        self._g_queue = m.gauge("queue_depth")
        self._g_live = m.gauge("live_capacity")
        self._g_off = m.gauge("powered_off_nodes")
        self._sync = sim.config.scheduling == "sync"
        self._launch_s = sim.config.launch_latency_s

    # -- installation --------------------------------------------------------

    def install(self) -> "TraceRecorder":
        self.engine.add_monitor(self)
        return self

    def uninstall(self) -> None:
        self.engine.remove_monitor(self)

    # -- engine monitor hooks ------------------------------------------------

    def on_schedule(self, event: Event) -> None:
        pass

    def before_event(self, event: Event) -> None:
        pass

    def after_event(self, event: Event) -> None:
        sim = self.sim
        t = self.engine.now
        name = type(event).__name__
        counter = self._event_counters.get(name)
        if counter is None:
            counter = self._event_counters[name] = \
                self.metrics.counter("events_total", type=name)
        counter.value += 1

        actions = sim.actions
        n = len(actions)
        if n != self._n_actions:
            for record in actions[self._n_actions:]:
                self._record_action(record)
            self._n_actions = n
        timeline = sim.timeline
        n = len(timeline)
        if n != self._n_timeline:
            for row in timeline[self._n_timeline:]:
                self._timeline.append(row)
                self._g_alloc.set(row[0], row[1])
                self._g_running.set(row[0], row[2])
                self._g_done.set(row[0], row[3])
            self._n_timeline = n
        capacity = sim.capacity_timeline
        n = len(capacity)
        if n != self._n_capacity:
            for row in capacity[self._n_capacity:]:
                self._capacity.append(row)
                self._g_live.set(row[0], row[1])
                self._g_off.set(row[0], row[2])
            self._n_capacity = n
        self._g_queue.set(t, len(sim._pending_map))
        if type(event) is TrafficTick:
            self._sample_slo(event, t)

    # -- action -> span/ledger/metrics ---------------------------------------

    def _record_action(self, a) -> None:
        code = reason_code(a.reason)
        key = (a.action, code)
        row = self._ledger.get(key)
        if row is None:
            row = self._ledger[key] = [0, 0.0, 0.0]
        row[0] += 1
        row[1] += a.decide_s
        row[2] += a.apply_s

        if a.action in RESIZE_ACTIONS and not a.timed_out:
            self.metrics.histogram("reconfig_duration_s",
                                   reason=code).observe(
                a.decide_s + a.apply_s)
            if a.job_id >= 0:
                # sync DMR pauses the app for the decision too; async
                # overlaps it with compute, so only the apply is charged
                charged = a.apply_s + (a.decide_s if self._sync else 0.0)
                self._reconfig_s[a.job_id] = \
                    self._reconfig_s.get(a.job_id, 0.0) + charged
                self._resizes[a.job_id] = \
                    self._resizes.get(a.job_id, 0) + 1

        dur = a.decide_s + a.apply_s
        args = {"reason": a.reason, "from": a.from_nodes, "to": a.to_nodes}
        if a.timed_out:
            args["timed_out"] = True
        if a.action in DMR_ACTIONS and a.job_id >= 0:
            job = self.sim._by_id.get(a.job_id)
            if job is not None:
                args["band"] = [job.min_nodes, job.max_nodes,
                                job.preferred]
            kind, track = "dmr", f"dmr/job{a.job_id}"
        elif a.job_id < 0:
            kind, track = "capacity", "cluster"
        else:
            # disruptions: preempt/failure/drain/straggler paths and
            # EVOLVING phase_change announcements
            kind, track = "disruption", f"dmr/job{a.job_id}"
        self.spans.append(Span(a.action, kind, track, a.t, dur, args))

    def _sample_slo(self, event: TrafficTick, t: float) -> None:
        sim = self.sim
        jid = event.job_id
        samples = sim._p99_samples.get(jid)
        if samples is None:
            return
        seen = self._p99_seen.get(jid, 0)
        if len(samples) <= seen:
            return            # stale-epoch tick: the handler ignored it
        self._p99_seen[jid] = len(samples)
        p99 = samples[-1]
        job = sim._by_id[jid]
        slo = job.traffic.slo_p99_s
        backlog = sim._backlog.get(jid, 0.0)
        violated = p99 > slo
        self.metrics.gauge("serving_backlog", job=jid).set(t, backlog)
        self.metrics.gauge("serving_p99_s", job=jid).set(t, p99)
        if violated:
            self.metrics.counter("slo_violations", job=jid).inc()
        self.spans.append(Span(
            "slo_probe", "slo", f"slo/job{jid}", t, 0.0,
            {"p99_s": p99, "slo_s": slo, "backlog": backlog,
             "violated": violated}))

    # -- finalization --------------------------------------------------------

    def finalize(self, report, meta: Optional[dict] = None
                 ) -> "TraceRecorder":
        """Fold the finished run's report into per-job lifecycle spans,
        the breakdown table rows, and serving totals.  Idempotent inputs
        only: call once, after ``sim.run()``."""
        if self._finalized:
            return self
        self._finalized = True
        if meta:
            self.meta.update(meta)
        self.makespan = report.makespan
        for job in sorted(report.jobs, key=lambda j: j.job_id):
            self._finalize_job(job, report.makespan)
        for jid, (viol, served, p99) in sorted(
                report.serving_stats.items()):
            self.serving[jid] = {"slo_violations": viol,
                                 "served_requests": served, "p99_s": p99}
        return self

    def _finalize_job(self, job, makespan: float) -> None:
        end = job.end_time if job.end_time > 0 else makespan
        points: List[Tuple[float, Optional[int]]] = \
            [(job.submit_time, 0)] + list(job.nodes_history)
        # collapse to constant-value segments, emit one span per segment
        queued_s = run_s = 0.0
        starts = 0
        prev_t, prev_n = points[0]
        for t, n in points[1:] + [(end, None)]:
            t = min(t, end)
            if t > prev_t:
                dur = t - prev_t
                if prev_n == 0:
                    queued_s += dur
                    self.spans.append(Span(
                        "queued", "job", f"job/{job.job_id}",
                        prev_t, dur, {"nodes": 0}))
                else:
                    run_s += dur
                    self.spans.append(Span(
                        "run", "job", f"job/{job.job_id}",
                        prev_t, dur, {"nodes": prev_n}))
            if n is not None and n > 0 and prev_n == 0:
                starts += 1
            if n is not None:
                prev_t, prev_n = max(prev_t, t), n
        reconfig_s = self._reconfig_s.get(job.job_id, 0.0) + \
            starts * self._launch_s
        self.jobs.append({
            "job_id": job.job_id,
            "app": job.app,
            "state": job.state.value,
            "submit_t": job.submit_time,
            "start_t": job.start_time,
            "end_t": job.end_time,
            "queued_s": queued_s,
            "run_s": run_s,
            "reconfig_s": reconfig_s,
            "compute_s": max(run_s - reconfig_s, 0.0),
            "resizes": self._resizes.get(job.job_id, 0),
            "starts": starts,
        })

    # -- derived views -------------------------------------------------------

    def ledger(self) -> List[dict]:
        """DMR action ledger: (action, reason code) -> count + time sums.

        Every ``ActionRecord`` of the run lands in exactly one row, so
        the count column sums to ``len(report.actions)`` — the exactness
        the decision-audit CLI is checked against."""
        return [{"action": action, "reason": code, "count": row[0],
                 "decide_s": row[1], "apply_s": row[2]}
                for (action, code), row in sorted(self._ledger.items())]

    def utilization(self, sample_s: float = 10.0) -> Tuple[float, float]:
        """Recorder-side recomputation of ``SimReport.utilization`` from
        the recorder's private timeline copies — same sampling grid,
        same live-capacity denominator (the observer-effect cross-check).
        """
        if not self._timeline:
            return 0.0, 0.0
        ts = np.array([e[0] for e in self._timeline])
        alloc = np.array([e[1] for e in self._timeline], dtype=float)
        t_end = self.makespan if self.makespan > 0 else ts[-1]
        grid = np.arange(0.0, max(t_end, sample_s), sample_s)
        idx = np.clip(np.searchsorted(ts, grid, side="right") - 1, 0, None)
        if self._capacity:
            cts = np.array([e[0] for e in self._capacity])
            live = np.array([e[1] for e in self._capacity], dtype=float)
            cidx = np.clip(np.searchsorted(cts, grid, side="right") - 1,
                           0, None)
            denom = np.maximum(live[cidx], 1.0)
        else:
            denom = float(max(self.sim.config.num_nodes, 1))
        samples = alloc[idx] / denom * 100.0
        return float(samples.mean()), float(samples.std())
