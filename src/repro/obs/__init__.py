"""Deterministic observability layer over the RMS simulator.

Four pieces, layered on the engine's monitor fan-out:

- :mod:`repro.obs.recorder` — ``TraceRecorder``, an engine monitor that
  turns the event stream + ``ActionRecord`` audit trail into typed spans
  and event-sampled metrics (zero overhead when not installed);
- :mod:`repro.obs.metrics` — the counters/gauges/histograms registry,
  sampled on simulation time only, never wall clock;
- :mod:`repro.obs.export` — byte-deterministic artifacts: the
  ``repro.obs`` schema-v1 JSON, a JSONL span log, and a Chrome
  trace-event file loadable in Perfetto;
- :mod:`repro.obs.report` + ``python -m repro.obs`` — the per-job
  time-breakdown / DMR-action-ledger / SLO-timeline CLI.

The determinism contract extends here: a traced run's simulation output
is byte-identical to an untraced run, and the trace artifacts themselves
are byte-identical across repeated runs (``docs/observability.md``).
"""
from repro.obs.metrics import Counter, Gauge, Histogram, MetricsRegistry
from repro.obs.recorder import Span, TraceRecorder
from repro.obs.export import (SCHEMA_ID, SCHEMA_VERSION, build_artifact,
                              chrome_trace, dumps_artifact, write_trace)

__all__ = [
    "Counter", "Gauge", "Histogram", "MetricsRegistry",
    "Span", "TraceRecorder",
    "SCHEMA_ID", "SCHEMA_VERSION", "build_artifact", "chrome_trace",
    "dumps_artifact", "write_trace",
]
