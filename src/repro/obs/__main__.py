"""``python -m repro.obs`` — the decision-audit CLI entry point."""
from repro.obs.report import main

if __name__ == "__main__":
    raise SystemExit(main())
