"""Deterministic metrics registry — counters, gauges, histograms.

Everything here is sampled on *simulation* time supplied by the caller;
no metric ever reads a wall clock, so two runs of the same scenario
produce byte-identical metric documents.  Gauges store their full
``(t, value)`` step function (deduplicated: a sample is recorded only
when the value changes, and a later write at the same instant replaces
the earlier one — matching how ``searchsorted(side="right")`` reads a
step function).  Histograms use fixed bucket bounds declared at creation
so bucket layout can never drift between runs.
"""
from __future__ import annotations

import bisect
from typing import Dict, List, Optional, Tuple

#: Default duration buckets (seconds) for reconfiguration latencies —
#: spans Fig. 3's measured resize costs (sub-second) up to checkpoint
#: requeue restarts (minutes).
DEFAULT_BUCKETS: Tuple[float, ...] = (
    0.05, 0.1, 0.25, 0.5, 1.0, 2.5, 5.0, 10.0, 30.0, 60.0, 120.0, 300.0)


class Counter:
    """Monotonic event counter."""

    __slots__ = ("value",)

    def __init__(self):
        self.value = 0.0

    def inc(self, amount: float = 1.0) -> None:
        self.value += amount


class Gauge:
    """A step function of simulation time: changed-value samples only."""

    __slots__ = ("samples",)

    def __init__(self):
        self.samples: List[Tuple[float, float]] = []

    @property
    def last(self) -> Optional[float]:
        return self.samples[-1][1] if self.samples else None

    def set(self, t: float, value: float) -> None:
        samples = self.samples
        if samples:
            lt, lv = samples[-1]
            if lt == t:
                if len(samples) >= 2 and samples[-2][1] == value:
                    samples.pop()          # re-write erased the change
                else:
                    samples[-1] = (t, value)
                return
            if lv == value:
                return                     # unchanged: step continues
        samples.append((t, value))

    def integral(self, t_end: float) -> float:
        """Step-function integral over ``[t0_first_sample, t_end]``."""
        total = 0.0
        samples = self.samples
        for i, (t0, v) in enumerate(samples):
            t1 = t_end if i + 1 == len(samples) else samples[i + 1][0]
            t1 = min(t1, t_end)
            if t1 > t0:
                total += v * (t1 - t0)
        return total


class Histogram:
    """Fixed-bound cumulative-style histogram (``value <= bound``)."""

    __slots__ = ("bounds", "counts", "total", "count")

    def __init__(self, bounds: Tuple[float, ...] = DEFAULT_BUCKETS):
        self.bounds = tuple(bounds)
        self.counts = [0] * (len(self.bounds) + 1)   # +1: overflow bucket
        self.total = 0.0
        self.count = 0

    def observe(self, value: float) -> None:
        self.counts[bisect.bisect_left(self.bounds, value)] += 1
        self.total += value
        self.count += 1


class MetricsRegistry:
    """Keyed store of metrics; keys are ``(name, sorted label items)``.

    A metric keeps its kind for life — re-registering the same
    name+labels as a different kind is a programming error and raises.
    """

    def __init__(self):
        self._metrics: Dict[Tuple[str, Tuple[Tuple[str, str], ...]],
                            object] = {}

    def _get(self, name: str, labels: dict, kind: type, factory):
        key = (name, tuple(sorted((k, str(v)) for k, v in labels.items())))
        metric = self._metrics.get(key)
        if metric is None:
            metric = self._metrics[key] = factory()
        elif type(metric) is not kind:
            raise TypeError(f"metric {key} already registered "
                            f"as {type(metric).__name__}")
        return metric

    def counter(self, name: str, **labels) -> Counter:
        return self._get(name, labels, Counter, Counter)

    def gauge(self, name: str, **labels) -> Gauge:
        return self._get(name, labels, Gauge, Gauge)

    def histogram(self, name: str,
                  bounds: Tuple[float, ...] = DEFAULT_BUCKETS,
                  **labels) -> Histogram:
        return self._get(name, labels, Histogram, lambda: Histogram(bounds))

    # -- deterministic export ------------------------------------------------

    def to_doc(self) -> dict:
        """Sorted, JSON-ready document: counters, gauges, histograms."""
        counters, gauges, histograms = [], [], []
        for (name, labels), metric in sorted(self._metrics.items()):
            entry = {"name": name, "labels": dict(labels)}
            if isinstance(metric, Counter):
                entry["value"] = _num(metric.value)
                counters.append(entry)
            elif isinstance(metric, Gauge):
                entry["samples"] = [[_num(t), _num(v)]
                                    for t, v in metric.samples]
                gauges.append(entry)
            else:
                entry.update(bounds=[_num(b) for b in metric.bounds],
                             counts=list(metric.counts),
                             total=_num(metric.total), count=metric.count)
                histograms.append(entry)
        return {"counters": counters, "gauges": gauges,
                "histograms": histograms}


def _num(x: float):
    """JSON-safe deterministic number: 6-digit round, non-finite -> None."""
    x = float(x)
    if x != x or x in (float("inf"), float("-inf")):
        return None
    r = round(x, 6)
    return int(r) if r == int(r) else r
