"""Byte-deterministic trace artifacts: obs JSON, JSONL spans, Perfetto.

Three renderings of one :class:`~repro.obs.recorder.TraceRecorder`:

- ``<prefix>.obs.json`` — the ``repro.obs`` schema-v1 artifact (spans +
  metrics + per-job breakdown + DMR ledger), golden-locked in CI;
- ``<prefix>.spans.jsonl`` — one span per line, for streaming tooling;
- ``<prefix>.perfetto.json`` — Chrome trace-event JSON loadable in
  Perfetto / ``chrome://tracing``: jobs as tracks, DMR negotiations and
  disruptions on per-job negotiation tracks, resizes as flow arrows from
  the negotiation to the job track, metrics as counter tracks.

Determinism: floats are rounded to 6 digits at export (non-finite maps
to ``null``), spans are sorted by ``(t0, track, name, dur)``, and every
JSON document is dumped with ``sort_keys=True`` — two identical runs
produce byte-identical files (the contract ``docs/determinism.md``
extends to trace artifacts).
"""
from __future__ import annotations

import json
import os
from typing import List

from repro.obs.metrics import _num

SCHEMA_ID = "repro.obs"
SCHEMA_VERSION = 1

# Perfetto process ids: one per track family.
_PID_JOBS = 1        # job lifecycle tracks
_PID_DMR = 2         # per-job DMR negotiation / disruption / SLO tracks
_PID_CLUSTER = 3     # cluster capacity track
_PID_METRICS = 4     # counter tracks


def build_artifact(rec) -> dict:
    """The schema-v1 obs document for a finalized recorder."""
    if not rec._finalized:
        raise RuntimeError("finalize(report) the recorder before export")
    spans = sorted(rec.spans, key=lambda s: (s.t0, s.track, s.name, s.dur))
    avg, std = rec.utilization()
    return {
        "schema": SCHEMA_ID,
        "version": SCHEMA_VERSION,
        "meta": {str(k): rec.meta[k] for k in sorted(rec.meta)},
        "makespan": _num(rec.makespan),
        "utilization": {"avg_pct": _num(avg), "std_pct": _num(std)},
        "jobs": [_job_doc(j) for j in rec.jobs],
        "ledger": [{"action": row["action"], "reason": row["reason"],
                    "count": row["count"],
                    "decide_s": _num(row["decide_s"]),
                    "apply_s": _num(row["apply_s"])}
                   for row in rec.ledger()],
        "serving": {str(jid): {"slo_violations": s["slo_violations"],
                               "served_requests": _num(
                                   s["served_requests"]),
                               "p99_s": _num(s["p99_s"])}
                    for jid, s in sorted(rec.serving.items())},
        "spans": [_span_doc(s) for s in spans],
        "metrics": rec.metrics.to_doc(),
    }


def _job_doc(j: dict) -> dict:
    out = dict(j)
    for key in ("submit_t", "start_t", "end_t", "queued_s", "run_s",
                "reconfig_s", "compute_s"):
        out[key] = _num(out[key])
    return out


def _span_doc(span) -> dict:
    return {"name": span.name, "kind": span.kind, "track": span.track,
            "t0": _num(span.t0), "dur": _num(span.dur),
            "args": {k: (_num(v) if isinstance(v, float) else v)
                     for k, v in sorted(span.args.items())}}


def dumps_artifact(doc: dict) -> bytes:
    return (json.dumps(doc, indent=1, sort_keys=True) + "\n").encode()


def spans_jsonl(doc: dict) -> bytes:
    lines = [json.dumps(s, sort_keys=True, separators=(",", ":"))
             for s in doc["spans"]]
    return ("\n".join(lines) + "\n").encode() if lines else b""


# ---------------------------------------------------------------------------
# Chrome trace-event JSON (Perfetto)
# ---------------------------------------------------------------------------

def _us(t: float) -> float:
    v = round(t * 1e6, 3)
    return int(v) if v == int(v) else v


def _track_pid_tid(track: str):
    if track.startswith("job/"):
        return _PID_JOBS, int(track[4:]) + 1
    if track.startswith("dmr/job") or track.startswith("slo/job"):
        return _PID_DMR, int(track[7:]) + 1
    return _PID_CLUSTER, 1


def chrome_trace(doc: dict) -> dict:
    """Chrome trace-event rendering of an obs artifact document."""
    events: List[dict] = []
    threads = {}     # (pid, tid) -> thread name
    for pid, name in ((_PID_JOBS, "jobs"), (_PID_DMR, "dmr"),
                      (_PID_CLUSTER, "cluster"), (_PID_METRICS, "metrics")):
        events.append({"ph": "M", "name": "process_name", "pid": pid,
                       "tid": 0, "args": {"name": name}})
    flow_id = 0
    for span in doc["spans"]:
        pid, tid = _track_pid_tid(span["track"])
        threads.setdefault((pid, tid), span["track"])
        ev = {"name": span["name"], "cat": span["kind"],
              "pid": pid, "tid": tid, "ts": _us(span["t0"]),
              "args": span["args"]}
        if span["dur"] and span["dur"] > 0:
            ev["ph"] = "X"
            ev["dur"] = _us(span["dur"])
        else:
            ev["ph"] = "i"
            ev["s"] = "t"
        events.append(ev)
        # a granted resize: flow arrow negotiation-track -> job track
        if span["kind"] == "dmr" and span["name"] in ("expand", "shrink") \
                and span["args"].get("from") != span["args"].get("to"):
            flow_id += 1
            job_tid = tid
            events.append({"ph": "s", "id": flow_id, "name": "resize",
                           "cat": "resize", "pid": pid, "tid": tid,
                           "ts": _us(span["t0"])})
            events.append({"ph": "f", "bp": "e", "id": flow_id,
                           "name": "resize", "cat": "resize",
                           "pid": _PID_JOBS, "tid": job_tid,
                           "ts": _us(span["t0"] + (span["dur"] or 0))})
            threads.setdefault((_PID_JOBS, job_tid),
                               f"job/{job_tid - 1}")
    for (pid, tid), name in sorted(threads.items()):
        events.append({"ph": "M", "name": "thread_name", "pid": pid,
                       "tid": tid, "args": {"name": name}})
    for gauge in doc["metrics"]["gauges"]:
        label = gauge["name"]
        if gauge["labels"]:
            inner = ",".join(f"{k}={v}"
                             for k, v in sorted(gauge["labels"].items()))
            label = f"{label}{{{inner}}}"
        for t, v in gauge["samples"]:
            events.append({"ph": "C", "name": label, "pid": _PID_METRICS,
                           "tid": 0, "ts": _us(t),
                           "args": {"value": v}})
    return {"traceEvents": events, "displayTimeUnit": "ms",
            "otherData": {"schema": doc["schema"],
                          "version": doc["version"]}}


def dumps_chrome(trace: dict) -> bytes:
    return (json.dumps(trace, sort_keys=True, separators=(",", ": "))
            + "\n").encode()


# ---------------------------------------------------------------------------
# File bundle
# ---------------------------------------------------------------------------

def write_trace(prefix: str, rec) -> dict:
    """Write the three artifacts under ``prefix``; returns their paths."""
    doc = build_artifact(rec)
    parent = os.path.dirname(prefix)
    if parent:
        os.makedirs(parent, exist_ok=True)
    paths = {"obs": prefix + ".obs.json",
             "spans": prefix + ".spans.jsonl",
             "perfetto": prefix + ".perfetto.json"}
    with open(paths["obs"], "wb") as fh:
        fh.write(dumps_artifact(doc))
    with open(paths["spans"], "wb") as fh:
        fh.write(spans_jsonl(doc))
    with open(paths["perfetto"], "wb") as fh:
        fh.write(dumps_chrome(chrome_trace(doc)))
    return paths
