"""Decision-audit CLI over ``repro.obs`` artifacts.

``python -m repro.obs run.obs.json`` renders three deterministic text
sections from a trace artifact:

- the per-job time breakdown (queued / compute / reconfig attribution —
  the per-job timeline currency of the malleable-scheduling evaluations);
- the DMR action ledger: expand/shrink/no-action (and every disruption
  and capacity action) counted by vocabulary reason — the paper's
  Table-2 shape.  Ledger counts sum to the run's exact ``ActionRecord``
  total, which is what makes it an *audit*;
- the serving SLO timeline summary (violations, served requests, p99).

``--check GOLDEN`` byte-compares the rendered report against a golden
file (CI uses this on the churn smoke artifact).  All rendering returns
strings; only ``main`` prints.
"""
from __future__ import annotations

import argparse
import json
from typing import List

from repro.obs.export import SCHEMA_ID, SCHEMA_VERSION


def load_artifact(path: str) -> dict:
    with open(path, "r", encoding="utf-8") as fh:
        doc = json.load(fh)
    if doc.get("schema") != SCHEMA_ID:
        raise ValueError(f"{path}: not a {SCHEMA_ID} artifact")
    if doc.get("version") != SCHEMA_VERSION:
        raise ValueError(f"{path}: schema version {doc.get('version')!r}, "
                         f"expected {SCHEMA_VERSION}")
    return doc


def _fmt(value, width: int = 9, digits: int = 2) -> str:
    if value is None:
        return "-".rjust(width)
    return f"{value:>{width}.{digits}f}"


def job_table(doc: dict) -> str:
    lines = ["== per-job time breakdown =="]
    header = (f"{'job':>4} {'app':<10} {'state':<10} {'submit':>9} "
              f"{'start':>9} {'end':>9} {'queued':>9} {'run':>9} "
              f"{'reconfig':>9} {'compute':>9} {'resizes':>7}")
    lines.append(header)
    for j in doc["jobs"]:
        lines.append(
            f"{j['job_id']:>4} {j['app']:<10.10} {j['state']:<10.10} "
            f"{_fmt(j['submit_t'])} {_fmt(j['start_t'])} "
            f"{_fmt(j['end_t'])} {_fmt(j['queued_s'])} "
            f"{_fmt(j['run_s'])} {_fmt(j['reconfig_s'])} "
            f"{_fmt(j['compute_s'])} {j['resizes']:>7}")
    util = doc.get("utilization", {})
    lines.append(f"makespan {_fmt(doc['makespan'], 1)}s   "
                 f"utilization {_fmt(util.get('avg_pct'), 1)}% "
                 f"(std {_fmt(util.get('std_pct'), 1)}%)")
    return "\n".join(lines)


def ledger_table(doc: dict) -> str:
    lines = ["== DMR action ledger =="]
    lines.append(f"{'action':<18} {'reason':<28} {'count':>6} "
                 f"{'decide_s':>9} {'apply_s':>9}")
    total = 0
    for row in doc["ledger"]:
        total += row["count"]
        lines.append(f"{row['action']:<18.18} {row['reason']:<28.28} "
                     f"{row['count']:>6} {_fmt(row['decide_s'])} "
                     f"{_fmt(row['apply_s'])}")
    lines.append(f"{'total':<18} {'':<28} {total:>6}")
    return "\n".join(lines)


def slo_summary(doc: dict) -> str:
    lines = ["== serving SLO summary =="]
    serving = doc.get("serving", {})
    if not serving:
        lines.append("(no serving jobs)")
        return "\n".join(lines)
    lines.append(f"{'job':>4} {'violations':>10} {'served':>12} "
                 f"{'p99_s':>9}")
    for jid, s in sorted(serving.items(), key=lambda kv: int(kv[0])):
        lines.append(f"{int(jid):>4} {s['slo_violations']:>10} "
                     f"{_fmt(s['served_requests'], 12)} "
                     f"{_fmt(s['p99_s'])}")
    return "\n".join(lines)


def render_report(doc: dict) -> str:
    return "\n\n".join(
        [job_table(doc), ledger_table(doc), slo_summary(doc)]) + "\n"


def ledger_total(doc: dict) -> int:
    """Total actions accounted for by the ledger (== ActionRecord count)."""
    return sum(row["count"] for row in doc["ledger"])


def main(argv: List[str] = None) -> int:
    parser = argparse.ArgumentParser(
        prog="python -m repro.obs",
        description="Render the decision-audit report of a repro.obs "
                    "trace artifact.")
    parser.add_argument("artifact", help="path to a <run>.obs.json file")
    parser.add_argument("--check", metavar="GOLDEN",
                        help="byte-compare the rendered report against "
                             "this golden file; exit 1 on drift")
    parser.add_argument("--section", choices=("all", "jobs", "ledger",
                                              "slo"), default="all")
    args = parser.parse_args(argv)
    doc = load_artifact(args.artifact)
    if args.section == "jobs":
        text = job_table(doc) + "\n"
    elif args.section == "ledger":
        text = ledger_table(doc) + "\n"
    elif args.section == "slo":
        text = slo_summary(doc) + "\n"
    else:
        text = render_report(doc)
    if args.check:
        with open(args.check, "r", encoding="utf-8") as fh:
            golden = fh.read()
        if text != golden:
            print(f"OBS REPORT DRIFT vs {args.check}")
            print("--- got ---")
            print(text, end="")
            return 1
        print(f"obs report matches {args.check}")
        return 0
    print(text, end="")
    return 0
