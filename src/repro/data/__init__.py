"""Data pipeline."""
from repro.data.pipeline import (DataConfig, SyntheticLMData, batch_specs,
                                 make_batch)

__all__ = ["DataConfig", "SyntheticLMData", "batch_specs", "make_batch"]
