"""Synthetic token pipeline: deterministic, shardable, elastic.

Generates next-token-prediction batches from a seeded Markov-ish stream so
training losses actually descend (the model can learn the transition
structure).  The loader is *elastic*: batches are a pure function of
(seed, step), so after a job resize every slice can regenerate its shard of
the global batch without coordination — the data-pipeline analogue of the
paper's requirement that reconfiguration not lose application progress.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass(frozen=True)
class DataConfig:
    vocab_size: int
    seq_len: int
    global_batch: int
    seed: int = 1234
    frontend: Optional[str] = None    # "patches" | "frames"
    frontend_tokens: int = 0
    d_model: int = 0
    enc_dec: bool = False


class SyntheticLMData:
    """Deterministic synthetic LM stream."""

    def __init__(self, cfg: DataConfig):
        self.cfg = cfg
        rng = np.random.default_rng(cfg.seed)
        self.k = min(cfg.vocab_size, 4093)
        self.shift = int(rng.integers(1, self.k))

    def batch(self, step: int) -> Dict[str, jax.Array]:
        cfg = self.cfg
        key = jax.random.fold_in(jax.random.PRNGKey(cfg.seed), step)
        text_len = cfg.seq_len - cfg.frontend_tokens
        if cfg.enc_dec:
            text_len = cfg.seq_len // 2
        base = jax.random.randint(key, (cfg.global_batch, 1), 0, self.k)
        steps = jnp.arange(text_len + 1)[None, :]
        toks = (base + steps * self.shift) % self.k   # learnable structure
        noise = jax.random.bernoulli(key, 0.1, toks.shape)
        rnd = jax.random.randint(key, toks.shape, 0, self.k)
        toks = jnp.where(noise, rnd, toks).astype(jnp.int32)
        batch = {"tokens": toks[:, :-1], "labels": toks[:, 1:]}
        if cfg.frontend:
            fkey = jax.random.fold_in(key, 1)
            batch["frontend"] = jax.random.normal(
                fkey, (cfg.global_batch,
                       cfg.frontend_tokens or cfg.seq_len // 2,
                       cfg.d_model), jnp.float32)
        return batch


def batch_specs(cfg: DataConfig) -> Dict[str, jax.ShapeDtypeStruct]:
    """ShapeDtypeStruct stand-ins for the dry-run (no allocation)."""
    text_len = cfg.seq_len - cfg.frontend_tokens
    if cfg.enc_dec:
        text_len = cfg.seq_len // 2
    out = {
        "tokens": jax.ShapeDtypeStruct((cfg.global_batch, text_len),
                                       jnp.int32),
        "labels": jax.ShapeDtypeStruct((cfg.global_batch, text_len),
                                       jnp.int32),
    }
    if cfg.frontend:
        out["frontend"] = jax.ShapeDtypeStruct(
            (cfg.global_batch, cfg.frontend_tokens or cfg.seq_len // 2,
             cfg.d_model), jnp.float32)
    return out


def make_batch(cfg: DataConfig, step: int) -> Dict[str, jax.Array]:
    return SyntheticLMData(cfg).batch(step)
