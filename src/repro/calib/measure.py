"""Measurement harness: time real redistribute runs on device meshes.

Two backends close the loop between the jax runtime and the RMS simulator:

- ``jax`` — the *real* path: for each grid geometry ``(p, q)`` an array of
  ``data_bytes`` is laid out over a ``p``-slice mesh
  (:func:`repro.core.meshes.make_mesh`) and resharded onto a ``q``-slice
  mesh with ``jax.device_put`` — exactly the transfer the factor-based
  plans of :mod:`repro.core.redistribute` describe (the reshard tests pin
  that equivalence).  ``migrate_slice`` (the straggler path) is timed the
  same way, and RMS scheduling latency is sampled from real
  ``ReconfigPolicy.decide`` calls, reusing the ``kernel_bench`` timing
  pattern (warm-up, ``block_until_ready``, best-of-``repeats``).  On a
  host with fewer devices than a geometry needs (the 1-device CI CPU
  default), the harness falls back to a *link proxy*: it times a
  host→device ``device_put`` of the plan's busiest-link bytes, which is
  the quantity the Fig. 3 model divides by ``link_bw`` — honest bandwidth
  measurement, no synthetic numbers.  Multi-device CPU meshes are
  available by setting ``XLA_FLAGS=--xla_force_host_platform_device_count
  =8`` in a fresh process (the CI calibration step does).

- ``plan`` — the *deterministic* backend behind the committed golden
  artifact: samples are generated from hidden "ground truth" parameters
  (:data:`TRUE_PARAMS`, deliberately different from the paper-fit
  constants) plus seeded multiplicative noise, so measure → fit → artifact
  is byte-reproducible and the fitter's recovery accuracy is testable.
  Artifacts are labelled with their backend, so a ``plan`` calibration can
  never masquerade as a hardware measurement.

CLI (also the CI smoke step)::

    PYTHONPATH=src python -m repro.calib.measure --backend plan \\
        [--out calib.json] [--check tests/data/golden_calibration.json]
    XLA_FLAGS=--xla_force_host_platform_device_count=8 \\
    PYTHONPATH=src python -m repro.calib.measure --backend jax --quick
"""
from __future__ import annotations

import argparse
import dataclasses
import time
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.calib.artifact import SAMPLE_DIGITS

MiB = 1024 ** 2
GiB = 1024 ** 3

#: The CI CPU-mesh grid: factor-2 geometries across the Fig. 3 x-axis and
#: three data sizes.  ``(p, q)`` with ``q > p`` is an expand; every
#: geometry is also measured in the shrink direction ``(q, p)``.
CI_GEOMETRIES: Tuple[Tuple[int, int], ...] = (
    (1, 2), (2, 4), (4, 8), (8, 16), (16, 32), (32, 64))
CI_DATA_BYTES: Tuple[int, ...] = (64 * MiB, 256 * MiB, GiB)
CI_SCHED_NODES: Tuple[int, ...] = (2, 4, 8, 16, 32, 64)

#: Hidden ground truth of the ``plan`` backend — what the fitter must
#: recover.  Deliberately off the paper-fit constants so a fit that just
#: echoes the defaults fails the recovery test.
TRUE_PARAMS: Dict[str, float] = {
    "link_bw": 4.6e9, "spawn_s": 0.055, "shrink_sync_s": 0.0045,
    "sched_base_s": 0.38, "sched_per_node_s": 0.0028,
}
#: Multiplicative log-normal noise sigma of the ``plan`` backend.
PLAN_NOISE_SIGMA = 0.03


@dataclasses.dataclass(frozen=True)
class MeasureConfig:
    """One measurement campaign: geometries × data sizes (+ sched nodes)."""
    geometries: Tuple[Tuple[int, int], ...] = CI_GEOMETRIES
    data_bytes: Tuple[int, ...] = CI_DATA_BYTES
    sched_nodes: Tuple[int, ...] = CI_SCHED_NODES
    repeats: int = 3
    seed: int = 2026
    backend: str = "plan"            # "plan" | "jax"

    def grid_doc(self) -> Dict[str, object]:
        return {"geometries": [list(g) for g in self.geometries],
                "data_bytes": list(self.data_bytes),
                "sched_nodes": list(self.sched_nodes),
                "repeats": self.repeats, "seed": self.seed}


def _sample(kind: str, old: int, new: int, nbytes: int,
            participants: int, busiest: int, seconds: float
            ) -> Dict[str, object]:
    return {"kind": kind, "old": old, "new": new, "bytes": nbytes,
            "participants": participants, "busiest_bytes": busiest,
            "seconds": round(seconds, SAMPLE_DIGITS)}


def resize_features(kind: str, p: int, q: int, nbytes: int
                    ) -> Tuple[int, int]:
    """``(participants, busiest_bytes)`` of the (p → q, nbytes) plan."""
    # Deferred: repro.core.redistribute imports jax, and this module's
    # grid/config surface must stay importable from jax-free consumers
    # (the sweep driver imports repro.calib.artifact in every worker).
    from repro.core.redistribute import expand_plan, plan_stats, shrink_plan
    plan = expand_plan(p, q, nbytes) if kind == "expand" else \
        shrink_plan(p, q, nbytes)
    return plan_stats(plan)


# ---------------------------------------------------------------------------
# plan backend — deterministic synthetic measurement
# ---------------------------------------------------------------------------

def _measure_plan(config: MeasureConfig
                  ) -> Tuple[List[Dict[str, object]], Dict[str, object]]:
    rng = np.random.default_rng(config.seed)
    tp = TRUE_PARAMS
    samples: List[Dict[str, object]] = []

    def noisy(t: float) -> float:
        return t * float(np.exp(PLAN_NOISE_SIGMA * rng.standard_normal()))

    for p, q in config.geometries:
        for nbytes in config.data_bytes:
            for kind, a, b in (("expand", p, q), ("shrink", q, p)):
                parts, busiest = resize_features(kind, a, b, nbytes)
                sync = tp["shrink_sync_s"] if kind == "shrink" else 0.0
                true_t = (tp["spawn_s"] + busiest / tp["link_bw"]
                          + sync * parts)
                for _ in range(config.repeats):
                    samples.append(_sample(kind, a, b, nbytes, parts,
                                           busiest, noisy(true_t)))
    for nodes in config.sched_nodes:
        true_t = tp["sched_base_s"] + tp["sched_per_node_s"] * nodes
        for _ in range(config.repeats):
            samples.append(_sample("sched", nodes, nodes, 0, nodes, 0,
                                   noisy(true_t)))
    env = {"backend": "plan", "noise_sigma": PLAN_NOISE_SIGMA,
           "true_params": dict(TRUE_PARAMS)}
    return samples, env


# ---------------------------------------------------------------------------
# jax backend — real device-mesh measurement
# ---------------------------------------------------------------------------

def _best_of(fn, repeats: int) -> float:
    """kernel_bench-style timing: one warm-up call, then best of N."""
    import jax
    jax.block_until_ready(fn())
    best = float("inf")
    for _ in range(max(repeats, 1)):
        t0 = time.perf_counter()
        jax.block_until_ready(fn())
        best = min(best, time.perf_counter() - t0)
    return best


def _elems_for(nbytes: int, slices: int) -> int:
    """float32 element count ≈ nbytes, divisible by the slice count."""
    per_slice = max(nbytes // 4 // slices, 1)
    return per_slice * slices


def _measure_resize_jax(kind: str, p: int, q: int, nbytes: int,
                        repeats: int, devices) -> float:
    """Time the real reshard: device_put from a p-slice to a q-slice mesh."""
    import jax
    from jax.sharding import NamedSharding, PartitionSpec as P

    from repro.core.meshes import make_mesh

    elems = _elems_for(nbytes, max(p, q))
    old = NamedSharding(make_mesh(p, 1, devices=devices), P("data"))
    new = NamedSharding(make_mesh(q, 1, devices=devices), P("data"))
    x = jax.device_put(np.zeros(elems, np.float32), old)
    return _best_of(lambda: jax.device_put(x, new), repeats)


def _measure_link_proxy(busiest: int, repeats: int, device) -> float:
    """Single-device fallback: time a host→device copy of the busiest-link
    bytes — the quantity the model divides by ``link_bw``."""
    import jax
    buf = np.zeros(max(busiest // 4, 1), np.float32)
    return _best_of(lambda: jax.device_put(buf, device), repeats)


def _measure_migrate_jax(slices: int, nbytes: int, repeats: int,
                         devices) -> float:
    import jax
    from jax.sharding import NamedSharding, PartitionSpec as P

    from repro.core.meshes import make_mesh
    from repro.core.redistribute import migrate_slice

    mesh = make_mesh(slices, 1, devices=devices)
    elems = _elems_for(nbytes, slices)
    x = jax.device_put(np.zeros(elems, np.float32),
                       NamedSharding(mesh, P("data")))
    return _best_of(lambda: migrate_slice(x, mesh, 0, slices - 1), repeats)


def _measure_sched_jax(nodes: int, repeats: int) -> float:
    """Real in-process RMS policy latency (the measured part of Fig. 3a)."""
    from repro.rms.cluster import Cluster
    from repro.rms.job import Job, JobState
    from repro.rms.policy import ReconfigPolicy

    pol = ReconfigPolicy()
    cluster = Cluster(2 * nodes)
    job = Job(job_id=0, app="fs", submit_time=0, work=2, min_nodes=1,
              max_nodes=2 * nodes, preferred=None, requested_nodes=nodes)
    job.state = JobState.RUNNING
    job.nodes = nodes
    cluster.allocate(0, nodes)
    pol.decide(cluster, [], job, minimum=nodes, maximum=nodes, factor=2)
    best = float("inf")
    for _ in range(max(repeats, 1)):
        t0 = time.perf_counter()
        pol.decide(cluster, [], job, minimum=nodes, maximum=nodes, factor=2)
        best = min(best, time.perf_counter() - t0)
    return best


def _measure_jax(config: MeasureConfig
                 ) -> Tuple[List[Dict[str, object]], Dict[str, object]]:
    import jax

    devices = jax.devices()
    samples: List[Dict[str, object]] = []
    proxied = 0
    for p, q in config.geometries:
        for nbytes in config.data_bytes:
            for kind, a, b in (("expand", p, q), ("shrink", q, p)):
                parts, busiest = resize_features(kind, a, b, nbytes)
                if max(a, b) <= len(devices):
                    secs = _measure_resize_jax(kind, a, b, nbytes,
                                               config.repeats, devices)
                else:
                    secs = _measure_link_proxy(busiest, config.repeats,
                                               devices[0])
                    proxied += 1
                samples.append(_sample(kind, a, b, nbytes, parts, busiest,
                                       secs))
        if 2 <= p <= len(devices):
            nbytes = config.data_bytes[0]
            secs = _measure_migrate_jax(p, nbytes, config.repeats, devices)
            samples.append(_sample("migrate", p, p, nbytes, 2,
                                   nbytes // p, secs))
    for nodes in config.sched_nodes:
        samples.append(_sample("sched", nodes, nodes, 0, nodes, 0,
                               _measure_sched_jax(nodes, config.repeats)))
    env = {"backend": "jax",
           "device_kind": devices[0].device_kind,
           "num_devices": len(devices),
           "link_proxy_samples": proxied}
    return samples, env


# ---------------------------------------------------------------------------
# Entry points
# ---------------------------------------------------------------------------

def measure_grid(config: MeasureConfig
                 ) -> Tuple[List[Dict[str, object]], Dict[str, object]]:
    """Run the campaign; returns ``(samples, environment)``."""
    if config.backend == "plan":
        return _measure_plan(config)
    if config.backend == "jax":
        return _measure_jax(config)
    raise ValueError(f"unknown backend {config.backend!r} "
                     f"(expected 'plan' or 'jax')")


def calibrate(config: Optional[MeasureConfig] = None) -> Dict[str, object]:
    """measure → fit → artifact in one call."""
    from repro.calib.artifact import make_artifact
    from repro.calib.fit import fit_samples

    config = MeasureConfig() if config is None else config

    samples, env = measure_grid(config)
    fitted, residuals, checks = fit_samples(samples)
    return make_artifact(samples=samples, fitted=fitted,
                         residuals=residuals, checks=checks,
                         grid=config.grid_doc(), backend=config.backend,
                         environment=env)


QUICK_GEOMETRIES = ((1, 2), (2, 4), (4, 8))
QUICK_DATA_BYTES = (4 * MiB, 16 * MiB)


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--backend", choices=("plan", "jax"), default="plan")
    ap.add_argument("--repeats", type=int, default=3)
    ap.add_argument("--seed", type=int, default=2026)
    ap.add_argument("--quick", action="store_true",
                    help="small grid (fits single-device CI in seconds)")
    ap.add_argument("--out", default=None,
                    help="write the calibration artifact here")
    ap.add_argument("--check", default=None,
                    help="golden artifact to byte-compare against "
                         "(exit 1 on mismatch)")
    args = ap.parse_args(argv)

    kw: Dict[str, object] = dict(backend=args.backend,
                                 repeats=args.repeats, seed=args.seed)
    if args.quick:
        kw.update(geometries=QUICK_GEOMETRIES, data_bytes=QUICK_DATA_BYTES)
    doc = calibrate(MeasureConfig(**kw))

    f = doc["fitted"]
    print(f"# calibration {doc['calibration_id']} backend={doc['backend']} "
          f"samples={len(doc['samples'])}")
    print(f"# fitted: link_bw={f['link_bw']:.4g} B/s "
          f"spawn_s={f['spawn_s']:.4g} shrink_sync_s="
          f"{f['shrink_sync_s']:.4g} sched_base_s={f['sched_base_s']:.4g} "
          f"sched_per_node_s={f['sched_per_node_s']:.4g}")
    print(f"# residuals: {doc['residuals']}")
    print(f"# checks: {doc['checks']}")
    if not all(doc["checks"].values()):
        print("# FAIL: fitted model violates the Fig. 3 shape checks")
        return 2
    if args.out:
        from repro.calib.artifact import write_calibration
        write_calibration(args.out, doc)
        print(f"# wrote {args.out}")
    if args.check:
        from repro.calib.artifact import dumps_calibration, load_calibration
        golden = dumps_calibration(load_calibration(args.check))
        if dumps_calibration(doc) != golden:
            print(f"# MISMATCH against {args.check}: calibration bytes "
                  f"differ (grid or fitter changed — regenerate the golden "
                  f"only for intentional changes)")
            return 1
        print(f"# artifact matches {args.check}")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
