"""Versioned calibration artifacts — byte-deterministic, schema-checked.

A *calibration artifact* is the durable output of one measure → fit run:
the raw timing samples, the fitted :class:`~repro.rms.costmodel.
ReconfigCostModel` parameters, residual diagnostics, and the shape checks
(Fig. 3b) — all in one JSON document whose canonical serialization is
byte-stable, exactly like the sweep artifact schema
(:mod:`repro.rms.sweep`).  The ``calibration_id`` is a content hash of the
entire artifact body (samples, fitted parameters, backend label, grid,
diagnostics), so any consumer (scheduler, sweep rows, benchmarks) can
record *which* calibration produced its numbers and hand-edits are
detected at load time.

Schema (``SCHEMA_ID`` / ``SCHEMA_VERSION``)::

    {"schema": "repro.calib", "version": 1,
     "calibration_id": "<12 hex chars of sha256>",
     "backend": "plan" | "jax",
     "environment": {...},                  # device kind/count, proxy notes
     "grid": {"geometries": [[p, q], ...], "data_bytes": [...],
              "repeats": ..., "seed": ...},
     "samples": [{"kind": "expand|shrink|migrate|sched", "old": p,
                  "new": q, "bytes": b, "participants": k,
                  "busiest_bytes": B, "seconds": t}, ...],
     "fitted": {"link_bw": ..., "spawn_s": ..., "shrink_sync_s": ...,
                "sched_base_s": ..., "sched_per_node_s": ...},
     "residuals": {"resize_rms_s": ..., "resize_max_s": ..., "r2": ...,
                   "n_resize": ..., "n_sched": ...},
     "checks": {"more_participants_faster": ..., "shrink_ge_expand": ...,
                "link_bw_positive": ...},
     "paper_defaults": {...}}               # the hand-fit constants, for diff

``tests/data/golden_calibration.json`` pins the deterministic (``plan``
backend) CI CPU-mesh grid: re-measuring, re-fitting, and re-serializing it
must reproduce the committed bytes exactly.
"""
from __future__ import annotations

import hashlib
import json
from typing import Dict, List, Optional, Sequence

SCHEMA_ID = "repro.calib"
SCHEMA_VERSION = 1

#: Rounding applied before serialization so artifact bytes don't depend on
#: sub-nanosecond float noise: timing samples to nanoseconds, fitted
#: parameters / residuals to 6 significant digits.
SAMPLE_DIGITS = 9
FIT_SIG_DIGITS = 6

#: ``calibration_id`` value consumers report when no artifact is loaded —
#: the hand-fit Table 2 / Fig. 3 constants in ``repro.rms.costmodel``.
PAPER_FIT_ID = "paper-fit"


def round_sig(x: float, sig: int = FIT_SIG_DIGITS) -> float:
    """Round ``x`` to ``sig`` significant digits (0.0 stays 0.0)."""
    if x == 0 or not (x == x) or x in (float("inf"), float("-inf")):
        return x
    return float(f"{x:.{sig}g}")


def dumps_calibration(doc: Dict[str, object]) -> str:
    """Canonical byte-stable serialization (same style as sweep artifacts)."""
    return json.dumps(doc, sort_keys=True, indent=2) + "\n"


def content_id(doc: Dict[str, object]) -> str:
    """Deterministic 12-hex content hash of the whole artifact body.

    Everything except the id field itself is covered — samples, fitted
    parameters, but also the backend label, grid, environment, residuals
    and checks — so no part of the document can be hand-edited (e.g.
    relabelling a synthetic ``plan`` run as a ``jax`` measurement)
    without tripping :func:`validate_calibration`.
    """
    body = {k: v for k, v in sorted(doc.items())
            if k != "calibration_id"}
    blob = json.dumps(body, sort_keys=True).encode()
    return hashlib.sha256(blob).hexdigest()[:12]


def make_artifact(*, samples: Sequence[Dict[str, object]],
                  fitted: Dict[str, float],
                  residuals: Dict[str, object],
                  checks: Dict[str, bool],
                  grid: Dict[str, object],
                  backend: str,
                  environment: Optional[Dict[str, object]] = None
                  ) -> Dict[str, object]:
    """Assemble a schema-v1 artifact; the inputs must already be rounded
    (the fitter and measurement harness do so)."""
    from repro.rms.costmodel import ReconfigCostModel
    paper = ReconfigCostModel()
    doc: Dict[str, object] = {
        "schema": SCHEMA_ID, "version": SCHEMA_VERSION,
        "backend": backend,
        "environment": dict(environment or {}),
        "grid": dict(grid),
        "samples": list(samples),
        "fitted": dict(fitted),
        "residuals": dict(residuals),
        "checks": dict(checks),
        "paper_defaults": {
            "link_bw": paper.link_bw, "spawn_s": paper.spawn_s,
            "shrink_sync_s": paper.shrink_sync_s,
            "sched_base_s": paper.sched_base_s,
            "sched_per_node_s": paper.sched_per_node_s,
        },
    }
    doc["calibration_id"] = content_id(doc)
    return doc


def validate_calibration(doc: Dict[str, object]) -> Dict[str, object]:
    """Schema/version/content checks shared by loaders and consumers."""
    if doc.get("schema") != SCHEMA_ID:
        raise ValueError(
            f"not a calibration artifact: schema={doc.get('schema')!r}")
    if doc.get("version") != SCHEMA_VERSION:
        raise ValueError(f"calibration artifact version "
                         f"{doc.get('version')} != supported "
                         f"{SCHEMA_VERSION}")
    fitted = doc.get("fitted")
    if not isinstance(fitted, dict) or "link_bw" not in fitted:
        raise ValueError("calibration artifact has no fitted parameters")
    if doc.get("calibration_id") != content_id(doc):
        raise ValueError("calibration_id does not match artifact content "
                         "(corrupted or hand-edited artifact)")
    return doc


def write_calibration(path: str, doc: Dict[str, object]) -> None:
    with open(path, "w") as fh:
        fh.write(dumps_calibration(doc))


def load_calibration(path: str) -> Dict[str, object]:
    with open(path) as fh:
        doc = json.load(fh)
    return validate_calibration(doc)


def samples_by_kind(doc: Dict[str, object]
                    ) -> Dict[str, List[Dict[str, object]]]:
    """Group a loaded artifact's samples by kind (expand/shrink/…)."""
    out: Dict[str, List[Dict[str, object]]] = {}
    for s in doc.get("samples", []):
        out.setdefault(str(s["kind"]), []).append(s)
    return out
