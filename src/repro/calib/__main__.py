"""``python -m repro.calib`` — the measure → fit → artifact CLI."""
from repro.calib.measure import main

if __name__ == "__main__":
    raise SystemExit(main())
