"""Least-squares fit of ``ReconfigCostModel`` parameters from samples.

The Fig. 3 cost model is linear in its parameters once the transfer-plan
features are extracted (:func:`repro.core.redistribute.plan_stats`):

- redistribution: ``t = spawn_s + busiest_bytes / link_bw
  + shrink_sync_s * participants``            (sync term: shrinks only)
- scheduling:     ``t = sched_base_s + sched_per_node_s * nodes``

so both fits are ordinary least squares (`numpy.linalg.lstsq`) over the
measured samples.  The fitted parameters are clamped to their physical
domain (non-negative constants, strictly positive finite bandwidth — a fit
that produces anything else raises), rounded to a fixed number of
significant digits for byte-stable artifacts, and validated against the
paper's Fig. 3b observations:

- *more participants ⇒ faster redistribution* — the fitted model must time
  a 1→2 expand slower than a 32→64 expand at equal bytes;
- *shrinks pay the per-participant sync term* — a q→p shrink must cost at
  least the p→q expand at equal geometry and bytes.

``migrate`` samples (the straggler path) are diagnostic only: they are
carried in the artifact but excluded from the fit, because slice migration
is an in-mesh ``ppermute``, not a factor-plan transfer.
"""
from __future__ import annotations

from typing import Dict, List, Sequence, Tuple

import numpy as np

from repro.calib.artifact import round_sig

Samples = Sequence[Dict[str, object]]


class FitError(ValueError):
    """The samples do not support a physical fit (e.g. non-positive
    bandwidth)."""


def _resize_design(samples: Samples) -> Tuple[np.ndarray, np.ndarray]:
    rows, ys = [], []
    for s in samples:
        if s["kind"] not in ("expand", "shrink"):
            continue
        sync_parts = float(s["participants"]) if s["kind"] == "shrink" \
            else 0.0
        rows.append([1.0, float(s["busiest_bytes"]), sync_parts])
        ys.append(float(s["seconds"]))
    return np.asarray(rows, dtype=np.float64), np.asarray(ys,
                                                          dtype=np.float64)


def _sched_design(samples: Samples) -> Tuple[np.ndarray, np.ndarray]:
    rows, ys = [], []
    for s in samples:
        if s["kind"] != "sched":
            continue
        rows.append([1.0, float(s["old"])])
        ys.append(float(s["seconds"]))
    return np.asarray(rows, dtype=np.float64), np.asarray(ys,
                                                          dtype=np.float64)


def _lstsq(a: np.ndarray, y: np.ndarray) -> np.ndarray:
    coef, *_ = np.linalg.lstsq(a, y, rcond=None)
    return coef


def fit_samples(samples: Samples) -> Tuple[Dict[str, float],
                                           Dict[str, object],
                                           Dict[str, bool]]:
    """Fit the cost-model parameters; returns ``(fitted, residuals,
    checks)`` ready for :func:`repro.calib.artifact.make_artifact`."""
    a, y = _resize_design(samples)
    if len(y) < 3:
        raise FitError(f"need >= 3 expand/shrink samples, got {len(y)}")
    if float(a[:, 1].max() - a[:, 1].min()) <= 0.0:
        # A constant busiest-bytes column is collinear with the spawn
        # intercept: the bandwidth is unidentifiable, don't fit garbage.
        raise FitError("samples carry no busiest-bytes variation — "
                       "cannot identify link_bw")
    spawn, inv_bw, sync = _lstsq(a, y)
    if not np.isfinite(inv_bw) or inv_bw <= 0:
        raise FitError(f"fitted 1/link_bw = {inv_bw!r} is not positive — "
                       f"the samples carry no usable bandwidth signal")
    fitted: Dict[str, float] = {
        "link_bw": round_sig(1.0 / float(inv_bw)),
        "spawn_s": round_sig(max(float(spawn), 0.0)),
        "shrink_sync_s": round_sig(max(float(sync), 0.0)),
    }

    sa, sy = _sched_design(samples)
    if len(sy) >= 2:
        base, per_node = _lstsq(sa, sy)
        fitted["sched_base_s"] = round_sig(max(float(base), 0.0))
        fitted["sched_per_node_s"] = round_sig(max(float(per_node), 0.0))
    else:
        # No scheduling samples: keep the paper-fit transaction constants.
        from repro.rms.costmodel import ReconfigCostModel
        paper = ReconfigCostModel()
        fitted["sched_base_s"] = paper.sched_base_s
        fitted["sched_per_node_s"] = paper.sched_per_node_s

    residuals = _residuals(fitted, a, y, sa, sy)
    checks = validate_fit(fitted)
    return fitted, residuals, checks


def _predict_resize(fitted: Dict[str, float], a: np.ndarray) -> np.ndarray:
    return (fitted["spawn_s"] + a[:, 1] / fitted["link_bw"]
            + fitted["shrink_sync_s"] * a[:, 2])


def _residuals(fitted: Dict[str, float], a: np.ndarray, y: np.ndarray,
               sa: np.ndarray, sy: np.ndarray) -> Dict[str, object]:
    """Diagnostics computed with the *clamped, rounded* parameters — the
    model consumers will actually run."""
    r = y - _predict_resize(fitted, a)
    ss_tot = float(np.sum((y - y.mean()) ** 2))
    out: Dict[str, object] = {
        "n_resize": int(len(y)), "n_sched": int(len(sy)),
        "resize_rms_s": round_sig(float(np.sqrt(np.mean(r ** 2)))),
        "resize_max_s": round_sig(float(np.max(np.abs(r)))),
        "resize_r2": round_sig(1.0 - float(np.sum(r ** 2)) / ss_tot
                               if ss_tot > 0 else 1.0),
    }
    if len(sy):
        sr = sy - (fitted["sched_base_s"]
                   + fitted["sched_per_node_s"] * sa[:, 1])
        out["sched_rms_s"] = round_sig(float(np.sqrt(np.mean(sr ** 2))))
    return out


def validate_fit(fitted: Dict[str, float],
                 probe_bytes: int = 1 << 30) -> Dict[str, bool]:
    """Fig. 3b shape checks on the fitted model (see module docstring)."""
    from repro.rms.costmodel import ReconfigCostModel
    model = ReconfigCostModel(
        link_bw=fitted["link_bw"], spawn_s=fitted["spawn_s"],
        shrink_sync_s=fitted["shrink_sync_s"],
        sched_base_s=fitted["sched_base_s"],
        sched_per_node_s=fitted["sched_per_node_s"])
    small = model.resize_time(1, 2, probe_bytes)
    expand = model.resize_time(32, 64, probe_bytes)
    shrink = model.resize_time(64, 32, probe_bytes)
    return {
        "link_bw_positive": bool(np.isfinite(fitted["link_bw"])
                                 and fitted["link_bw"] > 0),
        "params_nonnegative": all(
            fitted[k] >= 0 for k in ("spawn_s", "shrink_sync_s",
                                     "sched_base_s", "sched_per_node_s")),
        "more_participants_faster": bool(expand < small),
        "shrink_ge_expand": bool(shrink >= expand),
    }


def fit_report_rows(doc: Dict[str, object]) -> List[Dict[str, object]]:
    """Measured vs fitted vs paper-default times per resize sample group —
    the comparison ``benchmarks/fig3_reconfig_overhead.py`` prints."""
    from repro.rms.costmodel import ReconfigCostModel
    fitted_model = ReconfigCostModel.from_artifact(doc)
    paper = ReconfigCostModel()
    groups: Dict[Tuple, List[float]] = {}
    for s in doc["samples"]:
        if s["kind"] not in ("expand", "shrink"):
            continue
        key = (s["kind"], s["old"], s["new"], s["bytes"])
        groups.setdefault(key, []).append(float(s["seconds"]))
    rows = []
    for (kind, old, new, nbytes), secs in sorted(groups.items()):
        rows.append({
            "action": kind, "from": old, "to": new, "bytes": nbytes,
            "measured_s": round_sig(float(np.mean(secs))),
            "fitted_s": round_sig(fitted_model.resize_time(old, new,
                                                           nbytes)),
            "paper_s": round_sig(paper.resize_time(old, new, nbytes)),
        })
    return rows
