"""Measured-cost calibration: fit the Fig. 3 overhead model from real runs.

The simulator's :class:`~repro.rms.costmodel.ReconfigCostModel` constants
were hand-fit to the paper's Table 2 / Fig. 3.  This package closes the
loop against the jax runtime so every scheduling result can carry measured
— not assumed — reconfiguration costs.  The pipeline has four stages:

**1. measure** (:mod:`repro.calib.measure`) — time real
:func:`~repro.core.redistribute.expand_plan` /
:func:`~repro.core.redistribute.shrink_plan` redistributions (a
``jax.device_put`` between meshes of different slice counts),
``migrate_slice`` and ``ReconfigPolicy.decide`` latency, across a grid of
``(old_nodes, new_nodes, data_bytes)``::

    from repro.calib import MeasureConfig, measure_grid
    samples, env = measure_grid(MeasureConfig(backend="jax"))

The ``plan`` backend generates the same sample schema deterministically
(seeded noise around hidden ground-truth parameters) — that is what the
committed golden artifact and the fit-recovery tests use.

**2. fit** (:mod:`repro.calib.fit`) — ordinary least squares for
``link_bw``, ``spawn_s``, ``shrink_sync_s``, ``sched_base_s``,
``sched_per_node_s`` (the model is linear in all of them), with residual
diagnostics and the Fig. 3b shape checks (more participants ⇒ faster;
shrink ≥ expand at equal geometry)::

    from repro.calib import fit_samples
    fitted, residuals, checks = fit_samples(samples)

**3. artifact** (:mod:`repro.calib.artifact`) — a versioned,
byte-deterministic JSON document (schema ``repro.calib`` v1) bundling
samples + fitted parameters + diagnostics under a content-hash
``calibration_id``; ``tests/data/golden_calibration.json`` pins the CI
grid::

    from repro.calib import load_calibration, write_calibration
    write_calibration("calib.json", doc);  doc = load_calibration("calib.json")

**4. consume** — ``ReconfigCostModel.from_artifact(doc_or_path)`` builds
the fitted model; ``SimConfig(cost=...)`` threads it through the
simulator *and* the moldable start-size optimizer
(``Scheduler(..., cost=...)``); ``repro.rms.sweep`` rows record the
``calibration_id`` provenance column (schema v3);
``benchmarks/fig3_reconfig_overhead.py --calibration`` and
``benchmarks/table2_actions.py --calibration`` re-derive the paper tables
under measured costs::

    model = ReconfigCostModel.from_artifact("calib.json")
    ClusterSimulator(jobs, SimConfig(cost=model)).run()

One-shot CLI (also the CI smoke step)::

    PYTHONPATH=src python -m repro.calib.measure --backend plan \\
        --check tests/data/golden_calibration.json
"""
from repro.calib.artifact import (PAPER_FIT_ID, dumps_calibration,
                                  load_calibration, make_artifact,
                                  validate_calibration, write_calibration)
from repro.calib.fit import (FitError, fit_report_rows, fit_samples,
                             validate_fit)
from repro.calib.measure import MeasureConfig, calibrate, measure_grid

__all__ = [
    "MeasureConfig", "measure_grid", "calibrate",
    "fit_samples", "validate_fit", "fit_report_rows", "FitError",
    "make_artifact", "validate_calibration", "load_calibration",
    "write_calibration", "dumps_calibration", "PAPER_FIT_ID",
]
