"""Action types and the opaque resize handler of the DMR API."""
from __future__ import annotations

import dataclasses
import enum
from typing import Any, Optional


class Action(enum.IntEnum):
    """Reconfiguration action decided by the RMS (paper §4/§5.1)."""

    NO_ACTION = 0
    EXPAND = 1
    SHRINK = 2

    def __bool__(self) -> bool:  # `if action:` idiom of Listing 2/3
        return self is not Action.NO_ACTION


@dataclasses.dataclass
class ResizeHandler:
    """Opaque handler returned by ``dmr_check_status`` (paper §5.1).

    Identifies the pending reconfiguration: which job, from how many slices
    to how many, and — once the runtime materializes it — the new mesh the
    surviving/expanded job continues on.  Subsequent operations (the offload
    of ``compute`` onto the new configuration, Listing 2 line 13) take this
    handler.
    """

    job_id: int
    action: Action
    old_slices: int
    new_slices: int
    resizer_job_id: Optional[int] = None   # expand path: the RJ of §5.2.1
    granted_at: float = 0.0
    # Filled in by the runtime when the new parallel context exists:
    new_mesh: Any = None
    # Diagnostics for the overhead study (Fig. 3 / Table 2):
    schedule_time_s: float = 0.0           # RMS decision latency
    wait_time_s: float = 0.0               # resizer-job pending->running wait
    resize_time_s: float = 0.0             # data-redistribution time
    timed_out: bool = False

    @property
    def factor(self) -> int:
        a, b = self.old_slices, self.new_slices
        if b >= a:
            return b // max(a, 1)
        return a // max(b, 1)


@dataclasses.dataclass(frozen=True)
class Decision:
    """RMS reply to a reconfiguration request."""

    action: Action
    new_slices: int
    schedule_time_s: float = 0.0
    reason: str = ""
    resizer_job_id: Optional[int] = None
    # Wide-optimization shrink: the queued job whose start triggered the
    # shrink — it inherits maximum priority (§4.3).
    boost_job_id: Optional[int] = None
