"""Factor-based data redistribution plans & collectives (Listing 3 / Fig. 2).

The paper's programming model redistributes data homogeneously: an *expand*
by factor ``f`` splits each of the ``P`` old ranks' data into ``f`` chunks,
chunk ``i`` of old rank ``r`` going to new rank ``r*f + i`` (Fig. 2a); a
*shrink* by factor ``f`` groups ranks in blocks of ``f``, the last member of
each block (the *receiver*) collecting the other ``f-1`` *senders'* data
(Fig. 2b) and continuing as new rank ``r // f``.

Three artefacts live here:

- :func:`expand_plan` / :func:`shrink_plan` — explicit transfer plans
  (src slice, dst slice, bytes).  These drive the simulator's
  redistribution cost model and are validated against what
  ``jax.device_put`` actually does.
- :func:`transfer_time_s` — the Fig.-3 cost model: concurrent transfers over
  per-slice links, plus the shrink synchronization term.
- :func:`migrate_slice` — an in-mesh ``shard_map``/``ppermute`` migration of
  one slice's shard to another slice (used for straggler mitigation, where
  the slice *count* is unchanged but membership rotates).
"""
from __future__ import annotations

import dataclasses
from typing import List, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P
from jax.experimental.shard_map import shard_map


@dataclasses.dataclass(frozen=True)
class Transfer:
    src: int          # old-configuration slice id
    dst: int          # new-configuration slice id
    nbytes: int
    local: bool       # True when src slice maps onto the same devices


def _check_factor(p: int, q: int) -> int:
    big, small = max(p, q), min(p, q)
    if small <= 0 or big % small:
        raise ValueError(f"sizes {p}->{q} are not multiple/divisor related")
    return big // small


def expand_plan(p: int, q: int, nbytes: int) -> List[Transfer]:
    """P -> Q = P*f slices. Old rank r keeps chunk 0 locally (original nodes
    are reused, §5.2.1) and sends chunks 1..f-1 out."""
    f = _check_factor(p, q)
    if q < p:
        raise ValueError("expand requires q > p")
    chunk = nbytes // q  # bytes per new slice (global nbytes)
    plan = []
    for r in range(p):
        for i in range(f):
            dst = r * f + i
            plan.append(Transfer(src=r, dst=dst, nbytes=chunk,
                                 local=(i == 0)))
    return plan


def shrink_plan(p: int, q: int, nbytes: int) -> List[Transfer]:
    """P -> Q = P/f slices. Receivers are ranks with r % f == f-1
    (Listing 3: ``sender = (rank % f) < f-1``); receiver r continues as new
    rank r // f."""
    f = _check_factor(p, q)
    if q > p:
        raise ValueError("shrink requires q < p")
    chunk = nbytes // p  # bytes per old slice
    plan = []
    for r in range(p):
        receiver = f * (r // f + 1) - 1           # Listing 3 line 19
        new_rank = r // f
        plan.append(Transfer(src=r, dst=new_rank, nbytes=chunk,
                             local=(r == receiver)))
    return plan


# -- Fig. 3 cost model -------------------------------------------------------

def plan_stats(plan: List[Transfer]) -> Tuple[int, int]:
    """``(participants, busiest_link_bytes)`` of a transfer plan.

    These are the two features the Fig.-3 cost model (and the calibration
    fitter in :mod:`repro.calib.fit`) is linear in: the busiest per-slice
    link bounds the transfer, the participant count drives the shrink
    synchronization barrier.
    """
    send = {}
    recv = {}
    participants = set()
    for t in plan:
        participants.add(t.src)
        participants.add(t.dst)
        if t.local:
            continue
        send[t.src] = send.get(t.src, 0) + t.nbytes
        recv[t.dst] = recv.get(t.dst, 0) + t.nbytes
    busiest = max([*send.values(), *recv.values(), 0])
    return len(participants), busiest


def transfer_time_s(plan: List[Transfer], *, link_bw: float,
                    latency_s: float = 0.0,
                    sync_s_per_participant: float = 0.0) -> float:
    """Completion time of a redistribution plan.

    Each slice sends/receives over its own link at ``link_bw`` B/s; the plan
    completes when the busiest link drains.  ``sync_s_per_participant``
    models the shrink barrier (ACK collection at the management node,
    §5.2.2) — the paper observes shrinks cost more synchronization the
    larger the participant-count gap.
    """
    participants, busiest = plan_stats(plan)
    return latency_s + busiest / link_bw + \
        sync_s_per_participant * participants


# -- In-mesh slice migration (straggler path) -------------------------------

def migrate_slice(x: jax.Array, mesh: Mesh, src: int, dst: int,
                  axis: str = "data") -> jax.Array:
    """Swap the shards held by slices ``src`` and ``dst`` along ``axis``.

    Used when the RMS reshapes a job away from a straggling slice: data
    moves, the logical layout (sharding) is unchanged.  Implemented as a
    ``ppermute`` inside ``shard_map`` so the collective schedule is explicit
    (one bidirectional ICI exchange).
    """
    n = mesh.shape[axis]
    perm = []
    for i in range(n):
        j = dst if i == src else (src if i == dst else i)
        perm.append((i, j))

    spec = P(axis)
    other_axes = tuple(a for a in mesh.axis_names if a != axis)

    def body(blk):
        return jax.lax.ppermute(blk, axis, perm)

    fn = shard_map(body, mesh=mesh, in_specs=spec, out_specs=spec,
                   check_rep=False)
    # Collapse other mesh axes by treating them as replicated for this op.
    del other_axes
    return fn(x)
