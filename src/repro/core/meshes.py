"""Mesh construction & elastic resizing helpers.

A *job allocation* in this framework is a set of data-parallel slices: the
mesh is ``(data, model)`` (optionally ``(pod, data, model)``) and malleability
resizes the ``data`` (and ``pod``) extent while ``model`` — tensor
parallelism inside a slice — stays fixed, mirroring the paper's model of a
fixed number of cores per node and a variable number of nodes per job.
"""
from __future__ import annotations

import numpy as np

import jax
from jax.sharding import Mesh


def make_mesh(data: int, model: int, pod: int = 1,
              devices=None) -> Mesh:
    """Build a mesh of ``pod*data*model`` devices.

    Uses the first ``pod*data*model`` entries of ``devices`` (defaults to
    ``jax.devices()``), so that meshes of different ``data`` extents share a
    device prefix — the elastic resize path relies on this nesting to reuse
    the original devices (the paper reuses the original nodes on expansion,
    §5.2.1).
    """
    if devices is None:
        devices = jax.devices()
    n = pod * data * model
    if len(devices) < n:
        raise ValueError(f"need {n} devices, have {len(devices)}")
    arr = np.asarray(devices[:n], dtype=object)
    if pod > 1:
        return Mesh(arr.reshape(pod, data, model), ("pod", "data", "model"))
    return Mesh(arr.reshape(data, model), ("data", "model"))


def mesh_num_slices(mesh: Mesh) -> int:
    """Number of data-parallel slices (the malleable resource count)."""
    n = 1
    for ax in ("pod", "data"):
        if ax in mesh.shape:
            n *= mesh.shape[ax]
    return n


def mesh_model_ways(mesh: Mesh) -> int:
    return mesh.shape.get("model", 1)


def resized_mesh(mesh: Mesh, new_slices: int, devices=None) -> Mesh:
    """Return a mesh with ``new_slices`` data-parallel slices.

    Expansion appends fresh devices after the current ones (original devices
    are reused, as in the paper's resizer-job protocol); shrinking keeps the
    leading prefix (the surviving slices of the sender/receiver fold).
    Multi-pod meshes keep the pod axis as long as ``new_slices`` divides by
    the pod count; otherwise they collapse to a single-pod mesh.
    """
    model = mesh_model_ways(mesh)
    pods = mesh.shape.get("pod", 1)
    if devices is None:
        devices = jax.devices()
    if pods > 1 and new_slices % pods == 0:
        return make_mesh(new_slices // pods, model, pod=pods, devices=devices)
    return make_mesh(new_slices, model, devices=devices)


def slice_of_rank(mesh: Mesh, device) -> int:
    """Index of the data-parallel slice a device belongs to."""
    ids = list(mesh.devices.flat)
    idx = ids.index(device)
    return idx // mesh_model_ways(mesh)
