"""The DMR (Dynamic Management of Resources) API — paper §5.1.

Two entry points, mirroring the paper exactly:

- :meth:`DMR.check_status` (``dmr_check_status``): synchronously contact the
  RMS, which inspects cluster + queue state and returns an action —
  ``EXPAND``, ``SHRINK`` or ``NO_ACTION`` — plus the new number of slices and
  an opaque :class:`~repro.core.actions.ResizeHandler`.
- :meth:`DMR.icheck_status` (``dmr_icheck_status``): the asynchronous
  variant — schedules the decision for the *next* reconfiguration point
  while the current step executes.  The decision is taken against a queue
  snapshot that may go stale; stale expand grants can time out while waiting
  for the resizer job (the pathology of Table 2 that leads the paper to
  dismiss async scheduling).

Arguments (paper §5.1): minimum and maximum number of processes, resizing
factor (resize only to multiples/divisors of ``factor``), preferred number of
processes.  A *checking inhibitor* ignores DMR calls for a configurable
period after the last RMS contact (env var ``DMR_INHIBITOR_SECONDS``),
intended for iterative applications with short iterations.
"""
from __future__ import annotations

import os
import time
from concurrent.futures import Future, ThreadPoolExecutor
from typing import Callable, Optional, Protocol, Tuple

from repro.core.actions import Action, Decision, ResizeHandler

INHIBITOR_ENV = "DMR_INHIBITOR_SECONDS"


class RMSProtocol(Protocol):
    """What the DMR runtime layer needs from a resource manager."""

    def request_reconfig(self, job_id: int, *, current: int, minimum: int,
                         maximum: int, factor: int,
                         preferred: Optional[int]) -> Decision:
        """Run the reconfiguration policy; may create a resizer job."""

    def confirm_resize(self, job_id: int, decision: Decision,
                       timeout_s: float) -> Tuple[bool, float]:
        """Expand path: wait for the resizer job to run (§5.2.1).

        Returns ``(granted, wait_time_s)``; ``granted=False`` means the
        wait hit the timeout and the action is aborted (the RJ is
        cancelled).
        """


class DMR:
    """Per-job DMR endpoint exposed by the runtime."""

    def __init__(self, rms: RMSProtocol, job_id: int, *,
                 current_slices: int,
                 inhibitor_s: Optional[float] = None,
                 expand_timeout_s: float = 30.0,
                 clock: Callable[[], float] = time.monotonic):
        self.rms = rms
        self.job_id = job_id
        self.current_slices = current_slices
        if inhibitor_s is None:
            inhibitor_s = float(os.environ.get(INHIBITOR_ENV, "0"))
        self.inhibitor_s = inhibitor_s
        self.expand_timeout_s = expand_timeout_s
        self.clock = clock
        self._last_contact = -float("inf")
        self._pending: Optional[Future] = None
        self._pending_args = None
        self._pool: Optional[ThreadPoolExecutor] = None
        # Telemetry for the overhead study (Table 2).
        self.history: list[ResizeHandler] = []

    # -- internals ----------------------------------------------------------

    def _inhibited(self) -> bool:
        return (self.clock() - self._last_contact) < self.inhibitor_s

    def _query(self, minimum: int, maximum: int, factor: int,
               preferred: Optional[int]) -> Decision:
        t0 = self.clock()
        decision = self.rms.request_reconfig(
            self.job_id, current=self.current_slices, minimum=minimum,
            maximum=maximum, factor=factor, preferred=preferred)
        elapsed = self.clock() - t0
        if decision.schedule_time_s == 0.0:
            import dataclasses as _dc
            decision = _dc.replace(decision, schedule_time_s=elapsed)
        return decision

    def _finalize(self, decision: Decision) -> Tuple[Action, int,
                                                     Optional[ResizeHandler]]:
        handler = ResizeHandler(
            job_id=self.job_id, action=decision.action,
            old_slices=self.current_slices, new_slices=decision.new_slices,
            resizer_job_id=decision.resizer_job_id,
            schedule_time_s=decision.schedule_time_s,
            granted_at=self.clock())
        if decision.action is Action.EXPAND:
            granted, waited = self.rms.confirm_resize(
                self.job_id, decision, timeout_s=self.expand_timeout_s)
            handler.wait_time_s = waited
            if not granted:
                # §5.2.1: RJ cancelled, action aborted — resources were
                # assigned to a different job while we waited.
                handler.timed_out = True
                handler.action = Action.NO_ACTION
                handler.new_slices = self.current_slices
                self.history.append(handler)
                return Action.NO_ACTION, self.current_slices, None
        if decision.action is not Action.NO_ACTION:
            self.current_slices = decision.new_slices
        self.history.append(handler)
        if decision.action is Action.NO_ACTION:
            return Action.NO_ACTION, self.current_slices, None
        return handler.action, handler.new_slices, handler

    # -- public API (paper §5.1) -------------------------------------------

    def check_status(self, *, minimum: int, maximum: int, factor: int = 1,
                     preferred: Optional[int] = None
                     ) -> Tuple[Action, int, Optional[ResizeHandler]]:
        """``dmr_check_status`` — synchronous reconfiguration check."""
        if self._inhibited():
            return Action.NO_ACTION, self.current_slices, None
        self._last_contact = self.clock()
        decision = self._query(minimum, maximum, factor, preferred)
        return self._finalize(decision)

    def icheck_status(self, *, minimum: int, maximum: int, factor: int = 1,
                      preferred: Optional[int] = None
                      ) -> Tuple[Action, int, Optional[ResizeHandler]]:
        """``dmr_icheck_status`` — asynchronous reconfiguration check.

        Returns the decision scheduled at the *previous* reconfiguration
        point (or ``NO_ACTION`` on the first call / while none is ready) and
        schedules a fresh decision to be computed concurrently with the next
        execution step.
        """
        if self._inhibited():
            return Action.NO_ACTION, self.current_slices, None
        result: Tuple[Action, int, Optional[ResizeHandler]]
        if self._pending is not None and self._pending.done():
            decision: Decision = self._pending.result()
            self._pending = None
            result = self._finalize(decision)
        else:
            result = (Action.NO_ACTION, self.current_slices, None)
        if self._pending is None:
            if self._pool is None:
                self._pool = ThreadPoolExecutor(max_workers=1,
                                                thread_name_prefix="dmr")
            self._last_contact = self.clock()
            self._pending = self._pool.submit(
                self._query, minimum, maximum, factor, preferred)
        return result

    def close(self) -> None:
        if self._pool is not None:
            self._pool.shutdown(wait=False, cancel_futures=True)
            self._pool = None
