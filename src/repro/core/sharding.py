"""Logical-axis sharding rules (divisibility-aware).

Every tensor in the framework is annotated with a *logical spec*: a tuple of
logical axis names (or ``None``) per dimension, e.g. an attention projection
``(embed, heads, head_dim)``.  A :class:`ShardingRules` table maps logical
axes to mesh axes.  ``spec_for`` resolves a logical spec against a concrete
shape and mesh, dropping mesh axes that do not divide the dimension — this is
what lets a single rule table serve e.g. smollm's 9 attention heads (not
divisible by ``model=16`` → replicated) and granite's 32 heads (sharded).
"""
from __future__ import annotations

import contextlib
import contextvars
import dataclasses
from typing import Mapping, Sequence

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

LogicalSpec = tuple  # tuple[str | None, ...]


def _as_tuple(x) -> tuple:
    if x is None:
        return ()
    if isinstance(x, str):
        return (x,)
    return tuple(x)


@dataclasses.dataclass(frozen=True)
class ShardingRules:
    """Maps logical axis names -> mesh axis name(s)."""

    rules: Mapping[str, tuple]

    def replace(self, **updates) -> "ShardingRules":
        new = dict(self.rules)
        for k, v in updates.items():
            new[k] = _as_tuple(v)
        return ShardingRules(new)

    def mesh_axes_for(self, logical_axis: str | None) -> tuple:
        if logical_axis is None:
            return ()
        return _as_tuple(self.rules.get(logical_axis))

    def spec_for(self, logical: Sequence, shape: Sequence[int], mesh: Mesh) -> P:
        """Resolve a logical spec to a PartitionSpec for ``shape`` on ``mesh``.

        Mesh axes that are missing from the mesh, already used by another
        dimension, or that do not evenly divide the dimension size are
        dropped (replication fallback).
        """
        if len(logical) != len(shape):
            raise ValueError(
                f"logical spec {logical} does not match shape {shape}")
        used: set = set()
        out = []
        for name, dim in zip(logical, shape):
            axes = []
            remaining = dim
            for ax in self.mesh_axes_for(name):
                if ax in used or ax not in mesh.shape:
                    continue
                size = mesh.shape[ax]
                if remaining % size != 0:
                    continue
                axes.append(ax)
                used.add(ax)
                remaining //= size
            if not axes:
                out.append(None)
            elif len(axes) == 1:
                out.append(axes[0])
            else:
                out.append(tuple(axes))
        # PartitionSpec trailing Nones are harmless; keep full rank for clarity.
        return P(*out)

    def sharding_for(self, logical: Sequence, shape: Sequence[int],
                     mesh: Mesh) -> NamedSharding:
        return NamedSharding(mesh, self.spec_for(logical, shape, mesh))


# ---------------------------------------------------------------------------
# Rule tables.
#
# "tp_dp" is the paper-faithful baseline: a job owns a set of data-parallel
# slices (the malleable resource) and each slice does tensor parallelism over
# the fixed "model" axis — mirroring the paper's fixed cores-per-node,
# variable node-count resource model.
# ---------------------------------------------------------------------------

TP_DP_RULES = ShardingRules({
    # activations
    "batch": ("pod", "data"),
    "seq": (),
    "kv_seq": (),            # decode-time KV cache sequence axis
    "embed": (),
    "heads": ("model",),
    "kv_heads": ("model",),
    "head_dim": (),
    "mlp": ("model",),
    "experts": ("model",),
    "expert_mlp": (),
    "vocab": ("model",),
    "state": (),             # SSM / RG-LRU recurrent state width
    "layers": (),            # stacked scan dimension — never sharded
    "frontend": (),
    "table_embed": (),       # embedding-table model dim (never FSDP)
    "zero1": ("pod", "data"),   # ZeRO-1 optimizer-moment sharding
})

# FSDP-style variant: weights additionally sharded over the data axis
# (all-gathered at use).  Candidate for the perf hillclimb.
FSDP_RULES = TP_DP_RULES.replace(embed=("data",))

# Long-context decode (batch too small to shard): shard the KV cache /
# sequence dimension over the data axis; distributed softmax via GSPMD.
LONG_CONTEXT_RULES = TP_DP_RULES.replace(
    batch=(), kv_seq=("pod", "data"), seq=("pod", "data"))


def rules_for_shape(shape_name: str, global_batch: int, mesh: Mesh,
                    base: ShardingRules = TP_DP_RULES) -> ShardingRules:
    """Pick a rule table appropriate for an input-shape family."""
    data_ways = 1
    for ax in ("pod", "data"):
        if ax in mesh.shape:
            data_ways *= mesh.shape[ax]
    if global_batch < data_ways:
        return LONG_CONTEXT_RULES
    return base


# -- activation sharding constraints -----------------------------------------
#
# GSPMD propagation alone mis-shards activations when weights carry exotic
# shardings (e.g. FSDP embed-dim sharding leaking through the embedding
# gather).  Model code calls ``constrain(x, logical)`` at block boundaries;
# it is a no-op unless a (mesh, rules) context is active — set by the cell
# builder / trainer around tracing.

_ACT_CTX: contextvars.ContextVar = contextvars.ContextVar(
    "activation_rules", default=None)


@contextlib.contextmanager
def activation_rules(mesh: Mesh, rules: "ShardingRules"):
    tok = _ACT_CTX.set((mesh, rules))
    try:
        yield
    finally:
        _ACT_CTX.reset(tok)


def constrain(x, logical):
    """Pin an activation to its logical sharding (no-op without context)."""
    ctx = _ACT_CTX.get()
    if ctx is None:
        return x
    mesh, rules = ctx
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(mesh, rules.spec_for(logical, x.shape, mesh)))


def logical_to_sharding(tree_logical, tree_shapes, mesh: Mesh,
                        rules: ShardingRules):
    """Map a pytree of logical specs + matching shapes -> NamedShardings."""
    return jax.tree.map(
        lambda logical, shape: rules.sharding_for(logical, shape, mesh),
        tree_logical, tree_shapes,
        is_leaf=lambda x: isinstance(x, tuple),
    )
