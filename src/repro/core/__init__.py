"""Core: the paper's contribution — DMR API, elastic resharding, policies."""
from repro.core.actions import Action, Decision, ResizeHandler
from repro.core.dmr import DMR, RMSProtocol
from repro.core.meshes import (make_mesh, mesh_model_ways, mesh_num_slices,
                               resized_mesh)
from repro.core.redistribute import (Transfer, expand_plan, migrate_slice,
                                     plan_stats, shrink_plan,
                                     transfer_time_s)
from repro.core.reshard import (checkpoint_reshard, ownership_map, reshard,
                                state_shardings, timed_reshard)
from repro.core.sharding import (FSDP_RULES, LONG_CONTEXT_RULES, TP_DP_RULES,
                                 ShardingRules, rules_for_shape)

__all__ = [
    "Action", "Decision", "ResizeHandler", "DMR", "RMSProtocol",
    "make_mesh", "mesh_num_slices", "mesh_model_ways", "resized_mesh",
    "Transfer", "expand_plan", "shrink_plan", "transfer_time_s",
    "plan_stats",
    "migrate_slice", "reshard", "checkpoint_reshard", "timed_reshard",
    "state_shardings", "ownership_map",
    "ShardingRules", "TP_DP_RULES", "FSDP_RULES", "LONG_CONTEXT_RULES",
    "rules_for_shape",
]
