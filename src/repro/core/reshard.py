"""Elastic resharding of program state across meshes.

This is the JAX analogue of the paper's §5.2 reconfiguration mechanics: after
the RMS grants an expand/shrink, the job's *entire state* (parameters,
optimizer moments, recurrent/KV state, RNG, step counter) must continue on a
mesh with a different number of data-parallel slices.

Two paths are provided, mirroring the paper's discussion:

- :func:`reshard` — *runtime data redistribution* (the paper's contribution):
  a single ``jax.device_put`` of the state pytree onto the new shardings.
  The XLA/IFRT transfer engine materializes exactly the factor-based
  sender/receiver exchange of Listing 3 / Fig. 2 (verified in tests against
  :mod:`repro.core.redistribute` plans).
- :func:`checkpoint_reshard` — the *checkpoint-and-reconfigure* baseline the
  paper improves on ([6] in the paper): state is pulled to host memory and
  re-placed onto the new mesh.  Slower (host round-trip) but survives device
  loss — this is also the node-failure recovery path.
"""
from __future__ import annotations

import time
from typing import Any, Callable

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding

from repro.core.sharding import ShardingRules


def state_shardings(state: Any, logical_specs: Any, mesh: Mesh,
                    rules: ShardingRules):
    """Build NamedShardings for a state pytree from its logical specs."""
    def one(leaf, logical):
        return rules.sharding_for(logical, np.shape(leaf), mesh)
    return jax.tree.map(
        lambda logical, leaf: one(leaf, logical), logical_specs, state,
        is_leaf=lambda x: isinstance(x, tuple))


def reshard(state: Any, shardings: Any, *, donate: bool = True) -> Any:
    """Runtime redistribution: move ``state`` onto ``shardings``.

    ``shardings`` is a pytree of NamedSharding matching ``state``.  The old
    buffers are donated (freed as soon as the transfer retires) so peak
    memory is ~1x state + in-flight chunks, matching the paper's
    redistribution (no full second copy, unlike checkpointing).
    """
    del donate  # device_put always copies; donation is a planned optimization
    return jax.device_put(state, shardings)


def checkpoint_reshard(state: Any, shardings: Any) -> Any:
    """Checkpoint-based baseline: host round-trip then re-place."""
    host = jax.tree.map(np.asarray, state)
    return jax.device_put(host, shardings)


def timed_reshard(state: Any, shardings: Any,
                  impl: Callable[[Any, Any], Any] = reshard):
    """Reshard and return ``(new_state, seconds)`` — the paper's resize time
    (Fig. 3 right)."""
    t0 = time.perf_counter()
    out = impl(state, shardings)
    jax.block_until_ready(out)
    return out, time.perf_counter() - t0


def ownership_map(arr: jax.Array) -> dict:
    """Which device owns which index-range — used to validate that
    :func:`reshard` realizes exactly the Listing-3 mapping."""
    out = {}
    for shard in arr.addressable_shards:
        out[shard.device.id] = shard.index
    return out
