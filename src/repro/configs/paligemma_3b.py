"""PaliGemma-3B [arXiv:2407.07726] — SigLIP patch stub + gemma backbone."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="paligemma-3b", family="vlm", num_layers=18, d_model=2048,
    num_heads=8, num_kv_heads=1, head_dim=256, d_ff=16384,
    vocab_size=257216, pattern=("global",), frontend="patches",
    frontend_tokens=256, act="gelu", embed_scale=True,
)
