"""Qwen3-4B [hf:Qwen/Qwen3-8B family] — dense GQA with qk_norm."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="qwen3-4b", family="dense", num_layers=36, d_model=2560,
    num_heads=32, num_kv_heads=8, head_dim=128, d_ff=9728,
    vocab_size=151936, qk_norm=True, pattern=("global",), act="silu",
    rope_theta=1000000.0,
)
