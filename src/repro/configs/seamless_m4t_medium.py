"""SeamlessM4T-medium [arXiv:2308.11596] — enc-dec backbone, frame stub."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="seamless-m4t-medium", family="encdec", num_layers=12,
    enc_layers=12, d_model=1024, num_heads=16, num_kv_heads=16,
    d_ff=4096, vocab_size=256206, pattern=("global",),
    cross_attention=True, frontend="frames", act="gelu",
)
