"""DeepSeekMoE-16B [arXiv:2401.06066] — 2 shared + 64 routed top-6."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="deepseek-moe-16b", family="moe", num_layers=28, d_model=2048,
    num_heads=16, num_kv_heads=16, head_dim=128, d_ff=1408,
    vocab_size=102400, pattern=("moe",), num_experts=64, top_k=6,
    num_shared_experts=2, expert_d_ff=1408, first_dense_layers=1,
    first_dense_ff=10944, act="silu", rope_theta=10000.0,
)
