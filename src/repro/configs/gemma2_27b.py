"""Gemma2-27B [arXiv:2408.00118] — local+global alternating, softcaps."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="gemma2-27b", family="dense", num_layers=46, d_model=4608,
    num_heads=32, num_kv_heads=16, head_dim=128, d_ff=36864,
    vocab_size=256000, pattern=("local", "global"), sliding_window=4096,
    attn_logit_softcap=50.0, final_logit_softcap=30.0, act="gelu",
    embed_scale=True, rope_theta=10000.0,
)
