"""RecurrentGemma-9B [arXiv:2402.19427] — RG-LRU + local attention, 2:1."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="recurrentgemma-9b", family="hybrid", num_layers=38, d_model=4096,
    num_heads=16, num_kv_heads=1, head_dim=256, d_ff=12288,
    vocab_size=256000, pattern=("rglru", "rglru", "local"),
    sliding_window=2048, lru_width=4096, conv_width=4, act="gelu",
    embed_scale=True, rope_theta=10000.0,
)
