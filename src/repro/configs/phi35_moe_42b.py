"""Phi-3.5-MoE (42B/6.6B active) [hf:microsoft/Phi-3.5-MoE-instruct]."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="phi3.5-moe-42b-a6.6b", family="moe", num_layers=32, d_model=4096,
    num_heads=32, num_kv_heads=8, head_dim=128, d_ff=6400,
    vocab_size=32064, pattern=("moe",), num_experts=16, top_k=2,
    expert_d_ff=6400, act="silu", rope_theta=10000.0,
    tie_embeddings=False,
)
