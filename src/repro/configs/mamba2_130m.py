"""Mamba2-130M [arXiv:2405.21060] — SSD (state-space duality), attn-free."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="mamba2-130m", family="ssm", num_layers=24, d_model=768,
    num_heads=0, num_kv_heads=0, head_dim=0, d_ff=0, vocab_size=50280,
    pattern=("ssd",), ssm_state=128, ssm_expand=2, ssm_head_dim=64,
    conv_width=4, ssd_chunk=128, act="silu",
)
