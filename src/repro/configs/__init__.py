"""Architecture configs (assigned pool) + lookup by --arch id."""
from repro.configs import (deepseek_moe_16b, gemma2_27b, granite_3_2b,
                           mamba2_130m, paligemma_3b, phi35_moe_42b,
                           qwen3_4b, recurrentgemma_9b, seamless_m4t_medium,
                           smollm_135m)

ARCHS = {
    m.CONFIG.name: m.CONFIG
    for m in (smollm_135m, granite_3_2b, qwen3_4b, gemma2_27b,
              recurrentgemma_9b, deepseek_moe_16b, phi35_moe_42b,
              seamless_m4t_medium, mamba2_130m, paligemma_3b)
}

# short aliases for --arch
ALIASES = {
    "smollm": "smollm-135m", "granite": "granite-3-2b", "qwen3": "qwen3-4b",
    "gemma2": "gemma2-27b", "recurrentgemma": "recurrentgemma-9b",
    "deepseek-moe": "deepseek-moe-16b", "phi35-moe": "phi3.5-moe-42b-a6.6b",
    "seamless": "seamless-m4t-medium", "mamba2": "mamba2-130m",
    "paligemma": "paligemma-3b",
}


def get_config(name: str):
    name = ALIASES.get(name, name)
    return ARCHS[name]


def list_archs():
    return sorted(ARCHS)
