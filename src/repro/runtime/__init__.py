"""Runtime: elastic trainer, local RMS endpoint, serving loop."""
from repro.runtime.local_rms import LocalRMS
from repro.runtime.serving import Request, Server
from repro.runtime.trainer import ElasticTrainer, TrainerConfig

__all__ = ["LocalRMS", "Request", "Server", "ElasticTrainer",
           "TrainerConfig"]
