"""In-process RMS endpoint for live (non-simulated) elastic jobs.

Wraps the same :class:`~repro.rms.policy.ReconfigPolicy` the simulator uses,
over a real :class:`~repro.rms.cluster.Cluster`, with wall-clock timing —
this is what a single-controller deployment talks to (in a multi-controller
deployment the same protocol rides a gRPC/socket transport to the real
scheduler; the policy code is identical).
"""
from __future__ import annotations

import threading
import time
from typing import List, Optional, Tuple

from repro.core.actions import Action, Decision
from repro.rms.cluster import Cluster
from repro.rms.job import Job, JobState
from repro.rms.policy import PolicyConfig, ReconfigPolicy
from repro.rms.scheduler import MAX_PRIORITY


class LocalRMS:
    """RMSProtocol implementation over an in-process cluster."""

    def __init__(self, num_nodes: int,
                 policy: PolicyConfig = PolicyConfig()):
        self.cluster = Cluster(num_nodes)
        self.policy = ReconfigPolicy(policy)
        self.jobs: List[Job] = []
        self._lock = threading.Lock()

    def submit(self, job: Job, start: bool = False) -> Job:
        with self._lock:
            self.jobs.append(job)
            if start:
                self.cluster.allocate(job.job_id, job.requested_nodes)
                job.nodes = job.requested_nodes
                job.state = JobState.RUNNING
                job.start_time = time.monotonic()
        return job

    def finish(self, job_id: int) -> None:
        with self._lock:
            self.cluster.release(job_id)
            for j in self.jobs:
                if j.job_id == job_id:
                    j.state = JobState.COMPLETED

    def pending(self) -> List[Job]:
        return [j for j in self.jobs if j.state is JobState.PENDING]

    # -- RMSProtocol -------------------------------------------------------

    def request_reconfig(self, job_id: int, *, current: int, minimum: int,
                         maximum: int, factor: int,
                         preferred: Optional[int]) -> Decision:
        with self._lock:
            job = next(j for j in self.jobs if j.job_id == job_id)
            t0 = time.perf_counter()
            decision = self.policy.decide(
                self.cluster, self.pending(), job, minimum=minimum,
                maximum=maximum, factor=factor, preferred=preferred)
            elapsed = time.perf_counter() - t0
            if decision.action is not Action.NO_ACTION:
                self.cluster.resize(job_id, decision.new_slices)
                job.nodes = decision.new_slices
            if decision.boost_job_id is not None:
                for q in self.jobs:
                    if q.job_id == decision.boost_job_id:
                        q.priority_boost = MAX_PRIORITY
            import dataclasses
            return dataclasses.replace(decision, schedule_time_s=elapsed)

    def confirm_resize(self, job_id: int, decision: Decision,
                       timeout_s: float) -> Tuple[bool, float]:
        # Single-controller: the resize transaction in request_reconfig is
        # atomic, so the RJ is already running by construction.
        return True, 0.0
