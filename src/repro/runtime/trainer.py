"""Elastic trainer — the Nanos++-analogue runtime driving malleable jobs.

The training loop exposes *reconfiguration points* at step boundaries: every
``check_period`` steps it calls the DMR API; on EXPAND/SHRINK it rebuilds
the mesh to the granted slice count and reshards the entire TrainState
(params + AdamW moments + RNG + step) via ``repro.core.reshard`` —
runtime data redistribution, not checkpoint restart.  Checkpoint/restart
is the *fault* path: any step failure restores the last checkpoint, onto a
smaller mesh if devices were lost (shrink-to-survivors).
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable, Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.core import (DMR, TP_DP_RULES, Action, ShardingRules, make_mesh,
                        mesh_num_slices, reshard, state_shardings)
from repro.core.sharding import logical_to_sharding
from repro.data import DataConfig, SyntheticLMData
from repro.checkpoint.store import CheckpointStore
from repro.optim import AdamWConfig, apply_updates, init_state, state_logical


@dataclasses.dataclass
class TrainerConfig:
    steps: int = 100
    grad_accum: int = 1
    check_period: int = 10            # steps between reconfiguration points
    min_slices: int = 1
    max_slices: int = 8
    factor: int = 2
    preferred: Optional[int] = None
    model_ways: int = 1               # TP width inside a slice
    ckpt_dir: Optional[str] = None
    ckpt_period: int = 50
    log_period: int = 10
    rules: ShardingRules = TP_DP_RULES
    donate: bool = True


class ElasticTrainer:
    def __init__(self, model, opt_cfg: AdamWConfig, data_cfg: DataConfig,
                 cfg: TrainerConfig, rms=None, job_id: int = 0,
                 devices=None):
        self.model = model
        self.opt_cfg = opt_cfg
        self.data = SyntheticLMData(data_cfg)
        self.data_cfg = data_cfg
        self.cfg = cfg
        self.devices = devices if devices is not None else jax.devices()
        self.slices = min(cfg.max_slices,
                          len(self.devices) // cfg.model_ways)
        self.mesh = make_mesh(self.slices, cfg.model_ways,
                              devices=self.devices)
        self.dmr = DMR(rms, job_id, current_slices=self.slices) \
            if rms is not None else None
        self.store = CheckpointStore(cfg.ckpt_dir) if cfg.ckpt_dir else None
        self._step_cache: Dict[int, Callable] = {}
        self.metrics: list = []
        self.resize_log: list = []

    # -- sharding ------------------------------------------------------------

    def _state_shardings(self, mesh):
        logical = {
            "params": self.model.logical(),
            "opt": state_logical(
                self.model.logical(),
                jax.tree.map(lambda s: s.shape, self.model.specs(),
                             is_leaf=lambda x: hasattr(x, "shape")
                             and hasattr(x, "logical")),
                mesh, self.cfg.rules, zero1=self.opt_cfg.zero1),
            "rng": (None,),
            "step": (),
        }
        shapes = {
            "params": jax.tree.map(lambda s: s.shape, self.model.specs(),
                                   is_leaf=lambda x: hasattr(x, "logical")),
            "opt": {"mu": jax.tree.map(
                        lambda s: s.shape, self.model.specs(),
                        is_leaf=lambda x: hasattr(x, "logical")),
                    "nu": jax.tree.map(
                        lambda s: s.shape, self.model.specs(),
                        is_leaf=lambda x: hasattr(x, "logical")),
                    "step": ()},
            "rng": (2,),
            "step": (),
        }
        return logical_to_sharding(logical, shapes, mesh, self.cfg.rules)

    def _batch_shardings(self, mesh):
        spec = {"tokens": P(("pod", "data")), "labels": P(("pod", "data"))}
        if self.data_cfg.frontend:
            spec["frontend"] = P(("pod", "data"))
        return jax.tree.map(
            lambda s: NamedSharding(
                mesh, P(*[ax if isinstance(ax, str) else tuple(
                    a for a in ax if a in mesh.shape) or None
                    for ax in s])), spec)

    # -- state ---------------------------------------------------------------

    def init_state(self, seed: int = 0):
        shardings = self._state_shardings(self.mesh)

        def make():
            params = self.model.init(jax.random.PRNGKey(seed))
            return {"params": params, "opt": init_state(params),
                    "rng": jax.random.PRNGKey(seed + 1),
                    "step": jnp.zeros((), jnp.int32)}
        with self.mesh:
            state = jax.jit(make, out_shardings=shardings)()
        return state

    # -- the jitted step -------------------------------------------------------

    def _build_step(self, mesh):
        model, opt_cfg, accum = self.model, self.opt_cfg, self.cfg.grad_accum
        shardings = self._state_shardings(mesh)
        batch_sh = self._batch_shardings(mesh)

        def loss_fn(params, batch):
            loss, parts = model.loss(params, batch)
            return loss, parts

        def train_step(state, batch):
            if accum > 1:
                def micro(c, mb):
                    (loss, parts), grads = jax.value_and_grad(
                        loss_fn, has_aux=True)(state["params"], mb)
                    g_acc = jax.tree.map(jnp.add, c[0], grads)
                    return (g_acc, c[1] + loss), None
                mbs = jax.tree.map(
                    lambda x: x.reshape((accum, -1) + x.shape[1:]), batch)
                zeros = jax.tree.map(
                    lambda p: jnp.zeros(p.shape, jnp.float32),
                    state["params"])
                (grads, loss), _ = jax.lax.scan(
                    micro, (zeros, jnp.zeros((), jnp.float32)), mbs)
                grads = jax.tree.map(lambda g: g / accum, grads)
                loss = loss / accum
            else:
                (loss, _parts), grads = jax.value_and_grad(
                    loss_fn, has_aux=True)(state["params"], batch)
            params, opt, metrics = apply_updates(
                opt_cfg, state["params"], grads, state["opt"])
            new_state = {"params": params, "opt": opt,
                         "rng": jax.random.fold_in(state["rng"], 0),
                         "step": state["step"] + 1}
            metrics = dict(metrics, loss=loss)
            return new_state, metrics

        donate = (0,) if self.cfg.donate else ()
        return jax.jit(train_step, in_shardings=(shardings, batch_sh),
                       out_shardings=(shardings, None),
                       donate_argnums=donate)

    def step_fn(self, mesh):
        key = mesh_num_slices(mesh)
        if key not in self._step_cache:
            self._step_cache[key] = self._build_step(mesh)
        return self._step_cache[key]

    # -- reconfiguration (the paper's §5.2 protocol) -----------------------------

    def maybe_reconfigure(self, state):
        if self.dmr is None:
            return state
        action, new_slices, handler = self.dmr.check_status(
            minimum=self.cfg.min_slices, maximum=self.cfg.max_slices,
            factor=self.cfg.factor, preferred=self.cfg.preferred)
        if action is Action.NO_ACTION:
            return state
        t0 = time.perf_counter()
        new_mesh = make_mesh(new_slices, self.cfg.model_ways,
                             devices=self.devices)
        new_shardings = self._state_shardings(new_mesh)
        state = reshard(state, new_shardings)
        jax.block_until_ready(state)
        dt = time.perf_counter() - t0
        if handler is not None:
            handler.new_mesh = new_mesh
            handler.resize_time_s = dt
        self.resize_log.append(
            {"step": int(state["step"]), "action": action.name,
             "from": self.slices, "to": new_slices, "resize_s": dt})
        self.mesh = new_mesh
        self.slices = new_slices
        return state

    # -- loop -----------------------------------------------------------------

    def train(self, state=None, seed: int = 0, on_step=None):
        if state is None:
            state = self.init_state(seed)
        start = int(state["step"])
        step = start
        while step < self.cfg.steps:
            if self.dmr is not None and step > start and \
                    step % self.cfg.check_period == 0:
                state = self.maybe_reconfigure(state)
            batch = self.data.batch(step)
            fn = self.step_fn(self.mesh)
            try:
                with self.mesh:
                    state, metrics = fn(state, batch)
            except Exception:
                state = self._recover()
                step = int(state["step"])
                continue
            step += 1
            if step % self.cfg.log_period == 0 or step == self.cfg.steps:
                m = {k: float(v) for k, v in metrics.items()}
                m["step"] = step
                m["slices"] = self.slices
                self.metrics.append(m)
            if self.store is not None and step % self.cfg.ckpt_period == 0:
                self.store.save_async(step, state)
        if self.store is not None:
            self.store.wait()
        return state

    def _recover(self):
        """Fault path: restore the latest checkpoint onto the current
        (possibly shrunken) mesh."""
        if self.store is None:
            raise RuntimeError("step failed and no checkpoint store")
        step = self.store.latest_step()
        if step is None:
            raise RuntimeError("step failed before first checkpoint")
        template = self.init_state()
        shardings = self._state_shardings(self.mesh)
        return self.store.restore(step, template, shardings)
