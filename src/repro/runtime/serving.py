"""Batched serving loop: continuous batching over prefill + decode.

A :class:`Server` owns a params copy and a slot-based KV cache; requests
join free slots (prefill), decode steps advance all active slots together,
finished sequences free their slots.  ``serve_step`` — one fused decode for
the whole batch — is the unit the decode dry-run cells lower.  The server
is malleable the same way the trainer is: at reconfiguration points the
cache+params reshard onto the granted mesh (a serving job can donate chips
to the queue under the paper's policy).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass
class Request:
    rid: int
    prompt: np.ndarray
    max_new_tokens: int = 32
    out: Optional[List[int]] = None


class Server:
    def __init__(self, model, params, *, batch: int, max_len: int,
                 temperature: float = 0.0):
        self.model = model
        self.params = params
        self.batch = batch
        self.max_len = max_len
        self.temperature = temperature
        self.cache = model.init_cache(batch, max_len)
        self.pos = np.zeros(batch, np.int32)
        self.active: Dict[int, Request] = {}
        self.slot_of: Dict[int, int] = {}
        self._decode = jax.jit(model.decode_step)

    def free_slots(self) -> List[int]:
        used = set(self.slot_of.values())
        return [i for i in range(self.batch) if i not in used]

    def add(self, req: Request) -> bool:
        if len(req.prompt) > self.max_len:
            raise ValueError(
                f"request {req.rid}: prompt length {len(req.prompt)} "
                f"exceeds the KV cache (max_len={self.max_len})")
        slots = self.free_slots()
        if not slots:
            return False
        slot = slots[0]
        self.slot_of[req.rid] = slot
        self.active[req.rid] = req
        req.out = []
        # prefill this slot by stepping the prompt (slot-local decode);
        # production path would batch prefills — sequential keeps the demo
        # simple and exact.
        for t, tok in enumerate(req.prompt[:-1]):
            self._step_slot(slot, int(tok), t)
        self.pos[slot] = len(req.prompt) - 1
        return True

    def _step_slot(self, slot: int, token: int, pos: int):
        toks = jnp.zeros((self.batch, 1), jnp.int32).at[slot, 0].set(token)
        _, self.cache = self._decode(self.params, self.cache, toks,
                                     jnp.int32(pos))

    def serve_step(self) -> Dict[int, int]:
        """One batched decode step for all active requests."""
        if not self.active:
            return {}
        toks = np.zeros((self.batch, 1), np.int32)
        for rid, req in self.active.items():
            slot = self.slot_of[rid]
            last = req.out[-1] if req.out else int(req.prompt[-1])
            toks[slot, 0] = last
        pos = int(max(self.pos[self.slot_of[r]] for r in self.active))
        logits, self.cache = self._decode(self.params, self.cache,
                                          jnp.asarray(toks), jnp.int32(pos))
        emitted = {}
        logits = np.asarray(logits[:, -1])
        for rid, req in list(self.active.items()):
            slot = self.slot_of[rid]
            if self.temperature > 0:
                p = np.exp(logits[slot] / self.temperature)
                nxt = int(np.argmax(np.random.default_rng(rid).multinomial(
                    1, p / p.sum())))
            else:
                nxt = int(np.argmax(logits[slot]))
            req.out.append(nxt)
            self.pos[slot] += 1
            emitted[rid] = nxt
            # finish on budget, or evict when the next decode position
            # would fall outside the KV cache — the sequence ends early
            # rather than writing past max_len
            if len(req.out) >= req.max_new_tokens or \
                    self.pos[slot] >= self.max_len:
                del self.active[rid]
                del self.slot_of[rid]
        return emitted

    def run(self, requests: List[Request]) -> Dict[int, List[int]]:
        queue = list(requests)
        done: Dict[int, List[int]] = {}
        while queue or self.active:
            while queue and self.add(queue[0]):
                queue.pop(0)
            before = set(self.active)
            self.serve_step()
            for rid in before - set(self.active):
                req = next(r for r in requests if r.rid == rid)
                done[rid] = req.out
        return done
