"""The paper's evaluation applications, implemented in JAX (§7).

CG (conjugate gradient on a 2D Laplacian), Jacobi (5-point stencil), N-body
(all-pairs gravity) and Flexible Sleep (the synthetic overhead probe).  Each
is an iterative kernel whose state is a flat pytree shardable over the
``data`` axis — i.e. each is a *malleable job*: the DMR runtime can resize
it and reshard its state exactly like an LM TrainState.

``calibrate()`` measures per-iteration wall time; the DES cost models in
:mod:`repro.rms.costmodel` are anchored to these measurements (scaled by
problem size) rather than invented constants.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Tuple

import jax
import jax.numpy as jnp


# -- Conjugate Gradient (2D Laplacian, matrix-free) ---------------------------


def laplacian_matvec(x):
    """5-point stencil matvec on an (N, N) grid with zero boundaries."""
    up = jnp.pad(x[:-1, :], ((1, 0), (0, 0)))
    dn = jnp.pad(x[1:, :], ((0, 1), (0, 0)))
    lf = jnp.pad(x[:, :-1], ((0, 0), (1, 0)))
    rt = jnp.pad(x[:, 1:], ((0, 0), (0, 1)))
    return 4.0 * x - up - dn - lf - rt


@dataclasses.dataclass
class CGState:
    x: jax.Array
    r: jax.Array
    p: jax.Array
    rs: jax.Array


def cg_init(n: int, key=None) -> CGState:
    key = key if key is not None else jax.random.PRNGKey(0)
    b = jax.random.normal(key, (n, n), jnp.float32)
    x = jnp.zeros((n, n), jnp.float32)
    r = b - laplacian_matvec(x)
    return CGState(x=x, r=r, p=r, rs=jnp.vdot(r, r))


@jax.jit
def cg_step(s: CGState) -> CGState:
    ap = laplacian_matvec(s.p)
    alpha = s.rs / jnp.vdot(s.p, ap)
    x = s.x + alpha * s.p
    r = s.r - alpha * ap
    rs_new = jnp.vdot(r, r)
    p = r + (rs_new / s.rs) * s.p
    return CGState(x=x, r=r, p=p, rs=rs_new)


jax.tree_util.register_pytree_node(
    CGState, lambda s: ((s.x, s.r, s.p, s.rs), None),
    lambda _, c: CGState(*c))


# -- Jacobi (5-point stencil relaxation) ----------------------------------------


def jacobi_init(n: int, key=None):
    key = key if key is not None else jax.random.PRNGKey(1)
    grid = jax.random.normal(key, (n, n), jnp.float32)
    rhs = jax.random.normal(jax.random.fold_in(key, 1), (n, n), jnp.float32)
    return {"grid": grid, "rhs": rhs}


@jax.jit
def jacobi_step(s):
    g = s["grid"]
    up = jnp.pad(g[:-1, :], ((1, 0), (0, 0)))
    dn = jnp.pad(g[1:, :], ((0, 1), (0, 0)))
    lf = jnp.pad(g[:, :-1], ((0, 0), (1, 0)))
    rt = jnp.pad(g[:, 1:], ((0, 0), (0, 1)))
    return {"grid": 0.25 * (up + dn + lf + rt + s["rhs"]), "rhs": s["rhs"]}


# -- N-body (all-pairs gravity) ---------------------------------------------------


def nbody_init(n: int, key=None):
    key = key if key is not None else jax.random.PRNGKey(2)
    ks = jax.random.split(key, 3)
    return {"pos": jax.random.normal(ks[0], (n, 3), jnp.float32),
            "vel": jax.random.normal(ks[1], (n, 3), jnp.float32) * 0.01,
            "mass": jax.nn.softplus(jax.random.normal(ks[2], (n,
                                                              ))) + 0.1}


@jax.jit
def nbody_step(s, dt: float = 0.01, eps: float = 1e-2):
    d = s["pos"][None, :, :] - s["pos"][:, None, :]          # (N,N,3)
    r2 = jnp.sum(d * d, axis=-1) + eps
    inv_r3 = jnp.where(r2 > eps, r2 ** -1.5, 0.0)
    acc = jnp.einsum("ijk,ij,j->ik", d, inv_r3, s["mass"])
    vel = s["vel"] + dt * acc
    return {"pos": s["pos"] + dt * vel, "vel": vel, "mass": s["mass"]}


# -- Flexible Sleep (the synthetic overhead probe, §7.3) --------------------------


@dataclasses.dataclass
class FlexibleSleep:
    """Holds ``nbytes`` of state and 'computes' by sleeping — isolating the
    framework's reconfiguration cost from application compute (Fig. 3)."""

    nbytes: int = 1 << 30
    step_s: float = 1.0

    def init(self):
        n = self.nbytes // 4
        return {"data": jnp.zeros((n,), jnp.float32)}

    def step(self, state):
        time.sleep(self.step_s)
        return state


APPS = {
    "cg": (cg_init, cg_step),
    "jacobi": (jacobi_init, jacobi_step),
    "nbody": (nbody_init, nbody_step),
}


def calibrate(app: str, n: int, iters: int = 10) -> Tuple[float, float]:
    """Measured per-iteration seconds (mean, std) on this host."""
    init, step = APPS[app]
    s = init(n)
    s = step(s)
    jax.block_until_ready(jax.tree.leaves(s)[0])
    times = []
    for _ in range(iters):
        t0 = time.perf_counter()
        s = step(s)
        jax.block_until_ready(jax.tree.leaves(s)[0])
        times.append(time.perf_counter() - t0)
    import numpy as np
    return float(np.mean(times)), float(np.std(times))
