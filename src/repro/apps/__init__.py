"""The paper's evaluation applications (CG, Jacobi, N-body, FlexibleSleep)."""
from repro.apps.paper_apps import (APPS, CGState, FlexibleSleep, calibrate,
                                   cg_init, cg_step, jacobi_init, jacobi_step,
                                   laplacian_matvec, nbody_init, nbody_step)

__all__ = ["APPS", "CGState", "FlexibleSleep", "calibrate", "cg_init",
           "cg_step", "jacobi_init", "jacobi_step", "laplacian_matvec",
           "nbody_init", "nbody_step"]
