"""Workload generation (Feitelson model, Poisson arrivals)."""
from repro.workload.feitelson import (feitelson_sizes, make_workload,
                                      poisson_arrivals)

__all__ = ["feitelson_sizes", "make_workload", "poisson_arrivals"]
