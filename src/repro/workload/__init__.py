"""Workload generation (Feitelson model, Poisson arrivals, SWF replay)."""
from repro.workload.feitelson import (feitelson_sizes, make_workload,
                                      poisson_arrivals)
from repro.workload.swf import (MALLEABLE, MOLDABLE, RIGID, MalleabilityMix,
                                SWFJob, SWFTrace, annotate_malleability,
                                jobs_from_swf, parse_swf)

__all__ = ["feitelson_sizes", "make_workload", "poisson_arrivals",
           "SWFJob", "SWFTrace", "MalleabilityMix", "annotate_malleability",
           "jobs_from_swf", "parse_swf", "RIGID", "MOLDABLE", "MALLEABLE"]
