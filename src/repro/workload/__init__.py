"""Workload generation (Feitelson model, Poisson arrivals, SWF replay,
open-loop serving traffic)."""
from repro.workload.feitelson import (evolving_phases_for, feitelson_sizes,
                                      make_workload, poisson_arrivals)
from repro.workload.swf import (EVOLVING, MALLEABLE, MOLDABLE, RIGID,
                                SERVING, MalleabilityMix, SWFJob, SWFTrace,
                                annotate_malleability, clamp_band,
                                jobs_from_swf, parse_swf)
from repro.workload.traffic import (DiurnalCurve, TrafficGenerator,
                                    TrafficSpec)

__all__ = ["evolving_phases_for", "feitelson_sizes", "make_workload",
           "poisson_arrivals", "SWFJob", "SWFTrace", "MalleabilityMix",
           "annotate_malleability", "clamp_band", "jobs_from_swf",
           "parse_swf", "RIGID", "MOLDABLE", "MALLEABLE", "EVOLVING",
           "SERVING", "DiurnalCurve", "TrafficGenerator", "TrafficSpec"]
