"""Workload generation (Feitelson model, Poisson arrivals, SWF replay)."""
from repro.workload.feitelson import (evolving_phases_for, feitelson_sizes,
                                      make_workload, poisson_arrivals)
from repro.workload.swf import (EVOLVING, MALLEABLE, MOLDABLE, RIGID,
                                MalleabilityMix, SWFJob, SWFTrace,
                                annotate_malleability, clamp_band,
                                jobs_from_swf, parse_swf)

__all__ = ["evolving_phases_for", "feitelson_sizes", "make_workload",
           "poisson_arrivals", "SWFJob", "SWFTrace", "MalleabilityMix",
           "annotate_malleability", "clamp_band", "jobs_from_swf",
           "parse_swf", "RIGID", "MOLDABLE", "MALLEABLE", "EVOLVING"]
