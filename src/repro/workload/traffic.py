"""Open-loop request-arrival model for SERVING jobs (diurnal traffic).

A serving job's "work" is not a fixed iteration count but a stream of
requests arriving from outside the cluster.  The stream is **open-loop**:
arrivals are a pure function of ``(seed, rate curve)`` and never react to
allocation decisions — the cluster can fall behind (backlog grows) but it
cannot slow the world down.  That property is what makes the SLO-pressure
negotiation in :mod:`repro.rms.simulator` meaningful, and it is what the
property tests in ``tests/test_traffic.py`` lock down.

The model is *fluid*: rather than drawing millions of individual arrival
timestamps (a day of traffic at 10k req/s is ~1e9 events), we integrate a
deterministic diurnal rate curve analytically and modulate each
``bucket_s``-wide bucket with a seeded multiplicative noise factor.  The
cumulative-arrivals function ``F(t)`` is then exact and partition-additive:
``arrivals_between(a, c) == arrivals_between(a, b) + arrivals_between(b, c)``
holds to float precision by construction, which the simulator's
conservation invariant (``serving_conservation``) relies on.
"""
from __future__ import annotations

import dataclasses
import math
from typing import List, Tuple

import numpy as np

#: Default traffic bucket width (seconds).  Noise is i.i.d. per bucket.
DEFAULT_BUCKET_S = 60.0


@dataclasses.dataclass(frozen=True)
class DiurnalCurve:
    """Deterministic request-rate curve: cosine diurnal + additive bursts.

    ``rate(t) = base_rps * (1 + amplitude * cos(2*pi*(t - phase_s)/period_s))
    + sum(extra_rps for active bursts)``, clamped at zero.  Bursts are
    additive rectangles ``(start_s, duration_s, extra_rps)`` so the integral
    stays closed-form.

    A curve with ``base_rps=2300`` and ``period_s=86400`` models roughly
    200M requests/day — the "millions of users" scale from the ROADMAP —
    but smoke scenarios scale the same shape down to minutes.
    """

    base_rps: float
    amplitude: float = 0.5
    period_s: float = 86400.0
    phase_s: float = 0.0
    bursts: Tuple[Tuple[float, float, float], ...] = ()

    def __post_init__(self):
        if self.base_rps < 0:
            raise ValueError(f"base_rps must be >= 0, got {self.base_rps}")
        if not 0.0 <= self.amplitude <= 1.0:
            raise ValueError(
                f"amplitude must be in [0, 1], got {self.amplitude}")
        if self.period_s <= 0:
            raise ValueError(f"period_s must be > 0, got {self.period_s}")

    def rate(self, t: float) -> float:
        """Instantaneous request rate (req/s) at time ``t``."""
        w = 2.0 * math.pi / self.period_s
        r = self.base_rps * (1.0 + self.amplitude * math.cos(
            w * (t - self.phase_s)))
        for start, dur, extra in self.bursts:
            if start <= t < start + dur:
                r += extra
        return max(r, 0.0)

    def integral(self, a: float, b: float) -> float:
        """Exact integral of :meth:`rate` over ``[a, b]`` (requests).

        Closed-form: the cosine term integrates to a sine difference and
        each burst contributes ``extra * overlap``.  Amplitude <= 1 keeps
        the diurnal term non-negative, so no clamping is needed inside.
        """
        if b <= a:
            return 0.0
        w = 2.0 * math.pi / self.period_s
        total = self.base_rps * (b - a)
        total += (self.base_rps * self.amplitude / w) * (
            math.sin(w * (b - self.phase_s)) - math.sin(w * (a - self.phase_s)))
        for start, dur, extra in self.bursts:
            lo = max(a, start)
            hi = min(b, start + dur)
            if hi > lo:
                total += extra * (hi - lo)
        return max(total, 0.0)


@dataclasses.dataclass(frozen=True)
class TrafficSpec:
    """Everything that defines a serving job's request stream + SLO."""

    curve: DiurnalCurve
    seed: int
    t0: float = 0.0
    duration_s: float = 86400.0
    slo_p99_s: float = 2.0
    bucket_s: float = DEFAULT_BUCKET_S
    noise: float = 0.1

    def __post_init__(self):
        if self.duration_s <= 0:
            raise ValueError(
                f"duration_s must be > 0, got {self.duration_s}")
        if self.bucket_s <= 0:
            raise ValueError(f"bucket_s must be > 0, got {self.bucket_s}")
        if not 0.0 <= self.noise < 1.0:
            raise ValueError(f"noise must be in [0, 1), got {self.noise}")

    @property
    def end(self) -> float:
        return self.t0 + self.duration_s


class TrafficGenerator:
    """Seeded fluid arrival process: cumulative arrivals ``F(t)``.

    ``F`` is piecewise: within bucket ``k`` (a ``bucket_s`` window starting
    at ``t0 + k*bucket_s``) arrivals accrue at ``m_k * curve.rate(t)``
    where ``m_k`` is a multiplicative noise factor drawn from
    ``np.random.default_rng([seed, k])`` — each bucket's noise is an
    independent, order-free function of ``(seed, k)``, so two generators
    with the same spec agree bucket-for-bucket no matter which times they
    were queried at first.
    """

    def __init__(self, spec: TrafficSpec):
        self.spec = spec
        # cumulative arrivals at bucket boundaries; _cum[k] = F(t0 + k*dt)
        self._cum: List[float] = [0.0]
        self._mult: List[float] = []

    def _bucket_mult(self, k: int) -> float:
        """Noise multiplier for bucket ``k`` (pure in (seed, k))."""
        if self.spec.noise == 0.0:
            return 1.0
        rng = np.random.default_rng([self.spec.seed, k])
        return 1.0 + self.spec.noise * (2.0 * float(rng.random()) - 1.0)

    def _extend(self, k: int) -> None:
        """Ensure boundary cumulative sums exist through bucket ``k``."""
        t0, dt = self.spec.t0, self.spec.bucket_s
        while len(self._cum) <= k:
            j = len(self._cum) - 1      # bucket index being closed
            mult = self._bucket_mult(j)
            self._mult.append(mult)
            lo = t0 + j * dt
            hi = min(lo + dt, self.spec.end)
            self._cum.append(
                self._cum[-1] + mult * self.spec.curve.integral(lo, hi))

    def arrivals_until(self, t: float) -> float:
        """Cumulative arrivals ``F(t)`` since the window opened."""
        t = min(max(t, self.spec.t0), self.spec.end)
        rel = t - self.spec.t0
        dt = self.spec.bucket_s
        k = int(rel // dt)
        self._extend(k + 1)
        lo = self.spec.t0 + k * dt
        if t <= lo:
            return self._cum[k]
        return self._cum[k] + self._mult[k] * self.spec.curve.integral(lo, t)

    def arrivals_between(self, a: float, b: float) -> float:
        """Arrivals in ``[a, b]`` — exactly ``F(b) - F(a)``."""
        return self.arrivals_until(b) - self.arrivals_until(a)

    def total(self) -> float:
        """Total arrivals over the whole window (the job's ``work``)."""
        return self.arrivals_until(self.spec.end)

    def rate(self, t: float) -> float:
        """Noise-adjusted instantaneous rate at ``t`` (0 outside window)."""
        if not self.spec.t0 <= t < self.spec.end:
            return 0.0
        k = int((t - self.spec.t0) // self.spec.bucket_s)
        self._extend(k + 1)
        return self._mult[k] * self.spec.curve.rate(t)
