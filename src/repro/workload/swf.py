"""Standard Workload Format (SWF) trace ingestion — real-trace replay.

The Parallel Workloads Archive's SWF is the lingua franca of scheduling
studies: one job per line, 18 whitespace-separated fields, ``;`` comment
lines carrying header metadata (``; MaxNodes: 128``).  The ElastiSim-style
malleability studies (Chadha et al.; Zojer et al.) replay such traces with
a configurable fraction of jobs *annotated* as rigid / moldable /
malleable; conclusions about malleability shift materially with the trace
and the fractions, which is exactly why the simulator must ingest them.

This module provides:

- :func:`parse_swf` — tolerant line parser returning :class:`SWFJob`
  records (malformed/truncated lines are skipped and counted, or raised in
  ``strict`` mode).
- :func:`annotate_malleability` — deterministic
  rigid/moldable/malleable/evolving assignment from a
  :class:`MalleabilityMix`.
- :func:`jobs_from_swf` — trace → (:class:`repro.rms.job.Job` list,
  per-job ``AppModel`` dict) adapter; each trace job becomes an
  Amdahl-model app calibrated so that running at the recorded size takes
  the recorded runtime.  The SWF ``user_id`` is threaded onto
  ``Job.user`` (fair-share scheduling); moldable-annotated jobs get a
  factor-of-two size band around the recorded size so the moldable
  start-size optimizer has real freedom; evolving-annotated jobs get a
  deterministic per-phase demand schedule (§2 EVOLVING) whose bands,
  serial fractions, and data sizes cycle around the recorded size.

All size bands pass through :func:`clamp_band`, which pins the invariant
``1 <= min_nodes <= preferred <= max_nodes <= cluster`` — without it a
recorded size far above the simulated cluster (or an aggressive phase
band) could invert the band and wedge the scheduler.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Iterable, List, Optional, Sequence, Tuple, Union

import numpy as np

from repro.rms.costmodel import AppModel
from repro.rms.job import Job, JobPhase, clamp_band
from repro.workload.traffic import (DiurnalCurve, TrafficGenerator,
                                    TrafficSpec)

#: SWF field indices (0-based), per the Parallel Workloads Archive spec.
_FIELDS = ("job_id", "submit_time", "wait_time", "run_time",
           "allocated_procs", "avg_cpu_time", "used_memory",
           "requested_procs", "requested_time", "requested_memory",
           "status", "user_id", "group_id", "executable", "queue",
           "partition", "preceding_job", "think_time")

RIGID, MOLDABLE, MALLEABLE, EVOLVING = ("rigid", "moldable", "malleable",
                                        "evolving")
SERVING = "serving"


@dataclasses.dataclass(frozen=True)
class SWFJob:
    """One parsed SWF record (missing fields default to -1, per the spec)."""
    job_id: int
    submit_time: float
    wait_time: float
    run_time: float
    allocated_procs: int
    requested_procs: int
    requested_time: float
    status: int
    user_id: int = -1
    executable: int = -1
    queue: int = -1

    @property
    def procs(self) -> int:
        """Best-known size: allocated, falling back to requested."""
        if self.allocated_procs > 0:
            return self.allocated_procs
        return max(self.requested_procs, 1)


@dataclasses.dataclass
class SWFTrace:
    jobs: List[SWFJob]
    header: Dict[str, str]          # "; Key: Value" comment metadata
    skipped_lines: int = 0

    @property
    def max_nodes(self) -> Optional[int]:
        for key in ("MaxNodes", "MaxProcs"):
            raw = self.header.get(key)
            if raw is not None:
                try:
                    return int(raw.split()[0])
                except ValueError:
                    continue
        return None


def parse_swf(source: Union[str, Iterable[str]], *,
              strict: bool = False) -> SWFTrace:
    """Parse SWF text.

    ``source`` is a filesystem path or an iterable of lines.  Comment lines
    (``;``) feed the header dict; blank lines are ignored; lines with
    non-numeric or too-few fields are skipped (counted in
    ``SWFTrace.skipped_lines``) unless ``strict=True``.
    """
    if isinstance(source, str):
        with open(source) as fh:
            lines = fh.readlines()
    else:
        lines = list(source)
    jobs: List[SWFJob] = []
    header: Dict[str, str] = {}
    skipped = 0
    for lineno, line in enumerate(lines, 1):
        text = line.strip()
        if not text:
            continue
        if text.startswith(";"):
            body = text.lstrip(";").strip()
            if ":" in body:
                key, _, value = body.partition(":")
                header[key.strip()] = value.strip()
            continue
        fields = text.split()
        # A record needs at least the scheduling-relevant prefix
        # (through requested_time, field 9); shorter lines are truncated.
        if len(fields) < 9:
            if strict:
                raise ValueError(f"SWF line {lineno}: truncated "
                                 f"({len(fields)} fields): {text!r}")
            skipped += 1
            continue
        try:
            vals = [float(x) for x in fields[:len(_FIELDS)]]
        except ValueError:
            if strict:
                raise ValueError(f"SWF line {lineno}: non-numeric field: "
                                 f"{text!r}") from None
            skipped += 1
            continue
        rec = dict(zip(_FIELDS, vals))
        job = SWFJob(
            job_id=int(rec["job_id"]),
            submit_time=float(rec["submit_time"]),
            wait_time=float(rec["wait_time"]),
            run_time=float(rec["run_time"]),
            allocated_procs=int(rec["allocated_procs"]),
            requested_procs=int(rec.get("requested_procs", -1)),
            requested_time=float(rec.get("requested_time", -1.0)),
            status=int(rec.get("status", -1)),
            user_id=int(rec.get("user_id", -1)),
            executable=int(rec.get("executable", -1)),
            queue=int(rec.get("queue", -1)))
        if job.run_time <= 0 or job.procs <= 0:
            # Cancelled / never-ran records carry no load; skip.
            skipped += 1
            continue
        jobs.append(job)
    return SWFTrace(jobs=jobs, header=header, skipped_lines=skipped)


# ---------------------------------------------------------------------------
# Malleability annotation (trace jobs carry no such flag; studies assign it)
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class MalleabilityMix:
    """Fractions annotated rigid / moldable / malleable / evolving /
    serving."""
    rigid: float = 0.0
    moldable: float = 0.0
    malleable: float = 1.0
    evolving: float = 0.0
    serving: float = 0.0

    def __post_init__(self):
        total = (self.rigid + self.moldable + self.malleable
                 + self.evolving + self.serving)
        if abs(total - 1.0) > 1e-9:
            raise ValueError(f"fractions must sum to 1, got {total}")
        if min(self.rigid, self.moldable, self.malleable,
               self.evolving, self.serving) < 0:
            raise ValueError("fractions must be non-negative")

    def as_tuple(self) -> Tuple[float, float, float, float, float]:
        return (self.rigid, self.moldable, self.malleable, self.evolving,
                self.serving)


def annotate_malleability(jobs: Sequence[SWFJob],
                          mix: Optional[MalleabilityMix] = None,
                          *, seed: int = 7) -> List[str]:
    """Deterministically assign a kind to each job, honouring the mix.

    Uses a seeded permutation + exact quota split (not per-job coin flips)
    so the realised fractions match the requested ones to within one job.
    The quota layout keeps earlier classes' slots where they were before
    each later class existed (serving slots come after evolving, before
    the malleable fill), so 3- and 4-way mixes reproduce their historic
    assignment exactly.
    """
    mix = MalleabilityMix() if mix is None else mix
    n = len(jobs)
    n_rigid = min(int(round(mix.rigid * n)), n)
    n_mold = min(int(round(mix.moldable * n)), n - n_rigid)
    n_evol = min(int(round(mix.evolving * n)), n - n_rigid - n_mold)
    n_serv = min(int(round(mix.serving * n)),
                 n - n_rigid - n_mold - n_evol)
    kinds = ([RIGID] * n_rigid + [MOLDABLE] * n_mold + [EVOLVING] * n_evol
             + [SERVING] * n_serv
             + [MALLEABLE] * (n - n_rigid - n_mold - n_evol - n_serv))
    rng = np.random.default_rng(seed)
    perm = rng.permutation(n)
    out = [""] * n
    for slot, kind in zip(perm, kinds):
        out[slot] = kind
    return out


# ---------------------------------------------------------------------------
# Trace -> Job adapter
# ---------------------------------------------------------------------------

def _pow2_at_most(n: int) -> int:
    p = 1
    while p * 2 <= n:
        p *= 2
    return p


def _evolving_phases(rec: SWFJob, iterations: int, base: int, cap: int,
                     serial_frac: float, data_bytes_per_node: int
                     ) -> Tuple[JobPhase, ...]:
    """Deterministic phase schedule for an EVOLVING trace job.

    2–4 phases (``2 + job_id % 3``) split the work evenly; the demanded
    preferred size cycles base → up → down around the recorded size, with
    per-phase serial fractions and data sizes moving in step so both the
    execution rate and the reconfiguration cost track the phase.  Pure
    arithmetic on the record — no RNG — so the schedule is reproducible
    from the trace alone.
    """
    n_phases = 2 + rec.job_id % 3
    prefs = (base, min(base * 2, cap), max(base // 2, 1), min(base * 4, cap))
    fracs = (serial_frac, serial_frac * 0.5, min(serial_frac * 2.0, 0.5),
             serial_frac)
    phases = []
    for p in range(n_phases):
        pref = prefs[p % len(prefs)]
        lo, hi, pref = clamp_band(max(pref // 2, 1), pref * 2, pref, cap)
        phases.append(JobPhase(
            work=iterations / n_phases, min_nodes=lo, max_nodes=hi,
            preferred=pref, serial_frac=fracs[p % len(fracs)],
            data_bytes=data_bytes_per_node * pref))
    return tuple(phases)


def _serving_spec(rec: SWFJob, app: AppModel, seed: int) -> TrafficSpec:
    """Deterministic request stream for a SERVING trace job.

    The window is the job's recorded lifetime; the mean arrival rate sits
    at 60% of the recorded-size throughput with a ±50% diurnal swing
    compressed so two full cycles fit inside the window — peaks push
    occupancy through the DMR headroom (forcing SLO expands), ebbs drop
    it far enough that the negotiation hands nodes back to the batch
    queue.  Pure arithmetic on ``(workload seed, record id)``.
    """
    duration = max(rec.run_time, 1.0)
    period = max(duration / 2.0, 1.0)
    curve = DiurnalCurve(
        base_rps=0.6 * app.rate(app.preferred), amplitude=0.5,
        period_s=period, phase_s=period * (rec.job_id % 8) / 8.0)
    return TrafficSpec(
        curve=curve, seed=seed * 100003 + rec.job_id,
        t0=rec.submit_time, duration_s=duration, slo_p99_s=2.0,
        bucket_s=max(min(60.0, duration / 8.0), 1.0))


def _trace_app(rec: SWFJob, kind: str, num_nodes: int,
               serial_frac: float, data_bytes_per_node: int) -> AppModel:
    """Amdahl model calibrated so exec at the recorded size = run_time.

    Work is measured in seconds-at-recorded-size: ``iterations =
    run_time`` with ``iter_time(recorded) = 1``.  Malleable jobs may move
    a factor-of-2 around the recorded size; rigid/moldable stay put;
    evolving jobs carry a per-phase demand schedule.
    """
    size = min(rec.procs, num_nodes)
    cap = _pow2_at_most(num_nodes)
    phases: Tuple[JobPhase, ...] = ()
    iterations = max(int(round(rec.run_time)), 1)
    if kind == MALLEABLE:
        base = _pow2_at_most(size)
        min_nodes, max_nodes, preferred = clamp_band(
            max(base // 4, 1), base * 2, base, cap)
        period = 15.0
    elif kind == MOLDABLE:
        # Startable at any power-of-two in a factor-of-two band around the
        # recorded size (the "moldable" start-size optimizer exploits this),
        # but never reconfigured after launch.
        base = _pow2_at_most(size)
        min_nodes, max_nodes, preferred = clamp_band(
            max(base // 4, 1), base * 2, base, cap)
        period = 0.0
    elif kind == SERVING:
        # Wide elastic band around the recorded size: the SLO-pressure
        # negotiation rides the diurnal curve across it.
        base = _pow2_at_most(size)
        min_nodes, max_nodes, preferred = clamp_band(
            max(base // 4, 1), base * 4, base, cap)
        period = 15.0
    elif kind == EVOLVING:
        base = _pow2_at_most(size)
        phases = _evolving_phases(rec, iterations, base, cap, serial_frac,
                                  data_bytes_per_node)
        # envelope band on the app; the live per-phase band lives on Job
        min_nodes = min(ph.min_nodes for ph in phases)
        max_nodes = max(ph.max_nodes for ph in phases)
        preferred = phases[0].preferred
        period = 15.0
    else:
        base = size
        min_nodes, max_nodes, preferred = clamp_band(size, size, size,
                                                     num_nodes)
        period = 0.0
    t_at_base = rec.run_time / iterations
    t1 = t_at_base / (serial_frac + (1.0 - serial_frac) / max(base, 1))
    return AppModel(
        name=f"swf:{rec.job_id}", iterations=iterations, t1_iter_s=t1,
        serial_frac=serial_frac, data_bytes=data_bytes_per_node * base,
        min_nodes=min_nodes, max_nodes=max_nodes, preferred=preferred,
        check_period_s=period, phases=phases)


def jobs_from_swf(trace: Union[SWFTrace, Sequence[SWFJob]], *,
                  num_nodes: int = 64,
                  mix: Optional[MalleabilityMix] = None,
                  seed: int = 7,
                  serial_frac: float = 0.05,
                  data_bytes_per_node: int = 64 * 1024 ** 2,
                  max_jobs: Optional[int] = None,
                  time_scale: float = 1.0
                  ) -> Tuple[List[Job], Dict[str, AppModel]]:
    """Convert a parsed trace into simulator jobs + their app models.

    ``time_scale`` compresses submit/run times (e.g. 0.1 replays a day-long
    trace in a tenth of simulated time, preserving relative load);
    ``mix`` controls the rigid/moldable/malleable/evolving annotation; the
    recorded size is clamped to ``num_nodes``.  Returns ``(jobs, apps)``
    ready for ``ClusterSimulator(jobs, SimConfig(num_nodes=...),
    apps=apps)``.
    """
    records = list(trace.jobs if isinstance(trace, SWFTrace) else trace)
    if max_jobs is not None:
        records = records[:max_jobs]
    kinds = annotate_malleability(records, mix, seed=seed)
    t0 = min((r.submit_time for r in records), default=0.0)
    jobs: List[Job] = []
    apps: Dict[str, AppModel] = {}
    for i, (rec, kind) in enumerate(zip(records, kinds)):
        scaled = dataclasses.replace(
            rec, submit_time=(rec.submit_time - t0) * time_scale,
            run_time=max(rec.run_time * time_scale, 1.0))
        app = _trace_app(scaled, kind, num_nodes, serial_frac,
                         data_bytes_per_node)
        apps[app.name] = app
        start_nodes = (app.preferred if kind in (MALLEABLE, MOLDABLE,
                                                 EVOLVING, SERVING)
                       else app.max_nodes)
        # An evolving job's *live* band starts at phase 0 (the app model
        # keeps the envelope); the PhaseChange handler rewrites it per phase.
        if kind == EVOLVING:
            ph0 = app.phases[0]
            band = (ph0.min_nodes, ph0.max_nodes, ph0.preferred)
        else:
            band = (app.min_nodes, app.max_nodes, app.preferred)
        # A serving job's work is its stream's total arrivals (requests),
        # not the calibrated iteration count.
        spec = None
        work = float(app.iterations)
        if kind == SERVING:
            spec = _serving_spec(scaled, app, seed)
            work = TrafficGenerator(spec).total()
        jobs.append(Job(
            job_id=i, app=app.name, submit_time=float(scaled.submit_time),
            work=work,
            min_nodes=band[0], max_nodes=band[1],
            preferred=band[2], factor=2,
            malleable=(kind in (MALLEABLE, EVOLVING, SERVING)),
            check_period_s=app.check_period_s,
            requested_nodes=start_nodes, data_bytes=app.data_bytes,
            user=max(int(rec.user_id), 0), phases=app.phases,
            traffic=spec))
    return jobs, apps
