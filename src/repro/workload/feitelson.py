"""Workload generation — Feitelson's statistical model (paper §7.1).

The paper generates workloads with Feitelson's rigid-job model [4],
customizing two knobs: the number of jobs and the inter-arrival times
("Poisson distribution of factor 10" — exponential inter-arrivals with a
10-second mean scale, which avoids bursts while keeping a realistic arrival
pattern).  Each job instantiates one of the three applications (CG, Jacobi,
N-body) chosen by a randomly-sorted sequence with a fixed seed; jobs are
submitted with their *maximum* size (the user-preferred fast-execution
scenario, §7.5).
"""
from __future__ import annotations

from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.rms.costmodel import PAPER_APPS, AppModel
from repro.rms.job import Job, JobPhase, clamp_band


def feitelson_sizes(rng: np.random.Generator, n: int, max_size: int
                    ) -> np.ndarray:
    """Feitelson'96 size model: sizes biased to small values & powers of two.

    Used for synthetic rigid workloads (the paper's non-app experiments);
    the throughput workloads take sizes from the applications' Table-1
    maxima instead.
    """
    log_max = int(np.log2(max_size))
    # Harmonic-ish distribution over log2 sizes, with extra mass on serial.
    probs = np.array([1.0 / (k + 1.5) for k in range(log_max + 1)])
    probs /= probs.sum()
    k = rng.choice(log_max + 1, size=n, p=probs)
    sizes = 2 ** k
    # Feitelson: ~30% of jobs perturb away from an exact power of two.
    jitter = rng.random(n) < 0.3
    sizes = np.where(jitter & (sizes > 1),
                     np.maximum(1, sizes - rng.integers(0, 3, n)), sizes)
    return np.minimum(sizes, max_size)


def poisson_arrivals(rng: np.random.Generator, n: int,
                     scale_s: float = 10.0) -> np.ndarray:
    """Exponential inter-arrivals (Poisson process), mean ``scale_s``."""
    gaps = rng.exponential(scale_s, size=n)
    t = np.cumsum(gaps)
    t[0] = 0.0
    return t


def evolving_phases_for(app: AppModel, n_phases: int = 3
                        ) -> Tuple[JobPhase, ...]:
    """Deterministic EVOLVING schedule derived from an app's Table-1 band.

    Demand rises then falls: preferred → maximum → minimum-side, with the
    serial fraction halving in the wide middle phase (scalable burst) and
    doubling in the narrow final phase — so rate and reconfiguration cost
    genuinely change per phase.  Pure arithmetic, no RNG.
    """
    pref0 = app.preferred or app.max_nodes
    targets = (pref0, app.max_nodes, max(app.min_nodes, pref0 // 2))
    fracs = (app.serial_frac, app.serial_frac * 0.5,
             min(app.serial_frac * 2.0, 0.5))
    phases = []
    for p in range(n_phases):
        t = targets[p % len(targets)]
        lo, hi, pref = clamp_band(max(t // 2, 1), max(t * 2, t), t,
                                  app.max_nodes)
        phases.append(JobPhase(
            work=app.iterations / n_phases, min_nodes=lo, max_nodes=hi,
            preferred=pref, serial_frac=fracs[p % len(fracs)],
            data_bytes=max(app.data_bytes // (1 if t >= pref0 else 2), 1)))
    return tuple(phases)


def make_workload(num_jobs: int, *, seed: int = 7,
                  apps: Optional[Dict[str, AppModel]] = None,
                  app_names: Sequence[str] = ("cg", "jacobi", "nbody"),
                  arrival_scale_s: float = 10.0,
                  malleable: bool = True,
                  num_users: int = 5,
                  evolving_fraction: float = 0.0) -> List[Job]:
    """The paper's throughput workloads (§7.5): randomly-sorted app jobs,
    fixed seed, Poisson arrivals, launched at their maximum size.  Jobs are
    spread over ``num_users`` submitting users (fair-share accounting).

    ``evolving_fraction`` marks that share of jobs EVOLVING (§2): they get
    the deterministic :func:`evolving_phases_for` schedule.  The flag draw
    happens *after* all historic draws, so workloads with the fraction at
    0 are bit-identical to pre-evolving ones.
    """
    rng = np.random.default_rng(seed)
    apps = dict(PAPER_APPS if apps is None else apps)
    arrivals = poisson_arrivals(rng, num_jobs, arrival_scale_s)
    choices = rng.choice(len(app_names), size=num_jobs)
    users = rng.integers(0, max(num_users, 1), size=num_jobs)
    evolving = (rng.random(num_jobs) < evolving_fraction
                if evolving_fraction > 0 else np.zeros(num_jobs, bool))
    jobs = []
    for i in range(num_jobs):
        app = apps[app_names[choices[i]]]
        phases = evolving_phases_for(app) if evolving[i] else ()
        band = (phases[0] if phases else app)
        jobs.append(Job(
            job_id=i, app=app.name, submit_time=float(arrivals[i]),
            work=float(app.iterations),
            min_nodes=band.min_nodes, max_nodes=band.max_nodes,
            preferred=band.preferred, factor=2,
            malleable=malleable or bool(phases),
            check_period_s=app.check_period_s,
            requested_nodes=(band.preferred or band.max_nodes)
            if phases else app.max_nodes,
            data_bytes=app.data_bytes,
            user=int(users[i]), phases=phases))
    return jobs
