"""Command line for the determinism linter (``python -m repro.lint``)."""
from __future__ import annotations

import argparse
import sys
from typing import List, Optional

from repro.lint.core import REGISTRY, lint_paths, make_rules, render_json


def _split_ids(value: Optional[str]) -> Optional[List[str]]:
    if not value:
        return None
    return [v.strip() for v in value.split(",") if v.strip()]


def main(argv: Optional[List[str]] = None) -> int:
    parser = argparse.ArgumentParser(
        prog="python -m repro.lint",
        description="repo-specific determinism/invariant linter")
    parser.add_argument("paths", nargs="*", default=["src"],
                        help="files or directories to lint (default: src)")
    parser.add_argument("--json", action="store_true",
                        help="emit the stable machine-readable report")
    parser.add_argument("--check", action="store_true",
                        help="CI mode: same as default (exit 1 on any "
                             "finding), kept explicit for pipelines")
    parser.add_argument("--select", metavar="IDS",
                        help="comma-separated rule ids to run exclusively")
    parser.add_argument("--ignore", metavar="IDS",
                        help="comma-separated rule ids to skip")
    parser.add_argument("--list-rules", action="store_true",
                        help="print registered rules and exit")
    args = parser.parse_args(argv)

    if args.list_rules:
        for rule in make_rules():
            print(f"{rule.rule_id}  {rule.title}")
        return 0

    select, ignore = _split_ids(args.select), _split_ids(args.ignore)
    try:
        findings = lint_paths(args.paths, select=select, ignore=ignore)
    except ValueError as exc:
        print(f"error: {exc}", file=sys.stderr)
        return 2

    if args.json:
        print(render_json(findings, make_rules(select, ignore)))
    else:
        for f in findings:
            print(f.render())
        if findings:
            print(f"{len(findings)} finding(s) in "
                  f"{len({f.path for f in findings})} file(s) "
                  f"[{len(REGISTRY)} rules]", file=sys.stderr)
    return 1 if findings else 0


if __name__ == "__main__":
    sys.exit(main())
