"""ENT rules: wall-clock, entropy, and ad-hoc output in simulation code.

The simulator's outputs must be a pure function of (workload, config,
seed).  Any wall-clock or unseeded-RNG call inside a determinism-
critical module can leak host state into a golden artifact.  The one
sanctioned timing call is ``time.perf_counter`` — used to *measure*
in-process policy latency, which is reported out-of-band
(``SimReport.policy_wall_s``) and never injected into simulation time.

ENT002 extends the discipline to *reporting*: library code under
``repro.rms``/``repro.obs`` must not ``print()`` or write to
stdout/stderr directly — results flow through returned artifacts or the
observability layer (:mod:`repro.obs`), so traced and untraced runs
emit identical streams.  The one sanctioned surface is a module's
``main()`` CLI entry point.
"""
from __future__ import annotations

import ast
from typing import Iterator

from repro.lint.core import Finding, Module, Rule, dotted_parts, register

TIME_MODULES = {"time", "_time"}
BANNED_TIME_ATTRS = {"time", "time_ns", "monotonic", "monotonic_ns",
                     "sleep"}
DATETIME_ATTRS = {"now", "utcnow", "today"}
NUMPY_RANDOM_OK = {"default_rng", "Generator", "SeedSequence", "PCG64",
                   "Philox", "BitGenerator"}


@register
class EntropyRule(Rule):
    rule_id = "ENT001"
    title = ("wall-clock/entropy call outside the sanctioned seeded-RNG "
             "helpers (np.random.default_rng(seed), time.perf_counter)")

    def run(self, mod: Module) -> Iterator[Finding]:
        for node in ast.walk(mod.tree):
            if not isinstance(node, ast.Call):
                continue
            parts = dotted_parts(node.func)
            if not parts or len(parts) < 2:
                continue
            head, tail = parts[0], parts[-1]
            if head in TIME_MODULES and len(parts) == 2 and \
                    tail in BANNED_TIME_ATTRS:
                yield self.finding(
                    mod, node, f"{'.'.join(parts)}() reads the wall "
                    f"clock; simulation time must come from the engine")
            elif head == "datetime" and tail in DATETIME_ATTRS:
                yield self.finding(
                    mod, node, f"{'.'.join(parts)}() reads the wall "
                    f"clock; thread timestamps in explicitly")
            elif head == "random":
                # the stdlib global RNG is process-state; a seeded
                # random.Random(seed) instance is the only sanctioned use
                if tail == "Random" and node.args:
                    continue
                yield self.finding(
                    mod, node, f"{'.'.join(parts)}() uses the process-"
                    f"global RNG; use a seeded np.random.default_rng")
            elif head in ("np", "numpy") and len(parts) >= 3 and \
                    parts[1] == "random":
                if tail == "default_rng":
                    if not node.args:
                        yield self.finding(
                            mod, node, "np.random.default_rng() without "
                            "a seed draws OS entropy; pass the config "
                            "seed")
                elif tail not in NUMPY_RANDOM_OK:
                    yield self.finding(
                        mod, node, f"legacy {'.'.join(parts)}() uses "
                        f"numpy's global RNG; use a seeded "
                        f"np.random.default_rng")


@register
class AdHocOutputRule(Rule):
    rule_id = "ENT002"
    title = ("print()/stdout/stderr write in library code; report through "
             "repro.obs artifacts (main() entry points are exempt)")
    domains = ("rms", "obs")

    STREAMS = {"stdout", "stderr"}
    WRITE_ATTRS = {"write", "writelines"}

    def _in_main(self, mod: Module, node: ast.AST) -> bool:
        while node is not None:
            if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
                return node.name == "main"
            node = mod.parent(node)
        return False

    def run(self, mod: Module) -> Iterator[Finding]:
        for node in ast.walk(mod.tree):
            if not isinstance(node, ast.Call):
                continue
            func = node.func
            if isinstance(func, ast.Name) and func.id == "print":
                if not self._in_main(mod, node):
                    yield self.finding(
                        mod, node, "print() in library code writes to the "
                        "process stream; return data or record it via "
                        "repro.obs")
                continue
            parts = dotted_parts(func)
            if not parts or len(parts) < 2:
                continue
            if parts[-1] in self.WRITE_ATTRS and \
                    parts[-2] in self.STREAMS and \
                    not self._in_main(mod, node):
                yield self.finding(
                    mod, node, f"{'.'.join(parts)}() is an ad-hoc stream "
                    f"write in library code; report through repro.obs "
                    f"artifacts")
