"""AST lint framework for the repo's determinism contract.

Layer 1 of the correctness tooling (`docs/determinism.md`): a small
rule registry + file walker + pragma handling + stable JSON output.
Rules are repo-specific — each encodes a bug class that actually
shipped in a past PR (stale ``config.num_nodes`` denominators,
unsorted dict iteration feeding golden artifacts, duplicated
epoch-guard chains, …) so the byte-determinism and conservation
contracts are enforced by tooling instead of rediscovered per PR.

Suppression: a finding on line N is suppressed by ``# lint:
disable=RULE`` (comma-separated ids, or ``all``) on that same line.
Every pragma in the tree should carry a justification comment.

The JSON document (``--json``) is schema-stable and fully
deterministic: no timestamps, findings sorted by
``(path, line, col, rule)`` — safe to golden-compare in CI.
"""
from __future__ import annotations

import ast
import dataclasses
import json
import os
import posixpath
import re
from typing import Dict, Iterator, List, Optional, Sequence, Tuple, Type

SCHEMA = "repro.lint/v1"

PRAGMA_RE = re.compile(r"#\s*lint:\s*disable=([A-Za-z0-9_,\s]+)")

# Subdirectory names whose modules are determinism-critical: golden
# artifacts and conservation invariants are derived from what runs here.
CRITICAL_DIRS: Tuple[str, ...] = ("rms", "calib", "workload")


@dataclasses.dataclass(frozen=True, slots=True)
class Finding:
    rule: str
    path: str
    line: int
    col: int
    message: str

    @property
    def sort_key(self) -> Tuple[str, int, int, str]:
        return (self.path, self.line, self.col, self.rule)

    def to_dict(self) -> Dict[str, object]:
        return {"rule": self.rule, "path": self.path, "line": self.line,
                "col": self.col, "message": self.message}

    def render(self) -> str:
        return f"{self.path}:{self.line}:{self.col}: " \
               f"{self.rule} {self.message}"


class Module:
    """One parsed source file plus the per-file analysis context."""

    def __init__(self, source: str, path: str):
        self.source = source
        self.path = path.replace(os.sep, "/")
        self.name = posixpath.basename(self.path)
        self.tree = ast.parse(source, filename=path)
        self._parents: Dict[ast.AST, ast.AST] = {}
        for parent in ast.walk(self.tree):
            for child in ast.iter_child_nodes(parent):
                self._parents[child] = parent
        # line -> set of rule ids disabled on that line ("all" wildcard)
        self.disabled: Dict[int, set] = {}
        for lineno, text in enumerate(source.splitlines(), 1):
            m = PRAGMA_RE.search(text)
            if m:
                self.disabled[lineno] = {
                    r.strip() for r in m.group(1).split(",") if r.strip()}

    def parent(self, node: ast.AST) -> Optional[ast.AST]:
        return self._parents.get(node)

    def in_dirs(self, names: Sequence[str]) -> bool:
        """True when the file lives under any of the named subdirs."""
        probe = "/" + self.path
        return any(f"/{n}/" in probe for n in names)

    def suppressed(self, finding: Finding) -> bool:
        rules = self.disabled.get(finding.line)
        return bool(rules) and (finding.rule in rules or "all" in rules)


class Rule:
    """Base rule: subclass, set ``rule_id``/``title``, implement ``run``."""

    rule_id: str = ""
    title: str = ""
    # Only files under these subdirs are checked; () means every file.
    domains: Tuple[str, ...] = CRITICAL_DIRS

    def applies(self, mod: Module) -> bool:
        return not self.domains or mod.in_dirs(self.domains)

    def run(self, mod: Module) -> Iterator[Finding]:
        raise NotImplementedError

    def finding(self, mod: Module, node: ast.AST, message: str) -> Finding:
        return Finding(self.rule_id, mod.path,
                       getattr(node, "lineno", 1),
                       getattr(node, "col_offset", 0), message)


REGISTRY: Dict[str, Type[Rule]] = {}


def register(cls: Type[Rule]) -> Type[Rule]:
    if not cls.rule_id:
        raise ValueError(f"{cls.__name__} has no rule_id")
    if cls.rule_id in REGISTRY:
        raise ValueError(f"duplicate rule id {cls.rule_id}")
    REGISTRY[cls.rule_id] = cls
    return cls


def make_rules(select: Optional[Sequence[str]] = None,
               ignore: Optional[Sequence[str]] = None) -> List[Rule]:
    ids = sorted(REGISTRY)
    if select:
        unknown = sorted(set(select) - set(ids))
        if unknown:
            raise ValueError(f"unknown rule ids: {unknown}")
        ids = [i for i in ids if i in set(select)]
    if ignore:
        ids = [i for i in ids if i not in set(ignore)]
    return [REGISTRY[i]() for i in ids]


# -- helpers shared by rule modules ------------------------------------------

def dotted_parts(node: ast.AST) -> Optional[List[str]]:
    """``a.b.c`` -> ["a", "b", "c"]; None when the chain doesn't end in a
    plain name (e.g. a call result)."""
    parts: List[str] = []
    while isinstance(node, ast.Attribute):
        parts.append(node.attr)
        node = node.value
    if isinstance(node, ast.Name):
        parts.append(node.id)
        parts.reverse()
        return parts
    return None


def terminal_name(node: ast.AST) -> Optional[str]:
    """Last component of a Name/Attribute expression."""
    if isinstance(node, ast.Name):
        return node.id
    if isinstance(node, ast.Attribute):
        return node.attr
    return None


# -- driving -----------------------------------------------------------------

def lint_module(mod: Module, rules: Sequence[Rule]) -> List[Finding]:
    out: List[Finding] = []
    for rule in rules:
        if not rule.applies(mod):
            continue
        for f in rule.run(mod):
            if not mod.suppressed(f):
                out.append(f)
    return out


def lint_source(source: str, path: str = "<string>",
                select: Optional[Sequence[str]] = None,
                ignore: Optional[Sequence[str]] = None) -> List[Finding]:
    """Lint one source string; ``path`` decides domain scoping (a fixture
    passed as ``rms/x.py`` is checked as a determinism-critical module)."""
    rules = make_rules(select, ignore)
    try:
        mod = Module(source, path)
    except SyntaxError as exc:
        return [Finding("E000", path.replace(os.sep, "/"),
                        exc.lineno or 1, (exc.offset or 1) - 1,
                        f"syntax error: {exc.msg}")]
    return sorted(lint_module(mod, rules), key=lambda f: f.sort_key)


def iter_files(paths: Sequence[str]) -> Iterator[str]:
    for path in paths:
        if os.path.isfile(path):
            yield path
            continue
        for root, dirs, files in os.walk(path):
            dirs[:] = sorted(d for d in dirs
                             if not d.startswith(".") and d != "__pycache__")
            for name in sorted(files):
                if name.endswith(".py"):
                    yield os.path.join(root, name)


def lint_paths(paths: Sequence[str],
               select: Optional[Sequence[str]] = None,
               ignore: Optional[Sequence[str]] = None) -> List[Finding]:
    rules = make_rules(select, ignore)
    out: List[Finding] = []
    for path in iter_files(paths):
        with open(path, "r", encoding="utf-8") as fh:
            source = fh.read()
        try:
            mod = Module(source, path)
        except SyntaxError as exc:
            out.append(Finding("E000", path.replace(os.sep, "/"),
                               exc.lineno or 1, (exc.offset or 1) - 1,
                               f"syntax error: {exc.msg}"))
            continue
        out.extend(lint_module(mod, rules))
    return sorted(out, key=lambda f: f.sort_key)


def to_json_doc(findings: Sequence[Finding],
                rules: Sequence[Rule]) -> Dict[str, object]:
    """Deterministic machine-readable report (no timestamps, stable sort)."""
    return {
        "schema": SCHEMA,
        "rules": {r.rule_id: r.title for r in
                  sorted(rules, key=lambda r: r.rule_id)},
        "findings": [f.to_dict() for f in
                     sorted(findings, key=lambda f: f.sort_key)],
    }


def render_json(findings: Sequence[Finding], rules: Sequence[Rule]) -> str:
    return json.dumps(to_json_doc(findings, rules), indent=1, sort_keys=True)
