"""CAP rules: the live-capacity denominator contract.

PR 7's bug class: after node churn (failures, drains, joins, power
cycling) the construction-time ``config.num_nodes`` is *initial*
capacity, not current capacity.  Every denominator, clamp ceiling, and
normalization in ``rms/`` must read ``cluster.live_capacity`` instead;
``cluster.py`` itself (which owns the lifecycle accounting) is the one
module allowed to touch ``num_nodes``.
"""
from __future__ import annotations

import ast
from typing import Iterator

from repro.lint.core import Finding, Module, Rule, register


@register
class StaleCapacityRule(Rule):
    rule_id = "CAP001"
    title = ("config.num_nodes read outside cluster.py; "
             "cluster.live_capacity is the only legal denominator")
    domains = ("rms",)

    def applies(self, mod: Module) -> bool:
        return super().applies(mod) and mod.name != "cluster.py"

    def run(self, mod: Module) -> Iterator[Finding]:
        for node in ast.walk(mod.tree):
            if not (isinstance(node, ast.Attribute) and
                    node.attr == "num_nodes"):
                continue
            base = node.value
            if (isinstance(base, ast.Name) and
                    base.id in ("config", "cfg")) or \
                    (isinstance(base, ast.Attribute) and
                     base.attr == "config"):
                yield self.finding(
                    mod, node, "config.num_nodes is initial capacity, "
                    "stale after churn; use cluster.live_capacity")
