"""DET rules: iteration order and float accumulation.

Golden artifacts are byte-compared in CI, so any value derived from
Python's *insertion-ordered-but-history-dependent* dict/set iteration
is a latent nondeterminism bug: two code paths that build the same
mapping in different orders produce different bytes.  The winner-table
collapse fixed in PR 6 and the fairshare ledger both hit this class.
"""
from __future__ import annotations

import ast
from typing import Iterator, Optional

from repro.lint.core import Finding, Module, Rule, register, terminal_name

# Consumers that cannot observe iteration order.  ``sum`` is listed here
# because DET002 owns it: integer sums are order-free, float sums are a
# distinct (worse) bug class with its own rule below.
ORDER_INSENSITIVE = {"sorted", "set", "frozenset", "sum", "any", "all",
                     "max", "min", "len", "Counter"}

UNORDERED_METHODS = {"values", "items", "keys"}


def unordered_source(node: ast.AST) -> Optional[str]:
    """Describe ``node`` when it iterates in unordered/history-dependent
    order: ``d.values()/.items()/.keys()``, ``set(...)``, set displays."""
    if isinstance(node, ast.Call):
        if isinstance(node.func, ast.Attribute) and \
                node.func.attr in UNORDERED_METHODS and \
                not node.args and not node.keywords:
            return f".{node.func.attr}()"
        if isinstance(node.func, ast.Name) and \
                node.func.id in ("set", "frozenset"):
            return f"{node.func.id}()"
    if isinstance(node, ast.Set):
        return "set literal"
    if isinstance(node, ast.SetComp):
        return "set comprehension"
    return None


def _consumer_name(mod: Module, node: ast.AST) -> Optional[str]:
    """Name of the call directly consuming a comprehension, if any."""
    parent = mod.parent(node)
    if isinstance(parent, ast.Call) and node in parent.args:
        return terminal_name(parent.func)
    return None


@register
class UnsortedIterationRule(Rule):
    rule_id = "DET001"
    title = ("unordered dict/set iteration in a determinism-critical "
             "module; wrap in sorted() or consume order-insensitively")

    def run(self, mod: Module) -> Iterator[Finding]:
        for node in ast.walk(mod.tree):
            if isinstance(node, ast.For):
                src = unordered_source(node.iter)
                if src:
                    yield self.finding(
                        mod, node.iter,
                        f"for-loop over {src}: iteration order is "
                        f"history-dependent; use sorted()")
            elif isinstance(node, (ast.ListComp, ast.SetComp,
                                   ast.DictComp, ast.GeneratorExp)):
                consumer = _consumer_name(mod, node)
                if consumer in ORDER_INSENSITIVE:
                    continue
                for comp in node.generators:
                    src = unordered_source(comp.iter)
                    if src:
                        yield self.finding(
                            mod, comp.iter,
                            f"comprehension over {src}: iteration order "
                            f"is history-dependent; use sorted()")


def _int_safe_element(elt: ast.AST) -> bool:
    """Elements whose sum is order-free: integer literals and len()."""
    if isinstance(elt, ast.Constant) and isinstance(elt.value, int):
        return True
    if isinstance(elt, ast.Call) and isinstance(elt.func, ast.Name) and \
            elt.func.id in ("len", "int"):
        return True
    return False


@register
class FloatSumOrderRule(Rule):
    rule_id = "DET002"
    title = ("float accumulation (sum) over an unsorted unordered "
             "iterable; float addition is not associative")

    def run(self, mod: Module) -> Iterator[Finding]:
        for node in ast.walk(mod.tree):
            if not (isinstance(node, ast.Call) and
                    isinstance(node.func, ast.Name) and
                    node.func.id == "sum" and node.args):
                continue
            arg = node.args[0]
            if isinstance(arg, (ast.GeneratorExp, ast.ListComp)):
                if _int_safe_element(arg.elt):
                    continue
                for comp in arg.generators:
                    src = unordered_source(comp.iter)
                    if src:
                        yield self.finding(
                            mod, comp.iter,
                            f"sum over {src}: float accumulation order "
                            f"is history-dependent; sort first or prove "
                            f"the elements integral")
            else:
                src = unordered_source(arg)
                if src:
                    yield self.finding(
                        mod, arg,
                        f"sum({src.lstrip('.')}): float accumulation "
                        f"order is history-dependent; sort first")
