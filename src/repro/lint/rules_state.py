"""MUT rules: shared mutable state.

MUT001 — default argument values are evaluated once at ``def`` time
and shared across every call: a mutable default (or any constructor
call) aliases state between independent simulations — the
``SimConfig()``-default bug class where one sweep point's band edits
leaked into the next.

MUT002 — module-level mutable bindings are process-global state that
survives across runs in one interpreter; ALL_CAPS constants (frozen
registries populated at import time) and dunders are exempt by
convention, everything else needs a pragma with a justification.
"""
from __future__ import annotations

import ast
from typing import Iterator, Optional

from repro.lint.core import Finding, Module, Rule, register, terminal_name

IMMUTABLE_CALLS = {"tuple", "frozenset", "int", "float", "str", "bool",
                   "bytes"}
MUTABLE_CALLS = {"dict", "list", "set", "defaultdict", "OrderedDict",
                 "Counter", "deque"}
MUTABLE_DISPLAYS = (ast.List, ast.Dict, ast.Set, ast.ListComp,
                    ast.SetComp, ast.DictComp)


def _default_problem(node: ast.AST) -> Optional[str]:
    if isinstance(node, MUTABLE_DISPLAYS):
        return "mutable literal"
    if isinstance(node, ast.Call):
        name = terminal_name(node.func)
        if name in IMMUTABLE_CALLS:
            return None
        return f"call to {name or 'expression'}()"
    return None


@register
class MutableDefaultRule(Rule):
    rule_id = "MUT001"
    title = ("mutable (or constructor-call) default argument: evaluated "
             "once at def time and shared across calls")

    def run(self, mod: Module) -> Iterator[Finding]:
        for node in ast.walk(mod.tree):
            if not isinstance(node, (ast.FunctionDef,
                                     ast.AsyncFunctionDef, ast.Lambda)):
                continue
            defaults = list(node.args.defaults) + \
                [d for d in node.args.kw_defaults if d is not None]
            for default in defaults:
                problem = _default_problem(default)
                if problem:
                    yield self.finding(
                        mod, default, f"default argument is a {problem}: "
                        f"one shared instance across all calls; default "
                        f"to None and construct inside the function")


def _is_constant_name(name: str) -> bool:
    return name.upper() == name or \
        (name.startswith("__") and name.endswith("__"))


@register
class ModuleMutableStateRule(Rule):
    rule_id = "MUT002"
    title = ("module-level mutable state (non-ALL_CAPS binding): "
             "process-global, survives across runs")

    def run(self, mod: Module) -> Iterator[Finding]:
        for stmt in mod.tree.body:
            if isinstance(stmt, ast.Assign):
                targets, value = stmt.targets, stmt.value
            elif isinstance(stmt, ast.AnnAssign) and stmt.value is not None:
                targets, value = [stmt.target], stmt.value
            else:
                continue
            mutable = isinstance(value, MUTABLE_DISPLAYS) or (
                isinstance(value, ast.Call) and
                terminal_name(value.func) in MUTABLE_CALLS)
            if not mutable:
                continue
            for target in targets:
                if isinstance(target, ast.Name) and \
                        not _is_constant_name(target.id):
                    yield self.finding(
                        mod, stmt, f"module-level mutable binding "
                        f"{target.id!r}: shared process-global state; "
                        f"make it a function-local, a constant "
                        f"(ALL_CAPS), or pragma with a justification")
