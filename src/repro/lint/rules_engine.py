"""ENG rules: engine event hygiene.

ENG001 — event dataclasses are allocated once per scheduled event
(millions in a big sweep) and live in the heap: they must be
``frozen=True, slots=True``.

ENG002 — the epoch-guard pattern: handlers for events that carry an
``epoch`` (ReconfigPoint / CheckpointTick / PhaseChange /
ExpandTimeout — the chains that survive a requeue/restart) must
consult that epoch, otherwise a stale chain left in the heap from a
prior start doubles the check frequency or mutates a restarted job's
band (the duplicated-chain bug class fixed in PR 3/6).
"""
from __future__ import annotations

import ast
from typing import Dict, Iterator, Optional

from repro.lint.core import Finding, Module, Rule, register, terminal_name

EPOCH_EVENTS = {"ReconfigPoint", "CheckpointTick", "PhaseChange",
                "ExpandTimeout", "TrafficTick"}


def _dataclass_decorator(cls: ast.ClassDef) -> Optional[ast.AST]:
    for deco in cls.decorator_list:
        target = deco.func if isinstance(deco, ast.Call) else deco
        if terminal_name(target) == "dataclass":
            return deco
    return None


def _is_event_class(cls: ast.ClassDef) -> bool:
    if cls.name == "Event":
        return _dataclass_decorator(cls) is not None
    return any(terminal_name(base) == "Event" for base in cls.bases)


@register
class EventSlotsRule(Rule):
    rule_id = "ENG001"
    title = ("engine Event dataclasses must be declared "
             "@dataclass(frozen=True, slots=True)")
    domains = ("rms",)

    def run(self, mod: Module) -> Iterator[Finding]:
        for node in ast.walk(mod.tree):
            if not (isinstance(node, ast.ClassDef) and
                    _is_event_class(node)):
                continue
            deco = _dataclass_decorator(node)
            if deco is None:
                has_slots = any(
                    isinstance(stmt, ast.Assign) and any(
                        isinstance(t, ast.Name) and t.id == "__slots__"
                        for t in stmt.targets)
                    for stmt in node.body)
                if not has_slots:
                    yield self.finding(
                        mod, node, f"event class {node.name} has neither "
                        f"a slotted dataclass decorator nor __slots__")
                continue
            kwargs = {kw.arg: kw.value for kw in deco.keywords} \
                if isinstance(deco, ast.Call) else {}
            missing = [name for name in ("frozen", "slots")
                       if not (isinstance(kwargs.get(name), ast.Constant)
                               and kwargs[name].value is True)]
            if missing:
                yield self.finding(
                    mod, node, f"event class {node.name} missing "
                    f"{'/'.join(name + '=True' for name in missing)} in "
                    f"its dataclass decorator")


def _mentions_epoch(node: ast.AST) -> bool:
    return any((isinstance(n, ast.Attribute) and n.attr == "epoch") or
               (isinstance(n, ast.Name) and n.id == "epoch")
               for n in ast.walk(node))


def _collect_functions(mod: Module) -> Dict[str, ast.AST]:
    out: Dict[str, ast.AST] = {}
    for node in ast.walk(mod.tree):
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
            out.setdefault(node.name, node)
    return out


@register
class EpochGuardRule(Rule):
    rule_id = "ENG002"
    title = ("handler registered for an epoch-carrying event must "
             "consult the event's epoch (stale-chain guard)")
    domains = ("rms",)

    def run(self, mod: Module) -> Iterator[Finding]:
        functions = _collect_functions(mod)
        for node in ast.walk(mod.tree):
            if not (isinstance(node, ast.Call) and
                    isinstance(node.func, ast.Attribute) and
                    node.func.attr == "on" and len(node.args) >= 2):
                continue
            event_name = terminal_name(node.args[0])
            if event_name not in EPOCH_EVENTS:
                continue
            handler = node.args[1]
            if isinstance(handler, ast.Lambda):
                body: Optional[ast.AST] = handler
            else:
                name = terminal_name(handler)
                body = functions.get(name) if name else None
            if body is None:
                continue        # dynamically built handler: can't resolve
            if not _mentions_epoch(body):
                yield self.finding(
                    mod, node, f"handler for {event_name} never reads "
                    f"the event epoch; a stale chain from a prior start "
                    f"will not die at the guard")
