"""repro.lint — repo-specific determinism/invariant static analysis.

Usage::

    PYTHONPATH=src python -m repro.lint src/ [tests/] [--json] [--check]

Rules (each encodes a bug class that shipped in a past PR; see
``docs/determinism.md`` for the contract they enforce):

=======  ===================================================================
DET001   unordered dict/set iteration in determinism-critical modules
DET002   float accumulation (sum) over unsorted unordered iterables
ENT001   wall-clock / entropy calls outside sanctioned seeded-RNG helpers
CAP001   ``config.num_nodes`` reads outside cluster.py (use live_capacity)
ENG001   engine Event dataclasses missing ``frozen=True, slots=True``
ENG002   epoch-carrying event handlers without an epoch guard
MUT001   mutable / constructor-call default arguments
MUT002   module-level mutable state (non-ALL_CAPS bindings)
=======  ===================================================================

Suppress a true-but-intended finding with ``# lint: disable=RULE`` on
the flagged line, always with a justification comment.
"""
from repro.lint.core import (CRITICAL_DIRS, Finding, Module, REGISTRY,
                             Rule, SCHEMA, lint_paths, lint_source,
                             make_rules, register, render_json,
                             to_json_doc)
# importing the rule modules populates REGISTRY
from repro.lint import rules_capacity    # noqa: F401
from repro.lint import rules_determinism  # noqa: F401
from repro.lint import rules_engine      # noqa: F401
from repro.lint import rules_entropy     # noqa: F401
from repro.lint import rules_state       # noqa: F401

__all__ = ["CRITICAL_DIRS", "Finding", "Module", "REGISTRY", "Rule",
           "SCHEMA", "lint_paths", "lint_source", "make_rules",
           "register", "render_json", "to_json_doc"]
