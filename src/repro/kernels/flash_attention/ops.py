"""Jitted wrapper for the flash-attention kernel.

On TPU this dispatches to the Pallas kernel; on CPU (this container) it runs
the kernel body in interpret mode — same code path, Python-executed — which
is how the shape/dtype sweeps in tests validate it against ``ref.py``.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax

from repro.kernels.flash_attention.kernel import flash_attention
from repro.kernels.flash_attention.ref import attention_ref


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


@functools.partial(jax.jit, static_argnames=(
    "causal", "window", "softcap", "block_q", "block_k", "impl"))
def flash_attention_op(q, k, v, *, causal: bool = True,
                       window: Optional[int] = None,
                       softcap: Optional[float] = None,
                       block_q: int = 256, block_k: int = 256,
                       impl: str = "auto") -> jax.Array:
    """q: (B, H, Sq, D); k/v: (B, KV, Sk, D) -> (B, H, Sq, D)."""
    if impl == "auto":
        impl = "pallas" if _on_tpu() else "interpret"
    if impl == "ref":
        return attention_ref(q, k, v, causal=causal, window=window,
                             softcap=softcap)
    return flash_attention(q, k, v, causal=causal, window=window,
                           softcap=softcap, block_q=block_q,
                           block_k=block_k,
                           interpret=(impl == "interpret"))
