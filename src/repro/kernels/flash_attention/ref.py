"""Pure-jnp oracle for flash attention (naive materialized softmax)."""
from __future__ import annotations

import math
from typing import Optional

import jax
import jax.numpy as jnp

NEG_INF = -2.0 ** 30


def attention_ref(q, k, v, *, causal: bool = True,
                  window: Optional[int] = None,
                  softcap: Optional[float] = None) -> jax.Array:
    """q: (B, H, Sq, D); k/v: (B, KV, Sk, D). GQA via H % KV == 0.

    Materializes the full (Sq, Sk) score matrix — the correctness oracle the
    Pallas kernel is validated against (kernel sweeps call assert_allclose
    on this).
    """
    b, h, sq, d = q.shape
    kvh, sk = k.shape[1], k.shape[2]
    g = h // kvh
    qg = q.reshape(b, kvh, g, sq, d)
    logits = jnp.einsum("bkgqd,bksd->bkgqs", qg.astype(jnp.float32),
                        k.astype(jnp.float32)) / math.sqrt(d)
    if softcap is not None:
        logits = jnp.tanh(logits / softcap) * softcap
    qpos = jnp.arange(sq)[:, None] + (sk - sq)   # right-aligned positions
    kpos = jnp.arange(sk)[None, :]
    mask = jnp.ones((sq, sk), bool)
    if causal:
        mask &= qpos >= kpos
    if window is not None:
        mask &= qpos - kpos < window
    logits = jnp.where(mask[None, None, None], logits, NEG_INF)
    p = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bkgqs,bksd->bkgqd", p, v.astype(jnp.float32))
    return out.reshape(b, h, sq, d).astype(q.dtype)
