"""Pallas TPU flash-attention kernel.

Canonical TPU flash structure: grid ``(batch, q_heads, Sq/bq, Sk/bk)`` with
the KV dimension innermost (sequential on TPU); the online-softmax state
(m, l) and the output accumulator live in VMEM scratch that persists across
the innermost grid steps.  BlockSpecs tile Q/K/V into (bq, d) / (bk, d) VMEM
tiles (d padded to the 128-lane register width by the caller).  Causal and
sliding-window blocks that are fully masked are skipped with ``pl.when``
(the TPU grid is sequential, so the skip saves real time).  GQA is handled
in the K/V index maps (kv head = q head // group).  Supports the gemma2
logit softcap.
"""
from __future__ import annotations

import functools
import math
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -2.0 ** 30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr, *,
                  scale: float, block_q: int, block_k: int, seq_k: int,
                  causal: bool, window: Optional[int],
                  softcap: Optional[float], kv_steps: int):
    iq = pl.program_id(2)
    ik = pl.program_id(3)

    @pl.when(ik == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q_start = iq * block_q
    k_start = ik * block_k

    def _compute():
        q = q_ref[0, 0].astype(jnp.float32)          # (bq, d)
        k = k_ref[0, 0].astype(jnp.float32)          # (bk, d)
        v = v_ref[0, 0].astype(jnp.float32)
        logits = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32) * scale
        if softcap is not None:
            logits = jnp.tanh(logits / softcap) * softcap
        qpos = q_start + jax.lax.broadcasted_iota(jnp.int32,
                                                  (block_q, block_k), 0)
        kpos = k_start + jax.lax.broadcasted_iota(jnp.int32,
                                                  (block_q, block_k), 1)
        mask = jnp.ones((block_q, block_k), jnp.bool_)
        if causal:
            mask &= qpos >= kpos
        if window is not None:
            mask &= (qpos - kpos) < window
        logits = jnp.where(mask, logits, NEG_INF)

        m_prev = m_scr[...]
        l_prev = l_scr[...]
        m_cur = jnp.max(logits, axis=1)[:, None]      # (bq, 1)
        m_new = jnp.maximum(m_prev, m_cur)
        p = jnp.exp(logits - m_new)
        corr = jnp.exp(m_prev - m_new)
        l_new = corr * l_prev + jnp.sum(p, axis=1)[:, None]
        acc_scr[...] = acc_scr[...] * corr + jax.lax.dot_general(
            p.astype(v.dtype), v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        m_scr[...] = m_new
        l_scr[...] = l_new

    if causal or window is not None:
        # skip fully-masked blocks (real time saved: TPU grid is sequential)
        needed = jnp.asarray(True)
        if causal:
            needed &= k_start <= q_start + block_q - 1
        if window is not None:
            needed &= k_start + block_k - 1 >= q_start - (window - 1)
        pl.when(needed)(_compute)
    else:
        _compute()

    @pl.when(ik == kv_steps - 1)
    def _finish():
        o_ref[0, 0] = (acc_scr[...] /
                       jnp.maximum(l_scr[...], 1e-30)).astype(o_ref.dtype)


def flash_attention(q, k, v, *, causal: bool = True,
                    window: Optional[int] = None,
                    softcap: Optional[float] = None,
                    block_q: int = 256, block_k: int = 256,
                    interpret: bool = False) -> jax.Array:
    """q: (B, H, Sq, D); k/v: (B, KV, Sk, D) -> (B, H, Sq, D)."""
    b, h, sq, d = q.shape
    kvh, sk = k.shape[1], k.shape[2]
    assert h % kvh == 0, (h, kvh)
    group = h // kvh
    block_q = min(block_q, sq)
    block_k = min(block_k, sk)
    assert sq % block_q == 0 and sk % block_k == 0
    grid = (b, h, sq // block_q, sk // block_k)
    kv_steps = sk // block_k
    scale = 1.0 / math.sqrt(d)

    kernel = functools.partial(
        _flash_kernel, scale=scale, block_q=block_q, block_k=block_k,
        seq_k=sk, causal=causal, window=window, softcap=softcap,
        kv_steps=kv_steps)

    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, block_q, d),
                         lambda ib, ih, iq, ik: (ib, ih, iq, 0)),
            pl.BlockSpec((1, 1, block_k, d),
                         lambda ib, ih, iq, ik: (ib, ih // group, ik, 0)),
            pl.BlockSpec((1, 1, block_k, d),
                         lambda ib, ih, iq, ik: (ib, ih // group, ik, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, block_q, d),
                               lambda ib, ih, iq, ik: (ib, ih, iq, 0)),
        out_shape=jax.ShapeDtypeStruct((b, h, sq, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q, 1), jnp.float32),   # running max m
            pltpu.VMEM((block_q, 1), jnp.float32),   # running sum l
            pltpu.VMEM((block_q, d), jnp.float32),   # output accumulator
        ],
        interpret=interpret,
    )(q, k, v)
