"""Pallas TPU kernels for the workload's compute hot-spots.

flash_attention -- causal / sliding-window / softcap / GQA attention
ssd             -- Mamba-2 state-space-duality chunked scan
rglru           -- RG-LRU gated linear recurrence

Each has kernel.py (pl.pallas_call + BlockSpec), ops.py (jitted wrapper
with CPU interpret fallback), ref.py (pure-jnp oracle used by the tests).
"""
