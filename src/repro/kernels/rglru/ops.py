"""Jitted wrapper for the RG-LRU kernel (TPU: pallas; CPU: interpret)."""
from __future__ import annotations

import functools

import jax

from repro.kernels.rglru.kernel import rglru_scan_pallas
from repro.kernels.rglru.ref import rglru_ref


@functools.partial(jax.jit, static_argnames=("chunk", "block_w", "impl"))
def rglru_op(a, b, *, chunk: int = 256, block_w: int = 512,
             impl: str = "auto"):
    if impl == "auto":
        impl = "pallas" if jax.default_backend() == "tpu" else "interpret"
    if impl == "ref":
        return rglru_ref(a, b)
    return rglru_scan_pallas(a, b, chunk=chunk, block_w=block_w,
                             interpret=(impl == "interpret"))
