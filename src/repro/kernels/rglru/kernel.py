"""Pallas TPU kernel for the RG-LRU linear recurrence.

Grid ``(B, W/block_w, S/chunk)`` with the chunk dimension innermost
(sequential on TPU); the recurrent state (1, block_w) persists in VMEM
scratch.  Within a chunk the recurrence runs as a fori_loop of (1, block_w)
vector ops on the VPU — the width axis rides the 128-lane dimension, so a
block_w of 512 keeps 4 full vector registers busy per step while HBM
traffic stays at exactly 2 reads + 1 write per element (the roofline floor
for a gated scan).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _rglru_kernel(a_ref, b_ref, h_ref, state_scr, *, chunk: int):
    ic = pl.program_id(2)

    @pl.when(ic == 0)
    def _init():
        state_scr[...] = jnp.zeros_like(state_scr)

    def step(t, h):
        a_t = a_ref[0, t, :].astype(jnp.float32)
        b_t = b_ref[0, t, :].astype(jnp.float32)
        h = a_t * h + b_t
        h_ref[0, t, :] = h.astype(h_ref.dtype)
        return h

    h = jax.lax.fori_loop(0, chunk, step, state_scr[0])
    state_scr[0] = h


def rglru_scan_pallas(a, b, *, chunk: int = 256, block_w: int = 512,
                      interpret: bool = False):
    """a, b: (B, S, W) -> h: (B, S, W)."""
    bsz, s, w = a.shape
    chunk = min(chunk, s)
    block_w = min(block_w, w)
    assert s % chunk == 0 and w % block_w == 0
    grid = (bsz, w // block_w, s // chunk)
    kernel = functools.partial(_rglru_kernel, chunk=chunk)
    spec = pl.BlockSpec((1, chunk, block_w), lambda ib, iw, ic: (ib, ic, iw))
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[spec, spec],
        out_specs=spec,
        out_shape=jax.ShapeDtypeStruct((bsz, s, w), a.dtype),
        scratch_shapes=[pltpu.VMEM((1, block_w), jnp.float32)],
        interpret=interpret,
    )(a, b)
