"""Oracle for the RG-LRU kernel: naive sequential recurrence."""
from __future__ import annotations

import jax.numpy as jnp


def rglru_ref(a, b, h0=None):
    """h_t = a_t * h_{t-1} + b_t, step by step in fp32.

    a, b: (B, S, W); h0: (B, W) or None. Returns h: (B, S, W).
    """
    bsz, s, w = a.shape
    af = a.astype(jnp.float32)
    bf = b.astype(jnp.float32)
    h = jnp.zeros((bsz, w), jnp.float32) if h0 is None else \
        h0.astype(jnp.float32)
    hs = []
    for t in range(s):
        h = af[:, t] * h + bf[:, t]
        hs.append(h)
    return jnp.stack(hs, axis=1).astype(a.dtype)
