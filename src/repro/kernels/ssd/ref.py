"""Oracle for the SSD kernel: naive step-by-step SSM recurrence."""
from __future__ import annotations

import jax.numpy as jnp


def ssd_ref(x, dt, a_log, b, c):
    """Sequential scan oracle.

    x: (B,S,H,P); dt: (B,S,H) (already softplus'ed); a_log: (H,);
    b, c: (B,S,N).  Returns y: (B,S,H,P) with fp32 state:
        h_t = exp(dt_t * a) h_{t-1} + dt_t * B_t x_t ;  y_t = C_t . h_t
    """
    bsz, s, h, p = x.shape
    n = b.shape[-1]
    a = -jnp.exp(a_log.astype(jnp.float32))
    xf = x.astype(jnp.float32)
    dtf = dt.astype(jnp.float32)
    bf = b.astype(jnp.float32)
    cf = c.astype(jnp.float32)
    state = jnp.zeros((bsz, h, p, n), jnp.float32)
    ys = []
    for t in range(s):
        da = jnp.exp(dtf[:, t] * a[None, :])              # (B,H)
        bx = jnp.einsum("bn,bhp->bhpn", bf[:, t],
                        xf[:, t] * dtf[:, t][..., None])
        state = state * da[..., None, None] + bx
        ys.append(jnp.einsum("bn,bhpn->bhp", cf[:, t], state))
    return jnp.stack(ys, axis=1).astype(x.dtype)
