"""Jitted wrapper for the SSD kernel (TPU: pallas; CPU: interpret)."""
from __future__ import annotations

import functools

import jax

from repro.kernels.ssd.kernel import ssd_scan
from repro.kernels.ssd.ref import ssd_ref


@functools.partial(jax.jit, static_argnames=("chunk", "impl"))
def ssd_op(x, dt, a_log, b, c, *, chunk: int = 128, impl: str = "auto"):
    if impl == "auto":
        impl = "pallas" if jax.default_backend() == "tpu" else "interpret"
    if impl == "ref":
        return ssd_ref(x, dt, a_log, b, c)
    return ssd_scan(x, dt, a_log, b, c, chunk=chunk,
                    interpret=(impl == "interpret"))
