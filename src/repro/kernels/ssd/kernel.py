"""Pallas TPU kernel for the Mamba-2 SSD chunked scan.

Grid ``(B, H, S/chunk)`` with the chunk dimension innermost (sequential);
the inter-chunk SSM state (P, N) lives in VMEM scratch across chunk steps.
Each grid step computes the intra-chunk quadratic term (chunk x chunk decay
matrix on the MXU) plus the carried-state contribution, then updates the
state — the exact blocking of the SSD paper adapted to (8,128)-lane VMEM
tiles (chunk and N are multiples of 128 for full MXU utilization; P=64 head
dim rides the sublane axis).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _ssd_kernel(x_ref, dt_ref, alog_ref, b_ref, c_ref, y_ref, state_scr, *,
                chunk: int):
    ic = pl.program_id(2)

    @pl.when(ic == 0)
    def _init():
        state_scr[...] = jnp.zeros_like(state_scr)

    x = x_ref[0, :, 0, :].astype(jnp.float32)        # (q, P)
    dt = dt_ref[0, :, 0].astype(jnp.float32)         # (q,)
    b = b_ref[0].astype(jnp.float32)                 # (q, N)
    c = c_ref[0].astype(jnp.float32)                 # (q, N)
    a = -jnp.exp(alog_ref[0].astype(jnp.float32))    # scalar

    da = dt * a                                      # (q,)
    seg = jnp.cumsum(da)                             # (q,)
    total = seg[-1]
    xdt = x * dt[:, None]

    # intra-chunk: (C B^T ⊙ decay) X
    iq = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 0)
    ik = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 1)
    li = seg[:, None] - seg[None, :]
    decay = jnp.where(iq >= ik, jnp.exp(li), 0.0)
    cb = jax.lax.dot_general(c, b, (((1,), (1,)), ((), ())),
                             preferred_element_type=jnp.float32)
    y_intra = jax.lax.dot_general(cb * decay, xdt,
                                  (((1,), (0,)), ((), ())),
                                  preferred_element_type=jnp.float32)

    # inter-chunk: C h_in, with per-position decay from the chunk start
    state = state_scr[...]                           # (P, N)
    y_inter = jax.lax.dot_general(c, state, (((1,), (1,)), ((), ())),
                                  preferred_element_type=jnp.float32)
    y_inter = y_inter * jnp.exp(seg)[:, None]

    # state update: h_out = e^total h_in + B^T (X ⊙ rem)
    rem = jnp.exp(total - seg)                       # (q,)
    bx = jax.lax.dot_general(xdt * rem[:, None], b,
                             (((0,), (0,)), ((), ())),
                             preferred_element_type=jnp.float32)  # (P, N)
    state_scr[...] = state * jnp.exp(total) + bx

    y_ref[0, :, 0, :] = (y_intra + y_inter).astype(y_ref.dtype)


def ssd_scan(x, dt, a_log, b, c, *, chunk: int = 128,
             interpret: bool = False):
    """x: (B,S,H,P); dt: (B,S,H); a_log: (H,); b,c: (B,S,N) -> y (B,S,H,P)."""
    bsz, s, h, p = x.shape
    n = b.shape[-1]
    chunk = min(chunk, s)
    assert s % chunk == 0
    grid = (bsz, h, s // chunk)
    kernel = functools.partial(_ssd_kernel, chunk=chunk)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, chunk, 1, p), lambda ib, ih, ic: (ib, ic, ih, 0)),
            pl.BlockSpec((1, chunk, 1), lambda ib, ih, ic: (ib, ic, ih)),
            pl.BlockSpec((1,), lambda ib, ih, ic: (ih,)),
            pl.BlockSpec((1, chunk, n), lambda ib, ih, ic: (ib, ic, 0)),
            pl.BlockSpec((1, chunk, n), lambda ib, ih, ic: (ib, ic, 0)),
        ],
        out_specs=pl.BlockSpec((1, chunk, 1, p),
                               lambda ib, ih, ic: (ib, ic, ih, 0)),
        out_shape=jax.ShapeDtypeStruct((bsz, s, h, p), x.dtype),
        scratch_shapes=[pltpu.VMEM((p, n), jnp.float32)],
        interpret=interpret,
    )(x, dt, a_log, b, c)
