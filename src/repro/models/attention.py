"""Attention: GQA projections, exact-FLOPs chunked causal attention (XLA
path), local sliding windows, logit softcaps, qk-norm, and KV caches.

Implementation notes (TPU adaptation):

- The training/prefill XLA path is *chunked online-softmax* attention: the
  query axis is split into chunks (python-unrolled, so each chunk's key
  prefix is a static slice) and each chunk scans its key prefix with a
  running (max, sum, acc) — flash attention expressed in jnp.  This keeps
  the compiled HLO at the exact causal FLOP count (no wasted upper-triangle
  work) and O(chunk²) live memory, so the dry-run roofline reflects what a
  production TPU run would do.  On real TPUs the Pallas kernel
  (:mod:`repro.kernels.flash_attention`) replaces it via ``attn_impl``.
- Local (sliding-window) layers attend a static window around each query
  chunk; decode-time local layers use a **ring-buffer cache** of window
  size, which is what keeps hybrid archs O(window) at 500k tokens.
"""
from __future__ import annotations

import math
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp

from repro.models.layers import ParamSpec, apply_rope, rms_norm, softcap

NEG_INF = -2.0 ** 30


def attention_specs(cfg) -> Dict[str, Any]:
    e, h, kv, hd = cfg.d_model, cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    specs = {
        "wq": ParamSpec((e, h, hd), ("embed", "heads", "head_dim")),
        "wk": ParamSpec((e, kv, hd), ("embed", "kv_heads", "head_dim")),
        "wv": ParamSpec((e, kv, hd), ("embed", "kv_heads", "head_dim")),
        "wo": ParamSpec((h, hd, e), ("heads", "head_dim", "embed")),
    }
    if cfg.qk_norm:
        specs["q_norm"] = ParamSpec((hd,), ("head_dim",), init="zeros")
        specs["k_norm"] = ParamSpec((hd,), ("head_dim",), init="zeros")
    return specs


def cross_attention_specs(cfg) -> Dict[str, Any]:
    return attention_specs(cfg)


def _project_qkv(params, x, cfg, positions, rope: bool = True,
                 x_kv=None):
    dt = x.dtype
    x_kv = x if x_kv is None else x_kv
    q = jnp.einsum("bse,ehd->bshd", x, params["wq"].astype(dt))
    k = jnp.einsum("bse,ehd->bshd", x_kv, params["wk"].astype(dt))
    v = jnp.einsum("bse,ehd->bshd", x_kv, params["wv"].astype(dt))
    if cfg.qk_norm:
        q = rms_norm(q, params["q_norm"], cfg.norm_eps)
        k = rms_norm(k, params["k_norm"], cfg.norm_eps)
    if rope:
        q = apply_rope(q, positions, cfg.rope_theta)
        k = apply_rope(k, positions, cfg.rope_theta)
    return q, k, v


def _sdpa_chunk(q, k, v, mask, cfg, state=None):
    """Online-softmax update of one (q-chunk, kv-chunk) pair.

    q: (B, Sq, KV, G, D); k/v: (B, Sk, KV, D); mask: (Sq, Sk) or None.
    state: (m, l, acc) running max / normalizer / weighted accumulator.
    """
    scale = 1.0 / math.sqrt(q.shape[-1])
    logits = jnp.einsum("bqkgd,bskd->bkgqs", q, k).astype(jnp.float32) * scale
    logits = softcap(logits, cfg.attn_logit_softcap)
    if mask is not None:
        logits = jnp.where(mask[None, None, None], logits, NEG_INF)
    m_new = jnp.max(logits, axis=-1)                       # (B,KV,G,Sq)
    if state is not None:
        m_prev, l_prev, acc_prev = state
        m_new = jnp.maximum(m_prev, m_new)
    p = jnp.exp(logits - m_new[..., None])
    l_new = jnp.sum(p, axis=-1)
    pv = jnp.einsum("bkgqs,bskd->bkgqd", p.astype(q.dtype), v)
    if state is not None:
        corr = jnp.exp(m_prev - m_new)
        l_new = l_new + corr * l_prev
        pv = pv + corr[..., None].astype(q.dtype) * acc_prev
    return m_new, l_new, pv


def _finish(l, acc):
    return (acc / jnp.maximum(l, 1e-30)[..., None].astype(acc.dtype))


def chunked_attention(q, k, v, cfg, *, causal: bool, window: Optional[int]):
    """Exact-FLOPs chunked attention.

    q: (B, S, H, D) -> grouped (B, S, KV, G, D).  The query axis is python-
    unrolled in chunks; each chunk attends a *static* key slice (its causal
    prefix, or its sliding window), with an inner scan over kv chunks
    carrying the online-softmax state.
    """
    b, s, h, d = q.shape
    kvh = k.shape[2]
    g = h // kvh
    q = q.reshape(b, s, kvh, g, d)
    c = min(cfg.attn_chunk, s)
    while s % c:
        c //= 2
    n_chunks = s // c

    def one_chunk(q_i, k_slice, v_slice, i, lo, hi):
        """One query chunk against its static key slice.

        The kv loop is python-unrolled (not lax.scan) so every chunk-pair's
        FLOPs appear explicitly in the HLO — XLA's cost_analysis counts
        while-loop bodies only once, which would hide the causal-prefix
        work from the roofline.  Masks are only applied on the diagonal /
        window-edge pairs, so the compiled FLOP count is the exact causal
        cost.
        """
        q_pos = i * c + jnp.arange(c)
        n_kv = (hi - lo) // c
        state = None
        for j in range(n_kv):
            kk = k_slice[:, j * c:(j + 1) * c]
            vv = v_slice[:, j * c:(j + 1) * c]
            kv_lo = lo + j * c
            mask = None
            # mask only where the chunk-pair can be partially invalid:
            # the causal diagonal and the sliding-window edge.
            diag = causal and kv_lo + c > i * c
            edge = window is not None and kv_lo < i * c + c - window
            if diag or edge:
                kv_pos = kv_lo + jnp.arange(c)
                mask = jnp.ones((c, c), bool)
                if causal:
                    mask &= q_pos[:, None] >= kv_pos[None, :]
                if window is not None:
                    mask &= q_pos[:, None] - kv_pos[None, :] < window
            m, l, acc = _sdpa_chunk(q_i, kk, vv, mask, cfg, state)
            state = (m, l, acc)
        return _finish(state[1], state[2])

    # Remat each q-chunk: the backward pass recomputes the chunk's online
    # softmax instead of saving per-kv-step residuals — this is what keeps
    # the train-time activation footprint O(chunk^2), like the TPU kernel.
    one_chunk_ckpt = jax.checkpoint(
        one_chunk, policy=jax.checkpoint_policies.nothing_saveable,
        prevent_cse=False, static_argnums=(3, 4, 5))

    outs = []
    for i in range(n_chunks):
        q_i = q[:, i * c:(i + 1) * c]
        if causal:
            lo = 0 if window is None else max(0, (i * c + c) - window - c + 1)
            lo = (lo // c) * c                 # static prefix chunk start
            hi = (i + 1) * c
        else:
            lo, hi = 0, s
        fn = one_chunk_ckpt if n_chunks > 1 else one_chunk
        outs.append(fn(q_i, k[:, lo:hi], v[:, lo:hi], i, lo, hi))
    out = jnp.concatenate(outs, axis=-2) if len(outs) > 1 else outs[0]
    # (B,KV,G,S,D) -> (B,S,H,D)
    out = out.transpose(0, 3, 1, 2, 4).reshape(b, s, h, d)
    return out


# -- KV cache ------------------------------------------------------------------


def cache_specs(cfg, batch: int, length: int) -> Dict[str, Any]:
    kv, hd = cfg.num_kv_heads, cfg.head_dim
    return {
        "k": ParamSpec((batch, length, kv, hd),
                       ("batch", "kv_seq", "kv_heads", "head_dim"), "zeros"),
        "v": ParamSpec((batch, length, kv, hd),
                       ("batch", "kv_seq", "kv_heads", "head_dim"), "zeros"),
        "pos": ParamSpec((length,), ("kv_seq",), "zeros"),
    }


def init_cache(cfg, batch: int, length: int, dtype):
    kv, hd = cfg.num_kv_heads, cfg.head_dim
    return {"k": jnp.zeros((batch, length, kv, hd), dtype),
            "v": jnp.zeros((batch, length, kv, hd), dtype),
            "pos": jnp.full((length,), -1, jnp.int32)}


def decode_attention(params, x, cfg, cache, pos, *,
                     window: Optional[int] = None):
    """One-token decode: update cache at ``pos`` (ring-buffered for local
    windows) and attend over it.  x: (B, 1, E); pos: scalar int32."""
    b = x.shape[0]
    length = cache["k"].shape[1]
    positions = jnp.full((b, 1), pos, jnp.int32)
    q, k, v = _project_qkv(params, x, cfg, positions)
    slot = pos % length    # ring buffer (global caches: length == max_seq)
    k_cache = jax.lax.dynamic_update_slice_in_dim(cache["k"], k, slot, axis=1)
    v_cache = jax.lax.dynamic_update_slice_in_dim(cache["v"], v, slot, axis=1)
    pos_arr = jax.lax.dynamic_update_slice_in_dim(
        cache["pos"], jnp.full((1,), pos, jnp.int32), slot, axis=0)
    new_cache = {"k": k_cache, "v": v_cache, "pos": pos_arr}

    kvh, hd = k.shape[2], k.shape[3]
    g = cfg.num_heads // kvh
    qg = q.reshape(b, 1, kvh, g, hd)
    scale = 1.0 / math.sqrt(hd)
    logits = jnp.einsum("bqkgd,bskd->bkgqs", qg, k_cache).astype(jnp.float32)
    logits = softcap(logits * scale, cfg.attn_logit_softcap)
    valid = (pos_arr >= 0) & (pos_arr <= pos)
    if window is not None:
        valid &= pos_arr > pos - window
    logits = jnp.where(valid[None, None, None, None, :], logits, NEG_INF)
    p = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bkgqs,bskd->bkgqd", p.astype(x.dtype), v_cache)
    out = out.transpose(0, 3, 1, 2, 4).reshape(b, 1, cfg.num_heads, hd)
    y = jnp.einsum("bshd,hde->bse", out, params["wo"].astype(x.dtype))
    return y, new_cache


def attention_apply(params, x, cfg, *, kind: str = "global",
                    positions=None, x_kv=None, causal: bool = True):
    """Training/prefill attention.  kind: "global" | "local" | "cross"."""
    b, s, _ = x.shape
    if positions is None:
        positions = jnp.broadcast_to(jnp.arange(s), (b, s))
    rope = kind != "cross"
    q, k, v = _project_qkv(params, x, cfg, positions, rope=rope, x_kv=x_kv)
    window = cfg.sliding_window if kind == "local" else None
    out = chunked_attention(q, k, v, cfg,
                            causal=causal and kind != "cross",
                            window=window)
    return jnp.einsum("bshd,hde->bse", out, params["wo"].astype(x.dtype))


def attention_prefill(params, x, cfg, *, kind: str = "global",
                      cache_len: int):
    """Full-sequence attention that also returns the filled KV cache.

    Global layers keep all S positions (padded up to ``cache_len``); local
    layers keep the trailing ``window`` positions in ring-buffer order so
    that subsequent :func:`decode_attention` steps continue seamlessly.
    """
    b, s, _ = x.shape
    positions = jnp.broadcast_to(jnp.arange(s), (b, s))
    q, k, v = _project_qkv(params, x, cfg, positions)
    window = cfg.sliding_window if kind == "local" else None
    out = chunked_attention(q, k, v, cfg, causal=True, window=window)
    y = jnp.einsum("bshd,hde->bse", out, params["wo"].astype(x.dtype))

    if kind == "local":
        # ring buffer of the window size (this is what keeps hybrid archs
        # O(window) at 500k tokens); position p lives at slot p % w.
        w = min(window or cache_len, cache_len)
        m = min(s, w)
        slots = (jnp.arange(s - m, s) % w).astype(jnp.int32)
        kvh, hd = k.shape[2], k.shape[3]
        k_keep = jnp.zeros((b, w, kvh, hd), k.dtype).at[:, slots].set(
            k[:, s - m:])
        v_keep = jnp.zeros((b, w, kvh, hd), v.dtype).at[:, slots].set(
            v[:, s - m:])
        pos = jnp.full((w,), -1, jnp.int32).at[slots].set(
            jnp.arange(s - m, s, dtype=jnp.int32))
    else:
        length = cache_len
        pad = length - s
        k_keep = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v_keep = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
        pos = jnp.concatenate([jnp.arange(s, dtype=jnp.int32),
                               jnp.full((pad,), -1, jnp.int32)])
    return y, {"k": k_keep, "v": v_keep, "pos": pos}
