"""Model registry: config -> model instance + reduced smoke configs."""
from __future__ import annotations

import dataclasses

from repro.models.config import ModelConfig
from repro.models.encdec import EncDecLM
from repro.models.transformer import CausalLM


def build_model(cfg: ModelConfig):
    if cfg.family == "encdec":
        return EncDecLM(cfg)
    return CausalLM(cfg)


def get_model(name: str):
    from repro.configs import get_config      # lazy: configs import models
    cfg = get_config(name)
    return build_model(cfg), cfg


def list_archs():
    from repro.configs import list_archs as _la
    return _la()


def reduced_config(cfg: ModelConfig, *, layers: int = None,
                   vocab: int = 2048) -> ModelConfig:
    """Tiny same-family config for CPU smoke tests.

    Keeps the *structure* (pattern, GQA ratio, qk_norm, softcaps, MoE
    top-k, SSD/RG-LRU mixers, frontend) while shrinking width/depth/vocab.
    """
    n_pat = len(cfg.pattern)
    depth = layers if layers is not None else max(
        2 * n_pat, n_pat + cfg.first_dense_layers + 1)
    heads = max(min(cfg.num_heads, 4), 1) if cfg.num_heads else 0
    kv = max(1, heads // max(cfg.q_per_kv, 1)) if heads else 0
    updates = dict(
        num_layers=depth,
        d_model=128,
        num_heads=heads,
        num_kv_heads=kv,
        head_dim=32 if heads else 0,
        d_ff=256 if cfg.d_ff else 0,
        vocab_size=vocab,
        sliding_window=min(cfg.sliding_window, 64) if cfg.sliding_window
        else None,
        attn_chunk=64,
        remat="none",
    )
    if cfg.num_experts:
        updates.update(num_experts=min(cfg.num_experts, 8),
                       top_k=min(cfg.top_k, 2), expert_d_ff=64,
                       capacity_factor=8.0,
                       first_dense_ff=256 if cfg.first_dense_layers else 0)
    if cfg.family == "ssm":
        updates.update(ssm_state=16, ssm_head_dim=16, ssd_chunk=16)
    if cfg.lru_width:
        updates.update(lru_width=128)
    if cfg.enc_layers:
        updates.update(enc_layers=2)
    if cfg.frontend_tokens:
        updates.update(frontend_tokens=8)
    return dataclasses.replace(cfg, **updates)


__all__ = ["build_model", "get_model", "reduced_config", "list_archs"]
