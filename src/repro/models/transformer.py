"""Unified decoder-only LM over repeating block patterns.

A model is a *pattern* of block kinds — e.g. ``("global",)`` (llama-style),
``("local", "global")`` (gemma2), ``("rglru", "rglru", "local")``
(recurrentgemma), ``("ssd",)`` (mamba2), ``("moe",)`` — scanned over
``num_layers // len(pattern)`` repeats (plus an unscanned tail when the depth
is not a multiple).  Scanning keeps trace/compile time O(pattern), which is
what makes 80 dry-run compiles tractable, and the stacked parameter layout
["layers", ...] is what the elastic resharding engine moves between meshes.
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models import attention as attn
from repro.models import moe as moe_mod
from repro.models import rglru as rglru_mod
from repro.models import ssm as ssm_mod
from repro.core.sharding import constrain
from repro.models.config import ModelConfig
from repro.models.layers import (ParamSpec, embed_apply, embed_specs,
                                 init_from_specs, is_spec, logical_tree,
                                 mlp_apply, mlp_specs, rms_norm,
                                 unembed_apply)

ATTN_KINDS = ("global", "local")


def stack_specs(specs, n: int):
    return jax.tree.map(
        lambda s: ParamSpec((n,) + s.shape, ("layers",) + s.logical,
                            s.init, s.scale),
        specs, is_leaf=is_spec)


# -- block definitions -------------------------------------------------------


def block_specs(cfg: ModelConfig, kind: str, dense_ff: Optional[int] = None
                ) -> Dict[str, Any]:
    e = cfg.d_model
    norm = lambda: ParamSpec((e,), ("embed",), "zeros")  # noqa: E731
    if kind in ATTN_KINDS:
        specs = {"ln1": norm(), "attn": attn.attention_specs(cfg),
                 "ln2": norm()}
        if cfg.family == "moe" and dense_ff is None:
            specs["ffn"] = moe_mod.moe_specs(cfg)
        else:
            specs["ffn"] = mlp_specs(cfg, d_ff=dense_ff)
        return specs
    if kind == "moe":
        return {"ln1": norm(), "attn": attn.attention_specs(cfg),
                "ln2": norm(),
                "ffn": (mlp_specs(cfg, d_ff=dense_ff) if dense_ff
                        else moe_mod.moe_specs(cfg))}
    if kind == "ssd":
        return {"ln1": norm(), "mixer": ssm_mod.ssd_specs(cfg)}
    if kind == "rglru":
        return {"ln1": norm(), "mixer": rglru_mod.rglru_specs(cfg),
                "ln2": norm(), "ffn": mlp_specs(cfg)}
    raise ValueError(kind)


def block_apply(params, x, cfg: ModelConfig, kind: str, aux):
    """One block, training/prefill path (full sequence)."""
    x = constrain(x, ("batch", "seq", "embed"))
    if kind in ("global", "local", "moe"):
        a_kind = "local" if kind == "local" else "global"
        h = rms_norm(x, params["ln1"], cfg.norm_eps)
        x = x + attn.attention_apply(params["attn"], h, cfg, kind=a_kind)
        h = rms_norm(x, params["ln2"], cfg.norm_eps)
        if "router" in params["ffn"]:
            y, a = moe_mod.moe_apply(params["ffn"], h, cfg)
            aux = aux + a
        else:
            y = mlp_apply(params["ffn"], h, cfg)
        return x + y, aux
    if kind == "ssd":
        h = rms_norm(x, params["ln1"], cfg.norm_eps)
        return x + ssm_mod.ssd_apply(params["mixer"], h, cfg), aux
    if kind == "rglru":
        h = rms_norm(x, params["ln1"], cfg.norm_eps)
        x = x + rglru_mod.rglru_mixer_apply(params["mixer"], h, cfg)
        h = rms_norm(x, params["ln2"], cfg.norm_eps)
        return x + mlp_apply(params["ffn"], h, cfg), aux
    raise ValueError(kind)


# -- block caches -------------------------------------------------------------


def block_cache_specs(cfg, kind: str, batch: int, max_len: int):
    if kind in ("global", "moe"):
        return attn.cache_specs(cfg, batch, max_len)
    if kind == "local":
        w = min(cfg.sliding_window or max_len, max_len)
        return attn.cache_specs(cfg, batch, w)
    if kind == "ssd":
        return ssm_mod.ssd_cache_specs(cfg, batch)
    if kind == "rglru":
        return rglru_mod.rglru_cache_specs(cfg, batch)
    raise ValueError(kind)


def block_decode(params, x, cfg, kind: str, cache, pos):
    x = constrain(x, ("batch", "seq", "embed"))
    if kind in ("global", "local", "moe"):
        h = rms_norm(x, params["ln1"], cfg.norm_eps)
        window = cfg.sliding_window if kind == "local" else None
        y, cache = attn.decode_attention(params["attn"], h, cfg, cache, pos,
                                         window=window)
        x = x + y
        h = rms_norm(x, params["ln2"], cfg.norm_eps)
        if "router" in params["ffn"]:
            y, _ = moe_mod.moe_apply(params["ffn"], h, cfg,
                                     capacity_factor=float(cfg.top_k))
        else:
            y = mlp_apply(params["ffn"], h, cfg)
        return x + y, cache
    if kind == "ssd":
        h = rms_norm(x, params["ln1"], cfg.norm_eps)
        y, cache = ssm_mod.ssd_decode(params["mixer"], h, cfg, cache)
        return x + y, cache
    if kind == "rglru":
        h = rms_norm(x, params["ln1"], cfg.norm_eps)
        y, cache = rglru_mod.rglru_decode(params["mixer"], h, cfg, cache)
        x = x + y
        h = rms_norm(x, params["ln2"], cfg.norm_eps)
        return x + mlp_apply(params["ffn"], h, cfg), cache
    raise ValueError(kind)


def block_prefill(params, x, cfg, kind: str, max_len: int):
    """Full-sequence forward that also fills the block cache."""
    x = constrain(x, ("batch", "seq", "embed"))
    if kind in ("global", "local", "moe"):
        a_kind = "local" if kind == "local" else "global"
        h = rms_norm(x, params["ln1"], cfg.norm_eps)
        y, cache = attn.attention_prefill(params["attn"], h, cfg,
                                          kind=a_kind, cache_len=max_len)
        x = x + y
        h = rms_norm(x, params["ln2"], cfg.norm_eps)
        if "router" in params["ffn"]:
            y, _ = moe_mod.moe_apply(params["ffn"], h, cfg)
        else:
            y = mlp_apply(params["ffn"], h, cfg)
        return x + y, cache
    if kind == "ssd":
        h = rms_norm(x, params["ln1"], cfg.norm_eps)
        y, cache = ssm_mod.ssd_prefill(params["mixer"], h, cfg)
        return x + y, cache
    if kind == "rglru":
        h = rms_norm(x, params["ln1"], cfg.norm_eps)
        y, cache = rglru_mod.rglru_prefill(params["mixer"], h, cfg)
        x = x + y
        h = rms_norm(x, params["ln2"], cfg.norm_eps)
        return x + mlp_apply(params["ffn"], h, cfg), cache
    raise ValueError(kind)


# -- the model -----------------------------------------------------------------


class CausalLM:
    """Decoder-only LM (all non-encdec families)."""

    def __init__(self, cfg: ModelConfig):
        self.cfg = cfg

    # ---- parameters ----

    def specs(self) -> Dict[str, Any]:
        cfg = self.cfg
        reps, tail = self._pattern_layout()
        specs: Dict[str, Any] = {"embed": embed_specs(cfg)}
        for i in range(cfg.first_dense_layers):
            specs[f"head{i}"] = block_specs(
                cfg, cfg.pattern[0],
                dense_ff=cfg.first_dense_ff or cfg.d_ff)
        if reps > 0:
            unit = {f"p{j}": block_specs(cfg, kind)
                    for j, kind in enumerate(cfg.pattern)}
            specs["blocks"] = stack_specs(unit, reps)
        for t in range(tail):
            specs[f"tail{t}"] = block_specs(cfg, cfg.pattern[t])
        specs["final_norm"] = ParamSpec((cfg.d_model,), ("embed",), "zeros")
        return specs

    def _pattern_layout(self) -> Tuple[int, int]:
        cfg = self.cfg
        n = cfg.num_layers - cfg.first_dense_layers
        return n // len(cfg.pattern), n % len(cfg.pattern)

    def init(self, key) -> Dict[str, Any]:
        return init_from_specs(key, self.specs(),
                               jnp.dtype(self.cfg.param_dtype))

    def logical(self):
        return logical_tree(self.specs())

    # ---- forward (training / prefill trunk) ----

    def _trunk(self, params, x):
        cfg = self.cfg
        reps, tail = self._pattern_layout()
        aux = jnp.zeros((), jnp.float32)
        for i in range(cfg.first_dense_layers):
            x, aux = block_apply(params[f"head{i}"], x, cfg,
                                 cfg.pattern[0], aux)
        if reps > 0:
            def unit(carry, unit_params):
                x, aux = carry
                for j, kind in enumerate(cfg.pattern):
                    x, aux = block_apply(unit_params[f"p{j}"], x, cfg,
                                         kind, aux)
                return (x, aux), None
            if cfg.remat != "none":
                policy = (jax.checkpoint_policies.nothing_saveable
                          if cfg.remat == "nothing_saveable" else
                          jax.checkpoint_policies.checkpoint_dots)
                unit = jax.checkpoint(unit, policy=policy,
                                      prevent_cse=False)
            (x, aux), _ = jax.lax.scan(unit, (x, aux), params["blocks"])
        for t in range(tail):
            x, aux = block_apply(params[f"tail{t}"], x, cfg,
                                 cfg.pattern[t], aux)
        return rms_norm(x, params["final_norm"], cfg.norm_eps), aux

    def forward(self, params, tokens, extra_embeds=None):
        """tokens: (B, S_text). extra_embeds: (B, S_front, E) modality stub
        prepended to the sequence (VLM patches / audio frames)."""
        cfg = self.cfg
        x = embed_apply(params["embed"], tokens, cfg)
        if extra_embeds is not None:
            x = jnp.concatenate([extra_embeds.astype(x.dtype), x], axis=1)
        x = constrain(x, ("batch", "seq", "embed"))
        x, aux = self._trunk(params, x)
        logits = unembed_apply(params["embed"], x, cfg)
        return constrain(logits, ("batch", "seq", "vocab")), aux

    def loss(self, params, batch):
        """batch: tokens (B,S), labels (B,S) [-1 = masked], optional
        frontend embeds."""
        cfg = self.cfg
        labels = batch["labels"]
        mask = (labels >= 0)
        labels = jnp.maximum(labels, 0)
        denom = jnp.maximum(mask.sum(), 1)

        if cfg.ce_chunk:
            # chunked CE: run the trunk once, then unembed + log-softmax
            # per sequence chunk — the (B, S, V) logits never materialize.
            x = embed_apply(params["embed"], batch["tokens"], cfg)
            fr = batch.get("frontend")
            if fr is not None:
                x = jnp.concatenate([fr.astype(x.dtype), x], axis=1)
            x = constrain(x, ("batch", "seq", "embed"))
            x, aux = self._trunk(params, x)
            n_front = fr.shape[1] if fr is not None else 0
            x = x[:, n_front:]
            s = x.shape[1]
            c = cfg.ce_chunk
            total = jnp.zeros((), jnp.float32)
            for i in range(0, s, c):
                lg = unembed_apply(params["embed"], x[:, i:i + c], cfg)
                lp = jax.nn.log_softmax(lg.astype(jnp.float32), axis=-1)
                ll = jnp.take_along_axis(
                    lp, labels[:, i:i + c, None], axis=-1)[..., 0]
                total = total + (ll * mask[:, i:i + c]).sum()
            loss = -total / denom
            return loss + aux, {"ce": loss, "aux": aux}

        logits, aux = self.forward(params, batch["tokens"],
                                   batch.get("frontend"))
        if batch.get("frontend") is not None:
            # frontend positions carry no labels
            n_front = batch["frontend"].shape[1]
            logits = logits[:, n_front:]
        logp = jax.nn.log_softmax(logits.astype(jnp.float32), axis=-1)
        ll = jnp.take_along_axis(logp, labels[..., None], axis=-1)[..., 0]
        loss = -(ll * mask).sum() / denom
        return loss + aux, {"ce": loss, "aux": aux}

    # ---- serving ----

    def cache_specs(self, batch: int, max_len: int) -> Dict[str, Any]:
        cfg = self.cfg
        reps, tail = self._pattern_layout()
        out: Dict[str, Any] = {}
        for i in range(cfg.first_dense_layers):
            out[f"head{i}"] = block_cache_specs(cfg, cfg.pattern[0],
                                                batch, max_len)
        if reps > 0:
            unit = {f"p{j}": block_cache_specs(cfg, kind, batch, max_len)
                    for j, kind in enumerate(cfg.pattern)}
            out["blocks"] = stack_specs(unit, reps)
        for t in range(tail):
            out[f"tail{t}"] = block_cache_specs(cfg, cfg.pattern[t],
                                                batch, max_len)
        return out

    def init_cache(self, batch: int, max_len: int):
        cfg = self.cfg
        dtype = jnp.dtype(cfg.dtype)
        specs = self.cache_specs(batch, max_len)

        def build(path, spec):
            name = path[-1].key if hasattr(path[-1], "key") else ""
            if name == "pos":
                return jnp.full(spec.shape, -1, jnp.int32)
            if name in ("state", "h"):
                return jnp.zeros(spec.shape, jnp.float32)
            return jnp.zeros(spec.shape, dtype)
        return jax.tree_util.tree_map_with_path(build, specs,
                                                is_leaf=is_spec)

    def prefill(self, params, tokens, max_len: int, extra_embeds=None):
        """Run the full prompt, returning (last-position logits, cache)."""
        cfg = self.cfg
        x = embed_apply(params["embed"], tokens, cfg)
        if extra_embeds is not None:
            x = jnp.concatenate([extra_embeds.astype(x.dtype), x], axis=1)
        cache: Dict[str, Any] = {}
        for i in range(cfg.first_dense_layers):
            x, cache[f"head{i}"] = block_prefill(
                params[f"head{i}"], x, cfg, cfg.pattern[0], max_len)
        reps, tail = self._pattern_layout()
        if reps > 0:
            def unit(x, unit_params):
                caches = {}
                for j, kind in enumerate(cfg.pattern):
                    x, caches[f"p{j}"] = block_prefill(
                        unit_params[f"p{j}"], x, cfg, kind, max_len)
                return x, caches
            x, cache["blocks"] = jax.lax.scan(unit, x, params["blocks"])
        for t in range(tail):
            x, cache[f"tail{t}"] = block_prefill(
                params[f"tail{t}"], x, cfg, cfg.pattern[t], max_len)
        x = rms_norm(x, params["final_norm"], cfg.norm_eps)
        logits = unembed_apply(params["embed"], x[:, -1:], cfg)
        return logits, cache

    def decode_step(self, params, cache, token, pos):
        """token: (B, 1) int32; pos: scalar int32. Returns (logits, cache)."""
        cfg = self.cfg
        x = embed_apply(params["embed"], token, cfg)
        for i in range(cfg.first_dense_layers):
            x, cache[f"head{i}"] = block_decode(
                params[f"head{i}"], x, cfg, cfg.pattern[0],
                cache[f"head{i}"], pos)
        reps, tail = self._pattern_layout()
        if reps > 0:
            def unit(x, inp):
                unit_params, unit_cache = inp
                new_cache = {}
                for j, kind in enumerate(cfg.pattern):
                    x, new_cache[f"p{j}"] = block_decode(
                        unit_params[f"p{j}"], x, cfg, kind,
                        unit_cache[f"p{j}"], pos)
                return x, new_cache
            x, cache["blocks"] = jax.lax.scan(
                unit, x, (params["blocks"], cache["blocks"]))
        for t in range(tail):
            x, cache[f"tail{t}"] = block_decode(
                params[f"tail{t}"], x, cfg, cfg.pattern[t],
                cache[f"tail{t}"], pos)
        x = rms_norm(x, params["final_norm"], cfg.norm_eps)
        logits = unembed_apply(params["embed"], x, cfg)
        return logits, cache
