"""Mixture-of-Experts FFN: top-k routing, capacity dispatch, shared experts.

TPU adaptation: experts are stacked ``(E, D, F)`` and sharded over the
``model`` mesh axis (expert parallelism rides the existing TP axis).
Activations are sharded over ``batch`` (data axes) and *replicated* over
``model``, so the capacity gather/scatter is local to each device and the
only communication is the same reduction TP already pays at the block
output — no all-to-all.  Routing is per batch row (group) with capacity
``C = ceil(S * k / E * capacity_factor)``; overflow tokens drop to the
residual path (standard Switch behaviour).

Supports DeepSeekMoE fine-grained layout (64 routed top-6 + 2 shared
experts, first layer dense) and Phi-3.5-MoE (16 routed top-2).
"""
from __future__ import annotations

import math
from typing import Any, Dict

import jax
import jax.numpy as jnp

from repro.models.layers import ParamSpec, act_fn, mlp_apply, mlp_specs


def moe_specs(cfg) -> Dict[str, Any]:
    e, f, ne = cfg.d_model, cfg.expert_d_ff or cfg.d_ff, cfg.num_experts
    specs = {
        "router": ParamSpec((e, ne), ("embed", "experts")),
        "w_gate": ParamSpec((ne, e, f), ("experts", "embed", "expert_mlp")),
        "w_up": ParamSpec((ne, e, f), ("experts", "embed", "expert_mlp")),
        "w_down": ParamSpec((ne, f, e), ("experts", "expert_mlp", "embed")),
    }
    if cfg.num_shared_experts:
        specs["shared"] = mlp_specs(
            cfg, d_ff=cfg.num_shared_experts * (cfg.expert_d_ff or cfg.d_ff))
    return specs


def capacity(cfg, seq: int, factor: float = 1.25) -> int:
    c = math.ceil(seq * cfg.top_k / cfg.num_experts * factor)
    return max(8, min(c, seq))


def moe_apply(params, x, cfg, capacity_factor: float = None):
    """x: (B, S, E) -> (y, aux_loss)."""
    bsz, s, d = x.shape
    ne, k = cfg.num_experts, cfg.top_k
    if capacity_factor is None:
        capacity_factor = cfg.capacity_factor
    cap = capacity(cfg, s, capacity_factor)
    dt = x.dtype

    logits = (x @ params["router"].astype(dt)).astype(jnp.float32)
    probs = jax.nn.softmax(logits, axis=-1)                   # (B,S,E)
    weights, experts = jax.lax.top_k(probs, k)                # (B,S,k)
    weights = weights / jnp.sum(weights, -1, keepdims=True)   # renormalize

    # Load-balancing auxiliary loss (Switch): E * sum_e f_e * P_e
    one_hot_all = jax.nn.one_hot(experts, ne, dtype=jnp.float32)  # (B,S,k,E)
    f_e = one_hot_all.sum(axis=2).mean(axis=(0, 1))           # fraction routed
    p_e = probs.mean(axis=(0, 1))
    aux = cfg.router_aux_coef * ne * jnp.sum(f_e * p_e)

    # ---- capacity dispatch (per batch row; gathers stay device-local) ----
    flat_e = experts.reshape(bsz, s * k)                      # (B,S*k)
    flat_w = weights.reshape(bsz, s * k).astype(dt)
    choice_oh = jax.nn.one_hot(flat_e, ne, dtype=jnp.int32)   # (B,S*k,E)
    pos = jnp.cumsum(choice_oh, axis=1) - 1                   # pos within expert
    my_pos = jnp.take_along_axis(pos, flat_e[..., None],
                                 axis=-1)[..., 0]             # (B,S*k)
    keep = my_pos < cap
    slot = jnp.where(keep, flat_e * cap + my_pos, ne * cap)   # overflow slot
    token_of_choice = jnp.broadcast_to(
        (jnp.arange(s * k) // k)[None, :], (bsz, s * k))

    # dispatch index buffer: slot -> token id (sentinel s for empty)
    disp = jnp.full((bsz, ne * cap + 1), s, jnp.int32)
    disp = jax.vmap(lambda d_, sl, tok: d_.at[sl].set(tok, mode="drop"))(
        disp, slot, token_of_choice.astype(jnp.int32))
    disp_w = jnp.zeros((bsz, ne * cap + 1), dt)
    disp_w = jax.vmap(lambda d_, sl, w_: d_.at[sl].set(w_, mode="drop"))(
        disp_w, slot, flat_w)
    disp, disp_w = disp[:, :-1], disp_w[:, :-1]

    x_pad = jnp.concatenate([x, jnp.zeros((bsz, 1, d), dt)], axis=1)
    expert_in = jnp.take_along_axis(
        x_pad, disp[..., None], axis=1).reshape(bsz, ne, cap, d)

    act = act_fn(cfg.act)
    h = act(jnp.einsum("becd,edf->becf", expert_in,
                       params["w_gate"].astype(dt))) * \
        jnp.einsum("becd,edf->becf", expert_in, params["w_up"].astype(dt))
    expert_out = jnp.einsum("becf,efd->becd", h, params["w_down"].astype(dt))
    expert_out = expert_out.reshape(bsz, ne * cap, d) * disp_w[..., None]

    y = jnp.zeros((bsz, s + 1, d), dt)
    y = jax.vmap(lambda y_, idx, val: y_.at[idx].add(val, mode="drop"))(
        y, disp, expert_out)[:, :-1]

    if cfg.num_shared_experts:
        y = y + mlp_apply(params["shared"], x, cfg)
    return y, aux
