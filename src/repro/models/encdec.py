"""Encoder-decoder LM (seamless-m4t backbone: speech/text enc -> text dec).

The modality frontend is a stub: ``input_specs`` supplies precomputed frame
embeddings (B, S_enc, E).  Encoder = bidirectional attention blocks; decoder
= causal self-attention + cross-attention + MLP.  Decode keeps a self-
attention KV cache plus a precomputed cross-attention KV (from the encoder
output), as a production seq2seq server would.
"""
from __future__ import annotations

from typing import Any, Dict

import jax
import jax.numpy as jnp

from repro.core.sharding import constrain
from repro.models import attention as attn
from repro.models.config import ModelConfig
from repro.models.layers import (ParamSpec, embed_apply, embed_specs,
                                 init_from_specs, logical_tree, mlp_apply,
                                 mlp_specs, rms_norm, unembed_apply)
from repro.models.transformer import stack_specs


def _norm(cfg):
    return ParamSpec((cfg.d_model,), ("embed",), "zeros")


def enc_block_specs(cfg) -> Dict[str, Any]:
    return {"ln1": _norm(cfg), "attn": attn.attention_specs(cfg),
            "ln2": _norm(cfg), "ffn": mlp_specs(cfg)}


def dec_block_specs(cfg) -> Dict[str, Any]:
    return {"ln1": _norm(cfg), "self_attn": attn.attention_specs(cfg),
            "ln_x": _norm(cfg), "cross_attn": attn.cross_attention_specs(cfg),
            "ln2": _norm(cfg), "ffn": mlp_specs(cfg)}


def enc_block_apply(params, x, cfg):
    x = constrain(x, ("batch", "seq", "embed"))
    h = rms_norm(x, params["ln1"], cfg.norm_eps)
    x = x + attn.attention_apply(params["attn"], h, cfg, kind="global",
                                 causal=False)
    h = rms_norm(x, params["ln2"], cfg.norm_eps)
    return x + mlp_apply(params["ffn"], h, cfg)


def dec_block_apply(params, x, enc_out, cfg):
    x = constrain(x, ("batch", "seq", "embed"))
    h = rms_norm(x, params["ln1"], cfg.norm_eps)
    x = x + attn.attention_apply(params["self_attn"], h, cfg, kind="global")
    h = rms_norm(x, params["ln_x"], cfg.norm_eps)
    x = x + attn.attention_apply(params["cross_attn"], h, cfg, kind="cross",
                                 x_kv=enc_out)
    h = rms_norm(x, params["ln2"], cfg.norm_eps)
    return x + mlp_apply(params["ffn"], h, cfg)


class EncDecLM:
    def __init__(self, cfg: ModelConfig):
        assert cfg.enc_layers > 0
        self.cfg = cfg

    def specs(self) -> Dict[str, Any]:
        cfg = self.cfg
        return {
            "embed": embed_specs(cfg),
            "enc_in": ParamSpec((cfg.d_model, cfg.d_model),
                                ("frontend", "embed")),
            "enc_blocks": stack_specs(enc_block_specs(cfg), cfg.enc_layers),
            "enc_norm": _norm(cfg),
            "dec_blocks": stack_specs(dec_block_specs(cfg), cfg.num_layers),
            "final_norm": _norm(cfg),
        }

    def init(self, key):
        return init_from_specs(key, self.specs(),
                               jnp.dtype(self.cfg.param_dtype))

    def logical(self):
        return logical_tree(self.specs())

    def encode(self, params, frames):
        """frames: (B, S_enc, E) stub frontend embeddings."""
        cfg = self.cfg
        x = (frames.astype(jnp.dtype(cfg.dtype))
             @ params["enc_in"].astype(jnp.dtype(cfg.dtype)))

        def body(x, blk):
            return enc_block_apply(blk, x, cfg), None
        if cfg.remat != "none":
            body = jax.checkpoint(body, prevent_cse=False)
        x, _ = jax.lax.scan(body, x, params["enc_blocks"])
        return rms_norm(x, params["enc_norm"], cfg.norm_eps)

    def forward(self, params, frames, tokens):
        cfg = self.cfg
        enc_out = self.encode(params, frames)
        x = embed_apply(params["embed"], tokens, cfg)

        def body(x, blk):
            return dec_block_apply(blk, x, enc_out, cfg), None
        if cfg.remat != "none":
            body = jax.checkpoint(body, prevent_cse=False)
        x, _ = jax.lax.scan(body, x, params["dec_blocks"])
        x = rms_norm(x, params["final_norm"], cfg.norm_eps)
        return unembed_apply(params["embed"], x, cfg), jnp.zeros(
            (), jnp.float32)

    def loss(self, params, batch):
        logits, aux = self.forward(params, batch["frontend"],
                                   batch["tokens"])
        labels = batch["labels"]
        mask = labels >= 0
        labels = jnp.maximum(labels, 0)
        logp = jax.nn.log_softmax(logits.astype(jnp.float32), axis=-1)
        ll = jnp.take_along_axis(logp, labels[..., None], axis=-1)[..., 0]
        loss = -(ll * mask).sum() / jnp.maximum(mask.sum(), 1)
        return loss, {"ce": loss, "aux": aux}

    # ---- serving ----

    def cache_specs(self, batch: int, max_len: int) -> Dict[str, Any]:
        cfg = self.cfg
        kv, hd = cfg.num_kv_heads, cfg.head_dim
        unit = {
            "self": attn.cache_specs(cfg, batch, max_len),
            "cross_k": ParamSpec((batch, max_len, kv, hd),
                                 ("batch", "kv_seq", "kv_heads", "head_dim"),
                                 "zeros"),
            "cross_v": ParamSpec((batch, max_len, kv, hd),
                                 ("batch", "kv_seq", "kv_heads", "head_dim"),
                                 "zeros"),
        }
        return {"dec_blocks": stack_specs(unit, cfg.num_layers)}

    def prefill(self, params, frames, tokens, max_len: int):
        """Encode + run the decoder prompt, producing decode caches."""
        cfg = self.cfg
        enc_out = self.encode(params, frames)
        x = embed_apply(params["embed"], tokens, cfg)

        def body(x, blk):
            h = rms_norm(x, blk["ln1"], cfg.norm_eps)
            y, self_cache = attn.attention_prefill(
                blk["self_attn"], h, cfg, kind="global", cache_len=max_len)
            x = x + y
            h = rms_norm(x, blk["ln_x"], cfg.norm_eps)
            dt = x.dtype
            ck = jnp.einsum("bse,ehd->bshd", enc_out,
                            blk["cross_attn"]["wk"].astype(dt))
            cv = jnp.einsum("bse,ehd->bshd", enc_out,
                            blk["cross_attn"]["wv"].astype(dt))
            x = x + attn.attention_apply(blk["cross_attn"], h, cfg,
                                         kind="cross", x_kv=enc_out)
            h = rms_norm(x, blk["ln2"], cfg.norm_eps)
            x = x + mlp_apply(blk["ffn"], h, cfg)
            return x, {"self": self_cache, "cross_k": ck, "cross_v": cv}

        x, caches = jax.lax.scan(body, x, params["dec_blocks"])
        x = rms_norm(x, params["final_norm"], cfg.norm_eps)
        logits = unembed_apply(params["embed"], x[:, -1:], cfg)
        return logits, {"dec_blocks": caches}

    def decode_step(self, params, cache, token, pos):
        cfg = self.cfg
        x = embed_apply(params["embed"], token, cfg)

        def body(x, inp):
            blk, c = inp
            h = rms_norm(x, blk["ln1"], cfg.norm_eps)
            y, self_cache = attn.decode_attention(blk["self_attn"], h, cfg,
                                                  c["self"], pos)
            x = x + y
            h = rms_norm(x, blk["ln_x"], cfg.norm_eps)
            x = x + _cross_decode(blk["cross_attn"], h, cfg,
                                  c["cross_k"], c["cross_v"])
            h = rms_norm(x, blk["ln2"], cfg.norm_eps)
            x = x + mlp_apply(blk["ffn"], h, cfg)
            return x, {"self": self_cache, "cross_k": c["cross_k"],
                       "cross_v": c["cross_v"]}

        x, caches = jax.lax.scan(body, x, (params["dec_blocks"],
                                           cache["dec_blocks"]))
        x = rms_norm(x, params["final_norm"], cfg.norm_eps)
        logits = unembed_apply(params["embed"], x, cfg)
        return logits, {"dec_blocks": caches}


def _cross_decode(params, x, cfg, ck, cv):
    """Single-query cross attention over precomputed encoder KV."""
    import math
    b = x.shape[0]
    dt = x.dtype
    q = jnp.einsum("bse,ehd->bshd", x, params["wq"].astype(dt))
    kvh, hd = ck.shape[2], ck.shape[3]
    g = cfg.num_heads // kvh
    qg = q.reshape(b, 1, kvh, g, hd)
    logits = jnp.einsum("bqkgd,bskd->bkgqs", qg, ck).astype(jnp.float32)
    p = jax.nn.softmax(logits / math.sqrt(hd), axis=-1)
    out = jnp.einsum("bkgqs,bskd->bkgqd", p.astype(dt), cv)
    out = out.transpose(0, 3, 1, 2, 4).reshape(b, 1, cfg.num_heads, hd)
    return jnp.einsum("bshd,hde->bse", out, params["wo"].astype(dt))
