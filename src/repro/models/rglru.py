"""RG-LRU recurrent block (RecurrentGemma / Griffin — arXiv:2402.19427).

Real-Gated Linear Recurrent Unit:
    r_t = sigmoid(W_a x_t + b_a)            (recurrence gate)
    i_t = sigmoid(W_x x_t + b_x)            (input gate)
    log a_t = -c * r_t * softplus(Lambda)   (c = 8)
    h_t = a_t * h_{t-1} + sqrt(1 - a_t^2) * (i_t * x_t)

The temporal mix is: linear in, causal conv1d (width 4), RG-LRU, gated by a
GeLU branch, linear out.  Training/prefill uses ``jax.lax.associative_scan``
over time (log-depth, parallel); the Pallas kernel
(:mod:`repro.kernels.rglru`) implements the same recurrence with chunked
VMEM tiles for TPU.
"""
from __future__ import annotations

from typing import Any, Dict

import jax
import jax.numpy as jnp

from repro.models.layers import ParamSpec

C_GATE = 8.0


def rglru_specs(cfg) -> Dict[str, Any]:
    e = cfg.d_model
    w = cfg.lru_width or e
    return {
        "in_proj": ParamSpec((e, 2 * w), ("embed", "mlp")),      # x, gate
        "conv_w": ParamSpec((cfg.conv_width, w), ((), "mlp")),
        "conv_b": ParamSpec((w,), ("mlp",), "zeros"),
        "w_a": ParamSpec((w, w), ("mlp", "state")),
        "b_a": ParamSpec((w,), ("state",), "zeros"),
        "w_x": ParamSpec((w, w), ("mlp", "state")),
        "b_x": ParamSpec((w,), ("state",), "zeros"),
        "lam": ParamSpec((w,), ("state",), "lru_a"),
        "out_proj": ParamSpec((w, e), ("mlp", "embed")),
    }


def _gates(params, x):
    """log_a: (B,S,W) fp32; gated input (B,S,W) fp32."""
    r = jax.nn.sigmoid((x @ params["w_a"].astype(x.dtype)
                        + params["b_a"].astype(x.dtype)).astype(jnp.float32))
    i = jax.nn.sigmoid((x @ params["w_x"].astype(x.dtype)
                        + params["b_x"].astype(x.dtype)).astype(jnp.float32))
    log_a = -C_GATE * r * jax.nn.softplus(params["lam"].astype(jnp.float32))
    a = jnp.exp(log_a)
    beta = jnp.sqrt(jnp.maximum(1.0 - jnp.exp(2.0 * log_a), 1e-12))
    b = beta * (i * x.astype(jnp.float32))
    return a, b


def rglru_scan(a, b):
    """h_t = a_t h_{t-1} + b_t via associative scan over axis 1."""
    def combine(l, r):
        al, bl = l
        ar, br = r
        return al * ar, bl * ar + br
    _, h = jax.lax.associative_scan(combine, (a, b), axis=1)
    return h


def _mixer(params, x, cfg, want_cache: bool):
    proj = x @ params["in_proj"].astype(x.dtype)
    w = cfg.lru_width or cfg.d_model
    xb, gate = jnp.split(proj, [w], axis=-1)
    k = params["conv_w"].shape[0]
    pad = jnp.pad(xb, ((0, 0), (k - 1, 0), (0, 0)))
    conv = jnp.zeros_like(xb)
    for i in range(k):
        conv = conv + pad[:, i:i + xb.shape[1]] * \
            params["conv_w"][i].astype(x.dtype)
    conv = conv + params["conv_b"].astype(x.dtype)
    a, b = _gates(params, conv)
    h = rglru_scan(a, b)
    y = h.astype(x.dtype) * jax.nn.gelu(gate, approximate=True)
    out = y @ params["out_proj"].astype(x.dtype)
    if not want_cache:
        return out, None
    cache = {"conv": xb[:, xb.shape[1] - (k - 1):], "h": h[:, -1]}
    return out, cache


def rglru_mixer_apply(params, x, cfg):
    """Temporal mix (training). x: (B,S,E)."""
    return _mixer(params, x, cfg, want_cache=False)[0]


def rglru_prefill(params, x, cfg):
    """Prefill: returns (y, cache) with final recurrent + conv state."""
    return _mixer(params, x, cfg, want_cache=True)


# -- decode -----------------------------------------------------------------------


def rglru_cache_specs(cfg, batch: int) -> Dict[str, Any]:
    w = cfg.lru_width or cfg.d_model
    return {
        "conv": ParamSpec((batch, cfg.conv_width - 1, w),
                          ("batch", (), "mlp"), "zeros"),
        "h": ParamSpec((batch, w), ("batch", "state"), "zeros"),
    }


def rglru_init_cache(cfg, batch: int, dtype):
    w = cfg.lru_width or cfg.d_model
    return {"conv": jnp.zeros((batch, cfg.conv_width - 1, w), dtype),
            "h": jnp.zeros((batch, w), jnp.float32)}


def rglru_decode(params, x, cfg, cache):
    proj = x @ params["in_proj"].astype(x.dtype)
    w = cfg.lru_width or cfg.d_model
    xb, gate = jnp.split(proj, [w], axis=-1)          # (B,1,W)
    window = jnp.concatenate([cache["conv"], xb], axis=1)
    conv = jnp.einsum("bkw,kw->bw", window, params["conv_w"].astype(x.dtype))
    conv = (conv + params["conv_b"].astype(x.dtype))[:, None, :]
    a, b = _gates(params, conv)                       # (B,1,W)
    h = a[:, 0] * cache["h"] + b[:, 0]
    y = h[:, None, :].astype(x.dtype) * \
        jax.nn.gelu(gate, approximate=True)
    out = y @ params["out_proj"].astype(x.dtype)
    return out, {"conv": window[:, 1:], "h": h}
