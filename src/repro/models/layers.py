"""Model primitives: parameter specs, norms, RoPE, MLP, embeddings.

Parameters are declared as :class:`ParamSpec` trees (shape + logical axes +
initializer); a single spec tree drives initialization, sharding resolution
and ``eval_shape`` — so the three can never diverge.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Any, Dict

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass(frozen=True)
class ParamSpec:
    shape: tuple
    logical: tuple                 # logical axis names, same rank as shape
    init: str = "normal"           # normal | zeros | ones | lru_a
    scale: float = 1.0

    def __post_init__(self):
        assert len(self.shape) == len(self.logical), (self.shape, self.logical)


def is_spec(x) -> bool:
    return isinstance(x, ParamSpec)


def init_param(key, spec: ParamSpec, dtype) -> jax.Array:
    if spec.init == "zeros":
        return jnp.zeros(spec.shape, dtype)
    if spec.init == "ones":
        return jnp.ones(spec.shape, dtype)
    if spec.init == "lru_a":
        # RG-LRU "a" parameter: recurrence gate init so that softplus-based
        # decay starts near 0.9–0.999 (Griffin §2.4).
        u = jax.random.uniform(key, spec.shape, jnp.float32, 0.9, 0.999)
        val = jnp.log(jnp.expm1(-jnp.log(u) * 8.0))  # inverse softplus
        return val.astype(dtype)
    fan_in = spec.shape[0] if len(spec.shape) > 1 else max(spec.shape[0], 1)
    std = spec.scale / math.sqrt(fan_in)
    return (jax.random.normal(key, spec.shape, jnp.float32) * std).astype(dtype)


def init_from_specs(key, specs, dtype=jnp.float32):
    leaves, treedef = jax.tree.flatten(specs, is_leaf=is_spec)
    keys = jax.random.split(key, len(leaves))
    params = [init_param(k, s, dtype) for k, s in zip(keys, leaves)]
    return jax.tree.unflatten(treedef, params)


def logical_tree(specs):
    return jax.tree.map(lambda s: s.logical, specs, is_leaf=is_spec)


def shape_tree(specs):
    return jax.tree.map(lambda s: s.shape, specs, is_leaf=is_spec)


def abstract_params(specs, dtype=jnp.float32):
    return jax.tree.map(
        lambda s: jax.ShapeDtypeStruct(s.shape, dtype), specs,
        is_leaf=is_spec)


def param_bytes(specs, bytes_per_el: int = 4) -> int:
    return sum(int(np.prod(s.shape)) * bytes_per_el
               for s in jax.tree.leaves(specs, is_leaf=is_spec))


# -- functional layers ---------------------------------------------------------


def rms_norm(x, scale, eps: float = 1e-6, zero_centered: bool = True):
    dt = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    x = x * jax.lax.rsqrt(var + eps)
    w = (1.0 + scale) if zero_centered else scale
    return (x * w.astype(jnp.float32)).astype(dt)


def act_fn(name: str):
    return {"silu": jax.nn.silu, "gelu": lambda x: jax.nn.gelu(x, approximate=True),
            "relu": jax.nn.relu}[name]


def softcap(x, cap):
    if cap is None:
        return x
    return jnp.tanh(x / cap) * cap


# -- RoPE ------------------------------------------------------------------------


def rope_frequencies(head_dim: int, theta: float):
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2,
                                       dtype=jnp.float32) / head_dim))


def apply_rope(x, positions, theta: float = 10000.0):
    """x: (..., seq, heads, head_dim); positions: (..., seq)."""
    freqs = rope_frequencies(x.shape[-1], theta)
    angles = positions[..., :, None].astype(jnp.float32) * freqs  # (..., S, hd/2)
    angles = angles[..., None, :]                                 # (..., S, 1, hd/2)
    cos, sin = jnp.cos(angles), jnp.sin(angles)
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


# -- gated MLP ---------------------------------------------------------------------


def mlp_specs(cfg, d_ff=None) -> Dict[str, Any]:
    e, f = cfg.d_model, d_ff or cfg.d_ff
    return {
        "w_gate": ParamSpec((e, f), ("embed", "mlp")),
        "w_up": ParamSpec((e, f), ("embed", "mlp")),
        "w_down": ParamSpec((f, e), ("mlp", "embed")),
    }


def mlp_apply(params, x, cfg):
    act = act_fn(cfg.act)
    h = act(x @ params["w_gate"].astype(x.dtype)) * \
        (x @ params["w_up"].astype(x.dtype))
    return h @ params["w_down"].astype(x.dtype)


# -- embeddings ----------------------------------------------------------------------


def embed_specs(cfg) -> Dict[str, Any]:
    # "table_embed" (not "embed"): the token-embedding gather reshards
    # catastrophically under FSDP embed-dim sharding, so the table stays
    # vocab-sharded (model axis) with its embed dim replicated.
    specs = {"tokens": ParamSpec((cfg.vocab_size, cfg.d_model),
                                 ("vocab", "table_embed"))}
    if not cfg.tie_embeddings:
        specs["unembed"] = ParamSpec((cfg.d_model, cfg.vocab_size),
                                     ("table_embed", "vocab"))
    return specs


def embed_apply(params, tokens, cfg):
    x = params["tokens"].astype(jnp.dtype(cfg.dtype))[tokens]
    if cfg.embed_scale:
        x = x * jnp.asarray(math.sqrt(cfg.d_model), x.dtype)
    return x


def unembed_apply(params, x, cfg):
    if cfg.tie_embeddings:
        logits = x @ params["tokens"].astype(x.dtype).T
    else:
        logits = x @ params["unembed"].astype(x.dtype)
    return softcap(logits.astype(jnp.float32), cfg.final_logit_softcap)
