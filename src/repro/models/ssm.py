"""Mamba-2 SSD (state-space duality) block — arXiv:2405.21060.

The XLA path implements the *chunked SSD algorithm* (the paper's Listing 1):
intra-chunk quadratic attention-like term + inter-chunk recurrent state
carry, scanned over chunks.  This is the same blocking the Pallas kernel
(:mod:`repro.kernels.ssd`) uses on TPU, so the dry-run HLO reflects the
production compute/memory pattern.  ``n_groups = 1`` (B/C shared across
heads), D skip connection, gated RMSNorm, causal conv1d — matching the
mamba2-130m reference.
"""
from __future__ import annotations

from typing import Any, Dict

import jax
import jax.numpy as jnp

from repro.models.layers import ParamSpec, rms_norm


def ssd_specs(cfg) -> Dict[str, Any]:
    e, di, n, h = cfg.d_model, cfg.d_inner, cfg.ssm_state, cfg.ssm_heads
    conv_dim = di + 2 * n
    return {
        "in_proj": ParamSpec((e, 2 * di + 2 * n + h), ("embed", "mlp")),
        "conv_w": ParamSpec((cfg.conv_width, conv_dim), ((), "mlp")),
        "conv_b": ParamSpec((conv_dim,), ("mlp",), "zeros"),
        "A_log": ParamSpec((h,), ("heads",), "ones"),
        "D": ParamSpec((h,), ("heads",), "ones"),
        "dt_bias": ParamSpec((h,), ("heads",), "zeros"),
        "norm": ParamSpec((di,), ("mlp",), "zeros"),
        "out_proj": ParamSpec((di, e), ("mlp", "embed")),
    }


def _split_proj(cfg, zxbcdt):
    di, n, h = cfg.d_inner, cfg.ssm_state, cfg.ssm_heads
    z, xc, b, c, dt = jnp.split(
        zxbcdt, [di, 2 * di, 2 * di + n, 2 * di + 2 * n], axis=-1)
    return z, xc, b, c, dt


def causal_conv1d(x, w, b):
    """x: (B, S, C); w: (K, C) depthwise; left-padded causal."""
    k = w.shape[0]
    pad = jnp.pad(x, ((0, 0), (k - 1, 0), (0, 0)))
    out = jnp.zeros_like(x)
    for i in range(k):            # K is 4: unrolled taps
        out = out + pad[:, i:i + x.shape[1]] * w[i].astype(x.dtype)
    return jax.nn.silu(out + b.astype(x.dtype))


def ssd_chunked(x, dt, a_log, b, c, chunk: int):
    """Chunked SSD. x: (B,S,H,P); dt: (B,S,H); b,c: (B,S,N) (n_groups=1).

    Returns y: (B,S,H,P).  Exact (fp32 state) — validated against the
    step-recurrence oracle in kernels/ssd/ref.py.
    """
    bsz, s, h, p = x.shape
    n = b.shape[-1]
    q = min(chunk, s)
    while s % q:
        q //= 2
    nc = s // q
    a = -jnp.exp(a_log.astype(jnp.float32))                 # (H,)
    dt = dt.astype(jnp.float32)
    da = dt * a[None, None, :]                              # (B,S,H)
    x_dt = x * dt[..., None].astype(x.dtype)

    def per_chunk(carry, inp):
        h_state = carry                                     # (B,H,P,N) fp32
        xc, dac, bc, cc = inp
        seg = jnp.cumsum(dac, axis=1)                       # (B,q,H)
        total = seg[:, -1]                                  # (B,H)
        # intra-chunk (quadratic) term; mask inside the exp so masked
        # positions neither overflow forward nor produce inf*0 cotangents
        li = seg[:, :, None, :] - seg[:, None, :, :]        # (B,q,q,H)
        mask = jnp.tril(jnp.ones((q, q), bool))
        li = jnp.where(mask[None, :, :, None], li, -jnp.inf)
        decay = jnp.exp(li)
        cb = jnp.einsum("bqn,bsn->bqs", cc.astype(jnp.float32),
                        bc.astype(jnp.float32))
        att = cb[..., None] * decay                         # (B,q,q,H)
        y_intra = jnp.einsum("bqsh,bshp->bqhp", att,
                             xc.astype(jnp.float32))
        # inter-chunk: contribution of the carried state
        state_decay = jnp.exp(seg)                          # (B,q,H)
        y_inter = jnp.einsum("bqn,bhpn->bqhp", cc.astype(jnp.float32),
                             h_state) * state_decay[..., None]
        # state update
        rem = jnp.exp(total[:, None, :] - seg)              # (B,q,H)
        bx = jnp.einsum("bqn,bqhp->bhpn",
                        bc.astype(jnp.float32),
                        xc.astype(jnp.float32) * rem[..., None])
        h_new = h_state * jnp.exp(total)[:, :, None, None] + bx
        return h_new, (y_intra + y_inter).astype(x.dtype)

    # Python-unrolled over chunks (not lax.scan): every chunk's FLOPs appear
    # explicitly in the HLO so cost_analysis reflects the true SSD cost.
    h_state = jnp.zeros((bsz, h, p, n), jnp.float32)
    ys = []
    for ci in range(nc):
        sl = slice(ci * q, (ci + 1) * q)
        h_state, y = per_chunk(
            h_state, (x_dt[:, sl], da[:, sl], b[:, sl], c[:, sl]))
        ys.append(y)
    out = jnp.concatenate(ys, axis=1) if nc > 1 else ys[0]
    return out, h_state


def _mixer(params, x, cfg, want_cache: bool):
    dt_proj = x @ params["in_proj"].astype(x.dtype)
    z, xc, b, c, dt = _split_proj(cfg, dt_proj)
    conv_in = jnp.concatenate([xc, b, c], axis=-1)
    conv_out = causal_conv1d(conv_in, params["conv_w"], params["conv_b"])
    di, n = cfg.d_inner, cfg.ssm_state
    xc, b, c = jnp.split(conv_out, [di, di + n], axis=-1)
    h, p = cfg.ssm_heads, cfg.ssm_head_dim
    xh = xc.reshape(*xc.shape[:-1], h, p)
    dt = jax.nn.softplus(dt.astype(jnp.float32)
                         + params["dt_bias"].astype(jnp.float32))
    y, h_final = ssd_chunked(xh, dt, params["A_log"], b, c, cfg.ssd_chunk)
    y = y + xh * params["D"].astype(x.dtype)[None, None, :, None]
    y = y.reshape(*xc.shape[:-1], di)
    y = rms_norm(y * jax.nn.silu(z), params["norm"], cfg.norm_eps)
    out = y @ params["out_proj"].astype(x.dtype)
    if not want_cache:
        return out, None
    k = params["conv_w"].shape[0]
    cache = {"conv": conv_in[:, conv_in.shape[1] - (k - 1):],
             "state": h_final}
    return out, cache


def ssd_apply(params, x, cfg):
    """Full Mamba-2 mixer (training). x: (B,S,E)."""
    return _mixer(params, x, cfg, want_cache=False)[0]


def ssd_prefill(params, x, cfg):
    """Prefill: returns (y, cache) with the post-sequence SSM/conv state."""
    return _mixer(params, x, cfg, want_cache=True)


# -- decode ---------------------------------------------------------------------


def ssd_cache_specs(cfg, batch: int) -> Dict[str, Any]:
    di, n = cfg.d_inner, cfg.ssm_state
    conv_dim = di + 2 * n
    return {
        "conv": ParamSpec((batch, cfg.conv_width - 1, conv_dim),
                          ("batch", (), "mlp"), "zeros"),
        "state": ParamSpec((batch, cfg.ssm_heads, cfg.ssm_head_dim, n),
                           ("batch", "heads", (), "state"), "zeros"),
    }


def ssd_init_cache(cfg, batch: int, dtype):
    di, n = cfg.d_inner, cfg.ssm_state
    return {"conv": jnp.zeros((batch, cfg.conv_width - 1, di + 2 * n), dtype),
            "state": jnp.zeros((batch, cfg.ssm_heads, cfg.ssm_head_dim, n),
                               jnp.float32)}


def ssd_decode(params, x, cfg, cache):
    """One-token step. x: (B,1,E)."""
    dt_proj = x @ params["in_proj"].astype(x.dtype)
    z, xc, b, c, dt = _split_proj(cfg, dt_proj)
    conv_in = jnp.concatenate([xc, b, c], axis=-1)       # (B,1,C)
    window = jnp.concatenate([cache["conv"], conv_in], axis=1)
    w, bias = params["conv_w"], params["conv_b"]
    conv_out = jnp.einsum("bkc,kc->bc", window, w.astype(x.dtype)) \
        + bias.astype(x.dtype)
    conv_out = jax.nn.silu(conv_out)[:, None, :]
    di, n = cfg.d_inner, cfg.ssm_state
    xc, b, c = jnp.split(conv_out, [di, di + n], axis=-1)
    h, p = cfg.ssm_heads, cfg.ssm_head_dim
    xh = xc.reshape(-1, h, p).astype(jnp.float32)
    dt = jax.nn.softplus(dt.astype(jnp.float32)
                         + params["dt_bias"].astype(jnp.float32))[:, 0]
    a = -jnp.exp(params["A_log"].astype(jnp.float32))
    da = jnp.exp(dt * a[None, :])                         # (B,H)
    bx = jnp.einsum("bn,bhp->bhpn", b[:, 0].astype(jnp.float32),
                    xh * dt[..., None])
    state = cache["state"] * da[..., None, None] + bx
    y = jnp.einsum("bn,bhpn->bhp", c[:, 0].astype(jnp.float32), state)
    y = y.astype(x.dtype) + xh.astype(x.dtype) * \
        params["D"].astype(x.dtype)[None, :, None]
    y = y.reshape(-1, 1, di)
    y = rms_norm(y * jax.nn.silu(z), params["norm"], cfg.norm_eps)
    out = y @ params["out_proj"].astype(x.dtype)
    return out, {"conv": window[:, 1:], "state": state}
