"""Model zoo: the 10 assigned architectures over shared substrate layers."""
from repro.models.config import ModelConfig
from repro.models.registry import build_model, get_model, list_archs, reduced_config
from repro.models.transformer import CausalLM
from repro.models.encdec import EncDecLM

__all__ = ["ModelConfig", "build_model", "get_model", "list_archs",
           "reduced_config", "CausalLM", "EncDecLM"]
