"""Model configuration — one dataclass covering all 10 assigned families."""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                    # dense | moe | ssm | hybrid | encdec | vlm
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0              # 0 => d_model // num_heads

    # attention options
    qk_norm: bool = False
    attn_logit_softcap: Optional[float] = None
    final_logit_softcap: Optional[float] = None
    sliding_window: Optional[int] = None
    # repeating block pattern, e.g. ("local", "global") for gemma2,
    # ("rglru", "rglru", "local") for recurrentgemma, ("ssd",) for mamba2.
    pattern: Tuple[str, ...] = ("global",)
    rope_theta: float = 10000.0

    # MoE
    num_experts: int = 0
    num_shared_experts: int = 0
    top_k: int = 0
    expert_d_ff: int = 0
    first_dense_layers: int = 0
    first_dense_ff: int = 0
    router_aux_coef: float = 0.01
    capacity_factor: float = 1.25

    # SSM (mamba2 SSD)
    ssm_state: int = 0
    ssm_expand: int = 2
    ssm_head_dim: int = 64
    conv_width: int = 4
    ssd_chunk: int = 128

    # RG-LRU (recurrentgemma)
    lru_width: int = 0

    # encoder-decoder
    enc_layers: int = 0            # encdec: encoder depth (num_layers = dec)
    cross_attention: bool = False

    # modality frontend stub
    frontend: Optional[str] = None     # "patches" | "frames"
    frontend_tokens: int = 0           # tokens contributed by the frontend

    # norms / misc
    norm_eps: float = 1e-6
    act: str = "silu"
    tie_embeddings: bool = True
    embed_scale: bool = False          # gemma-style sqrt(d_model) scaling
    dtype: str = "bfloat16"
    param_dtype: str = "float32"
    remat: str = "nothing_saveable"    # "none" | "nothing_saveable" | "dots"
    # attention implementation: "auto" picks pallas on TPU, chunked on CPU
    attn_impl: str = "auto"
    attn_chunk: int = 512
    # chunked cross-entropy: compute logits+CE over sequence chunks of this
    # size (0 = whole sequence at once); bounds the (B,S,V) logits temp
    ce_chunk: int = 0

    def __post_init__(self):
        if self.head_dim == 0 and self.num_heads > 0:
            object.__setattr__(self, "head_dim",
                               self.d_model // self.num_heads)

    # -- derived -------------------------------------------------------------

    @property
    def q_per_kv(self) -> int:
        return self.num_heads // max(self.num_kv_heads, 1)

    @property
    def d_inner(self) -> int:
        """SSM inner width."""
        return self.ssm_expand * self.d_model

    @property
    def ssm_heads(self) -> int:
        return self.d_inner // self.ssm_head_dim

    @property
    def pattern_repeats(self) -> Tuple[int, int]:
        """(full pattern repeats, tail length) over num_layers."""
        n = len(self.pattern)
        return self.num_layers // n, self.num_layers % n

    def param_count(self) -> int:
        """Approximate parameter count (embeddings + blocks)."""
        e, h, kv, hd, f, v = (self.d_model, self.num_heads, self.num_kv_heads,
                              self.head_dim, self.d_ff, self.vocab_size)
        embed = v * e * (1 if self.tie_embeddings else 2)
        total = embed
        reps, tail = self.pattern_repeats
        counts = {}
        for kind in self.pattern:
            counts[kind] = counts.get(kind, 0) + reps
        for i, kind in enumerate(self.pattern[:tail]):
            counts[kind] = counts.get(kind, 0) + 1
        for kind, n in counts.items():
            if kind in ("global", "local"):
                attn = e * (h * hd + 2 * kv * hd) + h * hd * e
                blk = attn + 3 * e * f + 2 * e
            elif kind == "moe":
                attn = e * (h * hd + 2 * kv * hd) + h * hd * e
                routed = self.num_experts * 3 * e * self.expert_d_ff
                shared = self.num_shared_experts * 3 * e * self.expert_d_ff
                blk = attn + routed + shared + e * self.num_experts + 2 * e
            elif kind == "ssd":
                di = self.d_inner
                blk = (e * (2 * di + 2 * self.ssm_state + self.ssm_heads)
                       + di * e + self.conv_width * di + 2 * e)
            elif kind == "rglru":
                w = self.lru_width or e
                blk = (e * 2 * w + w * e + 2 * w * self.conv_width
                       + 2 * w * w + 3 * w + 3 * e * f + 2 * e)
            elif kind == "cross":
                blk = e * (h * hd * 2 + 2 * kv * hd) + h * hd * e + 2 * e
            else:
                blk = 0
            total += n * blk
        if self.family == "moe" and self.first_dense_layers:
            # replace routed block ffn with a dense one for the first layers
            total += self.first_dense_layers * (
                3 * self.d_model * (self.first_dense_ff or self.d_ff))
        if self.enc_layers:
            attn = e * (h * hd + 2 * kv * hd) + h * hd * e
            total += self.enc_layers * (attn + 3 * e * f + 2 * e)
            # decoder cross-attention
            total += self.num_layers * (e * (h * hd + 2 * kv * hd)
                                        + h * hd * e + e)
        return total

    def active_param_count(self) -> int:
        """Parameters touched per token (MoE: top-k + shared only)."""
        if self.family != "moe":
            return self.param_count()
        full = self.param_count()
        inactive = (self.num_experts - self.top_k) * 3 * self.d_model \
            * self.expert_d_ff * self.num_layers
        return full - inactive
