"""AdamW with cosine schedule, global-norm clipping, ZeRO-1 sharding.

Built from scratch (no optax).  The optimizer state mirrors the parameter
pytree; ``zero1_logical`` augments each moment's logical spec with the
``data`` axis on its largest shardable dimension, giving ZeRO-1 optimizer-
state sharding under the same rules engine that shards everything else —
XLA then materializes the reduce-scatter/all-gather pair in the update.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Optional

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    beta1: float = 0.9
    beta2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: Optional[float] = 1.0
    warmup_steps: int = 100
    total_steps: int = 10000
    min_lr_ratio: float = 0.1
    zero1: bool = True          # shard moments over the data axes
    # dtype for the cross-slice gradient reduction (None = fp32); bf16
    # halves the dominant DP collective at <1e-3 relative grad error
    grad_reduce_dtype: str = None


def schedule(cfg: AdamWConfig, step):
    step = step.astype(jnp.float32)
    warm = jnp.minimum(step / jnp.maximum(cfg.warmup_steps, 1), 1.0)
    frac = jnp.clip((step - cfg.warmup_steps)
                    / jnp.maximum(cfg.total_steps - cfg.warmup_steps, 1),
                    0.0, 1.0)
    cos = 0.5 * (1 + jnp.cos(jnp.pi * frac))
    return cfg.lr * warm * (cfg.min_lr_ratio + (1 - cfg.min_lr_ratio) * cos)


def init_state(params) -> dict:
    zeros = lambda: jax.tree.map(  # noqa: E731
        lambda p: jnp.zeros_like(p, dtype=jnp.float32), params)
    return {"mu": zeros(), "nu": zeros(),
            "step": jnp.zeros((), jnp.int32)}


def global_norm(tree) -> jax.Array:
    leaves = [jnp.sum(jnp.square(x.astype(jnp.float32)))
              for x in jax.tree.leaves(tree)]
    return jnp.sqrt(jnp.sum(jnp.stack(leaves)))


def apply_updates(cfg: AdamWConfig, params, grads, state):
    """Returns (new_params, new_state, metrics)."""
    step = state["step"] + 1
    lr = schedule(cfg, step)
    gnorm = global_norm(grads)
    if cfg.clip_norm is not None:
        scale = jnp.minimum(1.0, cfg.clip_norm / (gnorm + 1e-9))
        grads = jax.tree.map(lambda g: g * scale, grads)

    b1, b2 = cfg.beta1, cfg.beta2
    t = step.astype(jnp.float32)
    c1 = 1 - b1 ** t
    c2 = 1 - b2 ** t

    def upd(p, g, mu, nu):
        g = g.astype(jnp.float32)
        mu = b1 * mu + (1 - b1) * g
        nu = b2 * nu + (1 - b2) * jnp.square(g)
        u = (mu / c1) / (jnp.sqrt(nu / c2) + cfg.eps)
        if p.ndim >= 2:   # decoupled weight decay on matrices only
            u = u + cfg.weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * u).astype(p.dtype), mu, nu

    out = jax.tree.map(upd, params, grads, state["mu"], state["nu"])
    new_params = jax.tree.map(lambda o: o[0], out,
                              is_leaf=lambda x: isinstance(x, tuple))
    new_mu = jax.tree.map(lambda o: o[1], out,
                          is_leaf=lambda x: isinstance(x, tuple))
    new_nu = jax.tree.map(lambda o: o[2], out,
                          is_leaf=lambda x: isinstance(x, tuple))
    metrics = {"lr": lr, "grad_norm": gnorm}
    return new_params, {"mu": new_mu, "nu": new_nu, "step": step}, metrics


# -- ZeRO-1 logical specs --------------------------------------------------------


def zero1_logical(param_logical, param_shape, mesh, rules):
    """Moment spec = param spec + 'data' sharding on the largest dim that the
    param spec leaves unsharded and that the data axes divide evenly."""
    data_ways = 1
    for ax in ("pod", "data"):
        if ax in mesh.shape:
            data_ways *= mesh.shape[ax]
    used = rules.spec_for(param_logical, param_shape, mesh)
    best, best_size = None, 0
    for i, (name, dim) in enumerate(zip(param_logical, param_shape)):
        already = i < len(used) and used[i] is not None
        if already or name == "layers":
            continue
        if dim % data_ways == 0 and dim > best_size:
            best, best_size = i, dim
    if best is None:
        return tuple(param_logical)
    out = list(param_logical)
    out[best] = "zero1"
    return tuple(out)


def state_logical(params_logical, params_shapes, mesh, rules,
                  zero1: bool = True):
    """Logical specs for the optimizer state pytree."""
    if zero1:
        mom = jax.tree.map(
            lambda lg, sh: zero1_logical(lg, sh, mesh, rules),
            params_logical, params_shapes,
            is_leaf=lambda x: isinstance(x, tuple))
    else:
        mom = params_logical
    return {"mu": mom, "nu": mom, "step": ()}
