"""Optimizer substrate: AdamW, schedules, ZeRO-1, gradient compression."""
from repro.optim.adamw import (AdamWConfig, apply_updates, global_norm,
                               init_state, schedule, state_logical,
                               zero1_logical)
from repro.optim.compression import (compressed_psum_grads,
                                     make_compressed_allreduce)

__all__ = ["AdamWConfig", "apply_updates", "global_norm", "init_state",
           "schedule", "state_logical", "zero1_logical",
           "compressed_psum_grads", "make_compressed_allreduce"]
