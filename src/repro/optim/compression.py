"""Gradient compression for the data-parallel all-reduce.

int8 block-quantized gradient sync with error feedback: each DP slice
quantizes its local gradient shard to int8 (per-block scales), psums the
int8 payload (in int32 to avoid overflow), dequantizes, and keeps the
quantization residual to add into the next step's gradient (error
feedback), which preserves convergence.  Implemented with ``shard_map`` so
the collective is explicit — the wire traffic drops 4x vs fp32 (the
roofline collective term of DP-bound cells).
"""
from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P
from jax.experimental.shard_map import shard_map

BLOCK = 256


def _quantize(g: jax.Array) -> Tuple[jax.Array, jax.Array]:
    flat = g.reshape(-1)
    pad = (-flat.size) % BLOCK
    flat = jnp.pad(flat, (0, pad))
    blocks = flat.reshape(-1, BLOCK)
    scale = jnp.max(jnp.abs(blocks), axis=1, keepdims=True) / 127.0
    q = jnp.round(blocks / jnp.maximum(scale, 1e-12)).astype(jnp.int8)
    return q, scale


def _dequantize(q, scale, shape, size):
    flat = (q.astype(jnp.float32) * scale).reshape(-1)[:size]
    return flat.reshape(shape)


def compressed_psum_grads(grads, mesh: Mesh, axes=("pod", "data"),
                          errors=None):
    """All-reduce ``grads`` (already *local* per-slice values inside
    shard_map) with int8 compression + error feedback.

    grads/errors: pytrees of fp32 arrays replicated over `axes` semantics.
    Returns (mean_grads, new_errors).  Must be called inside shard_map with
    the data axes unmapped on these arrays.
    """
    axes = tuple(ax for ax in axes if ax in mesh.shape)
    n = 1
    for ax in axes:
        n *= mesh.shape[ax]

    def sync(g, e):
        g = g.astype(jnp.float32)
        if e is not None:
            g = g + e
        flat = g.reshape(-1)
        pad = (-flat.size) % BLOCK
        blocks = jnp.pad(flat, (0, pad)).reshape(-1, BLOCK)
        local_scale = jnp.max(jnp.abs(blocks), axis=1,
                              keepdims=True) / 127.0
        # shared per-block scale (pmax) -> the int8 sum is *exact*; only
        # the local rounding error remains, and error feedback carries it.
        scale = jax.lax.pmax(jnp.maximum(local_scale, 1e-12), axes)
        q = jnp.clip(jnp.round(blocks / scale), -127, 127).astype(jnp.int8)
        local = _dequantize(q, scale, g.shape, g.size)
        err = g - local                                 # error feedback
        q32 = jax.lax.psum(q.astype(jnp.int32), axes)
        total = _dequantize(q32, scale, g.shape, g.size)
        return total / n, err

    if errors is None:
        errors = jax.tree.map(lambda g: jnp.zeros_like(g, jnp.float32),
                              grads)
    out = jax.tree.map(sync, grads, errors)
    mean = jax.tree.map(lambda o: o[0], out,
                        is_leaf=lambda x: isinstance(x, tuple))
    errs = jax.tree.map(lambda o: o[1], out,
                        is_leaf=lambda x: isinstance(x, tuple))
    return mean, errs


def make_compressed_allreduce(mesh: Mesh, param_specs):
    """Build a jitted fn: (per-slice grads, errors) -> (mean grads, errors).

    Gradients are TP-sharded / DP-unreduced; the fn runs a shard_map over
    the whole mesh, psumming int8 payloads over the data axes only.
    """
    axes = tuple(ax for ax in ("pod", "data") if ax in mesh.shape)

    def body(grads, errors):
        return compressed_psum_grads(grads, mesh, axes=axes, errors=errors)

    specs = jax.tree.map(lambda s: s.spec, param_specs)
    fn = shard_map(body, mesh=mesh,
                   in_specs=(specs, specs), out_specs=(specs, specs),
                   check_rep=False)
    return jax.jit(fn)
