"""Event-driven simulation engine — the substrate under the RMS testbed.

The monolithic ``ClusterSimulator`` loop is split into a small, generic
discrete-event core (this module) plus pluggable handlers registered per
event *type*.  New scenario classes (preemption, power capping, network
contention, …) become new :class:`Event` subclasses with their own
handlers instead of edits to one hard-wired loop.

Event types map onto the source paper as follows:

=================  ==========================================================
Event              Paper section
=================  ==========================================================
``JobSubmit``      §7.1 workload generation — a job enters the RMS queue.
``JobFinish``      §7.4 metrics — completion bookkeeping (wait/exec/
                   completion times; invalidated by ``version`` on resize).
``ReconfigPoint``  §5.2 — the periodic DMR check where the application
                   contacts the RMS and an EXPAND/SHRINK/NO_ACTION decision
                   is taken (synchronous or asynchronous, §5.1).
``ExpandTimeout``  §5.2.1 / Table 2 — the asynchronous resizer-job (RJ)
                   reservation expires; the pathological async wait ceiling.
                   Carries an ``epoch`` so a requeue structurally kills the
                   pending timeout instead of relying on float equality.
``PhaseChange``    §2 taxonomy EVOLVING — the application enters its next
                   phase and announces a new ``(min, max, preferred)``
                   demand band; the handler updates the live band and
                   forces an immediate DMR check (§5.2 hook).
``NodeFail``       beyond-paper fault path: shrink-to-survivors for
                   malleable jobs, checkpoint requeue for rigid ones (§8's
                   deployment argument).
``NodeJoin``       beyond-paper elastic capacity: a node enters the pool
                   (scale-out, maintenance done, spot granted) — waiting
                   expands and queued jobs can claim it immediately.
``NodeDrain``      beyond-paper elastic capacity: a node must leave the
                   pool (maintenance, spot reclamation); the RMS negotiates
                   the owning job off it — slice migration, DMR shrink, or
                   checkpoint requeue — before release.
``NodePowerOff``   beyond-paper energy management (CLUES-style): the
                   capacity manager's armed idle timer fires; idle nodes
                   above the ``min_free`` headroom are parked.
``NodePowerOn``    beyond-paper energy management: a parked node finishes
                   booting (``power_up_delay_s`` after queue pressure
                   demanded it) and rejoins the allocatable pool.
``StragglerOnset`` beyond-paper: a node slows down; gates the whole job.
``StragglerScan``  beyond-paper: periodic detection + slice migration
                   (mechanically the §5.2.2 shrink data-fold on one slice).
``CheckpointTick`` §6 deployment — periodic checkpoint, the restore point
                   used by the ``NodeFail`` path.
``TrafficTick``    beyond-paper SERVING class: the periodic latency probe of
                   an open-loop request stream — drains backlog at the app
                   rate, samples p99 vs the SLO, and (like ReconfigPoint)
                   carries an ``epoch`` so a requeue structurally retires
                   the pending chain.
=================  ==========================================================

Determinism contract: events are dispatched in ``(t, seq)`` order where
``seq`` is the scheduling sequence number, so two runs that schedule the
same events in the same order replay identically (tier-1 golden-trace test
``tests/test_engine_determinism.py`` locks this down).

Hot-path notes: event dataclasses are ``slots=True`` (a simulation
allocates one per scheduled event — millions in a big sweep) and the
dispatcher resolves each event type's handler chain once, caching the
MRO walk, instead of re-walking it on every dispatch.
"""
from __future__ import annotations

import dataclasses
import heapq
import itertools
from typing import Callable, Dict, List, Optional, Tuple, Type


# ---------------------------------------------------------------------------
# Typed events
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True, slots=True)
class Event:
    """Base event: ``t`` is the simulation time the event fires at."""
    t: float


@dataclasses.dataclass(frozen=True, slots=True)
class JobSubmit(Event):
    job_id: int


@dataclasses.dataclass(frozen=True, slots=True)
class JobFinish(Event):
    job_id: int
    version: int          # invalidates stale completions after a resize


@dataclasses.dataclass(frozen=True, slots=True)
class ReconfigPoint(Event):
    job_id: int
    epoch: int = 0        # invalidates a chain left over from a prior start


@dataclasses.dataclass(frozen=True, slots=True)
class ExpandTimeout(Event):
    job_id: int
    since: float          # identifies which pending wait this timeout guards
    epoch: int = 0        # invalidated structurally when the job requeues


@dataclasses.dataclass(frozen=True, slots=True)
class NodeFail(Event):
    node: int


@dataclasses.dataclass(frozen=True, slots=True)
class NodeJoin(Event):
    """A node joins the pool; ``node < 0`` joins brand-new capacity under a
    fresh id, a known id re-joins after a drain (or repaired after death)."""
    node: int = -1


@dataclasses.dataclass(frozen=True, slots=True)
class NodeDrain(Event):
    """``node`` must leave the pool (maintenance / spot reclamation)."""
    node: int


@dataclasses.dataclass(frozen=True, slots=True)
class NodePowerOff(Event):
    """The capacity manager's idle timer: ``node < 0`` lets the manager
    pick which idle nodes to park (quarantined slow nodes first)."""
    node: int = -1


@dataclasses.dataclass(frozen=True, slots=True)
class NodePowerOn(Event):
    """A parked node finishes booting and becomes allocatable."""
    node: int = -1


@dataclasses.dataclass(frozen=True, slots=True)
class StragglerOnset(Event):
    node: int
    slowdown: float


@dataclasses.dataclass(frozen=True, slots=True)
class StragglerScan(Event):
    job_id: int


@dataclasses.dataclass(frozen=True, slots=True)
class CheckpointTick(Event):
    job_id: int
    epoch: int = 0        # invalidates a chain left over from a prior start


@dataclasses.dataclass(frozen=True, slots=True)
class TrafficTick(Event):
    """Periodic backlog/latency probe for a SERVING job.

    The handler accrues open-loop arrivals, drains what the current
    allocation served since the last tick, samples the queueing-delay p99
    against the job's SLO, and re-arms itself; ``epoch`` guards against a
    stale chain surviving a requeue/restart (same pattern as
    ReconfigPoint).
    """
    job_id: int
    epoch: int = 0        # invalidates a chain left over from a prior start


@dataclasses.dataclass(frozen=True, slots=True)
class PhaseChange(Event):
    """An EVOLVING job enters phase ``phase`` and demands a new band.

    The event carries the band so the handler applies exactly what the
    application announced; ``epoch`` guards against stale events left over
    from a prior start/resize prediction (same pattern as ReconfigPoint).
    """
    job_id: int
    phase: int            # index of the phase being entered
    min_nodes: int
    max_nodes: int
    preferred: Optional[int] = None
    epoch: int = 0        # invalidates a prediction from a prior start


Handler = Callable[[Event], None]


# ---------------------------------------------------------------------------
# Monitors
# ---------------------------------------------------------------------------

class _MonitorFanout:
    """Dispatches monitor hooks to several monitors in registration order.

    Only materialized when two or more monitors are installed, so the
    common cases (none, or just the sanitizer / just the recorder) pay no
    extra indirection: the hot loop sees either ``None`` or the single
    monitor object itself.
    """

    __slots__ = ("monitors",)

    def __init__(self, monitors):
        self.monitors = tuple(monitors)

    def on_schedule(self, event: Event) -> None:
        for m in self.monitors:
            m.on_schedule(event)

    def before_event(self, event: Event) -> None:
        for m in self.monitors:
            m.before_event(event)

    def after_event(self, event: Event) -> None:
        for m in self.monitors:
            m.after_event(event)


# ---------------------------------------------------------------------------
# Engine
# ---------------------------------------------------------------------------

class SimulationEngine:
    """Minimal deterministic discrete-event dispatcher.

    Handlers are registered per event type with :meth:`on`; dispatch walks
    the event's MRO so a handler registered for :class:`Event` observes
    everything (useful for tracing/monitor plugins).
    """

    def __init__(self, max_events: int = 5_000_000):
        self.now = 0.0
        self.max_events = max_events
        self._heap: List[Tuple[float, int, Event]] = []
        self._seq = itertools.count()
        self._handlers: Dict[Type[Event], List[Handler]] = {}
        # Per concrete event type: the flattened handler chain (own type
        # first, then base types up the MRO).  Rebuilt lazily after every
        # registration — dispatch never walks the MRO itself.
        self._chain: Dict[Type[Event], Tuple[Handler, ...]] = {}
        self.dispatched = 0
        # Optional monitors (sanitizer, trace recorder, …): objects with
        # ``on_schedule(event)`` / ``before_event(event)`` /
        # ``after_event(event)``, observing every event in registration
        # order.  ``_monitor`` holds the composed view the hot paths read:
        # None when empty, the sole monitor itself when one is installed,
        # a _MonitorFanout above that.  Install *before* run() — the hot
        # loop hoists the reference, so a mid-run change is not observed.
        self._monitors: Tuple = ()
        self._monitor = None

    # -- monitors ------------------------------------------------------------

    @property
    def monitor(self):
        """The composed monitor view (None / single monitor / fan-out)."""
        return self._monitor

    @monitor.setter
    def monitor(self, value) -> None:
        # Backwards-compatible single-slot assignment: replaces the whole
        # monitor set (``engine.monitor = None`` uninstalls everything).
        self._monitors = () if value is None else (value,)
        self._compose()

    def add_monitor(self, monitor) -> None:
        """Append ``monitor`` to the ordered fan-out (idempotent)."""
        if monitor not in self._monitors:
            self._monitors = self._monitors + (monitor,)
            self._compose()

    def remove_monitor(self, monitor) -> None:
        """Remove ``monitor`` if installed; no-op otherwise."""
        if monitor in self._monitors:
            self._monitors = tuple(
                m for m in self._monitors if m is not monitor)
            self._compose()

    def _compose(self) -> None:
        n = len(self._monitors)
        if n == 0:
            self._monitor = None
        elif n == 1:
            self._monitor = self._monitors[0]
        else:
            self._monitor = _MonitorFanout(self._monitors)

    # -- registration --------------------------------------------------------

    def on(self, event_type: Type[Event], handler: Handler = None):
        """Register ``handler`` for ``event_type``; usable as a decorator."""
        if handler is None:
            def deco(fn: Handler) -> Handler:
                self.on(event_type, fn)
                return fn
            return deco
        self._handlers.setdefault(event_type, []).append(handler)
        self._chain.clear()    # chains are stale once registrations change
        return handler

    def _build_chain(self, event_type: Type[Event]) -> Tuple[Handler, ...]:
        chain: List[Handler] = []
        for klass in event_type.__mro__:
            if klass is object:
                break
            chain.extend(self._handlers.get(klass, ()))
        out = tuple(chain)
        self._chain[event_type] = out
        return out

    # -- scheduling ----------------------------------------------------------

    def schedule(self, event: Event) -> None:
        monitor = self._monitor
        if monitor is not None:
            monitor.on_schedule(event)
        heapq.heappush(self._heap, (event.t, next(self._seq), event))

    def schedule_at(self, t: float, event_type: Type[Event], **fields) -> None:
        self.schedule(event_type(t=t, **fields))

    # -- main loop -----------------------------------------------------------

    def _dispatch(self, event: Event) -> None:
        cls = type(event)
        chain = self._chain.get(cls)
        if chain is None:
            chain = self._build_chain(cls)
        for handler in chain:
            handler(event)

    def step(self) -> bool:
        """Dispatch the next event; returns False when the heap is empty."""
        if not self._heap:
            return False
        t, _, event = heapq.heappop(self._heap)
        self.now = t
        self.dispatched += 1
        if self.dispatched > self.max_events:
            raise RuntimeError("simulation runaway: max_events exceeded")
        monitor = self._monitor
        if monitor is not None:
            monitor.before_event(event)
        self._dispatch(event)
        if monitor is not None:
            monitor.after_event(event)
        return True

    def run(self) -> None:
        # Tight inlining of step(): the loop body runs once per event, so
        # attribute lookups are hoisted out of it.  ``self._chain`` is
        # aliased, not copied — a handler registering new handlers mid-run
        # clears the same dict, so stale chains cannot be reused.
        heap = self._heap
        pop = heapq.heappop
        chains = self._chain
        dispatched = self.dispatched
        max_events = self.max_events
        monitor = self._monitor
        try:
            while heap:
                t, _, event = pop(heap)
                self.now = t
                dispatched += 1
                if dispatched > max_events:
                    raise RuntimeError(
                        "simulation runaway: max_events exceeded")
                cls = type(event)
                chain = chains.get(cls)
                if chain is None:
                    chain = self._build_chain(cls)
                if monitor is None:
                    for handler in chain:
                        handler(event)
                else:
                    monitor.before_event(event)
                    for handler in chain:
                        handler(event)
                    monitor.after_event(event)
        finally:
            self.dispatched = dispatched
