"""RMS: Slurm-analogue resource manager (cluster, policy, scheduler, sim)."""
from repro.rms.capacity import (CHURN_SCENARIOS, CapacityConfig,
                                CapacityManager, churn_schedule, plan_drain)
from repro.rms.cluster import Cluster
from repro.rms.costmodel import PAPER_APPS, AppModel, ReconfigCostModel, lm_app_model
from repro.rms.engine import (CheckpointTick, Event, ExpandTimeout, JobFinish,
                              JobSubmit, NodeDrain, NodeFail, NodeJoin,
                              NodePowerOff, NodePowerOn, PhaseChange,
                              ReconfigPoint, SimulationEngine,
                              StragglerOnset, StragglerScan, TrafficTick)
from repro.rms.job import Job, JobPhase, JobState
from repro.rms.policy import PolicyConfig, ReconfigPolicy, factor_sizes
from repro.rms.scheduler import (MAX_PRIORITY, POLICY_REGISTRY,
                                 FairSharePolicy, MoldableStartPolicy,
                                 PreemptiveBackfillPolicy, Scheduler,
                                 SchedulerConfig, SchedulingPolicy,
                                 SJFPolicy, make_policy, register_policy)
from repro.rms.simulator import (ActionRecord, ClusterSimulator, SimConfig,
                                 SimReport)

__all__ = ["Cluster", "PAPER_APPS", "AppModel", "ReconfigCostModel",
           "lm_app_model", "Job", "JobPhase", "JobState", "PolicyConfig",
           "ReconfigPolicy", "factor_sizes", "MAX_PRIORITY", "Scheduler",
           "SchedulerConfig", "SchedulingPolicy", "POLICY_REGISTRY",
           "SJFPolicy", "FairSharePolicy", "PreemptiveBackfillPolicy",
           "MoldableStartPolicy",
           "make_policy", "register_policy", "ActionRecord",
           "ClusterSimulator", "SimConfig", "SimReport",
           "SimulationEngine", "Event", "JobSubmit", "JobFinish",
           "ReconfigPoint", "ExpandTimeout", "NodeFail", "PhaseChange",
           "StragglerOnset", "StragglerScan", "CheckpointTick",
           "TrafficTick",
           "NodeJoin", "NodeDrain", "NodePowerOff", "NodePowerOn",
           "CapacityConfig", "CapacityManager", "CHURN_SCENARIOS",
           "churn_schedule", "plan_drain"]
