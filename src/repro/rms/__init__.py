"""RMS: Slurm-analogue resource manager (cluster, policy, scheduler, sim)."""
from repro.rms.cluster import Cluster
from repro.rms.costmodel import PAPER_APPS, AppModel, ReconfigCostModel, lm_app_model
from repro.rms.job import Job, JobState
from repro.rms.policy import PolicyConfig, ReconfigPolicy, factor_sizes
from repro.rms.scheduler import MAX_PRIORITY, Scheduler, SchedulerConfig
from repro.rms.simulator import (ActionRecord, ClusterSimulator, SimConfig,
                                 SimReport)

__all__ = ["Cluster", "PAPER_APPS", "AppModel", "ReconfigCostModel",
           "lm_app_model", "Job", "JobState", "PolicyConfig",
           "ReconfigPolicy", "factor_sizes", "MAX_PRIORITY", "Scheduler",
           "SchedulerConfig", "ActionRecord", "ClusterSimulator",
           "SimConfig", "SimReport"]
