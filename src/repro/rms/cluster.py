"""Homogeneous-cluster resource inventory.

Tracks node identity (not just counts) so node failures and stragglers can
target specific nodes.  Expansion reuses a job's original nodes and appends
new ones (the paper's resizer-job protocol, §5.2.1); shrinking releases the
tail (the sender nodes of the fold, §5.2.2).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Set


@dataclasses.dataclass
class Cluster:
    num_nodes: int

    def __post_init__(self):
        self.free: List[int] = list(range(self.num_nodes))
        self.owned: Dict[int, List[int]] = {}     # job_id -> ordered node list
        self.dead: Set[int] = set()
        self.slow: Dict[int, float] = {}          # node -> slowdown multiplier

    # -- queries --------------------------------------------------------------

    @property
    def free_nodes(self) -> int:
        return len(self.free)

    def allocation(self, job_id: int) -> int:
        return len(self.owned.get(job_id, ()))

    @property
    def allocated_nodes(self) -> int:
        return sum(len(v) for v in self.owned.values())

    def job_rate_factor(self, job_id: int) -> float:
        """min over owned nodes of 1/slowdown — a straggler gates the job."""
        if not self.slow:
            return 1.0            # hot path: no stragglers anywhere
        nodes = self.owned.get(job_id, ())
        if not nodes:
            return 1.0
        worst = max((self.slow.get(n, 1.0) for n in nodes), default=1.0)
        return 1.0 / worst

    # -- mutations -------------------------------------------------------------

    def allocate(self, job_id: int, n: int) -> List[int]:
        if n > len(self.free):
            raise RuntimeError(
                f"over-allocation: job {job_id} wants {n}, free {len(self.free)}")
        nodes, self.free = self.free[:n], self.free[n:]
        self.owned.setdefault(job_id, []).extend(nodes)
        return nodes

    def resize(self, job_id: int, new_n: int) -> int:
        """Grow/shrink a job's allocation; returns delta (nodes gained)."""
        cur = self.allocation(job_id)
        if new_n > cur:
            self.allocate(job_id, new_n - cur)
        elif new_n < cur:
            released = self.owned[job_id][new_n:]
            self.owned[job_id] = self.owned[job_id][:new_n]
            self.free.extend(released)
        return new_n - cur

    def release(self, job_id: int) -> None:
        self.free.extend(self.owned.pop(job_id, []))

    # -- failures / stragglers ---------------------------------------------------

    def fail_node(self, node: int):
        """Mark a node dead. Returns the owning job_id (or None)."""
        self.dead.add(node)
        if node in self.free:
            self.free.remove(node)
            return None
        for job_id, nodes in self.owned.items():
            if node in nodes:
                nodes.remove(node)
                return job_id
        return None

    def set_straggler(self, node: int, slowdown: float):
        """Owning job (if any) is returned so the RMS can react."""
        self.slow[node] = slowdown
        for job_id, nodes in self.owned.items():
            if node in nodes:
                return job_id
        return None

    def swap_straggler(self, job_id: int) -> int:
        """Migrate the job off its slowest node onto a free healthy node.

        Returns the number of swaps performed (0 or 1).  Data movement is one
        slice migration (``repro.core.redistribute.migrate_slice``).
        """
        nodes = self.owned.get(job_id, ())
        if not nodes:
            return 0
        worst = max(nodes, key=lambda n: self.slow.get(n, 1.0))
        if self.slow.get(worst, 1.0) <= 1.0:
            return 0
        healthy = [n for n in self.free
                   if self.slow.get(n, 1.0) <= 1.0 and n not in self.dead]
        if not healthy:
            return 0
        repl = healthy[0]
        self.free.remove(repl)
        idx = nodes.index(worst)
        nodes[idx] = repl
        self.free.append(worst)
        return 1
