"""Homogeneous-cluster resource inventory with an *elastic* node pool.

Tracks node identity (not just counts) so node failures, stragglers, and
capacity churn can target specific nodes.  Expansion reuses a job's
original nodes and appends new ones (the paper's resizer-job protocol,
§5.2.1); shrinking releases the tail (the sender nodes of the fold,
§5.2.2).

Node lifecycle (each node is in exactly one state at any time)::

    join ──> FREE <──────> OWNED            fail ──> DEAD (terminal unless
              │  quarantine │ drain                   re-joined "repaired")
              │  (slow,     │  (vacate first)
              │  alloc-last)▼
              ├─────────> DRAINING ──join──> FREE
              ▼
          POWERED_OFF ──power-on──> FREE

``live_capacity`` — free + quarantined + allocated — is the single source
of truth for "how many nodes can host work right now": band clamping,
utilization denominators, and scheduler normalization all read it instead
of the construction-time ``num_nodes`` (which is *initial* capacity and is
never mutated after churn; see the stale-denominator bug this replaced).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Set


@dataclasses.dataclass
class Cluster:
    num_nodes: int          # initial capacity (nodes present at t=0)

    def __post_init__(self):
        self.free: List[int] = list(range(self.num_nodes))
        self.quarantine: List[int] = []   # slow nodes: allocatable *last*
        self.owned: Dict[int, List[int]] = {}     # job_id -> ordered node list
        self.draining: List[int] = []     # drained out of the pool (rejoinable)
        self.powered_off: List[int] = []  # parked for energy (rebootable)
        self.dead: Set[int] = set()
        self.slow: Dict[int, float] = {}          # node -> slowdown multiplier
        # drain requested on an owned node: routed to `draining` (not `free`)
        # the moment its job vacates it
        self._drain_pending: Set[int] = set()
        self.nodes_ever_joined: int = self.num_nodes
        self._next_node_id: int = self.num_nodes

    # -- queries --------------------------------------------------------------

    @property
    def free_nodes(self) -> int:
        """Allocatable nodes right now (healthy free + quarantined)."""
        return len(self.free) + len(self.quarantine)

    @property
    def live_capacity(self) -> int:
        """Nodes that can host work now: free + quarantined + allocated.

        Excludes drained, powered-off, and dead nodes — the one denominator
        for band clamping, utilization, and scheduler normalization.
        """
        return len(self.free) + len(self.quarantine) + self.allocated_nodes

    def allocation(self, job_id: int) -> int:
        return len(self.owned.get(job_id, ()))

    @property
    def allocated_nodes(self) -> int:
        return sum(len(v) for v in self.owned.values())

    def state_counts(self) -> Dict[str, int]:
        """Disjoint per-state node counts; values sum to
        ``nodes_ever_joined`` (the conservation invariant the capacity
        property test pins).  An owned node with a pending drain counts as
        ``allocated`` until its job vacates it."""
        return {"free": self.free_nodes,
                "allocated": self.allocated_nodes,
                "draining": len(self.draining),
                "powered_off": len(self.powered_off),
                "dead": len(self.dead)}

    def owner_of(self, node: int) -> Optional[int]:
        for job_id, nodes in sorted(self.owned.items()):
            if node in nodes:
                return job_id
        return None

    def job_rate_factor(self, job_id: int) -> float:
        """min over owned nodes of 1/slowdown — a straggler gates the job."""
        if not self.slow:
            return 1.0            # hot path: no stragglers anywhere
        nodes = self.owned.get(job_id, ())
        if not nodes:
            return 1.0
        worst = max((self.slow.get(n, 1.0) for n in nodes), default=1.0)
        return 1.0 / worst

    # -- mutations -------------------------------------------------------------

    def allocate(self, job_id: int, n: int) -> List[int]:
        """Healthy-first: quarantined (slow) nodes are handed out only when
        no healthy free node is left."""
        if n > self.free_nodes:
            raise RuntimeError(
                f"over-allocation: job {job_id} wants {n}, "
                f"free {self.free_nodes}")
        nodes, self.free = self.free[:n], self.free[n:]
        if len(nodes) < n:
            k = n - len(nodes)
            nodes += self.quarantine[:k]
            self.quarantine = self.quarantine[k:]
        self.owned.setdefault(job_id, []).extend(nodes)
        return nodes

    def _route_released(self, nodes: List[int]) -> None:
        """Return vacated nodes to the right pool: a pending drain retires
        the node, a known-slow node is quarantined (allocate healthy-first),
        everything else goes back to ``free``."""
        for node in nodes:
            if node in self._drain_pending:
                self._drain_pending.discard(node)
                self.draining.append(node)
            elif self.slow.get(node, 1.0) > 1.0:
                self.quarantine.append(node)
            else:
                self.free.append(node)

    def resize(self, job_id: int, new_n: int) -> int:
        """Grow/shrink a job's allocation; returns delta (nodes gained)."""
        cur = self.allocation(job_id)
        if new_n > cur:
            self.allocate(job_id, new_n - cur)
        elif new_n < cur:
            released = self.owned[job_id][new_n:]
            self.owned[job_id] = self.owned[job_id][:new_n]
            self._route_released(released)
        return new_n - cur

    def release(self, job_id: int) -> None:
        self._route_released(self.owned.pop(job_id, []))

    def move_to_tail(self, job_id: int, node: int) -> bool:
        """Reorder a job's node list so ``node`` is released first by the
        next tail-shrink (the §5.2.2 fold senders are the tail)."""
        nodes = self.owned.get(job_id)
        if not nodes or node not in nodes:
            return False
        nodes.remove(node)
        nodes.append(node)
        return True

    # -- capacity churn ---------------------------------------------------------

    def _remove_from_pools(self, node: int) -> Optional[str]:
        """Drop ``node`` from whichever idle pool holds it; returns the pool
        name or None when the node is owned / not a live member."""
        for name in ("free", "quarantine", "powered_off", "draining"):
            pool: List[int] = getattr(self, name)
            if node in pool:
                pool.remove(node)
                return name
        return None

    def join_node(self, node: Optional[int] = None) -> int:
        """Bring a node into the ``free`` pool.

        ``None`` (or a negative id) joins a brand-new node under a fresh
        id; a known drained or dead id re-joins (maintenance done /
        repaired); an unknown explicit id joins as new capacity.  Joining a
        node that is already live is a no-op (idempotent).
        """
        if node is None or node < 0:
            node = self._next_node_id
            self._next_node_id += 1
            self.nodes_ever_joined += 1
        elif node in self.draining:
            self.draining.remove(node)
        elif node in self.dead:
            self.dead.discard(node)     # repaired: re-enters healthy
        elif node in self.free or node in self.quarantine or \
                node in self.powered_off or self.owner_of(node) is not None:
            return node                 # already a live member
        else:
            self.nodes_ever_joined += 1
            self._next_node_id = max(self._next_node_id, node + 1)
        self.slow.pop(node, None)       # joins come back healthy
        self._drain_pending.discard(node)
        self.free.append(node)
        return node

    def drain_node(self, node: int) -> Optional[int]:
        """Take ``node`` out of the allocatable pool for maintenance /
        reclamation.

        Idle nodes (free / quarantined / powered-off) retire immediately;
        returns ``None``.  An owned node returns the owning ``job_id`` and
        is flagged: the caller must negotiate the job off it (migrate /
        shrink / requeue) — the node retires automatically when vacated.
        Draining a dead, already-draining, or unknown node is a no-op.
        """
        if node in self.dead or node in self.draining:
            return None
        pool = self._remove_from_pools(node)
        if pool is not None:
            self.draining.append(node)
            return None
        owner = self.owner_of(node)
        if owner is not None:
            self._drain_pending.add(node)
        return owner

    def power_off_node(self, node: int) -> bool:
        """Park an *idle* node (free or quarantined) to save energy."""
        if node in self.free:
            self.free.remove(node)
        elif node in self.quarantine:
            self.quarantine.remove(node)
        else:
            return False
        self.powered_off.append(node)
        return True

    def power_on_node(self, node: int) -> bool:
        """Bring a powered-off node back to the allocatable pool."""
        if node not in self.powered_off:
            return False
        self.powered_off.remove(node)
        if self.slow.get(node, 1.0) > 1.0:
            self.quarantine.append(node)
        else:
            self.free.append(node)
        return True

    # -- failures / stragglers ---------------------------------------------------

    def fail_node(self, node: int):
        """Mark a node dead; idempotent.  Returns the owning job_id (or
        None).  A second failure of the same node — or of a node that never
        joined / already left — changes nothing, so capacity accounting
        cannot be double-decremented (regression: ``_on_failure`` used to
        charge ``num_nodes`` once per event)."""
        if node in self.dead:
            return None
        pool = self._remove_from_pools(node)
        if pool is not None:
            self.dead.add(node)
            self._drain_pending.discard(node)
            return None
        for job_id, nodes in sorted(self.owned.items()):
            if node in nodes:
                nodes.remove(node)
                self.dead.add(node)
                self._drain_pending.discard(node)
                return job_id
        return None                     # unknown node: nothing to fail

    def set_straggler(self, node: int, slowdown: float):
        """Owning job (if any) is returned so the RMS can react.  A free
        slow node moves to the quarantine pool (allocated healthy-first)."""
        self.slow[node] = slowdown
        if slowdown > 1.0 and node in self.free:
            self.free.remove(node)
            self.quarantine.append(node)
        return self.owner_of(node)

    def replace_node(self, job_id: int, node: int) -> Optional[int]:
        """Swap ``node`` out of a job's allocation for a healthy free node
        (one slice migration).  The vacated node is routed by state:
        drain-pending retires it, slow quarantines it.  Returns the
        replacement node id, or None when no healthy node is free."""
        nodes = self.owned.get(job_id)
        if not nodes or node not in nodes or not self.free:
            return None
        repl = self.free.pop(0)
        nodes[nodes.index(node)] = repl
        self._route_released([node])
        return repl

    def swap_straggler(self, job_id: int) -> int:
        """Migrate the job off its slowest node onto a free healthy node.

        Returns the number of swaps performed (0 or 1).  Data movement is
        one slice migration (``repro.core.redistribute.migrate_slice``).
        The swapped-out straggler lands in the quarantine pool — never at
        the head of ``free`` — so the very next allocation cannot hand the
        known-slow node to a fresh job while healthy nodes exist.
        """
        nodes = self.owned.get(job_id, ())
        if not nodes:
            return 0
        worst = max(nodes, key=lambda n: self.slow.get(n, 1.0))
        if self.slow.get(worst, 1.0) <= 1.0:
            return 0
        return 1 if self.replace_node(job_id, worst) is not None else 0
