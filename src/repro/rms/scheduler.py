"""Queue scheduling policies: multifactor priority + a pluggable registry.

The paper configures Slurm with the *backfill* scheduling policy and the
*multifactor* priority plug-in (defaults); that pair is the ``"easy"``
policy below and remains the default.  The registry adds the classic
alternatives studied in the malleable-scheduling literature (Chadha et al.;
Zojer et al.) so trace replays can compare them:

- ``fcfs``           strict priority order, no backfill — the head of the
                     queue blocks everything behind it.
- ``easy``           EASY backfill: the head job gets a reservation at the
                     earliest time enough nodes free up; lower-priority jobs
                     may start now only if they don't delay that reservation
                     (using runtime estimates).
- ``conservative``   every queued job gets a reservation; a backfill
                     candidate must not delay *any* reservation.  With
                     ``backfill=False`` it degenerates to strict priority
                     order (fcfs semantics).
- ``malleable``      EASY variant that knows running malleable jobs can be
                     shrunk at their next reconfiguration point, so the head
                     reservation lands earlier and backfill is bolder.
- ``sjf``            shortest-job-first EASY variant: queue ordered by
                     estimated remaining runtime, with an age guard — jobs
                     older than ``sjf_starvation_age_s`` jump ahead of every
                     younger job, so SJF never starves long jobs.
- ``fairshare``      EASY variant whose priority subtracts each user's
                     exponentially-decayed node-seconds usage
                     (half-life ``fairshare_halflife_s``) — heavy users sink,
                     light users rise.
- ``preempt``        preemptive backfill: when the head reservation slips
                     beyond ``preempt_grace_s``, running malleable jobs of
                     lower priority are shrunk one factor step (optionally
                     requeued) until the head starts *now*.
- ``moldable``       start-size optimizer: moldable/malleable jobs start at
                     the power-of-two size in ``[min_nodes, max_nodes]``
                     minimizing estimated completion (runtime scaling + the
                     ``ReconfigCostModel`` cost of factor-stepping to the
                     preferred size afterwards).

Shared priority: ``age_weight * age + size_weight * (1 - size/cluster)
+ boost`` where *boost* is the maximum-priority path used for resizer jobs
and for queued jobs that triggered a wide-optimization shrink (§4.3).

Evolving jobs (§2 EVOLVING): policies read ``Job.min_nodes`` /
``Job.max_nodes`` / ``Job.preferred`` / ``Job.requested_nodes`` at
schedule time — these are the *live* band, rewritten by the simulator's
``PhaseChange`` handler each time the application enters a new phase.  No
policy may cache submission-time copies: the malleable release estimate,
the preempt victim shrink floor, and the moldable candidate sizes all
follow the current phase automatically because they go through the live
fields.

Select a policy via ``SchedulerConfig(policy="conservative")`` — reachable
from ``SimConfig(sched=...)`` — or register new ones with
``@register_policy("name")``.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Dict, List, Optional, Tuple, Type

from repro.rms.cluster import Cluster
from repro.rms.costmodel import ReconfigCostModel
from repro.rms.job import Job, JobState

MAX_PRIORITY = 1e12

RuntimeEstimate = Callable[[Job], float]


@dataclasses.dataclass
class SchedulerConfig:
    age_weight: float = 1.0
    size_weight: float = 100.0
    backfill: bool = True          # False => strict priority, no backfill
    policy: str = "easy"           # key into POLICY_REGISTRY
    # -- sjf ------------------------------------------------------------------
    sjf_starvation_age_s: float = 3600.0   # age guard: older jobs jump ahead
    # -- fairshare ------------------------------------------------------------
    fairshare_halflife_s: float = 3600.0   # usage decay half-life
    fairshare_weight: float = 200.0        # priority penalty per capacity-
                                           # half-life of decayed usage
    # -- preempt --------------------------------------------------------------
    preempt_grace_s: float = 60.0          # tolerated head-reservation slip
    preempt_requeue: bool = False          # requeue victims stuck at min size


# ---------------------------------------------------------------------------
# Registry
# ---------------------------------------------------------------------------

POLICY_REGISTRY: Dict[str, Type["SchedulingPolicy"]] = {}


def register_policy(name: str):
    def deco(cls: Type["SchedulingPolicy"]):
        cls.name = name
        POLICY_REGISTRY[name] = cls
        return cls
    return deco


def make_policy(cluster: Cluster, config: SchedulerConfig,
                cost: Optional[ReconfigCostModel] = None
                ) -> "SchedulingPolicy":
    try:
        cls = POLICY_REGISTRY[config.policy]
    except KeyError:
        raise ValueError(
            f"unknown scheduling policy {config.policy!r}; "
            f"registered: {sorted(POLICY_REGISTRY)}") from None
    return cls(cluster, config, cost=cost)


# ---------------------------------------------------------------------------
# Policies
# ---------------------------------------------------------------------------

class SchedulingPolicy:
    """Base: multifactor priority + a `schedule` hook.

    ``schedule`` must not mutate the cluster; the simulator/runtime applies
    starts so that start-up costs are accounted in one place.
    """

    name = "base"

    def __init__(self, cluster: Cluster, config: SchedulerConfig,
                 cost: Optional[ReconfigCostModel] = None):
        self.cluster = cluster
        self.config = config
        # The reconfiguration cost model policies reason with (moldable's
        # start-size optimizer) — calibrated when the caller threads a
        # fitted model through (``Scheduler(..., cost=...)``), the
        # paper-fit constants otherwise.
        self.cost = cost if cost is not None else ReconfigCostModel()

    # -- priority ------------------------------------------------------------

    def priority(self, job: Job, now: float) -> float:
        if job.priority_boost:
            return job.priority_boost
        age = now - job.submit_time
        # normalize by *live* capacity so the size bias tracks the cluster
        # that actually exists after failures/drains/joins
        size = 1.0 - job.requested_nodes / max(self.cluster.live_capacity, 1)
        return (self.config.age_weight * age
                + self.config.size_weight * size)

    def order(self, pending: List[Job], now: float) -> List[Job]:
        return sorted(pending, key=lambda j: (-self.priority(j, now),
                                              j.submit_time, j.job_id))

    # -- helpers -------------------------------------------------------------

    def _queue(self, pending: List[Job], now: float) -> List[Job]:
        return self.order([j for j in pending
                           if j.state is JobState.PENDING], now)

    def _releases(self, running: List[Job], now: float,
                  runtime_estimate: RuntimeEstimate
                  ) -> List[Tuple[float, int]]:
        """(time, nodes) future node releases, soonest first."""
        return sorted(
            (now + max(runtime_estimate(j), 0.0), j.nodes)
            for j in running if j.state is JobState.RUNNING)

    # -- hook ----------------------------------------------------------------

    def schedule(self, pending: List[Job], running: List[Job], now: float,
                 runtime_estimate: RuntimeEstimate
                 ) -> List[Tuple[Job, int]]:
        raise NotImplementedError


@register_policy("fcfs")
class FCFSPolicy(SchedulingPolicy):
    """Strict priority order; the first job that doesn't fit blocks all."""

    def schedule(self, pending, running, now, runtime_estimate):
        free = self.cluster.free_nodes
        starts: List[Tuple[Job, int]] = []
        for job in self._queue(pending, now):
            if job.requested_nodes > free:
                break
            starts.append((job, job.requested_nodes))
            free -= job.requested_nodes
        return starts


@register_policy("easy")
class EasyBackfillPolicy(SchedulingPolicy):
    """EASY backfill (paper §7.2 setup): one reservation for the head job.

    Subclasses customize *sizing*, not structure: ``_start_size`` picks the
    allocation a job starts with now (None: must wait), ``_reservation_need``
    the head's reservation size, ``_est_end`` the backfill end estimate —
    the moldable start-size optimizer overrides exactly these three.
    """

    def _start_size(self, job: Job, free: int,
                    runtime_estimate: RuntimeEstimate) -> Optional[int]:
        """Nodes to start ``job`` with right now; None when it must wait."""
        return job.requested_nodes if job.requested_nodes <= free else None

    def _reservation_need(self, head: Job) -> int:
        return head.requested_nodes

    def _est_end(self, job: Job, size: int, now: float,
                 runtime_estimate: RuntimeEstimate) -> float:
        return now + max(runtime_estimate(job), 0.0)

    def schedule(self, pending, running, now, runtime_estimate):
        free = self.cluster.free_nodes
        queue = self._queue(pending, now)
        starts: List[Tuple[Job, int]] = []
        i = 0
        # Head-of-queue jobs start in priority order while they fit.
        while i < len(queue):
            s = self._start_size(queue[i], free, runtime_estimate)
            if s is None:
                break
            starts.append((queue[i], s))
            free -= s
            i += 1
        if i >= len(queue) or not self.config.backfill:
            return starts
        # Reservation for the blocked head: when will enough nodes free up?
        head_need = self._reservation_need(queue[i])
        avail = free
        shadow_time: Optional[float] = None
        shadow_free_at_reservation = 0
        for t, n in self._releases(running, now, runtime_estimate):
            avail += n
            if avail >= head_need:
                shadow_time = t
                shadow_free_at_reservation = avail - head_need
                break
        # Backfill the rest: start now iff it fits in `free` and either ends
        # before the reservation or fits in the reservation's spare nodes.
        for job in queue[i + 1:]:
            s = self._start_size(job, free, runtime_estimate)
            if s is None:
                continue
            est_end = self._est_end(job, s, now, runtime_estimate)
            if shadow_time is None or est_end <= shadow_time or \
                    s <= shadow_free_at_reservation:
                starts.append((job, s))
                free -= s
                if shadow_time is not None and est_end > shadow_time:
                    shadow_free_at_reservation -= s
        return starts


@register_policy("conservative")
class ConservativeBackfillPolicy(SchedulingPolicy):
    """Conservative backfill: no queued job's reservation may be delayed.

    Builds a piecewise node-availability profile from running-job release
    estimates, reserves every queued job at its earliest feasible slot in
    priority order, and lets a job start *now* only when `now` is that
    earliest slot — so nobody leapfrogs anybody's reservation.

    ``SchedulerConfig.backfill=False`` is honored: without backfill no job
    may start ahead of a blocked higher-priority job, which is exactly fcfs.
    """

    def schedule(self, pending, running, now, runtime_estimate):
        if not self.config.backfill:
            return FCFSPolicy.schedule(self, pending, running, now,
                                       runtime_estimate)
        queue = self._queue(pending, now)
        if not queue:
            return []
        # profile: sorted list of [time, free_nodes_from_t_onward]
        profile: List[List[float]] = [[now, float(self.cluster.free_nodes)]]
        for t, n in self._releases(running, now, runtime_estimate):
            profile.append([t, profile[-1][1] + n])
        starts: List[Tuple[Job, int]] = []
        for job in queue:
            need = job.requested_nodes
            dur = max(runtime_estimate(job), 0.0)
            t0 = self._earliest(profile, need, dur)
            if t0 is None:
                # Never fits the foreseeable profile (e.g. request larger
                # than the cluster): no reservation, nothing carved.
                continue
            if t0 <= now:
                starts.append((job, need))
            self._carve(profile, t0, t0 + dur, need)
        return starts

    @staticmethod
    def _earliest(profile, need: int, dur: float) -> Optional[float]:
        """Earliest start where `need` nodes stay free for `dur` seconds;
        None when no such window exists in the profile."""
        for i, (t0, _) in enumerate(profile):
            ok = True
            for t, avail in profile[i:]:
                if t >= t0 + dur:
                    break
                if avail < need:
                    ok = False
                    break
            if ok:
                return t0
        return None

    @staticmethod
    def _carve(profile, t0: float, t1: float, need: int) -> None:
        """Subtract `need` nodes from the profile on [t0, t1)."""
        # Split segments at t0 and t1 so subtraction stays piecewise-exact.
        for t_split in (t0, t1):
            for i, (t, avail) in enumerate(profile):
                if t == t_split:
                    break
                if t > t_split:
                    profile.insert(i, [t_split, profile[i - 1][1]])
                    break
            else:
                profile.append([t_split, profile[-1][1]])
        for seg in profile:
            if t0 <= seg[0] < t1:
                seg[1] -= need


@register_policy("malleable")
class MalleableEasyPolicy(EasyBackfillPolicy):
    """EASY backfill that exploits malleability of *running* jobs.

    A running malleable job can be shrunk by one factor step at its next
    reconfiguration point (§4.3 wide optimization), so those nodes count as
    an early release when placing the head reservation.  The reservation
    lands earlier, backfill windows shrink, and queued jobs start sooner —
    the scheduler-side half of the paper's productivity argument.

    ``j.min_nodes`` here is the *live* band floor: for an evolving job it
    reflects the current phase, so a phase that raises the floor stops this
    policy from counting a shrink that the DMR check would no longer grant.
    """

    def _releases(self, running, now, runtime_estimate):
        releases: List[Tuple[float, int]] = []
        for j in running:
            if j.state is not JobState.RUNNING:
                continue
            end = now + max(runtime_estimate(j), 0.0)
            shrunk = j.nodes // max(j.factor, 2)
            # A SERVING job negotiates on SLO pressure, not queue pressure:
            # its DMR check only releases nodes when traffic ebbs, so the
            # reservation must not bank on shrinking it (the grant may
            # never come while the diurnal peak holds).
            if j.serving:
                releases.append((end, j.nodes))
                continue
            if j.malleable and j.nodes > shrunk >= max(j.min_nodes, 1):
                # Split, not duplicate: the shrinkable part frees at the
                # next reconfig point, only the remainder at end of run.
                horizon = now + max(j.check_period_s, 1.0)
                releases.append((horizon, j.nodes - shrunk))
                releases.append((end, shrunk))
            else:
                releases.append((end, j.nodes))
        return sorted(releases)


@register_policy("sjf")
class SJFPolicy(EasyBackfillPolicy):
    """Shortest-job-first with EASY backfill and a starvation guard.

    Priority ranks by *estimated remaining runtime* (shorter first) plus the
    usual age term; any job older than ``sjf_starvation_age_s`` is promoted
    above every younger job (among the aged, older wins), so a long job can
    wait at most the guard age plus the drain of already-started work.
    """

    def __init__(self, cluster: Cluster, config: SchedulerConfig,
                 cost: Optional[ReconfigCostModel] = None):
        super().__init__(cluster, config, cost)
        self._est: Optional[RuntimeEstimate] = None

    def priority(self, job: Job, now: float) -> float:
        if job.priority_boost:
            return job.priority_boost
        age = now - job.submit_time
        if age >= self.config.sjf_starvation_age_s:
            # Aged out: beats any runtime estimate, loses only to boosts.
            return MAX_PRIORITY / 2 + age
        est = self._est(job) if self._est is not None else 0.0
        return self.config.age_weight * age - max(est, 0.0)

    def schedule(self, pending, running, now, runtime_estimate):
        self._est = runtime_estimate
        try:
            return super().schedule(pending, running, now, runtime_estimate)
        finally:
            self._est = None


@register_policy("fairshare")
class FairSharePolicy(EasyBackfillPolicy):
    """Multifactor priority minus per-user decayed usage (Slurm fair-share).

    Usage is node-seconds, decayed exponentially with half-life
    ``fairshare_halflife_s`` and charged on every ``schedule`` call from the
    running set.  The penalty is normalized by one *capacity half-life*
    (``num_nodes * halflife`` node-seconds), so ``fairshare_weight`` is
    comparable to the other priority weights.
    """

    def __init__(self, cluster: Cluster, config: SchedulerConfig,
                 cost: Optional[ReconfigCostModel] = None):
        super().__init__(cluster, config, cost)
        self._usage: Dict[int, float] = {}
        self._last_t: Optional[float] = None
        self._known: Dict[int, Job] = {}   # every job ever seen, until final

    # -- usage ledger --------------------------------------------------------

    def usage(self, user: int) -> float:
        return self._usage.get(user, 0.0)

    def record_usage(self, user: int, node_seconds: float) -> None:
        self._usage[user] = self._usage.get(user, 0.0) + node_seconds

    @staticmethod
    def _node_seconds(job: Job, a: float, b: float) -> float:
        """Node-seconds ``job`` consumed over ``(a, b]``, from its recorded
        allocation history (exact across starts/resizes/requeues)."""
        if b <= a:
            return 0.0
        hist = job.nodes_history
        if not hist:
            return 0.0
        total = 0.0
        for (t0, n0), (t1, _n1) in zip(hist, hist[1:]):
            lo, hi = max(t0, a), min(t1, b)
            if hi > lo:
                total += n0 * (hi - lo)
        # the open-ended last segment accrues only while still running
        t_last, n_last = hist[-1]
        if job.state is JobState.RUNNING and b > max(t_last, a):
            total += n_last * (b - max(t_last, a))
        return total

    def observe(self, jobs: List[Job], now: float) -> None:
        """Decay the ledger to ``now`` and charge the interval since the
        previous call.

        Every job ever seen (pending included) is tracked until it
        completes, and charged from its ``nodes_history`` — so a job that
        starts *and* finishes between two passes, is resized, or is
        requeued by a failure/preemption is still billed exactly for the
        node-seconds it held.
        """
        last = now if self._last_t is None else self._last_t
        dt = now - last
        if dt > 0:
            half = max(self.config.fairshare_halflife_s, 1e-9)
            decay = 0.5 ** (dt / half)
            self._usage = {u: v * decay
                           for u, v in sorted(self._usage.items())}
        for j in jobs:
            self._known.setdefault(j.job_id, j)
        if dt > 0:
            finished = []
            for job_id, j in sorted(self._known.items()):
                ns = self._node_seconds(j, last, now)
                if ns > 0:
                    self.record_usage(j.user, ns)
                if j.state in (JobState.COMPLETED, JobState.CANCELLED):
                    finished.append(job_id)     # history is final: settled
            for job_id in finished:
                del self._known[job_id]
        self._last_t = now

    # -- policy --------------------------------------------------------------

    def priority(self, job: Job, now: float) -> float:
        if job.priority_boost:
            return job.priority_boost
        cap = max(self.cluster.live_capacity, 1) * \
            max(self.config.fairshare_halflife_s, 1.0)
        return (super().priority(job, now)
                - self.config.fairshare_weight * self.usage(job.user) / cap)

    def schedule(self, pending, running, now, runtime_estimate):
        self.observe(list(pending) + list(running), now)
        return super().schedule(pending, running, now, runtime_estimate)


@register_policy("preempt")
class PreemptiveBackfillPolicy(EasyBackfillPolicy):
    """Preemptive backfill: shrink low-priority malleable runners for the head.

    When the blocked head's reservation would land more than
    ``preempt_grace_s`` in the future, running malleable jobs with priority
    below the head's are shrunk by one factor step (lowest priority first)
    until the head fits *now*.  Victims already at their minimum size are
    requeued instead when ``preempt_requeue`` is set.  If no plan frees
    enough nodes the policy falls back to plain EASY — no pointless churn.

    ``schedule`` itself stays mutation-free: the shrink/requeue directives
    are queued on :attr:`preemptions` (``(job, new_nodes)``, ``0`` means
    requeue) and applied by the simulator/runtime *before* the returned
    starts, so capacity accounting stays in one place.
    """

    def __init__(self, cluster: Cluster, config: SchedulerConfig,
                 cost: Optional[ReconfigCostModel] = None):
        super().__init__(cluster, config, cost)
        self.preemptions: List[Tuple[Job, int]] = []

    def pop_preemptions(self) -> List[Tuple[Job, int]]:
        out, self.preemptions = self.preemptions, []
        return out

    def _head_slip(self, free, head, running, now, runtime_estimate):
        """Seconds until the head's reservation (None: never in profile)."""
        avail = free
        for t, n in self._releases(running, now, runtime_estimate):
            avail += n
            if avail >= head.requested_nodes:
                return t - now
        return None

    def schedule(self, pending, running, now, runtime_estimate):
        self.preemptions = []
        free = self.cluster.free_nodes
        queue = self._queue(pending, now)
        starts: List[Tuple[Job, int]] = []
        i = 0
        # Same head-of-queue loop as EASY, via the sizing hook so preempt
        # composes with sizing overrides.
        while i < len(queue):
            s = self._start_size(queue[i], free, runtime_estimate)
            if s is None:
                break
            starts.append((queue[i], s))
            free -= s
            i += 1
        if i >= len(queue):
            return starts
        head = queue[i]
        slip = self._head_slip(free, head, running, now, runtime_estimate)
        if slip is not None and slip <= self.config.preempt_grace_s:
            return super().schedule(pending, running, now, runtime_estimate)
        head_pr = self.priority(head, now)
        victims = sorted(
            (j for j in running if j.state is JobState.RUNNING
             and j.malleable and self.priority(j, now) < head_pr),
            key=lambda j: (self.priority(j, now), j.job_id))
        plan: List[Tuple[Job, int]] = []
        freed = 0
        for v in victims:
            if free + freed >= head.requested_nodes:
                break
            factor = max(v.factor, 2)
            shrunk = v.nodes // factor
            if v.nodes % factor == 0 and shrunk >= max(v.min_nodes, 1):
                plan.append((v, shrunk))
                freed += v.nodes - shrunk
            elif self.config.preempt_requeue:
                plan.append((v, 0))
                freed += v.nodes
        if not plan or free + freed < head.requested_nodes:
            return super().schedule(pending, running, now, runtime_estimate)
        self.preemptions = plan
        starts.append((head, head.requested_nodes))
        free = free + freed - head.requested_nodes
        # Continue in strict priority order with what's left; stopping at the
        # first non-fitting job protects the *new* head from being leapfrogged.
        for job in queue[i + 1:]:
            s = self._start_size(job, free, runtime_estimate)
            if s is None:
                break
            starts.append((job, s))
            free -= s
        return starts


@register_policy("moldable")
class MoldableStartPolicy(EasyBackfillPolicy):
    """Moldable start-size optimizer (ROADMAP "policy zoo" item).

    For each startable job, picks the power-of-two size in
    ``[min_nodes, max_nodes]`` minimizing estimated completion: runtime
    scaled linearly from the requested-size estimate, plus — for malleable
    jobs — the :class:`ReconfigCostModel` cost of factor-stepping from the
    start size to the preferred size afterwards.  Jobs whose range contains
    no power of two start at their requested size unchanged.

    Uses the base class's ``self.cost`` — so a calibrated model threaded
    through ``SimConfig(cost=...)`` tightens the start-size estimates too.
    """

    # -- the optimizer -------------------------------------------------------

    @staticmethod
    def candidate_sizes(job: Job, cap: Optional[int] = None) -> List[int]:
        """Powers of two within the job's [min_nodes, max_nodes].

        ``cap`` (the cluster's live capacity) tightens the ceiling so the
        optimizer never weighs sizes the surviving cluster cannot host.
        """
        hi = job.max_nodes if cap is None else min(job.max_nodes, cap)
        sizes, p = [], 1
        while p <= hi:
            if p >= max(job.min_nodes, 1):
                sizes.append(p)
            p *= 2
        return sizes

    def reconfig_path_s(self, job: Job, start: int) -> float:
        """Redistribution cost of factor-stepping start -> preferred."""
        target = job.preferred or job.requested_nodes
        factor = max(job.factor, 2)
        total, cur = 0.0, start
        while cur < target and cur * factor <= job.max_nodes:
            total += self.cost.resize_time(cur, cur * factor, job.data_bytes)
            cur *= factor
        while cur > target and cur % factor == 0 and \
                cur // factor >= max(job.min_nodes, 1):
            total += self.cost.resize_time(cur, cur // factor, job.data_bytes)
            cur //= factor
        return total

    def best_start(self, job: Job, free: int,
                   runtime_estimate: RuntimeEstimate) -> Optional[int]:
        """Best power-of-two start size fitting ``free`` (None: none fits)."""
        cands = [s for s in self.candidate_sizes(
            job, self.cluster.live_capacity) if s <= free]
        if not cands:
            return None
        base = max(runtime_estimate(job), 0.0)
        req = max(job.requested_nodes, 1)
        best, best_cost = None, None
        for s in cands:
            t = base * req / s          # ~linear scaling around requested
            if job.malleable:
                t += self.reconfig_path_s(job, s)
            if best_cost is None or t < best_cost - 1e-12 or \
                    (abs(t - best_cost) <= 1e-12 and s < best):
                best, best_cost = s, t
        return best

    # -- EASY hooks: only the sizing differs from the base policy ------------

    def _start_size(self, job: Job, free: int,
                    runtime_estimate: RuntimeEstimate) -> Optional[int]:
        if not self.candidate_sizes(job):
            # No power of two in range (odd rigid request): as submitted.
            return job.requested_nodes if job.requested_nodes <= free else None
        return self.best_start(job, free, runtime_estimate)

    def _reservation_need(self, head: Job) -> int:
        # Reserve at the smallest size the head could ever start with.
        return min(self.candidate_sizes(head, self.cluster.live_capacity)
                   or [head.requested_nodes])

    def _est_end(self, job: Job, size: int, now: float,
                 runtime_estimate: RuntimeEstimate) -> float:
        return now + max(runtime_estimate(job), 0.0) * \
            max(job.requested_nodes, 1) / size


# ---------------------------------------------------------------------------
# Facade (back-compat API used by the simulator and runtime)
# ---------------------------------------------------------------------------

class Scheduler:
    """Thin facade: owns the policy selected by ``SchedulerConfig.policy``."""

    def __init__(self, cluster: Cluster,
                 config: Optional[SchedulerConfig] = None,
                 cost: Optional[ReconfigCostModel] = None):
        self.cluster = cluster
        self.config = SchedulerConfig() if config is None else config
        self.policy = make_policy(cluster, self.config, cost=cost)

    def priority(self, job: Job, now: float) -> float:
        return self.policy.priority(job, now)

    def order(self, pending: List[Job], now: float) -> List[Job]:
        return self.policy.order(pending, now)

    def schedule(self, pending: List[Job], running: List[Job], now: float,
                 runtime_estimate: RuntimeEstimate
                 ) -> List[Tuple[Job, int]]:
        return self.policy.schedule(pending, running, now, runtime_estimate)

    def pop_preemptions(self) -> List[Tuple[Job, int]]:
        """Drain preemption directives queued by the last ``schedule``.

        ``(job, new_nodes)`` pairs; ``new_nodes == 0`` means requeue.  Empty
        for policies that never preempt.
        """
        pop = getattr(self.policy, "pop_preemptions", None)
        return pop() if pop is not None else []
