"""Queue scheduler: multifactor priority + EASY backfill (paper §7.2 setup).

The paper configures Slurm with the *backfill* scheduling policy and the
*multifactor* priority plug-in (defaults).  We implement the same pair:

- priority = age_weight * age + size_weight * (1 - size/cluster) + boost,
  where *boost* is the maximum-priority path used for resizer jobs and for
  queued jobs that triggered a wide-optimization shrink (§4.3).
- EASY backfill: the head-of-queue job gets a reservation at the earliest
  time enough nodes free up; lower-priority jobs may start now only if they
  fit in the spare nodes without delaying that reservation (using runtime
  estimates).
"""
from __future__ import annotations

import dataclasses
from typing import Callable, List, Optional, Tuple

from repro.rms.cluster import Cluster
from repro.rms.job import Job, JobState

MAX_PRIORITY = 1e12


@dataclasses.dataclass
class SchedulerConfig:
    age_weight: float = 1.0
    size_weight: float = 100.0
    backfill: bool = True


class Scheduler:
    def __init__(self, cluster: Cluster,
                 config: SchedulerConfig = SchedulerConfig()):
        self.cluster = cluster
        self.config = config

    def priority(self, job: Job, now: float) -> float:
        if job.priority_boost:
            return job.priority_boost
        age = now - job.submit_time
        size = 1.0 - job.requested_nodes / max(self.cluster.num_nodes, 1)
        return (self.config.age_weight * age
                + self.config.size_weight * size)

    def order(self, pending: List[Job], now: float) -> List[Job]:
        return sorted(pending, key=lambda j: (-self.priority(j, now),
                                              j.submit_time, j.job_id))

    def schedule(self, pending: List[Job], running: List[Job], now: float,
                 runtime_estimate: Callable[[Job], float]
                 ) -> List[Tuple[Job, int]]:
        """Return the list of (job, nodes) to start now.

        Does not mutate the cluster; the simulator/runtime applies starts so
        that start-up costs are accounted in one place.
        """
        free = self.cluster.free_nodes
        queue = self.order([j for j in pending
                            if j.state is JobState.PENDING], now)
        starts: List[Tuple[Job, int]] = []
        if not queue:
            return starts
        shadow_time: Optional[float] = None
        shadow_free_at_reservation = 0
        i = 0
        # Head-of-queue jobs start in priority order while they fit.
        while i < len(queue) and queue[i].requested_nodes <= free:
            starts.append((queue[i], queue[i].requested_nodes))
            free -= queue[i].requested_nodes
            i += 1
        if i >= len(queue) or not self.config.backfill:
            return starts
        # Reservation for the blocked head: when will enough nodes free up?
        head = queue[i]
        releases = sorted(
            (now + max(runtime_estimate(j), 0.0), j.nodes)
            for j in running if j.state is JobState.RUNNING)
        avail = free
        shadow_time = None
        for t, n in releases:
            avail += n
            if avail >= head.requested_nodes:
                shadow_time = t
                shadow_free_at_reservation = avail - head.requested_nodes
                break
        # Backfill the rest: start now iff it fits in `free` and either ends
        # before the reservation or fits in the reservation's spare nodes.
        for job in queue[i + 1:]:
            if job.requested_nodes > free:
                continue
            est_end = now + max(runtime_estimate(job), 0.0)
            if shadow_time is None or est_end <= shadow_time or \
                    job.requested_nodes <= shadow_free_at_reservation:
                starts.append((job, job.requested_nodes))
                free -= job.requested_nodes
                if shadow_time is not None and est_end > shadow_time:
                    shadow_free_at_reservation -= job.requested_nodes
        return starts
