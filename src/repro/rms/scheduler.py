"""Queue scheduling policies: multifactor priority + a pluggable registry.

The paper configures Slurm with the *backfill* scheduling policy and the
*multifactor* priority plug-in (defaults); that pair is the ``"easy"``
policy below and remains the default.  The registry adds the classic
alternatives studied in the malleable-scheduling literature (Chadha et al.;
Zojer et al.) so trace replays can compare them:

- ``fcfs``           strict priority order, no backfill — the head of the
                     queue blocks everything behind it.
- ``easy``           EASY backfill: the head job gets a reservation at the
                     earliest time enough nodes free up; lower-priority jobs
                     may start now only if they don't delay that reservation
                     (using runtime estimates).
- ``conservative``   every queued job gets a reservation; a backfill
                     candidate must not delay *any* reservation.
- ``malleable``      EASY variant that knows running malleable jobs can be
                     shrunk at their next reconfiguration point, so the head
                     reservation lands earlier and backfill is bolder.

Shared priority: ``age_weight * age + size_weight * (1 - size/cluster)
+ boost`` where *boost* is the maximum-priority path used for resizer jobs
and for queued jobs that triggered a wide-optimization shrink (§4.3).

Select a policy via ``SchedulerConfig(policy="conservative")`` — reachable
from ``SimConfig(sched=...)`` — or register new ones with
``@register_policy("name")``.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Dict, List, Optional, Tuple, Type

from repro.rms.cluster import Cluster
from repro.rms.job import Job, JobState

MAX_PRIORITY = 1e12

RuntimeEstimate = Callable[[Job], float]


@dataclasses.dataclass
class SchedulerConfig:
    age_weight: float = 1.0
    size_weight: float = 100.0
    backfill: bool = True          # easy/malleable only: False => no backfill
    policy: str = "easy"           # key into POLICY_REGISTRY


# ---------------------------------------------------------------------------
# Registry
# ---------------------------------------------------------------------------

POLICY_REGISTRY: Dict[str, Type["SchedulingPolicy"]] = {}


def register_policy(name: str):
    def deco(cls: Type["SchedulingPolicy"]):
        cls.name = name
        POLICY_REGISTRY[name] = cls
        return cls
    return deco


def make_policy(cluster: Cluster, config: SchedulerConfig
                ) -> "SchedulingPolicy":
    try:
        cls = POLICY_REGISTRY[config.policy]
    except KeyError:
        raise ValueError(
            f"unknown scheduling policy {config.policy!r}; "
            f"registered: {sorted(POLICY_REGISTRY)}") from None
    return cls(cluster, config)


# ---------------------------------------------------------------------------
# Policies
# ---------------------------------------------------------------------------

class SchedulingPolicy:
    """Base: multifactor priority + a `schedule` hook.

    ``schedule`` must not mutate the cluster; the simulator/runtime applies
    starts so that start-up costs are accounted in one place.
    """

    name = "base"

    def __init__(self, cluster: Cluster, config: SchedulerConfig):
        self.cluster = cluster
        self.config = config

    # -- priority ------------------------------------------------------------

    def priority(self, job: Job, now: float) -> float:
        if job.priority_boost:
            return job.priority_boost
        age = now - job.submit_time
        size = 1.0 - job.requested_nodes / max(self.cluster.num_nodes, 1)
        return (self.config.age_weight * age
                + self.config.size_weight * size)

    def order(self, pending: List[Job], now: float) -> List[Job]:
        return sorted(pending, key=lambda j: (-self.priority(j, now),
                                              j.submit_time, j.job_id))

    # -- helpers -------------------------------------------------------------

    def _queue(self, pending: List[Job], now: float) -> List[Job]:
        return self.order([j for j in pending
                           if j.state is JobState.PENDING], now)

    def _releases(self, running: List[Job], now: float,
                  runtime_estimate: RuntimeEstimate
                  ) -> List[Tuple[float, int]]:
        """(time, nodes) future node releases, soonest first."""
        return sorted(
            (now + max(runtime_estimate(j), 0.0), j.nodes)
            for j in running if j.state is JobState.RUNNING)

    # -- hook ----------------------------------------------------------------

    def schedule(self, pending: List[Job], running: List[Job], now: float,
                 runtime_estimate: RuntimeEstimate
                 ) -> List[Tuple[Job, int]]:
        raise NotImplementedError


@register_policy("fcfs")
class FCFSPolicy(SchedulingPolicy):
    """Strict priority order; the first job that doesn't fit blocks all."""

    def schedule(self, pending, running, now, runtime_estimate):
        free = self.cluster.free_nodes
        starts: List[Tuple[Job, int]] = []
        for job in self._queue(pending, now):
            if job.requested_nodes > free:
                break
            starts.append((job, job.requested_nodes))
            free -= job.requested_nodes
        return starts


@register_policy("easy")
class EasyBackfillPolicy(SchedulingPolicy):
    """EASY backfill (paper §7.2 setup): one reservation for the head job."""

    def schedule(self, pending, running, now, runtime_estimate):
        free = self.cluster.free_nodes
        queue = self._queue(pending, now)
        starts: List[Tuple[Job, int]] = []
        if not queue:
            return starts
        i = 0
        # Head-of-queue jobs start in priority order while they fit.
        while i < len(queue) and queue[i].requested_nodes <= free:
            starts.append((queue[i], queue[i].requested_nodes))
            free -= queue[i].requested_nodes
            i += 1
        if i >= len(queue) or not self.config.backfill:
            return starts
        # Reservation for the blocked head: when will enough nodes free up?
        head = queue[i]
        avail = free
        shadow_time: Optional[float] = None
        shadow_free_at_reservation = 0
        for t, n in self._releases(running, now, runtime_estimate):
            avail += n
            if avail >= head.requested_nodes:
                shadow_time = t
                shadow_free_at_reservation = avail - head.requested_nodes
                break
        # Backfill the rest: start now iff it fits in `free` and either ends
        # before the reservation or fits in the reservation's spare nodes.
        for job in queue[i + 1:]:
            if job.requested_nodes > free:
                continue
            est_end = now + max(runtime_estimate(job), 0.0)
            if shadow_time is None or est_end <= shadow_time or \
                    job.requested_nodes <= shadow_free_at_reservation:
                starts.append((job, job.requested_nodes))
                free -= job.requested_nodes
                if shadow_time is not None and est_end > shadow_time:
                    shadow_free_at_reservation -= job.requested_nodes
        return starts


@register_policy("conservative")
class ConservativeBackfillPolicy(SchedulingPolicy):
    """Conservative backfill: no queued job's reservation may be delayed.

    Builds a piecewise node-availability profile from running-job release
    estimates, reserves every queued job at its earliest feasible slot in
    priority order, and lets a job start *now* only when `now` is that
    earliest slot — so nobody leapfrogs anybody's reservation.
    """

    def schedule(self, pending, running, now, runtime_estimate):
        queue = self._queue(pending, now)
        if not queue:
            return []
        # profile: sorted list of [time, free_nodes_from_t_onward]
        profile: List[List[float]] = [[now, float(self.cluster.free_nodes)]]
        for t, n in self._releases(running, now, runtime_estimate):
            profile.append([t, profile[-1][1] + n])
        starts: List[Tuple[Job, int]] = []
        for job in queue:
            need = job.requested_nodes
            dur = max(runtime_estimate(job), 0.0)
            t0 = self._earliest(profile, need, dur)
            if t0 is None:
                # Never fits the foreseeable profile (e.g. request larger
                # than the cluster): no reservation, nothing carved.
                continue
            if t0 <= now:
                starts.append((job, need))
            self._carve(profile, t0, t0 + dur, need)
        return starts

    @staticmethod
    def _earliest(profile, need: int, dur: float) -> Optional[float]:
        """Earliest start where `need` nodes stay free for `dur` seconds;
        None when no such window exists in the profile."""
        for i, (t0, _) in enumerate(profile):
            ok = True
            for t, avail in profile[i:]:
                if t >= t0 + dur:
                    break
                if avail < need:
                    ok = False
                    break
            if ok:
                return t0
        return None

    @staticmethod
    def _carve(profile, t0: float, t1: float, need: int) -> None:
        """Subtract `need` nodes from the profile on [t0, t1)."""
        # Split segments at t0 and t1 so subtraction stays piecewise-exact.
        for t_split in (t0, t1):
            for i, (t, avail) in enumerate(profile):
                if t == t_split:
                    break
                if t > t_split:
                    profile.insert(i, [t_split, profile[i - 1][1]])
                    break
            else:
                profile.append([t_split, profile[-1][1]])
        for seg in profile:
            if t0 <= seg[0] < t1:
                seg[1] -= need


@register_policy("malleable")
class MalleableEasyPolicy(EasyBackfillPolicy):
    """EASY backfill that exploits malleability of *running* jobs.

    A running malleable job can be shrunk by one factor step at its next
    reconfiguration point (§4.3 wide optimization), so those nodes count as
    an early release when placing the head reservation.  The reservation
    lands earlier, backfill windows shrink, and queued jobs start sooner —
    the scheduler-side half of the paper's productivity argument.
    """

    def _releases(self, running, now, runtime_estimate):
        releases: List[Tuple[float, int]] = []
        for j in running:
            if j.state is not JobState.RUNNING:
                continue
            end = now + max(runtime_estimate(j), 0.0)
            shrunk = j.nodes // max(j.factor, 2)
            if j.malleable and j.nodes > shrunk >= max(j.min_nodes, 1):
                # Split, not duplicate: the shrinkable part frees at the
                # next reconfig point, only the remainder at end of run.
                horizon = now + max(j.check_period_s, 1.0)
                releases.append((horizon, j.nodes - shrunk))
                releases.append((end, shrunk))
            else:
                releases.append((end, j.nodes))
        return sorted(releases)


# ---------------------------------------------------------------------------
# Facade (back-compat API used by the simulator and runtime)
# ---------------------------------------------------------------------------

class Scheduler:
    """Thin facade: owns the policy selected by ``SchedulerConfig.policy``."""

    def __init__(self, cluster: Cluster,
                 config: SchedulerConfig = SchedulerConfig()):
        self.cluster = cluster
        self.config = config
        self.policy = make_policy(cluster, config)

    def priority(self, job: Job, now: float) -> float:
        return self.policy.priority(job, now)

    def order(self, pending: List[Job], now: float) -> List[Job]:
        return self.policy.order(pending, now)

    def schedule(self, pending: List[Job], running: List[Job], now: float,
                 runtime_estimate: RuntimeEstimate
                 ) -> List[Tuple[Job, int]]:
        return self.policy.schedule(pending, running, now, runtime_estimate)
