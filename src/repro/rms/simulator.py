"""Cluster simulator — the paper's §7 testbed on the event-driven engine.

Runs a workload of jobs (CG / Jacobi / N-body / FS / elastic-LM, or SWF
trace replays via :mod:`repro.workload.swf`) through the RMS with either
the *fixed* or the *flexible* (malleable) configuration and either
*synchronous* or *asynchronous* DMR scheduling, reproducing the paper's
measurements:

- per-action overheads (Table 2, Fig. 3),
- cluster utilization + per-job wait/exec/completion gains (Table 3),
- workload throughput across sizes (Table 4, Figs. 4/5),
- time-evolution traces and per-job diffs (Figs. 6/7/8).

Beyond the paper: node-failure and straggler events exercise the
fault-tolerance paths (shrink-to-survivors, checkpoint restart, slice
migration) that make the same mechanism deployable at scale, and
``PhaseChange`` events realize the §2 EVOLVING class — jobs whose demand
band changes per phase at the application's initiative, renegotiated
through the same §5.2 DMR check as malleable resizes.

The discrete-event mechanics live in :mod:`repro.rms.engine`; this module
registers one handler per event type, so new scenario classes are new
event types + handlers, not edits to a monolithic loop.
"""
from __future__ import annotations

import dataclasses
import os
import time as _time
from typing import Dict, List, Optional, Tuple

import numpy as np

from repro.core.actions import Action, Decision
from repro.rms.capacity import CapacityConfig, CapacityManager, plan_drain
from repro.rms.cluster import Cluster
from repro.rms.costmodel import PAPER_APPS, AppModel, ReconfigCostModel
from repro.rms.engine import (CheckpointTick, ExpandTimeout, JobFinish,
                              JobSubmit, NodeDrain, NodeFail, NodeJoin,
                              NodePowerOff, NodePowerOn, PhaseChange,
                              ReconfigPoint, SimulationEngine,
                              StragglerOnset, StragglerScan, TrafficTick)
from repro.rms.job import Job, JobState, clamp_band
from repro.rms.policy import PolicyConfig, ReconfigPolicy
from repro.rms.reasons import make_reason
from repro.rms.scheduler import MAX_PRIORITY, Scheduler, SchedulerConfig
from repro.workload.traffic import TrafficGenerator


@dataclasses.dataclass
class SimConfig:
    num_nodes: int = 64
    flexible: bool = True
    scheduling: str = "sync"          # "sync" | "async"
    expand_timeout_s: float = 40.0
    launch_latency_s: float = 1.0
    checkpoint_period_s: float = 120.0
    straggler_scan_s: float = 30.0
    straggler_threshold: float = 0.8
    # SERVING class: latency-probe cadence and the SLO-pressure negotiation
    # knobs — expand targets run at <= ``serving_headroom`` of capacity;
    # shrink only when the smaller size still clears demand by
    # ``serving_shrink_margin`` (hysteresis against diurnal flapping)
    traffic_tick_s: float = 10.0
    serving_headroom: float = 0.85
    serving_shrink_margin: float = 1.3
    seed: int = 7
    policy: PolicyConfig = dataclasses.field(default_factory=PolicyConfig)
    sched: SchedulerConfig = dataclasses.field(default_factory=SchedulerConfig)
    cost: ReconfigCostModel = dataclasses.field(
        default_factory=ReconfigCostModel)
    # checked mode: install the runtime invariant sanitizer
    # (:mod:`repro.rms.sanitizer`); also enabled by ``REPRO_SANITIZE=1``
    sanitize: bool = False
    failures: Tuple[Tuple[float, int], ...] = ()          # (time, node)
    stragglers: Tuple[Tuple[float, int, float], ...] = () # (time, node, slow)
    # elastic capacity: scheduled churn + CLUES-style power management
    capacity: CapacityConfig = dataclasses.field(
        default_factory=CapacityConfig)
    drains: Tuple[Tuple[float, int], ...] = ()            # (time, node)
    joins: Tuple[Tuple[float, int], ...] = ()             # (time, node|-1)


@dataclasses.dataclass
class ActionRecord:
    t: float
    job_id: int
    action: str
    decide_s: float      # RMS decision latency (Table 2 reports this)
    apply_s: float       # data redistribution + waits (Fig. 3b)
    from_nodes: int
    to_nodes: int
    timed_out: bool = False
    reason: str = ""


@dataclasses.dataclass
class SimReport:
    config: SimConfig
    jobs: List[Job]
    actions: List[ActionRecord]
    timeline: List[Tuple[float, int, int, int]]  # (t, allocated, running, done)
    makespan: float
    wall_time_s: float
    # real measured in-process policy latencies (seconds), for Table 2
    policy_wall_s: List[float] = dataclasses.field(default_factory=list)
    # capacity step function: (t, live_capacity, powered_off) — recorded at
    # every capacity-changing event (fail/drain/join/power cycle)
    capacity_timeline: List[Tuple[float, int, int]] = \
        dataclasses.field(default_factory=list)
    # SERVING class: per-job (slo_violations, served_requests, p99_s)
    serving_stats: Dict[int, Tuple[int, float, float]] = \
        dataclasses.field(default_factory=dict)

    # -- aggregate measures (paper definitions) -----------------------------

    def utilization(self, sample_s: float = 10.0) -> Tuple[float, float]:
        """Time-sampled allocated-node fraction: (avg %, std %).

        Each sample is normalized by the *live* capacity at that instant
        (the capacity step function), not the construction-time
        ``config.num_nodes`` — after a failure or drain the old stale
        denominator under-reported utilization of the surviving cluster.
        """
        if not self.timeline:
            return 0.0, 0.0
        ts = np.array([e[0] for e in self.timeline])
        alloc = np.array([e[1] for e in self.timeline], dtype=float)
        t_end = self.makespan if self.makespan > 0 else ts[-1]
        grid = np.arange(0.0, max(t_end, sample_s), sample_s)
        idx = np.clip(np.searchsorted(ts, grid, side="right") - 1, 0, None)
        if self.capacity_timeline:
            cts = np.array([e[0] for e in self.capacity_timeline])
            live = np.array([e[1] for e in self.capacity_timeline],
                            dtype=float)
            cidx = np.clip(np.searchsorted(cts, grid, side="right") - 1,
                           0, None)
            denom = np.maximum(live[cidx], 1.0)
        else:
            # initial capacity IS the live capacity when no churn event
            # ever recorded a snapshot
            denom = float(max(self.config.num_nodes,  # lint: disable=CAP001
                              1))
        samples = alloc[idx] / denom * 100.0
        return float(samples.mean()), float(samples.std())

    def _capacity_integral(self, col: int) -> float:
        """Integrate a capacity_timeline column over [0, makespan] (h)."""
        t_end = self.makespan
        if t_end <= 0:
            return 0.0
        pts = self.capacity_timeline or \
            [(0.0, self.config.num_nodes, 0)]    # lint: disable=CAP001
        total = 0.0
        for i, pt in enumerate(pts):
            t0 = min(pt[0], t_end)
            t1 = t_end if i + 1 == len(pts) else min(pts[i + 1][0], t_end)
            if t1 > t0:
                total += pt[col] * (t1 - t0)
        return total / 3600.0

    def node_hours(self) -> float:
        """Live (powered, non-dead) node·hours over the run — the second
        objective axis next to makespan: what the cluster *cost*."""
        return self._capacity_integral(1)

    def powered_off_hours(self) -> float:
        """Node·hours spent parked by the power manager — energy saved."""
        return self._capacity_integral(2)

    def job_metrics(self) -> Dict[int, Tuple[float, float, float]]:
        return {j.job_id: (j.wait_time, j.exec_time, j.completion_time)
                for j in self.jobs if j.state is JobState.COMPLETED}

    # -- serving aggregates (SLO axis next to makespan/node-hours) ----------

    def slo_violations(self) -> int:
        """Total TrafficTick probes that found p99 above the job's SLO."""
        return sum(v[0] for _, v in sorted(self.serving_stats.items()))

    def served_requests(self) -> float:
        """Total requests drained by serving jobs over the run."""
        return sum(v[1] for _, v in sorted(self.serving_stats.items()))

    def p99_latency(self) -> float:
        """Worst per-job p99 queueing delay (seconds) across serving jobs —
        the cluster violates the SLO iff its worst tenant does."""
        if not self.serving_stats:
            return 0.0
        return max(v[2] for _, v in sorted(self.serving_stats.items()))

    def averages(self) -> Tuple[float, float, float]:
        m = list(self.job_metrics().values())
        if not m:
            return 0.0, 0.0, 0.0
        arr = np.array(m)
        return tuple(arr.mean(axis=0))  # wait, exec, completion


class ClusterSimulator:
    """RMS simulation: handlers over a :class:`SimulationEngine`."""

    def __init__(self, jobs: List[Job], config: Optional[SimConfig] = None,
                 apps: Optional[Dict[str, AppModel]] = None):
        config = SimConfig() if config is None else config
        self.config = config
        self.apps = dict(PAPER_APPS if apps is None else apps)
        self.jobs = jobs
        # the one legal construction-time read: t=0 initial capacity
        self.cluster = Cluster(config.num_nodes)   # lint: disable=CAP001
        self.policy = ReconfigPolicy(config.policy)
        # The scheduler's moldable start-size optimizer and the resize
        # accounting below share one cost model — calibrated when
        # ``config.cost`` came from a calibration artifact.
        self.scheduler = Scheduler(self.cluster, config.sched,
                                   cost=config.cost)
        self.rng = np.random.default_rng(config.seed)
        self.engine = SimulationEngine()
        self.capacity = CapacityManager(self.cluster, self.engine,
                                        config.capacity)
        self.actions: List[ActionRecord] = []
        self.timeline: List[Tuple[float, int, int, int]] = []
        self.capacity_timeline: List[Tuple[float, int, int]] = []
        self._by_id = {j.job_id: j for j in jobs}
        # Hot-path job-set tracking: the scheduler pass and every DMR check
        # need "pending jobs submitted by now" and "running jobs"; scanning
        # the whole workload per event is O(jobs) each time (quadratic over
        # a trace replay).  Instead the sets are maintained incrementally
        # at the three state-transition sites (start / requeue / complete)
        # plus a submit-time pointer, and materialized in ``self.jobs``
        # order so every consumer sees exactly the order the full scan
        # produced (byte-identical golden traces).
        self._pos = {j.job_id: i for i, j in enumerate(jobs)}
        self._by_submit = sorted(jobs, key=lambda j: j.submit_time)
        self._submit_idx = 0
        self._pending_map: Dict[int, Job] = {}
        self._running_map: Dict[int, Job] = {}
        # Amdahl rates are pure in (app, nodes, serial_frac) — memoized so
        # runtime estimates (hottest call in backfill passes) stop
        # recomputing the same division chain.
        self._rate_memo: Dict[Tuple[str, int, Optional[float]], float] = {}
        self._est_memo: Dict[int, Tuple[Tuple, float]] = {}
        self._completed = 0
        self._waiting_expands: List[dict] = []   # async stale-grant waits
        self._pending_async: Dict[int, Tuple[Decision, float]] = {}
        self._ckpt_work: Dict[int, float] = {}
        self._ckpt_epoch: Dict[int, int] = {}    # active tick chain per job
        self._reconfig_epoch: Dict[int, int] = {}  # active check chain / job
        self._phase_epoch: Dict[int, int] = {}   # live phase prediction / job
        self._expand_epoch: Dict[int, int] = {}  # live expand waits / job
        self._wall_decide_s: List[float] = []
        # SERVING class: one open-loop generator per serving job, plus the
        # queueing state it drives.  ``work`` is pinned to the stream's
        # total arrivals so the conservation invariant
        # (arrivals == backlog + served) is exact by construction.
        self._traffic: Dict[int, TrafficGenerator] = {}
        self._traffic_seen: Dict[int, float] = {}   # arrivals accrued to t
        self._backlog: Dict[int, float] = {}        # queued requests
        self._slo_violations: Dict[int, int] = {}
        self._p99_samples: Dict[int, List[float]] = {}
        self._traffic_epoch: Dict[int, int] = {}    # live tick chain / job
        for j in jobs:
            if j.traffic is not None:
                gen = TrafficGenerator(j.traffic)
                self._traffic[j.job_id] = gen
                j.work = gen.total()
                self._traffic_seen[j.job_id] = j.traffic.t0
                self._backlog[j.job_id] = 0.0
                self._slo_violations[j.job_id] = 0
                self._p99_samples[j.job_id] = []
        self._wire_handlers()
        self.sanitizer = None
        if config.sanitize or \
                os.environ.get("REPRO_SANITIZE", "") not in ("", "0"):
            # imported lazily: the sanitizer is optional machinery and
            # imports scheduler/cluster/engine names from this package
            from repro.rms.sanitizer import SimSanitizer
            self.sanitizer = SimSanitizer(self).install()

    @property
    def now(self) -> float:
        return self.engine.now

    # -- plumbing ------------------------------------------------------------

    def _wire_handlers(self):
        e = self.engine
        e.on(JobSubmit, lambda ev: self._on_arrival(self._by_id[ev.job_id]))
        e.on(JobFinish, lambda ev: self._on_complete(self._by_id[ev.job_id],
                                                     ev.version))
        e.on(ReconfigPoint, lambda ev: self._on_check(self._by_id[ev.job_id],
                                                     ev.epoch))
        e.on(ExpandTimeout,
             lambda ev: self._on_expand_timeout(ev.job_id, ev.since,
                                                ev.epoch))
        e.on(PhaseChange, self._on_phase_change)
        e.on(NodeFail, lambda ev: self._on_failure(ev.node))
        e.on(NodeJoin, lambda ev: self._on_node_join(ev.node))
        e.on(NodeDrain, lambda ev: self._on_node_drain(ev.node))
        e.on(NodePowerOff, lambda ev: self._on_power_off(ev.node))
        e.on(NodePowerOn, lambda ev: self._on_power_on(ev.node))
        e.on(StragglerOnset,
             lambda ev: self._on_straggler(ev.node, ev.slowdown))
        e.on(StragglerScan, lambda ev: self._on_straggler_scan(ev.job_id))
        e.on(CheckpointTick,
             lambda ev: self._on_checkpoint(ev.job_id, ev.epoch))
        e.on(TrafficTick,
             lambda ev: self._on_traffic_tick(ev.job_id, ev.epoch))

    def _app(self, job: Job) -> AppModel:
        return self.apps[job.app]

    def _serial_frac(self, job: Job) -> Optional[float]:
        """Per-phase serial-fraction override (None: app default)."""
        ph = job.current_phase()
        return None if ph is None else ph.serial_frac

    def _data_bytes(self, job: Job) -> int:
        """State moved on reconfiguration — per-phase when evolving."""
        ph = job.current_phase()
        if ph is not None and ph.data_bytes is not None:
            return ph.data_bytes
        return self._app(job).data_bytes

    def _app_rate(self, job: Job, nodes: int) -> float:
        """Memoized ``AppModel.rate`` — pure in (app, nodes, serial_frac)."""
        sf = self._serial_frac(job)
        key = (job.app, nodes, sf)
        r = self._rate_memo.get(key)
        if r is None:
            r = self._app(job).rate(nodes, sf)
            self._rate_memo[key] = r
        return r

    def _rate(self, job: Job) -> float:
        return (self._app_rate(job, job.nodes)
                * self.cluster.job_rate_factor(job.job_id))

    def _advance(self, job: Job):
        if job.state is not JobState.RUNNING:
            return
        if job.traffic is not None:
            self._serving_advance(job)
            return
        t0 = max(job.last_progress_t, job.paused_until)
        if self.now > t0 >= 0:
            job.work_done = min(job.work,
                                job.work_done + self._rate(job)
                                * (self.now - t0))
        job.last_progress_t = max(self.now, job.paused_until)

    def _serving_advance(self, job: Job):
        """SERVING progress = request drain against an open-loop stream.

        Arrivals accrue unconditionally (pauses and requeues cannot slow
        the world down — the lazy catch-up from ``_traffic_seen`` covers
        any gap); drain happens only over the unpaused interval, capped by
        what the backlog holds.  ``work_done`` counts served requests, so
        ``arrivals == backlog + work_done`` at all times (the sanitizer's
        ``serving_conservation`` invariant).
        """
        jid = job.job_id
        gen = self._traffic[jid]
        seen = self._traffic_seen[jid]
        if self.now > seen:
            self._backlog[jid] += gen.arrivals_between(seen, self.now)
            self._traffic_seen[jid] = self.now
        t0 = max(job.last_progress_t, job.paused_until)
        if self.now > t0 >= 0:
            served = min(self._backlog[jid],
                         self._rate(job) * (self.now - t0))
            self._backlog[jid] -= served
            job.work_done = min(job.work, job.work_done + served)
        job.last_progress_t = max(self.now, job.paused_until)
        # window over and drained: snap the float-drift remainder into
        # served so completion is exact, not asymptotic
        if self._traffic_seen[jid] >= job.traffic.end and \
                self._backlog[jid] <= 1e-6 * max(job.work, 1.0):
            self._backlog[jid] = 0.0
            job.work_done = job.work

    def _pause(self, job: Job, seconds: float):
        self._advance(job)
        job.paused_until = max(job.paused_until, self.now) + seconds
        job.last_progress_t = job.paused_until

    def _schedule_completion(self, job: Job):
        job.completion_version += 1
        remaining = max(job.work - job.work_done, 0.0)
        t0 = max(self.now, job.paused_until)
        if job.traffic is not None:
            # a serving job cannot finish before its window closes, and the
            # drain-rate estimate below is optimistic (arrivals keep
            # coming) — _on_complete re-checks and refines, converging
            # once ``now >= end`` because remaining == backlog then
            t_end = max(t0 + remaining / self._rate(job), job.traffic.end)
            self.engine.schedule(JobFinish(t_end, job.job_id,
                                           job.completion_version))
            return
        t_end = t0 + remaining / self._rate(job)
        self.engine.schedule(JobFinish(t_end, job.job_id,
                                       job.completion_version))
        self._schedule_phase_change(job, t0)

    def _schedule_phase_change(self, job: Job, t0: float):
        """(Re)predict when the running job crosses its next phase boundary.

        Called alongside every completion (re)scheduling — both predictions
        depend on the same ``(work_done, rate, paused_until)`` state, so
        they stay consistent by construction.  The epoch bump invalidates
        any prediction from a prior start/resize.
        """
        epoch = self._phase_epoch.get(job.job_id, 0) + 1
        self._phase_epoch[job.job_id] = epoch
        boundary = job.phase_boundary()
        if boundary is None or boundary >= job.work - 1e-9:
            return
        to_go = max(boundary - job.work_done, 0.0)
        nxt = job.phases[job.phase_index + 1]
        self.engine.schedule(PhaseChange(
            t0 + to_go / self._rate(job), job.job_id,
            job.phase_index + 1, nxt.min_nodes, nxt.max_nodes,
            nxt.preferred, epoch))

    def _snapshot(self):
        running = sum(1 for j in self._running_map.values()
                      if j.state is JobState.RUNNING)
        self.timeline.append((self.now, self.cluster.allocated_nodes,
                              running, self._completed))

    def _capacity_snapshot(self):
        self.capacity_timeline.append(
            (self.now, self.cluster.live_capacity,
             len(self.cluster.powered_off)))

    def _pending_jobs(self) -> List[Job]:
        """Pending jobs submitted by ``now``, in workload order.

        Incremental: newly-reachable submissions are folded in by
        advancing a pointer over the submit-time-sorted workload (a job
        with ``submit_time == now`` is visible even before its JobSubmit
        event dispatches, exactly like the full scan this replaces), and
        started jobs were already removed at their transition.
        """
        bys = self._by_submit
        i, n, now = self._submit_idx, len(bys), self.now
        while i < n and bys[i].submit_time <= now:
            j = bys[i]
            if j.state is JobState.PENDING:
                self._pending_map[j.job_id] = j
            i += 1
        self._submit_idx = i
        out = [j for j in                          # re-sorted by _pos below
               self._pending_map.values()          # lint: disable=DET001
               if j.state is JobState.PENDING]
        if len(out) != len(self._pending_map):    # externally mutated job
            self._pending_map = {j.job_id: j for j in out}
        out.sort(key=lambda j: self._pos[j.job_id])
        return out

    def _running_jobs(self) -> List[Job]:
        """Running jobs in workload order (see :meth:`_pending_jobs`)."""
        out = [j for j in                          # re-sorted by _pos below
               self._running_map.values()          # lint: disable=DET001
               if j.state is JobState.RUNNING]
        if len(out) != len(self._running_map):    # externally mutated job
            self._running_map = {j.job_id: j for j in out}
        out.sort(key=lambda j: self._pos[j.job_id])
        return out

    def _runtime_estimate(self, job: Job) -> float:
        if job.traffic is not None:
            # a serving job occupies nodes until its window closes plus
            # whatever requests are left to drain; depends on (now, drain
            # state) so it stays out of the memo below.  Outstanding work
            # is counted from the arrival curve, not the accrued backlog:
            # a job still PENDING after its window elapsed has zero
            # backlog on the books but a full window of requests to
            # serve, and an estimate of 0 makes reservation-based
            # policies (conservative) carve empty profiles and
            # over-allocate.
            nodes = job.nodes or job.requested_nodes
            gen = self._traffic[job.job_id]
            outstanding = max(
                gen.arrivals_until(min(self.now, job.traffic.end)) -
                job.work_done, 0.0)
            return max(job.traffic.end - self.now, 0.0) + \
                outstanding / self._app_rate(job, nodes)
        # Memoized on the exact state the estimate depends on: work_done
        # only moves at _advance calls, so between events the same value
        # is requested hundreds of times by backfill priority sorts.
        key = (job.work_done, job.nodes, job.requested_nodes,
               job.phase_index)
        hit = self._est_memo.get(job.job_id)
        if hit is not None and hit[0] == key:
            return hit[1]
        nodes = job.nodes or job.requested_nodes
        remaining = max(job.work - job.work_done, 0.0)
        est = remaining / self._app_rate(job, nodes)
        self._est_memo[job.job_id] = (key, est)
        return est

    # -- scheduling ------------------------------------------------------------

    def _scheduler_pass(self):
        self._grant_waiting_expands()
        starts = self.scheduler.schedule(
            self._pending_jobs(), self._running_jobs(),
            self.now, self._runtime_estimate)
        # Preemption directives (preempt policy) free capacity the returned
        # starts already count on, so they are applied first.
        preempted = self.scheduler.pop_preemptions()
        for job, new in preempted:
            self._apply_preemption(job, new)
        for job, n in starts:
            self.cluster.allocate(job.job_id, n)
            job.nodes = n
            job.state = JobState.RUNNING
            self._pending_map.pop(job.job_id, None)
            self._running_map[job.job_id] = job
            job.start_time = self.now
            job.priority_boost = 0.0
            job.last_progress_t = self.now + self.config.launch_latency_s
            job.paused_until = job.last_progress_t
            job.record_nodes(self.now)
            # Restore point = progress carried into this start (0 for fresh
            # jobs; preserved work for failure/preemption requeue restarts).
            self._ckpt_work[job.job_id] = job.work_done
            self._schedule_completion(job)
            if self.config.flexible and job.malleable:
                # New epoch: a check chain surviving a preemption/failure
                # requeue must die at the guard, not double the frequency.
                repoch = self._reconfig_epoch.get(job.job_id, 0) + 1
                self._reconfig_epoch[job.job_id] = repoch
                self.engine.schedule(ReconfigPoint(
                    self._next_check_time(job), job.job_id, repoch))
            if self.config.checkpoint_period_s > 0:
                # New epoch: a chain surviving a requeue/restart goes stale.
                epoch = self._ckpt_epoch.get(job.job_id, 0) + 1
                self._ckpt_epoch[job.job_id] = epoch
                self.engine.schedule(CheckpointTick(
                    self.now + self.config.checkpoint_period_s, job.job_id,
                    epoch))
            if job.traffic is not None:
                # New epoch: a tick chain surviving a requeue goes stale.
                tepoch = self._traffic_epoch.get(job.job_id, 0) + 1
                self._traffic_epoch[job.job_id] = tepoch
                self.engine.schedule(TrafficTick(
                    self.now + self.config.traffic_tick_s, job.job_id,
                    tepoch))
        if starts or preempted:
            self._snapshot()
        # power management observes queue pressure after every pass; unmet
        # waiting-expand deltas count as demand (a starving RJ can boot a
        # parked node, §5.2.1 meets CLUES)
        if self.config.capacity.enabled:
            extra = sum(
                max(w["decision"].new_slices - w["job"].nodes
                    - self.cluster.allocation(-(w["job"].job_id + 1)), 0)
                for w in self._waiting_expands)
            self.capacity.note_pass(self._pending_jobs(), self.now, extra)

    def _drop_waiting_expands(self, job_id: int) -> bool:
        """Structurally void a job's pending expand waits: remove the wait
        entries, release the RJ reservation, and bump the epoch so any
        in-flight ``ExpandTimeout`` dies at its guard instead of matching a
        stale ``(job_id, since)`` pair.  Returns True when a wait (and its
        reservation) was actually dropped."""
        self._expand_epoch[job_id] = self._expand_epoch.get(job_id, 0) + 1
        kept = [w for w in self._waiting_expands
                if w["job"].job_id != job_id]
        dropped = len(kept) != len(self._waiting_expands)
        if dropped:
            self.cluster.release(-(job_id + 1))
        self._waiting_expands = kept
        return dropped

    def _apply_phase_band(self, job: Job, phase_idx: int, min_nodes: int,
                          max_nodes: int, preferred: Optional[int]):
        """Make ``phase_idx`` the live phase with the announced band:
        rewrite the job's band (clamped to the cluster) and keep the
        restart size inside it."""
        job.phase_index = phase_idx
        # clamp to *live* capacity: after a failure/drain the old
        # ``config.num_nodes`` ceiling let a phase band exceed the real
        # cluster and blow up in ``allocate`` (over-allocation RuntimeError)
        lo, hi, pref = clamp_band(min_nodes, max_nodes, preferred,
                                  max(self.cluster.live_capacity, 1))
        job.min_nodes, job.max_nodes, job.preferred = lo, hi, pref
        job.requested_nodes = min(max(job.requested_nodes, lo), hi)

    def _sync_phase_to_work(self, job: Job):
        """A checkpoint restore can rewind ``work_done`` into an earlier
        phase; re-derive the live phase/band from the preserved progress so
        the queued job advertises the demand it will actually resume with
        (the skipped transitions re-fire as the replayed work crosses the
        boundaries again)."""
        if not job.phases:
            return
        cum, idx = 0.0, len(job.phases) - 1
        for i, ph in enumerate(job.phases):
            cum += ph.work
            if job.work_done < cum - 1e-9:
                idx = i
                break
        if idx != job.phase_index:
            ph = job.phases[idx]
            self._apply_phase_band(job, idx, ph.min_nodes, ph.max_nodes,
                                   ph.preferred)

    def _requeue(self, job: Job, action: str, from_nodes: int, reason: str):
        """Kill a running job back to the queue; progress survives."""
        self.cluster.release(job.job_id)
        job.state = JobState.PENDING
        job.nodes = 0
        self._running_map.pop(job.job_id, None)
        self._pending_map[job.job_id] = job
        job.completion_version += 1
        self._pending_async.pop(job.job_id, None)  # decision is stale now
        self._drop_waiting_expands(job.job_id)     # RJ wait is stale too
        # a stale phase prediction must not fire against the restart
        self._phase_epoch[job.job_id] = \
            self._phase_epoch.get(job.job_id, 0) + 1
        # a stale traffic-tick chain must not survive into the restart
        if job.traffic is not None:
            self._traffic_epoch[job.job_id] = \
                self._traffic_epoch.get(job.job_id, 0) + 1
        self._sync_phase_to_work(job)
        job.record_nodes(self.now)
        self.actions.append(ActionRecord(
            self.now, job.job_id, action, 0.0, 0.0, from_nodes, 0,
            reason=reason))

    def _apply_preemption(self, job: Job, new: int):
        """Shrink (``new > 0``) or requeue (``new == 0``) a running victim."""
        if job.state is not JobState.RUNNING:
            return
        self._advance(job)
        old = job.nodes
        if new <= 0:
            self._requeue(job, "preempt_requeue", old,
                          "head-reservation-slip")
            return
        self.cluster.resize(job.job_id, new)
        resize_s = self.config.cost.resize_time(
            old, new, self._data_bytes(job))
        self._pause(job, resize_s)
        job.nodes = new
        job.record_nodes(self.now)
        self._ckpt_work[job.job_id] = job.work_done   # state moved with it
        self.actions.append(ActionRecord(
            self.now, job.job_id, "preempt_shrink", 0.0, resize_s, old, new,
            reason="head-reservation-slip"))
        self._schedule_completion(job)

    def _next_check_time(self, job: Job) -> float:
        app = self._app(job)
        period = app.check_period_s or \
            app.iter_time(job.nodes, self._serial_frac(job))
        return max(self.now, job.paused_until) + period

    # -- the DMR check (paper §5) ----------------------------------------------

    def _serving_demand(self, job: Job) -> Tuple[float, float]:
        """(needed_rps, slo_pressure) for a serving job right now.

        Demand = the live arrival rate plus the throughput required to
        drain the current backlog within one SLO period; pressure is the
        p99-vs-SLO ratio the negotiation reasons report (>= 1: violating).
        """
        jid = job.job_id
        gen = self._traffic[jid]
        backlog = self._backlog.get(jid, 0.0)
        slo = max(job.traffic.slo_p99_s, 1e-9)
        needed = gen.rate(self.now) + backlog / slo
        rate = self._rate(job)
        pressure = (backlog / rate) / slo if rate > 0 else float("inf")
        return needed, pressure

    def _serving_target(self, job: Job, needed: float) -> int:
        """Smallest factor-ladder size in the band whose throughput covers
        ``needed`` req/s at ``serving_headroom`` occupancy."""
        lo = max(job.min_nodes, 1)
        hi = max(job.max_nodes, lo)
        f = max(job.factor, 2)
        n = lo
        while n < hi:
            if self._app_rate(job, n) * self.config.serving_headroom \
                    >= needed:
                return n
            n = min(n * f, hi)
        return hi

    def _serving_band(self, job: Job) -> Tuple[int, int, Optional[int],
                                               float]:
        """SLO-pressure band for the DMR check (§5.2 with a new driver).

        Instead of remaining work, the serving job's announcement is
        derived from queueing pressure: when the target size is above the
        current one the job *requests* an expansion (step-capped to the
        adjacent factor size so mode-1 negotiation always has a legal
        step); when traffic ebbs enough that the next step down still
        clears demand by ``serving_shrink_margin`` it offers the nodes
        back; otherwise it holds (preferred = current).
        """
        needed, pressure = self._serving_demand(job)
        cur = job.nodes
        lo, hi = max(job.min_nodes, 1), max(job.max_nodes, 1)
        target = self._serving_target(job, needed)
        if target > cur:
            return min(target, cur * max(job.factor, 2)), hi, None, pressure
        down = max(cur // max(job.factor, 2), lo)
        if down < cur and self._app_rate(job, down) * \
                self.config.serving_headroom >= \
                needed * self.config.serving_shrink_margin:
            return lo, down, None, pressure
        return lo, hi, cur, pressure

    def _decide(self, job: Job) -> Tuple[Decision, float]:
        app = self._app(job)
        # SERVING jobs negotiate on SLO pressure (backlog / capacity), not
        # remaining work; EVOLVING jobs negotiate over their *live* band
        # (rewritten by the PhaseChange handler); fixed-demand jobs keep
        # the app model's.
        pressure = None
        if job.serving:
            lo, hi, pref, pressure = self._serving_band(job)
        elif job.evolving:
            lo, hi, pref = job.min_nodes, job.max_nodes, job.preferred
        else:
            lo, hi, pref = app.min_nodes, app.max_nodes, app.preferred
        wall0 = _time.perf_counter()
        decision = self.policy.decide(
            self.cluster, self._pending_jobs(), job,
            minimum=lo, maximum=hi,
            factor=job.factor, preferred=pref, slo_pressure=pressure)
        wall = _time.perf_counter() - wall0  # real policy latency (measured)
        self._wall_decide_s.append(wall)
        nodes_involved = max(job.nodes, decision.new_slices)
        model_s = self.config.cost.schedule_time(
            decision.action, nodes_involved, rng=self.rng)
        # deterministic sim time: the measured in-process latency is
        # reported separately (SimReport.policy_wall_s), not injected.
        return decision, model_s

    def _apply(self, job: Job, decision: Decision, decide_s: float,
               waited_s: float = 0.0, pause_decide: bool = True):
        app = self._app(job)
        old = job.nodes
        if decision.action is Action.NO_ACTION:
            self.actions.append(ActionRecord(
                self.now, job.job_id, "no_action", decide_s, 0.0, old, old,
                reason=decision.reason))
            return
        new = decision.new_slices
        if decision.action is Action.EXPAND and \
                new - old > self.cluster.free_nodes:
            # Stale grant that cannot be satisfied now (async path).
            self.actions.append(ActionRecord(
                self.now, job.job_id, "expand", decide_s, waited_s, old, old,
                timed_out=True, reason="stale-grant"))
            return
        resize_s = self.config.cost.resize_time(old, new,
                                                self._data_bytes(job))
        self.cluster.resize(job.job_id, new)
        # Async mode hides the scheduling latency behind the previous step
        # (§5.1: "the communication overhead in that step is avoided").
        self._pause(job, (decide_s if pause_decide else 0.0) + resize_s)
        job.nodes = new
        job.record_nodes(self.now)
        self._ckpt_work[job.job_id] = job.work_done
        name = "expand" if decision.action is Action.EXPAND else "shrink"
        self.actions.append(ActionRecord(
            self.now, job.job_id, name, decide_s, waited_s + resize_s,
            old, new, reason=decision.reason))
        if decision.boost_job_id is not None:
            for q in self.jobs:
                if q.job_id == decision.boost_job_id:
                    q.priority_boost = MAX_PRIORITY
        self._schedule_completion(job)
        self._snapshot()
        if new < old:
            self._scheduler_pass()   # freed nodes may start queued jobs

    def _grant_waiting_expands(self):
        """Feed freed nodes to waiting resizer jobs (max priority, §5.2.1).

        An RJ holds a *reservation*: nodes it has already claimed are
        invisible to the scheduler until the expand completes or times out —
        this queue starvation is the async-mode pathology of Table 2.
        """
        still = []
        for w in self._waiting_expands:
            job, decision = w["job"], w["decision"]
            rj_id = -(job.job_id + 1)           # pseudo-job for the RJ
            if job.state is not JobState.RUNNING:
                self.cluster.release(rj_id)
                continue
            delta = decision.new_slices - job.nodes
            need = delta - self.cluster.allocation(rj_id)
            grab = min(need, self.cluster.free_nodes)
            if grab > 0:
                self.cluster.allocate(rj_id, grab)
            if self.cluster.allocation(rj_id) >= delta:
                self.cluster.release(rj_id)     # hand the nodes to the job
                waited = self.now - w["since"]
                # _apply reschedules completion itself (the grant always
                # takes the resize path: the released reservation covers
                # the delta, so the stale-grant branch can't trigger) —
                # rescheduling again here bumped completion_version twice
                # and left a dead JobFinish in the heap per granted expand.
                self._apply(job, decision, w["decide_s"], waited_s=waited,
                            pause_decide=False)
            else:
                still.append(w)
        self._waiting_expands = still

    def _on_check(self, job: Job, epoch: int = 0):
        if job.state is not JobState.RUNNING or \
                epoch != self._reconfig_epoch.get(job.job_id, 0):
            return
        self._advance(job)
        if any(w["job"].job_id == job.job_id for w in self._waiting_expands):
            self.engine.schedule(ReconfigPoint(self._next_check_time(job),
                                               job.job_id, epoch))
            return
        if self.config.scheduling == "async":
            # Apply the decision scheduled at the previous point…
            prev = self._pending_async.pop(job.job_id, None)
            if prev is not None:
                decision, decide_s = prev
                if decision.action is Action.EXPAND and \
                        decision.new_slices - job.nodes > \
                        self.cluster.free_nodes:
                    # …whose resources may have vanished: wait w/ timeout.
                    self._pause(job, 0.0)
                    self._waiting_expands.append(dict(
                        job=job, decision=decision, decide_s=decide_s,
                        since=self.now))
                    self.engine.schedule(ExpandTimeout(
                        self.now + self.config.expand_timeout_s,
                        job.job_id, self.now,
                        self._expand_epoch.get(job.job_id, 0)))
                    self.engine.schedule(ReconfigPoint(
                        self._next_check_time(job), job.job_id, epoch))
                    return
                self._apply(job, decision, decide_s, pause_decide=False)
            # …and schedule the next decision concurrently (zero job cost).
            decision, decide_s = self._decide(job)
            if decision.action is Action.NO_ACTION:
                self.actions.append(ActionRecord(
                    self.now, job.job_id, "no_action", decide_s, 0.0,
                    job.nodes, job.nodes, reason=decision.reason))
            else:
                self._pending_async[job.job_id] = (decision, decide_s)
        else:
            decision, decide_s = self._decide(job)
            self._apply(job, decision, decide_s)
        if job.state is JobState.RUNNING:
            self.engine.schedule(ReconfigPoint(self._next_check_time(job),
                                               job.job_id, epoch))

    # -- events ------------------------------------------------------------------

    def _on_arrival(self, job: Job):
        self._scheduler_pass()

    def _on_complete(self, job: Job, version: int):
        if job.state is not JobState.RUNNING or \
                version != job.completion_version:
            return
        self._advance(job)
        if job.work_done < job.work - 1e-9:
            self._schedule_completion(job)
            return
        job.state = JobState.COMPLETED
        job.end_time = self.now
        job.record_nodes(self.now)
        self.cluster.release(job.job_id)
        self._running_map.pop(job.job_id, None)
        self._completed += 1
        self._pending_async.pop(job.job_id, None)
        self._snapshot()
        self._scheduler_pass()

    def _on_expand_timeout(self, job_id: int, since: float, epoch: int = 0):
        if epoch != self._expand_epoch.get(job_id, 0):
            return          # requeue/phase-change voided this wait chain
        for w in list(self._waiting_expands):
            if w["job"].job_id == job_id and w["since"] == since:
                self._waiting_expands.remove(w)
                job = w["job"]
                self.cluster.release(-(job_id + 1))   # drop RJ reservation
                waited = self.now - since
                self.actions.append(ActionRecord(
                    self.now, job_id, "expand", w["decide_s"], waited,
                    job.nodes, job.nodes, timed_out=True,
                    reason="rj-timeout"))
                job.paused_until = max(job.paused_until, self.now)
                job.last_progress_t = job.paused_until
                self._schedule_completion(job)
                self._scheduler_pass()

    def _on_checkpoint(self, job_id: int, epoch: int):
        """Periodic checkpoint (§6): refresh the NodeFail restore point."""
        job = self._by_id.get(job_id)
        if job is None or job.state is not JobState.RUNNING or \
                epoch != self._ckpt_epoch.get(job_id):
            return
        self._advance(job)
        self._ckpt_work[job_id] = job.work_done
        self.engine.schedule(CheckpointTick(
            self.now + self.config.checkpoint_period_s, job_id, epoch))

    def _on_traffic_tick(self, job_id: int, epoch: int):
        """SERVING latency probe: accrue arrivals, drain, sample p99.

        The p99 proxy is the time to drain the current backlog at the
        current allocation — the queueing delay the *next* arriving
        request would see.  The chain re-arms itself while the job runs;
        the epoch guard retires a chain left over from a prior start
        (same pattern as ReconfigPoint/CheckpointTick).
        """
        job = self._by_id.get(job_id)
        if job is None or job.state is not JobState.RUNNING or \
                epoch != self._traffic_epoch.get(job_id, 0):
            return
        self._advance(job)
        rate = self._rate(job)
        backlog = self._backlog.get(job_id, 0.0)
        p99 = backlog / rate if rate > 0 else float("inf")
        self._p99_samples[job_id].append(p99)
        if p99 > job.traffic.slo_p99_s:
            self._slo_violations[job_id] += 1
        if job.work_done >= job.work - 1e-9:
            # window over and drained (the _serving_advance snap fired):
            # finalize now instead of waiting for the estimate to land
            self._schedule_completion(job)
            return
        self.engine.schedule(TrafficTick(
            self.now + self.config.traffic_tick_s, job_id, epoch))

    def _on_phase_change(self, ev: PhaseChange):
        """EVOLVING (§2): the application enters its next phase.

        Applies the band the event carries to the job's *live*
        ``min_nodes``/``max_nodes``/``preferred`` (every scheduling policy
        reads those, so the new demand is visible at the next pass), voids
        any outstanding expand wait negotiated under the old band, and
        forces an immediate DMR check (§5.2) on a fresh epoch so the RMS
        reacts now instead of at the next periodic point.
        """
        job = self._by_id.get(ev.job_id)
        if job is None or job.state is not JobState.RUNNING or \
                ev.epoch != self._phase_epoch.get(ev.job_id, 0):
            return
        self._advance(job)
        boundary = sum(ph.work for ph in job.phases[:ev.phase])
        if job.work_done < boundary - 1e-9:
            # prediction went stale without a reschedule (e.g. a straggler
            # slowed the rate after it was made): re-predict from actual
            # progress, same pattern as _on_complete
            self._schedule_phase_change(job, max(self.now, job.paused_until))
            return
        # apply exactly the band the application announced in the event
        self._apply_phase_band(job, ev.phase, ev.min_nodes, ev.max_nodes,
                               ev.preferred)
        self.actions.append(ActionRecord(
            self.now, job.job_id, "phase_change", 0.0, 0.0,
            job.nodes, job.nodes,
            reason=make_reason("phase-entered", ev.phase)))
        # an expand wait negotiated under the old band is void; if its RJ
        # reservation held nodes, offer them to the queue now (same as the
        # timeout path) instead of letting them idle until the next event
        if self._drop_waiting_expands(job.job_id):
            self._scheduler_pass()
        self._pending_async.pop(job.job_id, None)
        # rate may have changed (per-phase serial fraction): re-predict
        # completion and the next boundary
        self._schedule_completion(job)
        if self.config.flexible and job.malleable:
            repoch = self._reconfig_epoch.get(job.job_id, 0) + 1
            self._reconfig_epoch[job.job_id] = repoch
            self.engine.schedule(ReconfigPoint(self.now, job.job_id, repoch))

    def _on_failure(self, node: int):
        # ``fail_node`` is idempotent and live_capacity is derived from the
        # pools, so a double-failed node costs exactly one node of capacity
        # (the old ``cluster.num_nodes -= 1`` here charged it per event).
        owner = self.cluster.fail_node(node)
        self._capacity_snapshot()
        if owner is None:
            self._snapshot()
            return
        if owner < 0:
            # the node was held by an RJ reservation, not a job: the expand
            # it was reserved for can no longer count on it
            self._snapshot()
            self._scheduler_pass()
            return
        job = self._by_id[owner]
        self._advance(job)
        if job.traffic is None:
            # ckpt restore — serving jobs never rewind: a served request
            # cannot be un-served, only the backlog re-queues
            job.work_done = self._ckpt_work.get(job.job_id, 0.0)
            # the restore may rewind into an earlier phase: the live band
            # (and the min-nodes test below) must reflect the resumed phase
            self._sync_phase_to_work(job)
        survivors = self.cluster.allocation(job.job_id)
        # live band floor: for evolving jobs the current phase's minimum,
        # not the submission-time envelope (identical for fixed-demand jobs)
        min_floor = job.min_nodes if job.evolving else \
            self._app(job).min_nodes
        if job.malleable and survivors >= min_floor:
            # Shrink-to-survivors: largest factor-consistent size that fits.
            new = job.nodes
            while new > survivors or (new != survivors and new > min_floor):
                if new % job.factor or new // job.factor < 1:
                    break
                new //= job.factor
                if new <= survivors:
                    break
            new = max(min(new, survivors), 1)
            self.cluster.resize(job.job_id, new)
            resize_s = self.config.cost.resize_time(
                job.nodes, new, self._data_bytes(job))
            self._pause(job, resize_s + 5.0)   # restore overhead
            job.nodes = new
            job.record_nodes(self.now)
            self.actions.append(ActionRecord(
                self.now, job.job_id, "failure_shrink", 0.0, resize_s,
                survivors + 1, new,
                reason=make_reason("node-failed", node)))
            self._schedule_completion(job)
        else:
            # Rigid job (or too few survivors): requeue, checkpoint restart.
            self._requeue(job, "failure_requeue", survivors + 1,
                          make_reason("node-failed", node))
        self._snapshot()
        self._scheduler_pass()

    # -- elastic capacity (beyond-paper: the pool itself is dynamic) -----------

    def _on_node_join(self, node: int):
        """A node enters the pool (scale-out / maintenance done / repaired).

        Freed capacity is offered immediately: waiting resizer jobs grant
        first (max priority, §5.2.1), then queued jobs.
        """
        before = self.cluster.live_capacity
        nid = self.cluster.join_node(node if node >= 0 else None)
        after = self.cluster.live_capacity
        if after == before:
            return                      # already a live member: no-op
        self.actions.append(ActionRecord(
            self.now, -1, "node_join", 0.0, 0.0, before, after,
            reason=make_reason("node-join", nid)))
        self._capacity_snapshot()
        self._scheduler_pass()

    def _on_node_drain(self, node: int):
        """A node must leave the pool; negotiate its owner off it first.

        Idle nodes retire immediately.  For an owned node the RMS picks the
        cheapest exit (:func:`repro.rms.capacity.plan_drain`): slice
        migration to a healthy free node, a factor-consistent DMR shrink
        (§5.2.2 fold), or a checkpoint requeue — then the vacated node is
        routed to ``draining`` instead of back to ``free``.
        """
        before = self.cluster.live_capacity
        owner = self.cluster.drain_node(node)
        if owner is None:
            if self.cluster.live_capacity != before:
                self.actions.append(ActionRecord(
                    self.now, -1, "node_drain", 0.0, 0.0, before,
                    self.cluster.live_capacity,
                    reason=make_reason("node-drain-idle", node)))
                self._capacity_snapshot()
            return
        if owner < 0:
            # held by an RJ reservation: it retires when the reservation
            # releases (grant or timeout) — nothing to negotiate with
            return
        job = self._by_id[owner]
        self._advance(job)
        min_floor = job.min_nodes if job.evolving else \
            self._app(job).min_nodes
        kind, new = plan_drain(self.cluster, job, node, min_floor)
        if kind == "migrate":
            self.cluster.replace_node(owner, node)
            migrate_s = self.config.cost.resize_time(
                job.nodes, max(job.nodes // 2, 1),
                self._data_bytes(job) // max(job.nodes, 1))
            self._pause(job, migrate_s)
            self.actions.append(ActionRecord(
                self.now, owner, "drain_migrate", 0.0, migrate_s,
                job.nodes, job.nodes,
                reason=make_reason("drain-vacate", node)))
            self._schedule_completion(job)
        elif kind == "shrink":
            old = job.nodes
            self.cluster.move_to_tail(owner, node)   # fold sender = tail
            self.cluster.resize(owner, new)
            resize_s = self.config.cost.resize_time(
                old, new, self._data_bytes(job))
            self._pause(job, resize_s)
            job.nodes = new
            job.record_nodes(self.now)
            self._ckpt_work[job.job_id] = job.work_done
            self.actions.append(ActionRecord(
                self.now, owner, "drain_shrink", 0.0, resize_s, old, new,
                reason=make_reason("drain-vacate", node)))
            self._schedule_completion(job)
        else:
            self._requeue(job, "drain_requeue", job.nodes,
                          make_reason("drain-vacate", node))
        self.actions.append(ActionRecord(
            self.now, -1, "node_drain", 0.0, 0.0, before,
            self.cluster.live_capacity,
            reason=make_reason("node-drain", node)))
        self._capacity_snapshot()
        self._snapshot()
        self._scheduler_pass()

    def _on_power_off(self, node: int):
        """Park idle capacity: explicit node, or let the armed manager
        timer pick (re-validated against queue pressure at fire time)."""
        before = self.cluster.live_capacity
        if node >= 0:
            offs = [node] if self.cluster.power_off_node(node) else []
        else:
            offs = self.capacity.confirm_power_off(
                self._pending_jobs(), self.now)
        if not offs:
            return
        self.actions.append(ActionRecord(
            self.now, -1, "power_off", 0.0, 0.0, before,
            self.cluster.live_capacity,
            reason=make_reason("power-off",
                               ",".join(str(n) for n in offs))))
        self._capacity_snapshot()

    def _on_power_on(self, node: int):
        """A parked node finished booting: back into the pool, and offer
        it to waiting expands / queued jobs immediately."""
        before = self.cluster.live_capacity
        if not self.capacity.confirm_power_on(node):
            return
        self.actions.append(ActionRecord(
            self.now, -1, "power_on", 0.0, 0.0, before,
            self.cluster.live_capacity,
            reason=make_reason("power-on", node)))
        self._capacity_snapshot()
        self._scheduler_pass()

    def _on_straggler(self, node: int, slowdown: float):
        owner = self.cluster.set_straggler(node, slowdown)
        if owner is not None:
            self.engine.schedule(StragglerScan(
                self.now + self.config.straggler_scan_s, owner))

    def _on_straggler_scan(self, job_id: int):
        job = self._by_id.get(job_id)
        if job is None or job.state is not JobState.RUNNING:
            return
        if self.cluster.job_rate_factor(job_id) >= \
                self.config.straggler_threshold:
            return
        self._advance(job)
        if self.cluster.swap_straggler(job_id):
            migrate_s = self.config.cost.resize_time(
                job.nodes, max(job.nodes // 2, 1),
                self._data_bytes(job) // max(job.nodes, 1))
            self._pause(job, migrate_s)
            self.actions.append(ActionRecord(
                self.now, job_id, "straggler_migrate", 0.0, migrate_s,
                job.nodes, job.nodes, reason="slice-migration"))
            self._schedule_completion(job)
        else:
            self.engine.schedule(StragglerScan(
                self.now + self.config.straggler_scan_s, job_id))

    # -- main loop ------------------------------------------------------------------

    def run(self) -> SimReport:
        wall0 = _time.perf_counter()
        for job in self.jobs:
            if not self.config.flexible:
                job.malleable = False
            self.engine.schedule(JobSubmit(job.submit_time, job.job_id))
        for t, node in self.config.failures:
            self.engine.schedule(NodeFail(t, node))
        for t, node, slow in self.config.stragglers:
            self.engine.schedule(StragglerOnset(t, node, slow))
        for t, node in self.config.drains:
            self.engine.schedule(NodeDrain(t, node))
        for t, node in self.config.joins:
            self.engine.schedule(NodeJoin(t, node))
        self._capacity_snapshot()       # t=0 anchor of the step function
        self.engine.run()
        makespan = max((j.end_time for j in self.jobs
                        if j.end_time > 0), default=0.0)
        rep = SimReport(self.config, self.jobs, self.actions, self.timeline,
                        makespan, _time.perf_counter() - wall0,
                        capacity_timeline=self.capacity_timeline)
        rep.policy_wall_s = list(self._wall_decide_s)
        for jid in sorted(self._traffic):
            samples = self._p99_samples[jid]
            p99 = float(np.percentile(np.asarray(samples), 99)) \
                if samples else 0.0
            rep.serving_stats[jid] = (
                self._slo_violations[jid],
                self._by_id[jid].work_done, p99)
        return rep
