"""Job model for the RMS (paper §2 taxonomy).

A job is *fixed* (rigid/moldable: constant process count) or *flexible*
(malleable/evolving: reconfigurable on-the-fly).  The RMS counts resources in
*nodes*; in the JAX mapping one node = one data-parallel mesh slice (tensor
parallelism inside the slice is fixed, like cores within a node).
"""
from __future__ import annotations

import dataclasses
import enum
from typing import List, Optional, Tuple


class JobState(enum.Enum):
    PENDING = "pending"
    RUNNING = "running"
    COMPLETED = "completed"
    CANCELLED = "cancelled"


@dataclasses.dataclass
class Job:
    job_id: int
    app: str                      # "cg" | "jacobi" | "nbody" | "fs" | "lm:<arch>"
    submit_time: float
    work: float                   # total work units (app iterations)
    min_nodes: int
    max_nodes: int
    preferred: Optional[int]      # Table 1 "Preferred"
    factor: int = 2               # resize factor (Table 1: 2 for all malleable)
    malleable: bool = True
    check_period_s: float = 15.0  # Table 1 "Scheduling period" (0 = every iter)
    requested_nodes: int = 0      # submission size (paper: launched at max)
    data_bytes: int = 0           # redistributed state size (FS: 1 GB)
    user: int = 0                 # submitting user (fair-share accounting)

    # -- dynamic state (owned by the RMS / simulator) ------------------------
    state: JobState = JobState.PENDING
    nodes: int = 0                # current allocation
    priority_boost: float = 0.0   # max-priority path (shrink trigger / RJ)
    start_time: float = -1.0
    end_time: float = -1.0
    work_done: float = 0.0
    last_progress_t: float = -1.0
    paused_until: float = -1.0    # reconfiguration in progress
    completion_version: int = 0   # invalidates stale completion events
    resizer_for: Optional[int] = None   # this job is an RJ for job `id`
    nodes_history: List[Tuple[float, int]] = dataclasses.field(
        default_factory=list)

    def __post_init__(self):
        if self.requested_nodes == 0:
            self.requested_nodes = self.max_nodes

    # -- metrics (paper §7.4/§7.5 definitions) -------------------------------
    @property
    def wait_time(self) -> float:
        if self.start_time < 0:
            return 0.0
        return self.start_time - self.submit_time

    @property
    def exec_time(self) -> float:
        if self.end_time < 0 or self.start_time < 0:
            return 0.0
        return self.end_time - self.start_time

    @property
    def completion_time(self) -> float:
        """Submission -> finalization (wait + exec)."""
        if self.end_time < 0:
            return 0.0
        return self.end_time - self.submit_time

    def record_nodes(self, t: float) -> None:
        self.nodes_history.append((t, self.nodes))

    def node_seconds(self) -> float:
        """Integral of allocated nodes over time (for utilization)."""
        total, hist = 0.0, self.nodes_history
        for (t0, n0), (t1, _n1) in zip(hist, hist[1:]):
            total += n0 * (t1 - t0)
        return total
