"""Job model for the RMS (paper §2 taxonomy).

A job is *fixed* (rigid/moldable: constant process count) or *flexible*
(malleable/evolving: reconfigurable on-the-fly).  The RMS counts resources in
*nodes*; in the JAX mapping one node = one data-parallel mesh slice (tensor
parallelism inside the slice is fixed, like cores within a node).
"""
from __future__ import annotations

import dataclasses
import enum
from typing import TYPE_CHECKING, List, Optional, Tuple

if TYPE_CHECKING:   # import cycle: workload.swf builds Job instances
    from repro.workload.traffic import TrafficSpec


class JobState(enum.Enum):
    PENDING = "pending"
    RUNNING = "running"
    COMPLETED = "completed"
    CANCELLED = "cancelled"


def clamp_band(min_nodes: int, max_nodes: int, preferred: Optional[int],
               cap: int) -> Tuple[int, int, Optional[int]]:
    """Pin ``1 <= min <= preferred <= max <= cap``.

    The single source of the band invariant — used by the SWF adapter, the
    synthetic evolving schedules, and the simulator's PhaseChange handler.
    Without it, a recorded size far above the simulated cluster (or an
    aggressive evolving phase band) could invert the band into one no
    scheduler can satisfy.
    """
    hi = max(1, min(max_nodes, cap))
    lo = max(1, min(min_nodes, hi))
    if preferred is None:
        return lo, hi, None
    return lo, hi, min(max(preferred, lo), hi)


@dataclasses.dataclass(frozen=True)
class JobPhase:
    """One phase of an EVOLVING job (§2 taxonomy).

    The application consumes ``work`` work units in this phase while
    demanding the ``(min_nodes, max_nodes, preferred)`` band; the optional
    per-phase ``serial_frac``/``data_bytes`` override the app model's so the
    execution rate and the reconfiguration cost stay honest across phases
    (``None`` inherits the app-level value).
    """
    work: float
    min_nodes: int
    max_nodes: int
    preferred: Optional[int] = None
    serial_frac: Optional[float] = None
    data_bytes: Optional[int] = None


@dataclasses.dataclass
class Job:
    job_id: int
    app: str                      # "cg" | "jacobi" | "nbody" | "fs" | "lm:<arch>"
    submit_time: float
    work: float                   # total work units (app iterations)
    min_nodes: int
    max_nodes: int
    preferred: Optional[int]      # Table 1 "Preferred"
    factor: int = 2               # resize factor (Table 1: 2 for all malleable)
    malleable: bool = True
    check_period_s: float = 15.0  # Table 1 "Scheduling period" (0 = every iter)
    requested_nodes: int = 0      # submission size (paper: launched at max)
    data_bytes: int = 0           # redistributed state size (FS: 1 GB)
    user: int = 0                 # submitting user (fair-share accounting)
    # Phase schedule for EVOLVING jobs (empty: demand fixed for the whole
    # run).  ``min_nodes``/``max_nodes``/``preferred`` above are the *live*
    # band — the PhaseChange handler rewrites them per phase, and every
    # scheduling policy must consult them instead of submission-time copies.
    phases: Tuple[JobPhase, ...] = ()
    # SERVING class: the open-loop request stream this job drains.  When
    # set, ``work`` is the stream's total arrivals, progress is request
    # drain (no checkpoint rewind — served requests can't be un-served),
    # and DMR negotiation runs on SLO pressure instead of remaining work.
    traffic: Optional["TrafficSpec"] = None

    # -- dynamic state (owned by the RMS / simulator) ------------------------
    state: JobState = JobState.PENDING
    nodes: int = 0                # current allocation
    priority_boost: float = 0.0   # max-priority path (shrink trigger / RJ)
    start_time: float = -1.0
    end_time: float = -1.0
    work_done: float = 0.0
    last_progress_t: float = -1.0
    paused_until: float = -1.0    # reconfiguration in progress
    completion_version: int = 0   # invalidates stale completion events
    resizer_for: Optional[int] = None   # this job is an RJ for job `id`
    phase_index: int = 0                # current phase (EVOLVING jobs)
    nodes_history: List[Tuple[float, int]] = dataclasses.field(
        default_factory=list)

    def __post_init__(self):
        if self.requested_nodes == 0:
            self.requested_nodes = self.max_nodes

    @property
    def evolving(self) -> bool:
        return bool(self.phases)

    @property
    def serving(self) -> bool:
        return self.traffic is not None

    def current_phase(self) -> Optional[JobPhase]:
        if not self.phases:
            return None
        return self.phases[min(self.phase_index, len(self.phases) - 1)]

    def phase_boundary(self) -> Optional[float]:
        """Cumulative work at the end of the current phase; None when the
        job is in its last phase (completion ends it) or has no phases."""
        nxt = self.phase_index + 1
        if not self.phases or nxt >= len(self.phases):
            return None
        return sum(ph.work for ph in self.phases[:nxt])

    # -- metrics (paper §7.4/§7.5 definitions) -------------------------------
    @property
    def wait_time(self) -> float:
        if self.start_time < 0:
            return 0.0
        return self.start_time - self.submit_time

    @property
    def exec_time(self) -> float:
        if self.end_time < 0 or self.start_time < 0:
            return 0.0
        return self.end_time - self.start_time

    @property
    def completion_time(self) -> float:
        """Submission -> finalization (wait + exec)."""
        if self.end_time < 0:
            return 0.0
        return self.end_time - self.submit_time

    def record_nodes(self, t: float) -> None:
        self.nodes_history.append((t, self.nodes))

    def node_seconds(self) -> float:
        """Integral of allocated nodes over time (for utilization)."""
        total, hist = 0.0, self.nodes_history
        for (t0, n0), (t1, _n1) in zip(hist, hist[1:]):
            total += n0 * (t1 - t0)
        return total
