"""Closed vocabulary of ``ActionRecord.reason`` codes — the audit currency.

Every ``ActionRecord`` the simulator emits carries a ``reason`` string of
the form ``CODE`` or ``CODE:DETAIL``: a stable, enum-like *code* drawn
from :data:`REASON_CODES` plus an optional free-form *detail* (a node id,
a phase index, a boosted job id) after a single colon.  The observability
ledger (:mod:`repro.obs`) groups actions by code, so codes must never
embed varying data — historically ``phase{i}`` and ``node{n}`` did, which
made two distinct causes (a node joining vs. a node draining idle)
collide and every phase index a fresh "reason".

Adding a code is an intentional vocabulary change: add it here *and* to
the taxonomy table in ``docs/observability.md``; the regression test
``tests/test_reasons.py`` fails on any emission outside the vocabulary.
"""
from __future__ import annotations

#: Every reason code any simulator/policy code path may emit.
REASON_CODES = frozenset({
    # -- DMR policy decisions (paper §4 modes) ------------------------------
    "requested-expand",            # §4.1 app asked min>cur, granted
    "requested-expand-denied",     # §4.1 asked, no factor step / no nodes
    "requested-shrink",            # §4.1 app asked max<cur, granted
    "requested-shrink-denied",     # §4.1 asked, no factor step fits
    "slo-expand",                  # serving band pushed up by SLO pressure
    "slo-expand-denied",           # SLO asked up, cluster could not grant
    "slo-shrink",                  # serving band released nodes on ebb
    "slo-shrink-denied",           # SLO asked down, no factor step fits
    "slo-steady",                  # SLO band holds the current size
    "preferred-grow-empty-queue",  # §4.2 empty queue, grow toward max
    "at-preferred-or-max",         # §4.2 empty queue, nothing to grant
    "toward-preferred",            # §4.2 steer toward preferred size
    "preferred-shrink-unavailable",  # §4.2 wants down, no step available
    "preferred-expand-denied",     # §4.2 wants up, blocked by queue/nodes
    "at-preferred",                # §4.2 already at preferred
    "wide-expand",                 # §4.3 spare nodes no queued job can use
    "wide-shrink",                 # §4.3 shrink frees a queued job (detail)
    "wide-no-action",              # §4.3 nothing helps
    # -- asynchronous negotiation pathology (§5.2.1) ------------------------
    "stale-grant",                 # waited expand superseded before grant
    "rj-timeout",                  # resizer-job reservation expired
    # -- preemptive scheduling ----------------------------------------------
    "head-reservation-slip",       # preempted to honor head-of-queue ETA
    # -- EVOLVING job class -------------------------------------------------
    "phase-entered",               # new phase announced a new band (detail)
    # -- faults and stragglers ----------------------------------------------
    "node-failed",                 # shrink/requeue off a dead node (detail)
    "slice-migration",             # straggler slice moved to healthy node
    # -- elastic cluster capacity -------------------------------------------
    "node-join",                   # capacity arrived (detail = node id)
    "node-drain",                  # drain bookkeeping on a busy node
    "node-drain-idle",             # drain released an idle node directly
    "drain-vacate",                # owner migrated/shrunk/requeued off it
    "power-off",                   # idle timer parked nodes (detail = ids)
    "power-on",                    # parked node booted back (detail = id)
})


def make_reason(code: str, detail=None) -> str:
    """Build a validated reason string ``code`` or ``code:detail``."""
    if code not in REASON_CODES:
        raise ValueError(f"unknown reason code: {code!r}")
    return code if detail is None else f"{code}:{detail}"


def reason_code(reason: str) -> str:
    """The vocabulary code of a reason string (strips any detail)."""
    return reason.partition(":")[0]


def reason_detail(reason: str) -> str:
    """The detail part of a reason string ('' when there is none)."""
    return reason.partition(":")[2]


def is_known_reason(reason: str) -> bool:
    """True iff ``reason`` parses to a recognized vocabulary code."""
    return reason_code(reason) in REASON_CODES
