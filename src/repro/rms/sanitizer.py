"""Opt-in runtime invariant sanitizer — the dynamic half of the
determinism/correctness tooling (the static half is :mod:`repro.lint`).

Enabled with ``SimConfig(sanitize=True)`` or ``REPRO_SANITIZE=1``, the
sanitizer installs itself as the engine's ``monitor`` and validates the
simulation's structural invariants around every dispatched event:

==========================  ================================================
Invariant name              Meaning
==========================  ================================================
``node_conservation``       free + quarantine + allocated + draining +
                            powered_off + dead == nodes_ever_joined.
``node_state_disjoint``     no node appears in two lifecycle pools at once
                            (gated full scan).
``dead_node_allocated``     no job owns a dead / powered-off / quarantined
                            node (gated full scan).
``quarantine_routing``      a known-slow node never sits in the healthy
                            ``free`` pool.
``allocation_mismatch``     ``job.nodes`` matches the cluster's allocation
                            (0 unless RUNNING).
``band_order``              1 <= min_nodes <= preferred <= max_nodes.
``band_capacity``           a freshly-applied phase band fits live capacity.
``stale_expand_wait``       every async expand wait belongs to a RUNNING job.
``stale_rj_reservation``    every RJ pseudo-allocation has a live wait.
``epoch_monotonic``         per-job epoch counters never move backwards and
                            no event carries an epoch from the future.
``duplicate_check_chain``   at most one pending ReconfigPoint /
                            CheckpointTick / PhaseChange per (job, epoch) —
                            a duplicated chain doubles the check frequency.
``completion_version``      at most one pending JobFinish per (job, version)
                            — a version that isn't bumped before reschedule
                            can double-complete a job.
``causal_schedule``         no event is scheduled in the past.
``heap_invariant``          the engine's event heap satisfies the heap
                            property (gated full scan).
``fairshare_billing``       the FairShare ledger matches an independent
                            shadow re-billing to < 1e-9 relative drift.
``serving_backlog``         a SERVING job's backlog / served counters are
                            non-negative and served never exceeds the
                            stream total.
``serving_conservation``    open-loop conservation: arrivals accrued ==
                            backlog + served (requests are neither minted
                            nor dropped by resizes/requeues).
==========================  ================================================

A violation raises :class:`SanitizerError` carrying the invariant name,
the triggering event, and the simulation time — it is a *structural* bug
in the simulator (or a deliberately seeded mutation in the test suite),
never a property of the workload.

Cost: per-event checks are O(running jobs); the pool-membership scans are
amortized (every ``FULL_SCAN_EVERY`` events, plus every capacity-churn
event).  The engine-bench ``sanitize`` scenario pins the overhead < 3x.
"""
from __future__ import annotations

from typing import Dict, List, Optional, Set, Tuple

from repro.rms.engine import (CheckpointTick, Event, ExpandTimeout,
                              JobFinish, NodeDrain, NodeFail, NodeJoin,
                              NodePowerOff, NodePowerOn, PhaseChange,
                              ReconfigPoint, TrafficTick)
from repro.rms.job import Job, JobState
from repro.rms.scheduler import FairSharePolicy

# Absolute slack for float comparisons on simulation timestamps.
T_EPS = 1e-9
# Relative drift tolerated between the fairshare ledger and the shadow.
BILLING_TOL = 1e-9
# Pool-membership / heap scans run every N events (and on churn events).
FULL_SCAN_EVERY = 256

# Events that move nodes between lifecycle pools: always worth a full scan.
CHURN_EVENTS = (NodeFail, NodeJoin, NodeDrain, NodePowerOff, NodePowerOn)

# Chain events deduplicated per (kind, job_id, epoch).  ExpandTimeout is
# excluded: two pending timeouts under one epoch are legal (a wait can be
# granted and re-entered without an epoch bump; ``since`` disambiguates).
_CHAIN_KINDS = {ReconfigPoint: "reconfig", CheckpointTick: "ckpt",
                PhaseChange: "phase", TrafficTick: "traffic"}

_EPOCH_ATTRS = {ReconfigPoint: "_reconfig_epoch",
                CheckpointTick: "_ckpt_epoch",
                PhaseChange: "_phase_epoch",
                ExpandTimeout: "_expand_epoch",
                TrafficTick: "_traffic_epoch"}

# Relative slack for the serving conservation check: the drain integrates
# float arithmetic per event, and completion snaps a remainder of at most
# 1e-6 * work into served.
SERVING_TOL = 1e-6


class SanitizerError(AssertionError):
    """A structural invariant of the simulation was violated.

    Attributes:
        invariant: machine-readable invariant name (table in module doc).
        t:         simulation time at the violation.
        event:     the event being scheduled/dispatched (may be None).
        detail:    human-readable description of the violated condition.
    """

    def __init__(self, invariant: str, t: float, event: Optional[Event],
                 detail: str):
        self.invariant = invariant
        self.t = t
        self.event = event
        self.detail = detail
        super().__init__(
            f"[{invariant}] t={t:.6f} event={event!r}: {detail}")


def _true_node_seconds(job: Job, a: float, b: float) -> float:
    """Independent reimplementation of the fairshare node-second integral
    (NOT ``FairSharePolicy._node_seconds`` — the shadow must not inherit a
    bug, or a test mutation, in the code under check)."""
    if b <= a:
        return 0.0
    hist = job.nodes_history
    if not hist:
        return 0.0
    total = 0.0
    for (t0, n0), (t1, _n1) in zip(hist, hist[1:]):
        lo, hi = max(t0, a), min(t1, b)
        if hi > lo:
            total += n0 * (hi - lo)
    t_last, n_last = hist[-1]
    if job.state is JobState.RUNNING and b > max(t_last, a):
        total += n_last * (b - max(t_last, a))
    return total


class SimSanitizer:
    """Engine monitor validating simulator invariants around every event.

    Install with :meth:`install` *before* ``engine.run()`` (the hot loop
    hoists the monitor reference).  ``ClusterSimulator`` does this in its
    constructor when ``SimConfig.sanitize`` / ``REPRO_SANITIZE`` asks.
    """

    def __init__(self, sim):
        self.sim = sim
        self.engine = sim.engine
        self.checks = 0             # after_event invocations
        # pending JobFinish versions per job (duplicate => double-complete)
        self._finish_versions: Dict[int, Set[int]] = {}
        # pending chain events per (kind, job_id, epoch)
        self._chain_counts: Dict[Tuple[str, int, int], int] = {}
        # high-water mark of the simulator's stored epoch per (kind, job)
        self._epoch_high: Dict[Tuple[str, int], int] = {}
        self._fs_policy: Optional[FairSharePolicy] = None
        self._fs_usage: Dict[int, float] = {}
        self._fs_last_t: Optional[float] = None
        self._fs_known: Dict[int, Job] = {}

    # -- installation --------------------------------------------------------

    def install(self) -> "SimSanitizer":
        self.engine.add_monitor(self)
        self._wrap_phase_band()
        policy = self.sim.scheduler.policy
        if isinstance(policy, FairSharePolicy):
            self._wrap_fairshare(policy)
        return self

    def _wrap_phase_band(self):
        """Post-check every band application at the exact moment it happens
        — the only point where ``max_nodes <= live_capacity`` is guaranteed
        (later drains may legally strand an applied band above capacity)."""
        sim = self.sim
        inner = sim._apply_phase_band

        def checked(job, phase_idx, min_nodes, max_nodes, preferred):
            inner(job, phase_idx, min_nodes, max_nodes, preferred)
            self._check_band_order(job, None)
            cap = max(sim.cluster.live_capacity, 1)
            if job.max_nodes > cap:
                self._fail("band_capacity", None,
                           f"job {job.job_id} phase {phase_idx} band max "
                           f"{job.max_nodes} exceeds live capacity {cap}")

        sim._apply_phase_band = checked

    def _wrap_fairshare(self, policy: FairSharePolicy):
        """Shadow the usage ledger: re-bill every observe() from an
        independent node-second integral and compare per-user."""
        self._fs_policy = policy
        inner = policy.observe

        def observed(jobs, now):
            self._fs_shadow_observe(jobs, now)
            inner(jobs, now)
            self._fs_compare()

        policy.observe = observed

    # -- engine monitor hooks ------------------------------------------------

    def on_schedule(self, event: Event):
        now = self.engine.now
        if event.t < now - T_EPS:
            self._fail("causal_schedule", event,
                       f"scheduled at t={event.t} before now={now}")
        cls = type(event)
        if cls is JobFinish:
            pending = self._finish_versions.setdefault(event.job_id, set())
            if event.version in pending:
                self._fail("completion_version", event,
                           f"job {event.job_id} already has a pending "
                           f"JobFinish for version {event.version} — "
                           f"completion_version was not bumped")
            pending.add(event.version)
            return
        kind = _CHAIN_KINDS.get(cls)
        if kind is not None:
            key = (kind, event.job_id, event.epoch)
            n = self._chain_counts.get(key, 0) + 1
            self._chain_counts[key] = n
            if n > 1:
                self._fail("duplicate_check_chain", event,
                           f"{n} pending {kind} events for job "
                           f"{event.job_id} epoch {event.epoch}")

    def before_event(self, event: Event):
        # Bookkeeping must decrement *before* handlers run: a handler
        # rescheduling its own chain (the legal steady state) would
        # otherwise look like a duplicate.
        cls = type(event)
        if cls is JobFinish:
            pending = self._finish_versions.get(event.job_id)
            if pending is not None:
                pending.discard(event.version)
            return
        kind = _CHAIN_KINDS.get(cls)
        if kind is not None:
            key = (kind, event.job_id, event.epoch)
            n = self._chain_counts.get(key, 0)
            if n <= 1:
                self._chain_counts.pop(key, None)
            else:
                self._chain_counts[key] = n - 1

    def after_event(self, event: Event):
        self.checks += 1
        cluster = self.sim.cluster
        # node-state conservation: disjoint state counts must sum to every
        # node that ever joined (count form: O(running) per event)
        counts = cluster.state_counts()
        total = (counts["free"] + counts["allocated"] + counts["draining"]
                 + counts["powered_off"] + counts["dead"])
        if total != cluster.nodes_ever_joined:
            self._fail("node_conservation", event,
                       f"state counts {counts} sum to {total}, expected "
                       f"nodes_ever_joined={cluster.nodes_ever_joined}")
        # known-slow nodes must never sit in the healthy free pool
        if cluster.slow:
            for node in cluster.free:
                if cluster.slow.get(node, 1.0) > 1.0:
                    self._fail("quarantine_routing", event,
                               f"slow node {node} (x"
                               f"{cluster.slow[node]}) in the free pool")
        job_id = getattr(event, "job_id", None)
        if job_id is not None and job_id >= 0:
            job = self.sim._by_id.get(job_id)
            if job is not None:
                self._check_job(job, event)
        self._check_expand_waits(event)
        self._check_epochs(event)
        if self.checks % FULL_SCAN_EVERY == 0 or \
                isinstance(event, CHURN_EVENTS):
            self._full_scan(event)

    # -- invariant checks ----------------------------------------------------

    def _fail(self, invariant: str, event: Optional[Event], detail: str):
        raise SanitizerError(invariant, self.engine.now, event, detail)

    def _check_band_order(self, job: Job, event: Optional[Event]):
        lo, hi, pref = job.min_nodes, job.max_nodes, job.preferred
        if not 1 <= lo <= hi:
            self._fail("band_order", event,
                       f"job {job.job_id} band min={lo} max={hi} violates "
                       f"1 <= min <= max")
        if pref is not None and not lo <= pref <= hi:
            self._fail("band_order", event,
                       f"job {job.job_id} preferred={pref} outside band "
                       f"[{lo}, {hi}]")

    def _check_job(self, job: Job, event: Optional[Event]):
        self._check_band_order(job, event)
        alloc = self.sim.cluster.allocation(job.job_id)
        if job.state is JobState.RUNNING:
            if alloc != job.nodes or alloc <= 0:
                self._fail("allocation_mismatch", event,
                           f"RUNNING job {job.job_id} has job.nodes="
                           f"{job.nodes} but cluster allocation {alloc}")
        elif alloc != 0:
            self._fail("allocation_mismatch", event,
                       f"{job.state.name} job {job.job_id} still holds "
                       f"{alloc} cluster nodes")
        if job.traffic is not None:
            self._check_serving(job, event)

    def _check_serving(self, job: Job, event: Optional[Event]):
        """SERVING queueing state: sign bounds + open-loop conservation.

        The stream is open-loop, so at any instant the arrivals accrued up
        to ``_traffic_seen`` must equal backlog + served exactly (to float
        slack): a resize or requeue can delay requests but can neither
        drop nor mint them.  The re-derivation reads the generator — pure
        in (seed, curve) — not the simulator's own accounting.
        """
        sim = self.sim
        jid = job.job_id
        gen = sim._traffic.get(jid)
        if gen is None:
            self._fail("serving_backlog", event,
                       f"serving job {jid} has no traffic generator")
        backlog = sim._backlog.get(jid, 0.0)
        tol = SERVING_TOL * max(job.work, 1.0)
        if backlog < -T_EPS:
            self._fail("serving_backlog", event,
                       f"job {jid} backlog is negative: {backlog!r}")
        if not -T_EPS <= job.work_done <= job.work + tol:
            self._fail("serving_backlog", event,
                       f"job {jid} served {job.work_done!r} outside "
                       f"[0, work={job.work!r}]")
        seen = sim._traffic_seen.get(jid, job.traffic.t0)
        arrivals = gen.arrivals_until(seen)
        if abs(arrivals - (backlog + job.work_done)) > tol:
            self._fail("serving_conservation", event,
                       f"job {jid}: arrivals({seen!r})={arrivals!r} but "
                       f"backlog {backlog!r} + served {job.work_done!r} "
                       f"= {backlog + job.work_done!r}")

    def _check_expand_waits(self, event: Optional[Event]):
        waiting: Set[int] = set()
        for w in self.sim._waiting_expands:
            job = w["job"]
            waiting.add(job.job_id)
            if job.state is not JobState.RUNNING:
                self._fail("stale_expand_wait", event,
                           f"expand wait for job {job.job_id} in state "
                           f"{job.state.name}")
        for owner in self.sim.cluster.owned:
            if owner < 0 and (-owner - 1) not in waiting:
                self._fail("stale_rj_reservation", event,
                           f"RJ reservation {owner} (job {-owner - 1}) has "
                           f"no pending expand wait")

    def _check_epochs(self, event: Event):
        attr = _EPOCH_ATTRS.get(type(event))
        if attr is None:
            return
        stored = getattr(self.sim, attr).get(event.job_id, 0)
        if event.epoch > stored:
            self._fail("epoch_monotonic", event,
                       f"event epoch {event.epoch} is ahead of the stored "
                       f"{attr} {stored} for job {event.job_id}")
        key = (attr, event.job_id)
        prev = self._epoch_high.get(key)
        if prev is not None and stored < prev:
            self._fail("epoch_monotonic", event,
                       f"stored {attr} for job {event.job_id} moved "
                       f"backwards: {prev} -> {stored}")
        self._epoch_high[key] = stored

    def _full_scan(self, event: Optional[Event]):
        cluster = self.sim.cluster
        owned_nodes: List[int] = []
        for owner in sorted(cluster.owned):
            owned_nodes.extend(cluster.owned[owner])
        pools = (list(cluster.free) + list(cluster.quarantine)
                 + list(cluster.draining) + list(cluster.powered_off)
                 + sorted(cluster.dead) + owned_nodes)
        if len(pools) != len(set(pools)):
            seen: Set[int] = set()
            dupes = sorted(n for n in pools
                           if n in seen or seen.add(n))
            self._fail("node_state_disjoint", event,
                       f"nodes in more than one lifecycle pool: {dupes}")
        unusable = (set(cluster.dead) | set(cluster.powered_off)
                    | set(cluster.quarantine))
        bad = unusable.intersection(owned_nodes)
        if bad:
            self._fail("dead_node_allocated", event,
                       f"jobs own dead/powered-off/quarantined nodes: "
                       f"{sorted(bad)}")
        for job_id in sorted(self.sim._by_id):
            self._check_job(self.sim._by_id[job_id], event)
        heap = self.engine._heap
        for i in range(1, len(heap)):
            if heap[i] < heap[(i - 1) >> 1]:
                self._fail("heap_invariant", event,
                           f"heap property violated at index {i}")

    # -- fairshare shadow ledger ---------------------------------------------

    def _fs_shadow_observe(self, jobs: List[Job], now: float):
        """Mirror ``FairSharePolicy.observe`` arithmetic exactly (same
        operation order per user), but bill from the independent
        node-second integral."""
        policy = self._fs_policy
        last = now if self._fs_last_t is None else self._fs_last_t
        dt = now - last
        if dt > 0:
            half = max(policy.config.fairshare_halflife_s, 1e-9)
            decay = 0.5 ** (dt / half)
            self._fs_usage = {u: v * decay
                              for u, v in sorted(self._fs_usage.items())}
        for j in jobs:
            self._fs_known.setdefault(j.job_id, j)
        if dt > 0:
            finished = []
            for job_id, j in sorted(self._fs_known.items()):
                ns = _true_node_seconds(j, last, now)
                if ns > 0:
                    self._fs_usage[j.user] = \
                        self._fs_usage.get(j.user, 0.0) + ns
                if j.state in (JobState.COMPLETED, JobState.CANCELLED):
                    finished.append(job_id)
            for job_id in finished:
                del self._fs_known[job_id]
        self._fs_last_t = now

    def _fs_compare(self):
        real = self._fs_policy._usage
        for user in sorted(set(self._fs_usage) | set(real)):
            want = self._fs_usage.get(user, 0.0)
            got = real.get(user, 0.0)
            tol = BILLING_TOL * max(1.0, abs(want), abs(got))
            if abs(want - got) > tol:
                self._fail("fairshare_billing", None,
                           f"user {user} ledger drift: policy billed "
                           f"{got!r}, shadow billed {want!r}")
