"""DMR reconfiguration policy — the resource-selection plug-in of paper §4.

Three modes, of increasing scheduler freedom, evaluated in order:

1. *Request an action* (§4.1): the application "strongly suggests" a
   direction by sending ``minimum > current`` (expand) or
   ``maximum < current`` (shrink); the RMS grants subject to global state.
2. *Preferred number of nodes* (§4.2): "no action" when already at the
   preferred size — except that with an empty queue the job may grow up to
   its maximum; otherwise the RMS steers the job toward the preferred size.
3. *Wide optimization* (§4.3): expand iff the spare nodes could not start
   any queued job; shrink iff that lets a queued job start — the triggering
   queued job is raised to maximum priority so it runs next.

All targets are *factor-consistent*: the new size is ``current * factor^k``
or ``current / factor^k`` (Listing 3's homogeneous mappings need an integer
mapping factor), clamped to ``[minimum, maximum]`` and to the job's
min/max.
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional, Sequence

from repro.core.actions import Action, Decision
from repro.rms.cluster import Cluster
from repro.rms.job import Job, JobState
from repro.rms.reasons import make_reason


def factor_sizes(cur: int, factor: int, lo: int, hi: int) -> List[int]:
    """Factor-consistent *adjacent* sizes in [lo, hi] (excluding ``cur``).

    Every reconfiguration in the paper is a single factor step (Fig. 3
    measures exactly the pairs 1→2 … 32→64 and 64→32 … 2→1; §7.4 explains
    execution-time degradation as "halving the resources").  Larger moves
    happen over successive reconfiguration points.
    """
    if factor <= 1:
        return [n for n in range(lo, hi + 1) if n != cur]
    sizes = []
    if cur % factor == 0 and lo <= cur // factor <= hi:
        sizes.append(cur // factor)
    if lo <= cur * factor <= hi:
        sizes.append(cur * factor)
    return sorted(sizes)


def _expansions(cur, factor, lo, hi):
    return [s for s in factor_sizes(cur, factor, lo, hi) if s > cur]


def _shrinks(cur, factor, lo, hi):
    return [s for s in factor_sizes(cur, factor, lo, hi) if s < cur]


@dataclasses.dataclass
class PolicyConfig:
    # Expansions never steal nodes a queued job could use (spirit of §4.3).
    conservative_expand: bool = True
    # Shrinks toward preferred are granted eagerly (§7.5: jobs are
    # "scaled-down as soon as possible").
    eager_preferred_shrink: bool = True


class ReconfigPolicy:
    """Stateless decision function over cluster + queue state."""

    def __init__(self, config: Optional[PolicyConfig] = None):
        self.config = PolicyConfig() if config is None else config

    # -- helpers -------------------------------------------------------------

    @staticmethod
    def _startable(job: Job, free: int) -> bool:
        return job.requested_nodes <= free

    def _queue_can_use(self, pending: Sequence[Job], free: int) -> bool:
        return any(self._startable(j, free) for j in pending)

    # -- the policy ----------------------------------------------------------

    def decide(self, cluster: Cluster, pending: Sequence[Job], job: Job, *,
               minimum: int, maximum: int, factor: int = 2,
               preferred: Optional[int] = None,
               slo_pressure: Optional[float] = None) -> Decision:
        cur = cluster.allocation(job.job_id) or job.nodes
        free = cluster.free_nodes
        pending = [j for j in pending
                   if j.state is JobState.PENDING and j.resizer_for is None]
        # negotiate over the band the *live* cluster can host: after a
        # drain/failure the app-declared band may exceed real capacity
        live = max(cluster.live_capacity, 1)
        lo = max(1, min(minimum, live))
        hi = max(lo, min(maximum, live))
        # SERVING jobs ride mode 1 with dedicated reasons: the band was
        # derived from p99/SLO pressure, not remaining work, and a steady
        # announcement (neither bound crosses ``cur``) holds deliberately
        # instead of falling through to modes 2/3 — batch heuristics must
        # not resize a latency-bound job the SLO rule chose to leave alone.
        slo = slo_pressure is not None

        # ---- mode 1: request an action (§4.1) ------------------------------
        if minimum > cur:
            ups = _expansions(cur, factor, minimum, hi)
            ups = [s for s in ups if s - cur <= free]
            if ups:
                return Decision(Action.EXPAND, ups[0],
                                reason="slo-expand" if slo
                                else "requested-expand")
            return Decision(Action.NO_ACTION, cur,
                            reason="slo-expand-denied" if slo
                            else "requested-expand-denied")
        if maximum < cur:
            downs = _shrinks(cur, factor, lo, maximum)
            if downs:
                return Decision(Action.SHRINK, downs[-1],
                                reason="slo-shrink" if slo
                                else "requested-shrink")
            return Decision(Action.NO_ACTION, cur,
                            reason="slo-shrink-denied" if slo
                            else "requested-shrink-denied")
        if slo:
            return Decision(Action.NO_ACTION, cur, reason="slo-steady")

        # ---- mode 2: preferred number of nodes (§4.2) ----------------------
        if preferred is not None:
            if not pending:
                # Empty queue: "the expansion can be granted up to a
                # specified maximum" — grow from any current size.
                ups = [s for s in _expansions(cur, factor, lo, hi)
                       if s - cur <= free]
                if ups:
                    return Decision(Action.EXPAND, ups[-1],
                                    reason="preferred-grow-empty-queue")
                return Decision(Action.NO_ACTION, cur,
                                reason="at-preferred-or-max")
            if preferred < cur:
                # Queue pressure: steer down to the preferred size
                # ("scaled-down as soon as possible", §7.5).
                downs = [s for s in _shrinks(cur, factor, lo, hi)
                         if s >= preferred]
                if downs and (self.config.eager_preferred_shrink or pending):
                    return Decision(Action.SHRINK, downs[0],
                                    reason="toward-preferred")
                return Decision(Action.NO_ACTION, cur,
                                reason="preferred-shrink-unavailable")
            if preferred > cur:
                ups = [s for s in _expansions(cur, factor, lo, hi)
                       if s <= preferred and s - cur <= free]
                blocked = (self.config.conservative_expand
                           and self._queue_can_use(pending, free))
                if ups and not blocked:
                    return Decision(Action.EXPAND, ups[-1],
                                    reason="toward-preferred")
                return Decision(Action.NO_ACTION, cur,
                                reason="preferred-expand-denied")
            return Decision(Action.NO_ACTION, cur, reason="at-preferred")

        # ---- mode 3: wide optimization (§4.3) ------------------------------
        ups = [s for s in _expansions(cur, factor, lo, hi) if s - cur <= free]
        if ups and (not pending or not self._queue_can_use(pending, free)):
            return Decision(Action.EXPAND, ups[-1], reason="wide-expand")
        if pending:
            downs = _shrinks(cur, factor, lo, hi)
            for new in reversed(downs):   # minimal shrink that helps
                freed = cur - new
                for qjob in sorted(pending,
                                   key=lambda j: j.requested_nodes):
                    if qjob.requested_nodes <= free + freed:
                        return Decision(
                            Action.SHRINK, new,
                            reason=make_reason("wide-shrink",
                                               f"job{qjob.job_id}"),
                            boost_job_id=qjob.job_id)
        return Decision(Action.NO_ACTION, cur, reason="wide-no-action")
