"""Application scaling & reconfiguration cost models (calibrated to paper §7).

Execution model: Amdahl-style per-iteration time
``t_iter(P) = t1 * (s + (1 - s) / P)`` with per-app serial fraction ``s``.
The paper states CG and Jacobi scale ~linearly (halving resources doubles
iteration time — §7.4), while N-body *prefers a single node* (Table 1), i.e.
it scales poorly; its preferred=1 only makes sense with a large serial
fraction, which also matches §8's remark that for some applications the
execution-time drawback of shrinking "can be negligible".

Calibration: per-iteration times are set so each application runs ≈600 s at
its maximum (submission) size, matching the fixed-workload execution times in
Table 4 (520–620 s).

Reconfiguration model (Fig. 3): scheduling time grows mildly with the node
count involved (Fig. 3a); redistribution time follows the factor-based
transfer plans of :mod:`repro.core.redistribute` over per-node links —
more participants ⇒ smaller concurrent chunks ⇒ faster (Fig. 3b), and
shrinks pay an extra synchronization term per participant (§5.2.2).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional, Tuple

from repro.core.actions import Action
from repro.core.redistribute import expand_plan, shrink_plan, transfer_time_s
from repro.rms.job import JobPhase

GiB = 1024 ** 3


@dataclasses.dataclass(frozen=True)
class AppModel:
    name: str
    iterations: int
    t1_iter_s: float          # per-iteration time on 1 node
    serial_frac: float        # Amdahl serial fraction
    data_bytes: int           # state redistributed on reconfiguration
    min_nodes: int
    max_nodes: int
    preferred: Optional[int]
    check_period_s: float     # 0 => check at every iteration (Table 1 "-")
    # EVOLVING (§2): per-phase demand bands + serial-fraction/data-size
    # overrides; empty for the paper's fixed-demand applications.  The
    # ``min_nodes``/``max_nodes``/``preferred`` above are the envelope.
    phases: Tuple[JobPhase, ...] = ()

    def iter_time(self, nodes: int,
                  serial_frac: Optional[float] = None) -> float:
        p = max(nodes, 1)
        s = self.serial_frac if serial_frac is None else serial_frac
        return self.t1_iter_s * (s + (1.0 - s) / p)

    def rate(self, nodes: int,
             serial_frac: Optional[float] = None) -> float:
        """Work units (iterations) per second."""
        return 1.0 / self.iter_time(nodes, serial_frac)

    def exec_time(self, nodes: int) -> float:
        return self.iterations * self.iter_time(nodes)


def _calibrated(name, iterations, serial_frac, calib_nodes, calib_exec_s,
                data_bytes, min_nodes, max_nodes, preferred, period):
    t_iter_at_max = calib_exec_s / iterations
    t1 = t_iter_at_max / (serial_frac + (1 - serial_frac) / calib_nodes)
    return AppModel(name, iterations, t1, serial_frac, data_bytes,
                    min_nodes, max_nodes, preferred, period)


# Table 1 parameters; ≈600 s execution at maximum size.
PAPER_APPS: Dict[str, AppModel] = {
    "fs": AppModel("fs", iterations=2, t1_iter_s=60.0, serial_frac=0.0,
                   data_bytes=1 * GiB, min_nodes=1, max_nodes=20,
                   preferred=None, check_period_s=0.0),
    "cg": _calibrated("cg", 10000, 0.05, 32, 600.0, 1 * GiB, 2, 32, 8, 15.0),
    "jacobi": _calibrated("jacobi", 10000, 0.02, 32, 600.0, 2 * GiB,
                          2, 32, 8, 15.0),
    "nbody": _calibrated("nbody", 25, 0.70, 16, 600.0, GiB // 2,
                         1, 16, 1, 0.0),
}


@dataclasses.dataclass(frozen=True)
class ReconfigCostModel:
    """Fig. 3 overhead model.

    The defaults are the hand-fit paper constants;
    :meth:`from_artifact` replaces them with parameters fitted from
    measured redistribute runs (:mod:`repro.calib`), tagging the instance
    with the artifact's ``calibration_id`` so consumers (sweep rows,
    benchmarks) can record which calibration produced their numbers.
    """

    link_bw: float = 5e9            # FDR10 InfiniBand ≈ 5 GB/s per node
    sched_base_s: float = 0.35      # Slurm resize transaction (Table 2 ≈0.42)
    sched_per_node_s: float = 0.003 # Fig. 3a mild growth with node count
    noaction_s: float = 0.009       # Table 2 "no action" ≈ 0.009–0.014 s
    spawn_s: float = 0.05           # process-spawn / mesh-rebuild constant
    shrink_sync_s: float = 0.004    # ACK sync per participant (§5.2.2)
    calibration_id: Optional[str] = None   # None: the paper-fit constants

    @classmethod
    def from_artifact(cls, source) -> "ReconfigCostModel":
        """Build the model from a calibration artifact (path or loaded
        document) produced by :mod:`repro.calib`."""
        from repro.calib.artifact import (load_calibration,
                                          validate_calibration)
        doc = load_calibration(source) if isinstance(source, str) \
            else validate_calibration(source)
        f = doc["fitted"]
        return cls(link_bw=float(f["link_bw"]),
                   sched_base_s=float(f["sched_base_s"]),
                   sched_per_node_s=float(f["sched_per_node_s"]),
                   spawn_s=float(f["spawn_s"]),
                   shrink_sync_s=float(f["shrink_sync_s"]),
                   calibration_id=str(doc["calibration_id"]))

    def schedule_time(self, action: Action, nodes_involved: int,
                      rng=None) -> float:
        if action is Action.NO_ACTION:
            base = self.noaction_s
        else:
            base = self.sched_base_s + self.sched_per_node_s * nodes_involved
        if rng is not None:
            base *= max(0.2, 1.0 + 0.15 * rng.standard_normal())
        return base

    def resize_time(self, old_nodes: int, new_nodes: int,
                    data_bytes: int) -> float:
        """Redistribution time for the factor-based plan (Fig. 3b)."""
        if new_nodes == old_nodes or data_bytes == 0:
            return 0.0
        if new_nodes > old_nodes:
            plan = expand_plan(old_nodes, new_nodes, data_bytes)
            sync = 0.0
        else:
            plan = shrink_plan(old_nodes, new_nodes, data_bytes)
            sync = self.shrink_sync_s
        return self.spawn_s + transfer_time_s(
            plan, link_bw=self.link_bw, sync_s_per_participant=sync)


def lm_app_model(name: str, *, params: int, step_flops: float,
                 iterations: int, chip_flops: float = 197e12,
                 model_ways: int = 16, mfu: float = 0.4,
                 min_nodes: int = 1, max_nodes: int = 16,
                 preferred: Optional[int] = None,
                 bytes_per_param: int = 18) -> AppModel:
    """An elastic LM-training job as a malleable app (beyond-paper workload).

    One "node" = one data-parallel slice of ``model_ways`` chips.  Per-step
    time on P slices ≈ step_flops / (P * model_ways * chip_flops * mfu);
    state moved on reconfiguration = params + grads + optimizer moments.
    """
    t1 = step_flops / (model_ways * chip_flops * mfu)
    return AppModel(f"lm:{name}", iterations=iterations, t1_iter_s=t1,
                    serial_frac=0.02, data_bytes=params * bytes_per_param,
                    min_nodes=min_nodes, max_nodes=max_nodes,
                    preferred=preferred, check_period_s=30.0)
