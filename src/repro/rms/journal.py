"""Resumable on-disk grid journal for the sweep driver.

A calibrated 10k-point trace × policy × mix × calibration study cannot
afford to lose hours of finished grid points to one preempted host: the
sweep driver (:mod:`repro.rms.sweep`) appends every completed row to a
*journal* — an append-only JSONL file — the moment it finishes, so a
killed sweep resumes by replaying only the missing points
(``--resume``), and shards running on different hosts (``--shard i/N``)
merge by simply reading each other's journals.

Design constraints, in order:

1. **Kill-safety.**  Each entry is one ``\\n``-terminated JSON line
   written with a single ``os.write`` to an ``O_APPEND`` descriptor and
   fsynced — a crash can truncate at most the last line, never corrupt
   earlier entries.  :meth:`GridJournal.load` tolerates a trailing
   partial line (and any undecodable line) by skipping it: those points
   simply re-run on resume.
2. **Self-describing entries.**  An entry carries the canonical row key
   (:func:`repro.rms.sweep.row_key` of the finished row), the grid-point
   *fingerprint* it was produced from, and the row itself.  Resume
   matches on the key but *verifies* the fingerprint — a journal written
   under a different grid (e.g. another ``--max-jobs``) fails loudly
   instead of silently serving wrong rows.
3. **Merge-determinism.**  Journals carry no ordering promises; the
   sweep driver re-sorts merged rows by ``row_key``, so the final
   artifact is byte-identical to a fresh serial run no matter how many
   hosts/kills/resumes produced it (pinned by ``tests/test_journal.py``).

File format: first line is a header object
(``{"journal": "repro.rms.sweep", "version": 1}``); every further line is
``{"key": "...", "point": {...}, "row": {...}}``.  Duplicate keys are
legal (two resumed runs may race the same point); the *last* complete
entry wins — by determinism both carry the same row anyway.
"""
from __future__ import annotations

import json
import os
from typing import Dict, Iterable, List, Optional

JOURNAL_ID = "repro.rms.sweep"
JOURNAL_VERSION = 1


class JournalMismatch(ValueError):
    """A journal entry exists for a key but was produced by a different
    grid point (or an incompatible journal format)."""


class GridJournal:
    """Append-only completed-point journal (one instance per writer)."""

    def __init__(self, path: str):
        self.path = path
        self._fd: Optional[int] = None

    # -- writing -------------------------------------------------------------

    def _ensure_open(self) -> int:
        if self._fd is None:
            needs_header = True
            needs_newline = False
            if os.path.exists(self.path) and os.path.getsize(self.path) > 0:
                needs_header = False
                with open(self.path, "rb") as fh:
                    fh.seek(-1, os.SEEK_END)
                    needs_newline = fh.read(1) != b"\n"
            self._fd = os.open(self.path,
                               os.O_WRONLY | os.O_APPEND | os.O_CREAT,
                               0o644)
            if needs_header:
                header = json.dumps({"journal": JOURNAL_ID,
                                     "version": JOURNAL_VERSION},
                                    sort_keys=True)
                os.write(self._fd, (header + "\n").encode())
                os.fsync(self._fd)
            elif needs_newline:
                # A kill truncated the last entry mid-write: terminate the
                # partial line so it stays isolated (and skipped on load)
                # instead of swallowing the next appended entry.
                os.write(self._fd, b"\n")
                os.fsync(self._fd)
        return self._fd

    def append(self, key: str, row: Dict[str, object],
               point: Optional[Dict[str, object]] = None) -> None:
        """Durably append one completed row.

        The whole entry goes down in a single ``os.write`` on an
        ``O_APPEND`` descriptor (atomic with respect to other appenders)
        followed by ``fsync`` — after this returns, the row survives a
        kill."""
        entry = {"key": key, "row": row}
        if point is not None:
            entry["point"] = point
        line = json.dumps(entry, sort_keys=True) + "\n"
        fd = self._ensure_open()
        os.write(fd, line.encode())
        os.fsync(fd)

    def close(self) -> None:
        if self._fd is not None:
            os.close(self._fd)
            self._fd = None

    def __enter__(self) -> "GridJournal":
        return self

    def __exit__(self, *exc) -> None:
        self.close()

    # -- reading -------------------------------------------------------------

    @staticmethod
    def load(path: str) -> Dict[str, Dict[str, object]]:
        """Read a journal: ``{key: entry}`` with undecodable (partial)
        lines skipped — their points re-run on resume.  A missing file is
        an empty journal."""
        entries: Dict[str, Dict[str, object]] = {}
        if not os.path.exists(path):
            return entries
        with open(path, "rb") as fh:
            for raw in fh:
                try:
                    obj = json.loads(raw.decode("utf-8"))
                except (json.JSONDecodeError, UnicodeDecodeError):
                    continue            # partial trailing line: re-run it
                if not isinstance(obj, dict):
                    continue
                if "journal" in obj:    # header line
                    if obj.get("journal") != JOURNAL_ID:
                        raise JournalMismatch(
                            f"{path}: not a sweep journal "
                            f"(journal={obj.get('journal')!r})")
                    if obj.get("version") != JOURNAL_VERSION:
                        raise JournalMismatch(
                            f"{path}: journal version "
                            f"{obj.get('version')} != {JOURNAL_VERSION}")
                    continue
                key, row = obj.get("key"), obj.get("row")
                if isinstance(key, str) and isinstance(row, dict):
                    entries[key] = obj  # last complete entry wins
        return entries

    @staticmethod
    def load_many(paths: Iterable[str]) -> Dict[str, Dict[str, object]]:
        """Merge several journals (shards, prior attempts): later paths
        win on duplicate keys — irrelevant in practice, since determinism
        makes duplicate rows identical."""
        merged: Dict[str, Dict[str, object]] = {}
        for path in paths:
            merged.update(GridJournal.load(path))
        return merged


def parse_shard(spec: str) -> List[int]:
    """``"i/N"`` → ``[i, N]`` with ``0 <= i < N`` — the deterministic
    grid partition selector (shard ``i`` takes grid points ``i, i+N,
    i+2N, ...`` in build order)."""
    try:
        i_s, n_s = spec.split("/", 1)
        i, n = int(i_s), int(n_s)
    except ValueError:
        raise ValueError(f"shard spec must be i/N, got {spec!r}") from None
    if n <= 0 or not 0 <= i < n:
        raise ValueError(f"shard index out of range: {spec!r} "
                         f"(need 0 <= i < N)")
    return [i, n]
