"""Elastic cluster capacity: power management + drain negotiation.

The paper's throughput-aware DMR loop assumes a fixed cluster; production
clusters churn (maintenance drains, spot reclamation, energy management).
This module makes capacity a first-class dynamic quantity:

- :class:`CapacityConfig` — the knobs, reachable via ``SimConfig.capacity``.
- :class:`CapacityManager` — a CLUES-style hysteresis power manager
  (after ``indigo_orchestrator``'s power-on/off task queues): nodes are
  parked only after the queue has been pressure-free for
  ``idle_power_off_s`` (the armed :class:`~repro.rms.engine.NodePowerOff`
  timer re-validates at fire time), and are booted back — with a
  ``power_up_delay_s`` boot cost — the moment pending demand exceeds the
  free + already-booting headroom.
- :func:`plan_drain` — the graceful-drain negotiation: migrate the owning
  job's slice to a healthy free node if one exists, else fold it down one
  factor-consistent DMR shrink step, else checkpoint-requeue.  The
  simulator applies the plan so all cost accounting stays in one place.
- :data:`CHURN_SCENARIOS` — named deterministic drain/join/power-cycling
  schedules so capacity churn can run through the sweep driver
  (``--churn``) with byte-stable artifacts.

Everything is deterministic: the manager schedules typed events through
the engine and keeps no wall-clock state, so serial / parallel / resumed
sweeps over churn scenarios stay byte-identical.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Dict, List, Optional, Sequence, Tuple

from repro.rms.cluster import Cluster
from repro.rms.engine import NodePowerOff, NodePowerOn, SimulationEngine
from repro.rms.job import Job, JobState


@dataclasses.dataclass(frozen=True)
class CapacityConfig:
    """Power-management knobs (``enabled=False`` keeps the cluster fixed —
    bit-identical to the pre-elastic behavior)."""
    enabled: bool = False
    idle_power_off_s: float = 300.0   # queue pressure-free this long => park
    min_free: int = 1                 # hot headroom never powered off
    power_up_delay_s: float = 30.0    # boot time before a parked node serves


class CapacityManager:
    """CLUES-style hysteresis: park idle nodes, boot them under pressure.

    Driven from the simulator's scheduler pass (event-driven, no polling
    loop): :meth:`note_pass` observes queue pressure after every pass and
    either books power-ons for unmet demand or arms the idle power-off
    timer; the timer's event calls :meth:`confirm_power_off`, which
    re-validates idleness at fire time — pressure that arrived in between
    simply disarms the park (the hysteresis half of CLUES).
    """

    def __init__(self, cluster: Cluster, engine: SimulationEngine,
                 config: CapacityConfig):
        self.cluster = cluster
        self.engine = engine
        self.config = config
        self.last_pressure_t = 0.0     # last time a pending job was seen
        self._off_armed = False        # a NodePowerOff event is in flight
        self._booting: List[int] = []  # parked nodes with a booked power-on

    # -- pressure observation ------------------------------------------------

    def pending_demand(self, pending: Sequence[Job]) -> int:
        return sum(j.requested_nodes for j in pending
                   if j.state is JobState.PENDING)

    def note_pass(self, pending: Sequence[Job], now: float,
                  extra_demand: int = 0) -> None:
        """Observe queue pressure after a scheduler pass.

        ``extra_demand`` carries demand invisible to the queue — e.g. the
        unmet node deltas of waiting resizer-job expands — so a starving
        expand can also trigger a power-up.
        """
        if not self.config.enabled:
            return
        demand = self.pending_demand(pending) + max(extra_demand, 0)
        if demand > 0:
            self.last_pressure_t = now
            self._book_power_ons(demand, now)
        elif not self._off_armed and \
                self.cluster.free_nodes > self.config.min_free:
            self._off_armed = True
            self.engine.schedule(NodePowerOff(
                now + self.config.idle_power_off_s, -1))

    def _book_power_ons(self, demand: int, now: float) -> None:
        need = demand - self.cluster.free_nodes - len(self._booting)
        for node in self.cluster.powered_off:
            if need <= 0:
                break
            if node in self._booting:
                continue
            self._booting.append(node)
            self.engine.schedule(NodePowerOn(
                now + self.config.power_up_delay_s, node))
            need -= 1

    # -- event confirmations -------------------------------------------------

    def confirm_power_off(self, pending: Sequence[Job],
                          now: float) -> List[int]:
        """The armed idle timer fired: park idle nodes above the headroom
        iff the queue stayed pressure-free the whole interval.  Quarantined
        (known-slow) nodes are parked first — they are the least valuable
        capacity.  Returns the nodes actually powered off."""
        self._off_armed = False
        if not self.config.enabled:
            return []
        if self.pending_demand(pending) > 0 or \
                now - self.last_pressure_t < self.config.idle_power_off_s:
            return []                   # pressure arrived mid-interval
        off: List[int] = []
        excess = self.cluster.free_nodes - self.config.min_free
        while excess > 0:
            pool = self.cluster.quarantine or self.cluster.free
            if not pool:
                break
            node = pool[-1]
            if not self.cluster.power_off_node(node):
                break
            off.append(node)
            excess -= 1
        return off

    def confirm_power_on(self, node: int) -> bool:
        """A booked boot finished: move the node back to the pool."""
        if node in self._booting:
            self._booting.remove(node)
        return self.cluster.power_on_node(node)


# ---------------------------------------------------------------------------
# Graceful drain negotiation
# ---------------------------------------------------------------------------

def plan_drain(cluster: Cluster, job: Job, node: int,
               min_floor: int) -> Tuple[str, int]:
    """Decide how to get ``job`` off ``node`` before release (pure).

    Returns ``(kind, new_nodes)``:

    - ``("migrate", nodes)`` — a healthy free node exists: one slice
      migration replaces the draining node (cheapest; the §5.2.2 fold
      mechanics on a single slice), allocation size unchanged.
    - ``("shrink", new)`` — malleable job folds down to the largest
      factor-consistent size that fits the surviving nodes and respects
      the *live* band floor ``min_floor`` (a DMR shrink, §5.2.2).
    - ``("requeue", 0)`` — rigid job, or no factor-consistent size fits:
      checkpoint requeue (§6 deployment path).
    """
    if cluster.free:                       # healthy replacements only
        return "migrate", job.nodes
    survivors = job.nodes - 1
    if job.malleable and survivors >= max(min_floor, 1):
        factor = max(job.factor, 2)
        new = job.nodes
        while new > survivors:
            if new % factor or new // factor < 1:
                break
            new //= factor
        if new <= survivors and new >= max(min_floor, 1):
            return "shrink", new
    return "requeue", 0


# ---------------------------------------------------------------------------
# Named churn scenarios (deterministic drain/join/power schedules)
# ---------------------------------------------------------------------------

Schedule = Tuple[Tuple[float, int], ...]


def _smoke_churn(num_nodes: int) -> Tuple[Schedule, Schedule, CapacityConfig]:
    """The CI smoke schedule: two maintenance drains mid-run, both nodes
    re-join later, one brand-new node arrives near the end, with the power
    manager parking idle capacity in between.  Pure arithmetic in
    ``num_nodes`` so every worker rebuilds it identically."""
    drains = ((600.0, 0), (1200.0, 1))
    joins = ((2100.0, 0), (2400.0, 1), (2700.0, -1))
    cfg = CapacityConfig(enabled=True, idle_power_off_s=300.0,
                         min_free=max(2, num_nodes // 16),
                         power_up_delay_s=60.0)
    return drains, joins, cfg


CHURN_SCENARIOS: Dict[str, Callable[[int],
                                    Tuple[Schedule, Schedule,
                                          CapacityConfig]]] = {
    "smoke": _smoke_churn,
}


def churn_schedule(name: Optional[str], num_nodes: int
                   ) -> Tuple[Schedule, Schedule, CapacityConfig]:
    """Resolve a named churn scenario to ``(drains, joins, config)``.

    ``None``/empty means no churn: empty schedules, power management off.
    """
    if not name:
        return (), (), CapacityConfig()
    try:
        build = CHURN_SCENARIOS[name]
    except KeyError:
        raise ValueError(
            f"unknown churn scenario {name!r}; "
            f"registered: {sorted(CHURN_SCENARIOS)}") from None
    return build(num_nodes)
