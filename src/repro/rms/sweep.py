"""Parallel sweep driver: trace × policy × malleability-mix grids.

Trace replays are embarrassingly parallel (ROADMAP "engine-level parallel
sweeps"): every grid point is an independent, fully-seeded simulation.  This
module fans a grid of :class:`SweepPoint` across a ``multiprocessing`` pool
and emits one *versioned* artifact (JSON and/or CSV) whose byte content is
identical for serial and parallel runs — the golden-artifact regression
test (``tests/test_sweep_golden.py``) pins this.

Artifact schema (``SCHEMA_ID``/``SCHEMA_VERSION``): a JSON object

.. code-block:: json

    {"schema": "repro.rms.sweep", "version": 5,
     "grid": {"traces": [...], "policies": [...],
              "mixes": [[r,m,f,e,s], ...]},
     "results": [{"trace": ..., "policy": ..., "rigid": ...,
                  "calibration_id": "paper-fit", "churn": "", ...}]}

Schema v5 (this version) adds the SERVING job class: mixes widen to five
fractions — ``(rigid, moldable, malleable, evolving, serving)`` — and
rows carry the SLO axis next to makespan/node-hours: ``slo_violations``
(TrafficTick probes above the SLO), ``p99_latency`` (worst per-job p99
queueing delay, seconds) and ``served_requests`` (total request drain).
Pre-serving artifacts auto-upgrade with ``serving=0.0`` and zeroed
serving metrics, which is exactly what a fresh run of the same grid
produces — the existing golden files stay valid as v4 on disk.
Schema v4 added the elastic-capacity columns: ``churn``
(the named :data:`repro.rms.capacity.CHURN_SCENARIOS` drain/join/power
schedule the row ran under, ``""`` for a fixed cluster), ``node_hours``
(integral of live capacity over the run — the cost axis next to
makespan), ``powered_off_hours`` (node·hours parked by the power
manager) and the capacity event counts ``drains`` / ``joins`` /
``power_offs`` / ``power_ons``.
Schema v3 added the ``calibration_id`` provenance column:
which reconfiguration-cost calibration (:mod:`repro.calib` artifact) the
row was simulated under — ``"paper-fit"`` for the hand-fit Table 2/Fig. 3
constants.  A grid point carries the artifact path in
``SweepPoint.calibration`` (CLI ``--calibration``); the row records the
artifact's content-hash id, so results are machine-independent.
Schema v2 widened malleability mixes to four fractions —
``(rigid, moldable, malleable, evolving)`` — and added the ``evolving``
and ``phase_changes`` row columns.  Older artifacts load transparently:
:func:`load_artifact` upgrades v1, v2 and v3 in place (``evolving=0.0``,
``phase_changes=0``, ``calibration_id="paper-fit"``, ``churn=""`` with
``node_hours`` back-computed from the fixed capacity × makespan).

``results`` rows carry only deterministic fields (no wall-clock times),
floats rounded to :data:`ROUND_DIGITS` decimals, rows sorted by
:func:`row_key` — so ``dumps_artifact`` is reproducible byte-for-byte.
The same row schema is shared by ``benchmarks/trace_replay.py``,
``benchmarks/table4_throughput.py`` (via :func:`report_row`) and
``benchmarks/policy_zoo.py``.

Long grids are *resumable*: ``--journal path.jsonl`` appends every
completed row durably as it finishes (:mod:`repro.rms.journal`),
``--resume`` skips journaled points (validating each against the grid
point's fingerprint), and ``--shard i/N`` deterministically partitions
the grid for multi-host chunking.  Because rows are re-sorted by
:func:`row_key` before serialization, a kill-resume-merge artifact is
byte-identical to a fresh serial run — the golden determinism contract
extends to journals (``tests/test_journal.py``).

CLI (the CI smoke step runs the ``--smoke`` grid with two workers)::

    PYTHONPATH=src python -m repro.rms.sweep --trace tests/data/sample.swf \\
        --policies easy,sjf --mixes 0:0:1,0.5:0.25:0.25 --workers 2 \\
        --out sweep.json [--check tests/data/golden_sweep.json] [--smoke] \\
        [--journal sweep.jsonl [--resume]] [--shard 0/4]
"""
from __future__ import annotations

import argparse
import csv
import dataclasses
import io
import json
import multiprocessing
import os
from typing import Dict, List, Optional, Sequence, Tuple

from repro.calib.artifact import PAPER_FIT_ID

SCHEMA_ID = "repro.rms.sweep"
SCHEMA_VERSION = 5
ROUND_DIGITS = 6

#: Fixed CSV column order — the row schema, version ``SCHEMA_VERSION``.
COLUMNS = ("trace", "policy", "rigid", "moldable", "malleable", "evolving",
           "serving",
           "flexible", "scheduling", "num_nodes", "seed", "time_scale",
           "calibration_id", "churn", "jobs", "completed", "makespan_s",
           "util_avg_pct", "util_std_pct", "avg_wait_s", "avg_exec_s",
           "avg_completion_s", "node_hours", "powered_off_hours",
           "expands", "shrinks", "preempts", "requeues",
           "timeouts", "phase_changes", "drains", "joins", "power_offs",
           "power_ons", "slo_violations", "p99_latency", "served_requests")

#: Default smoke grid (2 policies × 3 mixes) — also the golden-artifact grid.
SMOKE_POLICIES = ("easy", "sjf")
SMOKE_MIXES = ((0.0, 0.0, 1.0, 0.0, 0.0), (0.5, 0.25, 0.25, 0.0, 0.0),
               (0.25, 0.15, 0.3, 0.3, 0.0))

#: Serving smoke grid (``--smoke --serving``): batch-vs-serving
#: co-scheduling mixes behind ``tests/data/golden_serving_sweep.json``.
#: ``preempt`` may shrink serving jobs for the batch head (the makespan
#: side of the trade-off); ``easy`` leaves them to SLO negotiation.
SMOKE_SERVING_POLICIES = ("easy", "preempt")
SMOKE_SERVING_MIXES = ((0.0, 0.0, 0.7, 0.0, 0.3),
                       (0.25, 0.0, 0.25, 0.2, 0.3),
                       (0.0, 0.0, 0.4, 0.0, 0.6))

Mix = Tuple[float, float, float, float, float]


def norm_mix(mix: Sequence[float]) -> Mix:
    """Normalize a 3-/4-/5-tuple mix to ``(rigid, moldable, malleable,
    evolving, serving)`` — shorter tuples are pre-v2/pre-v5 and carry no
    evolving/serving share."""
    vals = tuple(float(x) for x in mix)
    if len(vals) in (3, 4):
        return vals + (0.0,) * (5 - len(vals))
    if len(vals) != 5:
        raise ValueError(f"mix needs 3, 4 or 5 fractions, got {mix!r}")
    return vals


@dataclasses.dataclass(frozen=True)
class SweepPoint:
    """One grid point: everything a worker needs to replay deterministically.

    ``trace`` is a filesystem path; the artifact stores its basename as the
    trace label so artifacts are machine-independent.
    """
    trace: str
    policy: str
    mix: Tuple[float, ...]  # (rigid, moldable, malleable[, evolving[, serving]])
    flexible: bool = True
    num_nodes: int = 64
    seed: int = 7
    scheduling: str = "sync"
    time_scale: float = 1.0
    max_jobs: Optional[int] = None
    # Path to a repro.calib calibration artifact; None => paper-fit
    # constants.  The artifact's calibration_id lands in the row.
    calibration: Optional[str] = None
    # Named capacity-churn scenario (repro.rms.capacity.CHURN_SCENARIOS):
    # scheduled drains/joins + power management; None/"" => fixed cluster.
    churn: Optional[str] = None
    # Observability replay: when set, the point runs under a
    # :class:`repro.obs.recorder.TraceRecorder` and writes its span/
    # metrics/Perfetto artifacts under this directory.  Deliberately NOT
    # part of the journal key or fingerprint — tracing never changes the
    # row (the observer-effect guarantee, ``tests/test_obs.py``).
    trace_dir: Optional[str] = None

    @property
    def label(self) -> str:
        return os.path.basename(self.trace)

    @property
    def slug(self) -> str:
        """Deterministic per-point file stem for ``trace_dir`` artifacts."""
        m = norm_mix(self.mix)
        mix = "-".join(f"{x:g}" for x in m)
        parts = [self.label, self.policy, mix,
                 "flex" if self.flexible else "fixed", self.scheduling,
                 f"n{self.num_nodes}", f"s{self.seed}"]
        if self.churn:
            parts.append(f"churn_{self.churn}")
        return "__".join(parts).replace("/", "_")


def build_grid(traces: Sequence[str], policies: Sequence[str],
               mixes: Sequence[Sequence[float]],
               flexibles: Sequence[bool] = (True,),
               **fixed) -> List[SweepPoint]:
    """Cross product of the axes; ``fixed`` forwards SweepPoint fields."""
    return [SweepPoint(trace=t, policy=p, mix=norm_mix(m), flexible=f,
                       **fixed)
            for t in traces for p in policies for m in mixes
            for f in flexibles]


# ---------------------------------------------------------------------------
# Worker
# ---------------------------------------------------------------------------

def _action_counts(actions) -> Dict[str, int]:
    out = {"expands": 0, "shrinks": 0, "preempts": 0, "requeues": 0,
           "timeouts": 0, "phase_changes": 0, "drains": 0, "joins": 0,
           "power_offs": 0, "power_ons": 0}
    for a in actions:
        if a.timed_out:
            out["timeouts"] += 1
        elif a.action == "expand":
            out["expands"] += 1
        elif a.action == "shrink":
            out["shrinks"] += 1
        elif a.action == "preempt_shrink":
            out["preempts"] += 1
        elif a.action == "preempt_requeue":
            out["requeues"] += 1
        elif a.action == "phase_change":
            out["phase_changes"] += 1
        elif a.action == "node_drain":
            out["drains"] += 1
        elif a.action == "node_join":
            out["joins"] += 1
        elif a.action == "power_off":
            out["power_offs"] += 1
        elif a.action == "power_on":
            out["power_ons"] += 1
    return out


def report_row(report, *, trace: str, policy: str,
               mix: Sequence[float], flexible: bool,
               scheduling: str = "sync", seed: int = 7,
               time_scale: float = 1.0,
               calibration_id: str = PAPER_FIT_ID,
               churn: str = "") -> Dict[str, object]:
    """Serialize a :class:`~repro.rms.simulator.SimReport` into the shared
    row schema — deterministic fields only, floats rounded."""
    from repro.rms.job import JobState

    mix = norm_mix(mix)
    util_avg, util_std = report.utilization()
    wait, exec_, comp = report.averages()
    completed = sum(1 for j in report.jobs
                    if j.state is JobState.COMPLETED)
    row: Dict[str, object] = {
        "trace": trace, "policy": policy,
        "rigid": round(mix[0], ROUND_DIGITS),
        "moldable": round(mix[1], ROUND_DIGITS),
        "malleable": round(mix[2], ROUND_DIGITS),
        "evolving": round(mix[3], ROUND_DIGITS),
        "serving": round(mix[4], ROUND_DIGITS),
        "flexible": bool(flexible), "scheduling": scheduling,
        # provenance column: the *configured* initial capacity of the
        # point, not a denominator
        "num_nodes": report.config.num_nodes,    # lint: disable=CAP001
        "seed": seed,
        "time_scale": round(time_scale, ROUND_DIGITS),
        "calibration_id": calibration_id, "churn": churn or "",
        "jobs": len(report.jobs), "completed": completed,
        "makespan_s": round(float(report.makespan), ROUND_DIGITS),
        "util_avg_pct": round(float(util_avg), ROUND_DIGITS),
        "util_std_pct": round(float(util_std), ROUND_DIGITS),
        "avg_wait_s": round(float(wait), ROUND_DIGITS),
        "avg_exec_s": round(float(exec_), ROUND_DIGITS),
        "avg_completion_s": round(float(comp), ROUND_DIGITS),
        "node_hours": round(float(report.node_hours()), ROUND_DIGITS),
        "powered_off_hours": round(float(report.powered_off_hours()),
                                   ROUND_DIGITS),
        "slo_violations": int(report.slo_violations()),
        "p99_latency": round(float(report.p99_latency()), ROUND_DIGITS),
        "served_requests": round(float(report.served_requests()),
                                 ROUND_DIGITS),
    }
    row.update(_action_counts(report.actions))
    return row


def run_point(point: SweepPoint) -> Dict[str, object]:
    """Replay one grid point (top-level: picklable for worker pools)."""
    from repro.rms.costmodel import ReconfigCostModel
    from repro.rms.simulator import ClusterSimulator, SimConfig
    from repro.rms.scheduler import SchedulerConfig
    from repro.workload.swf import MalleabilityMix, jobs_from_swf, parse_swf

    m = norm_mix(point.mix)
    mix = MalleabilityMix(rigid=m[0], moldable=m[1], malleable=m[2],
                          evolving=m[3], serving=m[4])
    trace = parse_swf(point.trace)
    jobs, apps = jobs_from_swf(trace, num_nodes=point.num_nodes, mix=mix,
                               seed=point.seed, max_jobs=point.max_jobs,
                               time_scale=point.time_scale)
    from repro.rms.capacity import churn_schedule

    drains, joins, capacity = churn_schedule(point.churn, point.num_nodes)
    cfg = SimConfig(num_nodes=point.num_nodes, flexible=point.flexible,
                    scheduling=point.scheduling, seed=point.seed,
                    sched=SchedulerConfig(policy=point.policy),
                    capacity=capacity, drains=drains, joins=joins)
    calibration_id = PAPER_FIT_ID
    if point.calibration:
        cost = ReconfigCostModel.from_artifact(point.calibration)
        cfg = dataclasses.replace(cfg, cost=cost)
        calibration_id = cost.calibration_id or PAPER_FIT_ID
    sim = ClusterSimulator(jobs, cfg, apps=apps)
    recorder = None
    if point.trace_dir:
        from repro.obs.recorder import TraceRecorder
        recorder = TraceRecorder(sim, meta={
            "trace": point.label, "policy": point.policy,
            "mix": list(norm_mix(point.mix)),
            "flexible": bool(point.flexible),
            "scheduling": point.scheduling,
            "num_nodes": point.num_nodes, "seed": point.seed,
            "churn": point.churn or "",
            "calibration_id": calibration_id}).install()
    report = sim.run()
    if recorder is not None:
        from repro.obs.export import write_trace
        recorder.finalize(report)
        write_trace(os.path.join(point.trace_dir, point.slug), recorder)
    return report_row(report, trace=point.label, policy=point.policy,
                      mix=point.mix, flexible=point.flexible,
                      scheduling=point.scheduling, seed=point.seed,
                      time_scale=point.time_scale,
                      calibration_id=calibration_id,
                      churn=point.churn or "")


# ---------------------------------------------------------------------------
# Driver
# ---------------------------------------------------------------------------

def row_key(row: Dict[str, object]) -> Tuple:
    """Canonical sort key: artifact row order is independent of worker
    completion order."""
    return (row["trace"], row["policy"], row["rigid"], row["moldable"],
            row["malleable"], row.get("evolving", 0.0),
            row.get("serving", 0.0),
            not row["flexible"], row["scheduling"],
            row["num_nodes"], row["seed"], row["time_scale"],
            row.get("calibration_id", PAPER_FIT_ID),
            row.get("churn", ""))


# Calibration artifacts are read once per path, not once per grid point:
# point keys/fingerprints need the content-hash id before any simulation
# runs, so resume can decide what to skip without touching the simulator.
_calibration_ids: Dict[str, str] = {}    # lint: disable=MUT002
# (the cache is keyed by path and holds content-hash ids, so a stale
# entry is impossible without editing the artifact file mid-process)


def _calibration_id(path: Optional[str]) -> str:
    if not path:
        return PAPER_FIT_ID
    cached = _calibration_ids.get(path)
    if cached is None:
        from repro.calib.artifact import load_calibration
        cached = str(load_calibration(path)["calibration_id"])
        _calibration_ids[path] = cached
    return cached


def point_journal_key(point: SweepPoint) -> str:
    """The journal key for a grid point — the same tuple :func:`row_key`
    derives from the *finished* row, computed up front from the point so a
    resume can skip it without running anything.  JSON-encoded so it is a
    stable, hashable JSONL dict key."""
    m = norm_mix(point.mix)
    return json.dumps((point.label, point.policy,
                       round(m[0], ROUND_DIGITS), round(m[1], ROUND_DIGITS),
                       round(m[2], ROUND_DIGITS), round(m[3], ROUND_DIGITS),
                       round(m[4], ROUND_DIGITS),
                       not point.flexible, point.scheduling,
                       point.num_nodes, point.seed,
                       round(point.time_scale, ROUND_DIGITS),
                       _calibration_id(point.calibration),
                       point.churn or ""))


def point_fingerprint(point: SweepPoint) -> Dict[str, object]:
    """Full deterministic identity of a grid point — a superset of the key
    (``max_jobs`` changes results but is not a row column), recorded with
    each journal entry and verified on resume so a journal written under a
    different grid fails loudly instead of serving wrong rows."""
    m = norm_mix(point.mix)
    return {"trace": point.label, "policy": point.policy,
            "mix": [round(x, ROUND_DIGITS) for x in m],
            "flexible": bool(point.flexible),
            "num_nodes": point.num_nodes, "seed": point.seed,
            "scheduling": point.scheduling,
            "time_scale": round(point.time_scale, ROUND_DIGITS),
            "max_jobs": point.max_jobs,
            "calibration_id": _calibration_id(point.calibration),
            "churn": point.churn or ""}


def _run_indexed(item: Tuple[int, SweepPoint]) -> Tuple[int, Dict[str, object]]:
    """Pool worker for the journaled path: ``imap_unordered`` streams rows
    back as they complete, and the index ties each row to its journal key."""
    idx, point = item
    return idx, run_point(point)


def run_sweep(points: Sequence[SweepPoint], *, workers: int = 0,
              journal: Optional[str] = None,
              resume_from: Sequence[str] = ()) -> List[Dict[str, object]]:
    """Run the grid; ``workers <= 1`` is serial, else a spawn-context pool
    (spawn: safe after JAX/XLA initialization in the parent).

    With ``journal`` set, every completed row is durably appended to that
    JSONL file the moment it finishes (kill-safe; see
    :mod:`repro.rms.journal`).  With ``resume_from`` journals, points whose
    key is already journaled are *not* re-run — their rows are reused after
    a fingerprint check.  Either way the returned rows are sorted by
    :func:`row_key`, so the artifact is byte-identical to a fresh serial
    run of the same grid.
    """
    points = list(points)
    resume_paths = [p for p in resume_from if p]
    if journal is None and not resume_paths:
        # Fast path — unchanged from the pre-journal driver.
        if workers <= 1 or len(points) <= 1:
            rows = [run_point(p) for p in points]
        else:
            ctx = multiprocessing.get_context("spawn")
            with ctx.Pool(min(workers, len(points))) as pool:
                rows = pool.map(run_point, points)
        return sorted(rows, key=row_key)

    from repro.rms.journal import GridJournal, JournalMismatch

    keyed = [(point_journal_key(p), point_fingerprint(p), p) for p in points]
    seen: Dict[str, Dict[str, object]] = {}
    for key, fp, _ in keyed:
        if key in seen and seen[key] != fp:
            raise ValueError(
                f"grid points collide on journal key {key}: same row "
                f"identity, different fingerprints ({seen[key]!r} vs "
                f"{fp!r}) — the journal cannot tell them apart")
        seen[key] = fp

    done = GridJournal.load_many(resume_paths)
    rows: List[Dict[str, object]] = []
    todo: List[Tuple[str, Dict[str, object], SweepPoint]] = []
    for key, fp, point in keyed:
        entry = done.get(key)
        if entry is None:
            todo.append((key, fp, point))
            continue
        recorded = entry.get("point")
        if recorded is not None and recorded != fp:
            raise JournalMismatch(
                f"journal entry {key} was produced by a different grid "
                f"point: recorded {recorded!r}, expected {fp!r}")
        rows.append(dict(entry["row"]))

    writer = GridJournal(journal) if journal else None
    try:
        if workers <= 1 or len(todo) <= 1:
            for key, fp, point in todo:
                row = run_point(point)
                if writer is not None:
                    writer.append(key, row, fp)
                rows.append(row)
        elif todo:
            ctx = multiprocessing.get_context("spawn")
            with ctx.Pool(min(workers, len(todo))) as pool:
                items = [(i, point) for i, (_, _, point) in enumerate(todo)]
                for idx, row in pool.imap_unordered(_run_indexed, items):
                    key, fp, _ = todo[idx]
                    if writer is not None:
                        writer.append(key, row, fp)
                    rows.append(row)
    finally:
        if writer is not None:
            writer.close()
    return sorted(rows, key=row_key)


def artifact(rows: Sequence[Dict[str, object]],
             grid: Optional[Dict[str, object]] = None) -> Dict[str, object]:
    return {"schema": SCHEMA_ID, "version": SCHEMA_VERSION,
            "grid": grid or {}, "results": list(rows)}


def dumps_artifact(doc: Dict[str, object]) -> str:
    """Canonical byte-stable serialization of an artifact."""
    return json.dumps(doc, sort_keys=True, indent=2) + "\n"


def write_artifact(path: str, doc: Dict[str, object]) -> None:
    with open(path, "w") as fh:
        fh.write(dumps_artifact(doc))


def _upgrade_v1(doc: Dict[str, object]) -> Dict[str, object]:
    """In-place v1 → v2: pre-evolving artifacts carry a zero evolving
    fraction and no phase changes."""
    for row in doc.get("results", []):
        row.setdefault("evolving", 0.0)
        row.setdefault("phase_changes", 0)
    grid = doc.get("grid") or {}
    if "mixes" in grid:
        grid["mixes"] = [list(norm_mix(m)) for m in grid["mixes"]]
    doc["version"] = 2
    return doc


def _upgrade_v2(doc: Dict[str, object]) -> Dict[str, object]:
    """In-place v2 → v3: pre-calibration artifacts were simulated under
    the hand-fit constants."""
    for row in doc.get("results", []):
        row.setdefault("calibration_id", PAPER_FIT_ID)
    doc["version"] = 3
    return doc


def _upgrade_v3(doc: Dict[str, object]) -> Dict[str, object]:
    """In-place v3 → v4: pre-elastic artifacts ran on a fixed cluster, so
    their node-hour integral is exactly capacity × makespan, nothing was
    ever parked, and no capacity events fired."""
    for row in doc.get("results", []):
        row.setdefault("churn", "")
        row.setdefault("node_hours", round(
            row["num_nodes"] * row["makespan_s"] / 3600.0, ROUND_DIGITS))
        row.setdefault("powered_off_hours", 0.0)
        for col in ("drains", "joins", "power_offs", "power_ons"):
            row.setdefault(col, 0)
    doc["version"] = 4
    return doc


def _upgrade_v4(doc: Dict[str, object]) -> Dict[str, object]:
    """In-place v4 → v5: pre-serving artifacts carry a zero serving
    fraction and no serving traffic, so every SLO metric is zero —
    exactly what a fresh v5 run of the same grid produces."""
    for row in doc.get("results", []):
        row.setdefault("serving", 0.0)
        row.setdefault("slo_violations", 0)
        row.setdefault("p99_latency", 0.0)
        row.setdefault("served_requests", 0.0)
    grid = doc.get("grid") or {}
    if "mixes" in grid:
        grid["mixes"] = [list(norm_mix(m)) for m in grid["mixes"]]
    doc["version"] = SCHEMA_VERSION
    return doc


def load_artifact(path: str) -> Dict[str, object]:
    with open(path) as fh:
        doc = json.load(fh)
    if doc.get("schema") != SCHEMA_ID:
        raise ValueError(f"not a sweep artifact: schema={doc.get('schema')!r}")
    version = doc.get("version")
    if version == 1:
        doc = _upgrade_v1(doc)
        version = doc["version"]
    if version == 2:
        doc = _upgrade_v2(doc)
        version = doc["version"]
    if version == 3:
        doc = _upgrade_v3(doc)
        version = doc["version"]
    if version == 4:
        doc = _upgrade_v4(doc)
        version = doc["version"]
    if version != SCHEMA_VERSION:
        raise ValueError(f"sweep artifact version {version} != "
                         f"supported {SCHEMA_VERSION}")
    return doc


def _csv_line(values) -> str:
    buf = io.StringIO()
    csv.writer(buf, lineterminator="").writerow(list(values))
    return buf.getvalue()


def csv_lines(rows: Sequence[Dict[str, object]]) -> List[str]:
    """One CSV line per row under csv-module (RFC 4180) quoting: a trace
    name carrying a comma, quote, or newline round-trips through
    ``csv.reader`` instead of silently shifting every later column.
    Values without special characters serialize exactly as ``str(value)``
    did before, so normal-grid artifacts stay byte-identical."""
    lines = [_csv_line(COLUMNS)]
    for row in rows:
        lines.append(_csv_line(str(row.get(c, "")) for c in COLUMNS))
    return lines


def write_csv(path: str, rows: Sequence[Dict[str, object]]) -> None:
    with open(path, "w") as fh:
        fh.write("\n".join(csv_lines(rows)) + "\n")


def winners_by_mix(rows: Sequence[Dict[str, object]],
                   metric: str = "makespan_s") -> Dict[Tuple, str]:
    """Per ``(trace, rigid, moldable, malleable, evolving, serving)``: the
    policy minimizing ``metric`` (ties broken by policy name for
    determinism).

    The key must include the trace: keying by mix alone collapsed a
    multi-trace sweep into one winner table, silently crowning whichever
    trace happened to produce the global minimum ``metric`` for the mix.
    """
    best: Dict[Tuple, Tuple[float, str]] = {}
    for row in rows:
        key = (str(row.get("trace", "")), row["rigid"], row["moldable"],
               row["malleable"], row.get("evolving", 0.0),
               row.get("serving", 0.0))
        cand = (float(row[metric]), str(row["policy"]))
        if key not in best or cand < best[key]:
            best[key] = cand
    return {key: policy for key, (_, policy) in sorted(best.items())}


# ---------------------------------------------------------------------------
# CLI
# ---------------------------------------------------------------------------

def smoke_grid(trace: str, *, num_nodes: int = 64, seed: int = 7,
               churn: Optional[str] = None, serving: bool = False
               ) -> Tuple[List[SweepPoint], Dict[str, object]]:
    """The tiny deterministic grid behind ``--smoke`` and the golden
    artifacts (``tests/data/golden_sweep.json``; with ``churn="smoke"``,
    ``tests/data/golden_capacity_sweep.json``; with ``serving=True``,
    ``tests/data/golden_serving_sweep.json``) — keep them in sync by
    construction."""
    policies = SMOKE_SERVING_POLICIES if serving else SMOKE_POLICIES
    mixes = SMOKE_SERVING_MIXES if serving else SMOKE_MIXES
    points = build_grid([trace], policies, mixes, (True,),
                        num_nodes=num_nodes, seed=seed, churn=churn)
    grid = {"traces": [os.path.basename(trace)],
            "policies": list(policies),
            "mixes": [list(norm_mix(m)) for m in mixes],
            "flexibles": [True], "num_nodes": num_nodes, "seed": seed}
    if churn:
        grid["churn"] = churn
    return points, grid


def parse_mixes(spec: str) -> List[Mix]:
    """``"0:0:1,0.2:0.1:0.4:0.3"`` -> 5-tuples; 3-/4-field specs are
    pre-v2/pre-v5 and get zero evolving/serving shares."""
    mixes = []
    for part in spec.split(","):
        vals = tuple(float(x) for x in part.strip().split(":"))
        if len(vals) not in (3, 4, 5):
            raise ValueError(f"mix needs "
                             f"rigid:moldable:malleable[:evolving[:serving]],"
                             f" got {part!r}")
        mixes.append(norm_mix(vals))
    return mixes


def main(argv=None) -> int:
    default_trace = os.path.join(os.path.dirname(__file__), "..", "..", "..",
                                 "tests", "data", "sample.swf")
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--trace", action="append", default=None,
                    help="SWF trace path (repeatable)")
    ap.add_argument("--policies", default="easy,fcfs")
    ap.add_argument("--mixes", default="0.2:0.2:0.6",
                    help="comma list of rigid:moldable:malleable")
    ap.add_argument("--fixed", action="store_true",
                    help="also sweep the fixed (non-malleable) configuration")
    ap.add_argument("--nodes", type=int, default=64)
    ap.add_argument("--seed", type=int, default=7)
    ap.add_argument("--time-scale", type=float, default=1.0)
    ap.add_argument("--max-jobs", type=int, default=None)
    ap.add_argument("--calibration", default=None,
                    help="repro.calib artifact path: simulate under its "
                         "fitted cost model (rows record its id)")
    ap.add_argument("--churn", default=None,
                    help="named capacity-churn scenario "
                         "(repro.rms.capacity.CHURN_SCENARIOS): scheduled "
                         "drains/joins + CLUES-style power management")
    ap.add_argument("--trace-dir", default=None, metavar="DIR",
                    help="replay every grid point under a TraceRecorder "
                         "and write repro.obs span/metrics/Perfetto "
                         "artifacts into DIR (rows are unchanged: tracing "
                         "is observer-effect-free)")
    ap.add_argument("--workers", type=int, default=0)
    ap.add_argument("--journal", action="append", default=None,
                    metavar="PATH",
                    help="append completed rows to this JSONL journal as "
                         "they finish (kill-safe); repeatable — the first "
                         "path is the write target, and with --resume ALL "
                         "listed journals are read (shard merge)")
    ap.add_argument("--resume", action="store_true",
                    help="skip grid points already completed in the "
                         "--journal files (fingerprint-checked)")
    ap.add_argument("--shard", default=None, metavar="I/N",
                    help="run only grid points I, I+N, I+2N, ... of the "
                         "deterministic build order; merge shard journals "
                         "later with --resume")
    ap.add_argument("--smoke", action="store_true",
                    help="tiny fixed grid (the golden-artifact grid)")
    ap.add_argument("--serving", action="store_true",
                    help="with --smoke: the serving co-scheduling grid "
                         "(tests/data/golden_serving_sweep.json)")
    ap.add_argument("--out", default=None, help="write JSON artifact here")
    ap.add_argument("--csv", default=None, help="write CSV artifact here")
    ap.add_argument("--check", default=None,
                    help="golden JSON artifact to byte-compare against "
                         "(exit 1 on mismatch)")
    args = ap.parse_args(argv)
    if args.resume and not args.journal:
        ap.error("--resume needs at least one --journal to read")
    shard = None
    if args.shard:
        from repro.rms.journal import parse_shard
        try:
            shard = parse_shard(args.shard)
        except ValueError as exc:
            ap.error(str(exc))

    traces = args.trace or [os.path.normpath(default_trace)]
    if args.churn:
        from repro.rms.capacity import CHURN_SCENARIOS
        if args.churn not in CHURN_SCENARIOS:
            ap.error(f"unknown churn scenario {args.churn!r}; "
                     f"registered: {','.join(sorted(CHURN_SCENARIOS))}")
    if args.smoke:
        if args.calibration:
            ap.error("--smoke is the fixed paper-fit golden grid; "
                     "run a calibrated sweep without --smoke")
        points, grid = smoke_grid(traces[0], num_nodes=args.nodes,
                                  seed=args.seed, churn=args.churn,
                                  serving=args.serving)
    else:
        if args.serving:
            ap.error("--serving selects the serving smoke grid; without "
                     "--smoke, put a serving share in --mixes "
                     "(rigid:moldable:malleable:evolving:serving)")
        policies = [p.strip() for p in args.policies.split(",") if p.strip()]
        mixes = parse_mixes(args.mixes)
        flexibles = (False, True) if args.fixed else (True,)
        calibration_id = PAPER_FIT_ID
        if args.calibration:
            from repro.calib.artifact import load_calibration
            calibration_id = str(
                load_calibration(args.calibration)["calibration_id"])
        points = build_grid(traces, policies, mixes, flexibles,
                            num_nodes=args.nodes, seed=args.seed,
                            time_scale=args.time_scale,
                            max_jobs=args.max_jobs,
                            calibration=args.calibration,
                            churn=args.churn)
        grid = {"traces": [os.path.basename(t) for t in traces],
                "policies": policies, "mixes": [list(m) for m in mixes],
                "flexibles": list(flexibles), "num_nodes": args.nodes,
                "seed": args.seed, "calibration_id": calibration_id}
        if args.churn:
            grid["churn"] = args.churn
    if shard is not None:
        # A shard artifact covers a subset of the grid and says so; the
        # merge run (--resume over all shard journals, no --shard) has no
        # "shard" key, so its bytes match a fresh serial full-grid run.
        points = points[shard[0]::shard[1]]
        grid = dict(grid)
        grid["shard"] = shard
    if args.trace_dir:
        os.makedirs(args.trace_dir, exist_ok=True)
        points = [dataclasses.replace(p, trace_dir=args.trace_dir)
                  for p in points]
    journal_path = args.journal[0] if args.journal else None
    resume_from = tuple(args.journal) if args.resume else ()
    rows = run_sweep(points, workers=args.workers, journal=journal_path,
                     resume_from=resume_from)
    doc = artifact(rows, grid)
    for line in csv_lines(rows):
        print(line)
    if args.out:
        write_artifact(args.out, doc)
        print(f"# wrote {args.out} ({len(rows)} rows)")
    if args.csv:
        write_csv(args.csv, rows)
        print(f"# wrote {args.csv}")
    if args.check:
        golden = dumps_artifact(load_artifact(args.check))
        mine = dumps_artifact(doc)
        if golden != mine:
            print(f"# MISMATCH against {args.check}: artifact bytes differ "
                  f"(schema or semantics changed — regenerate the golden "
                  f"file only for intentional changes)")
            return 1
        print(f"# artifact matches {args.check}")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
