"""Checkpointing (atomic, compressed, elastic-restorable)."""
from repro.checkpoint.store import CheckpointStore

__all__ = ["CheckpointStore"]
