"""Checkpointing: atomic, compressed, elastic-restorable.

Format: one zstd-compressed msgpack blob of flattened leaves + a JSON
manifest (step, tree structure, shapes/dtypes).  ``restore`` places leaves
onto *any* target shardings — restoring onto a different mesh than the one
that saved is exactly the checkpoint-and-reconfigure malleability baseline
([6] in the paper) and the node-failure recovery path.

Async saves run on a host thread (``save_async``) so the training loop only
pays the device->host copy, not the compression/IO.
"""
from __future__ import annotations

import json
import os
import pathlib
import struct
import threading
from typing import Any, Optional

import jax
import numpy as np

try:
    import zstandard as zstd
except ImportError:                                    # pragma: no cover
    zstd = None

MAGIC = b"RPRC0001"


def _serialize(leaves) -> bytes:
    parts = [MAGIC, struct.pack("<I", len(leaves))]
    for arr in leaves:
        arr = np.asarray(arr)
        shape = list(arr.shape)          # before ascontiguousarray, which
        arr = np.ascontiguousarray(arr)  # promotes 0-d arrays to (1,)
        head = json.dumps({"dtype": str(arr.dtype),
                           "shape": shape}).encode()
        parts.append(struct.pack("<I", len(head)))
        parts.append(head)
        raw = arr.tobytes()
        parts.append(struct.pack("<Q", len(raw)))
        parts.append(raw)
    blob = b"".join(parts)
    if zstd is not None:
        return b"ZSTD" + zstd.ZstdCompressor(level=3).compress(blob)
    return b"RAW0" + blob


def _deserialize(data: bytes):
    tag, body = data[:4], data[4:]
    if tag == b"ZSTD":
        if zstd is None:
            raise RuntimeError("checkpoint is zstd-compressed")
        body = zstd.ZstdDecompressor().decompress(body)
    assert body[:8] == MAGIC, "bad checkpoint magic"
    off = 8
    (n,) = struct.unpack_from("<I", body, off)
    off += 4
    leaves = []
    for _ in range(n):
        (hlen,) = struct.unpack_from("<I", body, off)
        off += 4
        head = json.loads(body[off:off + hlen])
        off += hlen
        (rlen,) = struct.unpack_from("<Q", body, off)
        off += 8
        arr = np.frombuffer(body[off:off + rlen],
                            dtype=head["dtype"]).reshape(head["shape"])
        off += rlen
        leaves.append(arr)
    return leaves


class CheckpointStore:
    def __init__(self, directory: str, keep: int = 3):
        self.dir = pathlib.Path(directory)
        self.dir.mkdir(parents=True, exist_ok=True)
        self.keep = keep
        self._thread: Optional[threading.Thread] = None

    # -- save -------------------------------------------------------------

    def save(self, step: int, state: Any) -> pathlib.Path:
        host = jax.tree.map(np.asarray, state)
        return self._write(step, host)

    def save_async(self, step: int, state: Any) -> None:
        """Device->host copy now; compression+IO on a background thread."""
        self.wait()
        host = jax.tree.map(np.asarray, state)
        self._thread = threading.Thread(target=self._write,
                                        args=(step, host), daemon=True)
        self._thread.start()

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def _write(self, step: int, host_state) -> pathlib.Path:
        leaves, treedef = jax.tree.flatten(host_state)
        blob = _serialize(leaves)
        path = self.dir / f"ckpt_{step:08d}"
        tmp = path.with_suffix(".tmp")
        tmp.write_bytes(blob)
        os.replace(tmp, path)                       # atomic publish
        (self.dir / "manifest.json").write_text(json.dumps(
            {"latest": step, "treedef": str(treedef)}))
        self._gc()
        return path

    def _gc(self):
        ckpts = sorted(self.dir.glob("ckpt_*"))
        for old in ckpts[:-self.keep]:
            old.unlink()

    # -- restore ----------------------------------------------------------

    def latest_step(self) -> Optional[int]:
        ckpts = sorted(self.dir.glob("ckpt_*"))
        if not ckpts:
            return None
        return int(ckpts[-1].name.split("_")[1])

    def restore(self, step: int, like: Any, shardings: Any = None) -> Any:
        """Restore onto the structure of ``like``; if ``shardings`` given,
        place leaves there (elastic restore onto any mesh)."""
        path = self.dir / f"ckpt_{step:08d}"
        leaves = _deserialize(path.read_bytes())
        _, treedef = jax.tree.flatten(like)
        state = jax.tree.unflatten(treedef, leaves)
        if shardings is not None:
            state = jax.device_put(state, shardings)
        return state
