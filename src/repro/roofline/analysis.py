"""Roofline-term derivation from compiled dry-run artifacts.

Targets TPU v5e: 197 TFLOP/s bf16 per chip, 819 GB/s HBM, ~50 GB/s/link
ICI.  ``cost_analysis`` supplies per-device HLO FLOPs / bytes accessed;
collective bytes are parsed from the post-SPMD optimized HLO (summing the
result-shape bytes of every all-gather / all-reduce / reduce-scatter /
all-to-all / collective-permute).  Terms follow the assignment:

    compute    = HLO_FLOPs / (chips * peak)
    memory     = HLO_bytes / (chips * hbm_bw)
    collective = collective_bytes / (chips * link_bw)
"""
from __future__ import annotations

import dataclasses
import re
from typing import Dict

HW = {
    "chip_bf16_flops": 197e12,
    "hbm_bw": 819e9,
    "ici_link_bw": 50e9,
    "hbm_per_chip": 16e9,
}

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "tuple": 0, "token": 0, "s4": 1, "u4": 1, "f8e4m3fn": 1, "f8e5m2": 1,
}

_COLL_RE = re.compile(
    r"=\s+((?:\([^)]*\))|(?:[a-z0-9]+\[[0-9,]*\][^ ]*))\s+"
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start|-done)?\(")
_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")


def _shape_bytes(shape_str: str) -> int:
    total = 0
    for dtype, dims in _SHAPE_RE.findall(shape_str):
        size = _DTYPE_BYTES.get(dtype)
        if size is None or size == 0:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * size
    return total


def parse_collectives(hlo_text: str) -> Dict[str, int]:
    """Per-device collective bytes by op kind (result-shape bytes)."""
    out: Dict[str, int] = {}
    seen_done = set()
    for m in _COLL_RE.finditer(hlo_text):
        shape_str, kind = m.group(1), m.group(2)
        # async pairs appear as -start/-done; count once via -start only
        tail = hlo_text[m.end() - 1:m.end() + 4]
        del tail
        line_start = hlo_text.rfind("\n", 0, m.start()) + 1
        line = hlo_text[line_start:hlo_text.find("\n", m.start())]
        if f"{kind}-done" in line:
            seen_done.add(kind)
            continue
        out[kind] = out.get(kind, 0) + _shape_bytes(shape_str)
    return out


@dataclasses.dataclass
class Roofline:
    compute_s: float
    memory_s: float
    collective_s: float
    dominant: str
    hlo_flops_global: float
    hlo_bytes_global: float
    coll_bytes_global: float
    model_flops: float
    useful_ratio: float     # MODEL_FLOPS / HLO_FLOPs
    step_s: float           # max of the three terms (no-overlap lower bound)
    mfu: float              # MODEL_FLOPS / (chips * peak * step_s)

    def as_dict(self):
        return dataclasses.asdict(self)


def roofline_terms(*, per_device_flops: float, per_device_bytes: float,
                   per_device_coll_bytes: float, chips: int,
                   model_flops: float) -> Roofline:
    peak = HW["chip_bf16_flops"]
    compute_s = per_device_flops / peak
    memory_s = per_device_bytes / HW["hbm_bw"]
    collective_s = per_device_coll_bytes / HW["ici_link_bw"]
    terms = {"compute": compute_s, "memory": memory_s,
             "collective": collective_s}
    dominant = max(terms, key=terms.get)
    step_s = max(terms.values())
    gf = per_device_flops * chips
    return Roofline(
        compute_s=compute_s, memory_s=memory_s, collective_s=collective_s,
        dominant=dominant,
        hlo_flops_global=gf,
        hlo_bytes_global=per_device_bytes * chips,
        coll_bytes_global=per_device_coll_bytes * chips,
        model_flops=model_flops,
        useful_ratio=(model_flops / gf) if gf else 0.0,
        step_s=step_s,
        mfu=(model_flops / (chips * peak * step_s)) if step_s else 0.0)


def cost_summary(compiled) -> Dict[str, float]:
    """Extract per-device flops & bytes from compiled.cost_analysis()."""
    ca = compiled.cost_analysis()
    if isinstance(ca, (list, tuple)):
        ca = ca[0]
    flops = float(ca.get("flops", 0.0))
    bytes_accessed = float(ca.get("bytes accessed", 0.0))
    return {"flops": flops, "bytes": bytes_accessed, "raw_keys": len(ca)}


def memory_summary(compiled) -> Dict[str, float]:
    ma = compiled.memory_analysis()
    out = {}
    for k in ("argument_size_in_bytes", "output_size_in_bytes",
              "temp_size_in_bytes", "alias_size_in_bytes",
              "generated_code_size_in_bytes"):
        out[k] = float(getattr(ma, k, 0) or 0)
    out["total_hbm_bytes"] = (out["argument_size_in_bytes"]
                              + out["output_size_in_bytes"]
                              + out["temp_size_in_bytes"]
                              - out["alias_size_in_bytes"])
    return out
