"""Loop-unit programs for roofline extrapolation.

XLA's ``cost_analysis`` counts a while-loop body once, so a scanned-over-
layers model under-reports FLOPs/bytes/collective-bytes by ~the trip count.
For every cell we therefore also compile its *loop unit* — one pattern
repetition of the layer scan, with the exact remat policy the real program
uses — and correct:  ``total = full + (trips - 1) * unit``.

Train cells get two unit variants:
- ``flops`` unit: grad wrt (params, x) — correct FLOPs/bytes including
  weight gradients;
- ``coll`` unit: grad wrt x only — correct *per-iteration* collective bytes
  (TP forward psums + dgrad psums).  The data-parallel reduction of weight
  gradients happens once on the stacked tensors outside the loop and is
  already fully counted in the main HLO; the grad-wrt-x unit deliberately
  omits it.

Inner loops (attention kv chunks, SSD chunks) are python-unrolled in the
model code, so within a unit everything is counted exactly.
"""
from __future__ import annotations

from typing import Any, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding

from repro.models import encdec as ed
from repro.models import transformer as tf
from repro.core.sharding import activation_rules
from repro.models.layers import abstract_params, is_spec, logical_tree


def _wrap_act(fn, mesh, rules):
    def wrapped(*args):
        with activation_rules(mesh, rules):
            return fn(*args)
    return wrapped


def _shapes_of(specs):
    return jax.tree.map(lambda s: s.shape, specs, is_leaf=is_spec)


def _sh_tree(logical, shapes, mesh, rules):
    return jax.tree.map(
        lambda lg, sh: NamedSharding(mesh, rules.spec_for(lg, sh, mesh)),
        logical, shapes, is_leaf=lambda x: isinstance(x, tuple))


def _remat(fn, cfg):
    if cfg.remat == "none":
        return fn
    policy = (jax.checkpoint_policies.nothing_saveable
              if cfg.remat == "nothing_saveable"
              else jax.checkpoint_policies.checkpoint_dots)
    return jax.checkpoint(fn, policy=policy, prevent_cse=False)


def _cache_abs_of(cfg):
    def abs_of(spec):
        last = spec.logical[-1] if spec.logical else ""
        if last == "kv_seq":
            return jax.ShapeDtypeStruct(spec.shape, jnp.int32)
        if last == "state":
            return jax.ShapeDtypeStruct(spec.shape, jnp.float32)
        return jax.ShapeDtypeStruct(spec.shape, jnp.dtype(cfg.dtype))
    return abs_of


def lm_loops(model, cfg, shape, mesh, rules, kind: str, accum: int = 1):
    """LoopSpecs for a CausalLM cell (kind: train|prefill|decode).

    With grad accumulation the layer unit processes one *microbatch*.
    """
    from repro.launch.cells import LoopSpec   # local: avoid import cycle
    reps, _tail = model._pattern_layout()
    if reps <= 1:
        return ()
    unit_specs = {f"p{j}": tf.block_specs(cfg, kj)
                  for j, kj in enumerate(cfg.pattern)}
    up_abs = abstract_params(unit_specs, jnp.dtype(cfg.param_dtype))
    up_sh = _sh_tree(logical_tree(unit_specs), _shapes_of(unit_specs),
                     mesh, rules)
    b = shape.global_batch // (accum if kind == "train" else 1)
    if kind == "decode":
        s_tot = 1
    else:
        s_tot = shape.seq_len
    x_abs = jax.ShapeDtypeStruct((b, s_tot, cfg.d_model),
                                 jnp.dtype(cfg.dtype))
    x_sh = NamedSharding(mesh, rules.spec_for(("batch", "seq", "embed"),
                                              x_abs.shape, mesh))

    if kind == "train":
        def fwd(up, x):
            aux = jnp.zeros((), jnp.float32)
            for j, kj in enumerate(cfg.pattern):
                x, aux = tf.block_apply(up[f"p{j}"], x, cfg, kj, aux)
            return x.astype(jnp.float32).sum() + aux
        fwd_ck = _remat(fwd, cfg)

        def unit_flops(up, x):
            return jax.grad(fwd_ck, argnums=(0, 1))(up, x)

        def unit_coll(up, x):
            return jax.grad(fwd_ck, argnums=1)(up, x)

        return (
            LoopSpec("unit_flops", unit_flops, (up_abs, x_abs),
                     (up_sh, x_sh), reps, ("flops",)),
            LoopSpec("unit_coll", unit_coll, (up_abs, x_abs),
                     (up_sh, x_sh), reps, ("coll",)),
        )

    if kind == "prefill":
        max_len = shape.seq_len

        def unit(up, x):
            caches = {}
            for j, kj in enumerate(cfg.pattern):
                x, caches[f"p{j}"] = tf.block_prefill(
                    up[f"p{j}"], x, cfg, kj, max_len)
            return x, caches
        return (LoopSpec("unit", unit, (up_abs, x_abs), (up_sh, x_sh),
                         reps),)

    # decode
    max_len = shape.seq_len
    cu_specs = {f"p{j}": tf.block_cache_specs(cfg, kj, b, max_len)
                for j, kj in enumerate(cfg.pattern)}
    cu_abs = jax.tree.map(_cache_abs_of(cfg), cu_specs, is_leaf=is_spec)
    cu_sh = _sh_tree(logical_tree(cu_specs), _shapes_of(cu_specs),
                     mesh, rules)
    pos_abs = jax.ShapeDtypeStruct((), jnp.int32)

    def unit(up, cache, x, pos):
        new = {}
        for j, kj in enumerate(cfg.pattern):
            x, new[f"p{j}"] = tf.block_decode(
                up[f"p{j}"], x, cfg, kj, cache[f"p{j}"], pos)
        return x, new
    return (LoopSpec("unit", unit, (up_abs, cu_abs, x_abs, pos_abs),
                     (up_sh, cu_sh, x_sh, None), reps),)


def encdec_loops(model, cfg, shape, mesh, rules, kind: str,
                 accum: int = 1):
    from repro.launch.cells import LoopSpec
    b = shape.global_batch // (accum if kind == "train" else 1)
    s_half = shape.seq_len // 2
    dt = jnp.dtype(cfg.dtype)

    enc_specs = ed.enc_block_specs(cfg)
    dec_specs = ed.dec_block_specs(cfg)
    eu_abs = abstract_params(enc_specs, jnp.dtype(cfg.param_dtype))
    du_abs = abstract_params(dec_specs, jnp.dtype(cfg.param_dtype))
    eu_sh = _sh_tree(logical_tree(enc_specs), _shapes_of(enc_specs),
                     mesh, rules)
    du_sh = _sh_tree(logical_tree(dec_specs), _shapes_of(dec_specs),
                     mesh, rules)
    x_enc = jax.ShapeDtypeStruct((b, s_half, cfg.d_model), dt)
    x_sh = NamedSharding(mesh, rules.spec_for(("batch", "seq", "embed"),
                                              x_enc.shape, mesh))
    loops = []

    if kind == "train":
        def enc_fwd(up, x):
            return ed.enc_block_apply(up, x, cfg).astype(jnp.float32).sum()

        def dec_fwd(up, x, eo):
            return ed.dec_block_apply(up, x, eo,
                                      cfg).astype(jnp.float32).sum()
        enc_ck, dec_ck = _remat(enc_fwd, cfg), _remat(dec_fwd, cfg)
        loops += [
            LoopSpec("enc_flops", lambda up, x: jax.grad(
                enc_ck, argnums=(0, 1))(up, x),
                (eu_abs, x_enc), (eu_sh, x_sh), cfg.enc_layers, ("flops",)),
            LoopSpec("enc_coll", lambda up, x: jax.grad(
                enc_ck, argnums=1)(up, x),
                (eu_abs, x_enc), (eu_sh, x_sh), cfg.enc_layers, ("coll",)),
            LoopSpec("dec_flops", lambda up, x, eo: jax.grad(
                dec_ck, argnums=(0, 1, 2))(up, x, eo),
                (du_abs, x_enc, x_enc), (du_sh, x_sh, x_sh),
                cfg.num_layers, ("flops",)),
            LoopSpec("dec_coll", lambda up, x, eo: jax.grad(
                dec_ck, argnums=(1, 2))(up, x, eo),
                (du_abs, x_enc, x_enc), (du_sh, x_sh, x_sh),
                cfg.num_layers, ("coll",)),
        ]
        return tuple(loops)

    if kind == "prefill":
        def enc_unit(up, x):
            return ed.enc_block_apply(up, x, cfg)

        def dec_unit(up, x, eo):
            # mirrors EncDecLM.prefill body (self prefill + cross kv)
            import repro.models.attention as attn
            from repro.models.layers import mlp_apply, rms_norm
            h = rms_norm(x, up["ln1"], cfg.norm_eps)
            y, self_cache = attn.attention_prefill(
                up["self_attn"], h, cfg, kind="global", cache_len=s_half)
            x = x + y
            h = rms_norm(x, up["ln_x"], cfg.norm_eps)
            ck = jnp.einsum("bse,ehd->bshd", eo,
                            up["cross_attn"]["wk"].astype(dt))
            cv = jnp.einsum("bse,ehd->bshd", eo,
                            up["cross_attn"]["wv"].astype(dt))
            x = x + attn.attention_apply(up["cross_attn"], h, cfg,
                                         kind="cross", x_kv=eo)
            h = rms_norm(x, up["ln2"], cfg.norm_eps)
            x = x + mlp_apply(up["ffn"], h, cfg)
            return x, (self_cache, ck, cv)
        loops += [
            LoopSpec("enc_unit", enc_unit, (eu_abs, x_enc), (eu_sh, x_sh),
                     cfg.enc_layers),
            LoopSpec("dec_unit", dec_unit, (du_abs, x_enc, x_enc),
                     (du_sh, x_sh, x_sh), cfg.num_layers),
        ]
        return tuple(loops)

    # decode: one token through a decoder block with self+cross caches
    from repro.models import attention as attn
    kv, hd = cfg.num_kv_heads, cfg.head_dim
    cache_abs = {
        "self": jax.tree.map(_cache_abs_of(cfg),
                             attn.cache_specs(cfg, b, s_half),
                             is_leaf=is_spec),
        "cross_k": jax.ShapeDtypeStruct((b, s_half, kv, hd), dt),
        "cross_v": jax.ShapeDtypeStruct((b, s_half, kv, hd), dt),
    }
    cache_logical = {
        "self": logical_tree(attn.cache_specs(cfg, b, s_half)),
        "cross_k": ("batch", "kv_seq", "kv_heads", "head_dim"),
        "cross_v": ("batch", "kv_seq", "kv_heads", "head_dim"),
    }
    cache_shapes = jax.tree.map(lambda a: a.shape, cache_abs)
    cache_sh = _sh_tree(cache_logical, cache_shapes, mesh, rules)
    x_dec = jax.ShapeDtypeStruct((b, 1, cfg.d_model), dt)
    x_dec_sh = NamedSharding(mesh, rules.spec_for(
        ("batch", "seq", "embed"), x_dec.shape, mesh))
    pos_abs = jax.ShapeDtypeStruct((), jnp.int32)

    def dec_unit(up, c, x, pos):
        from repro.models.layers import mlp_apply, rms_norm
        h = rms_norm(x, up["ln1"], cfg.norm_eps)
        y, self_cache = attn.decode_attention(up["self_attn"], h, cfg,
                                              c["self"], pos)
        x = x + y
        h = rms_norm(x, up["ln_x"], cfg.norm_eps)
        x = x + ed._cross_decode(up["cross_attn"], h, cfg,
                                 c["cross_k"], c["cross_v"])
        h = rms_norm(x, up["ln2"], cfg.norm_eps)
        x = x + mlp_apply(up["ffn"], h, cfg)
        return x, self_cache
    return (LoopSpec("dec_unit", dec_unit,
                     (du_abs, cache_abs, x_dec, pos_abs),
                     (du_sh, cache_sh, x_dec_sh, None), cfg.num_layers),)


def micro_loop(model, cfg, shape, mesh, rules, accum, batch_abs, batch_sh):
    """LoopSpec for the grad-accumulation microbatch scan body."""
    from repro.launch.cells import LoopSpec
    params_abs = abstract_params(model.specs(), jnp.dtype(cfg.param_dtype))
    params_sh = _sh_tree(logical_tree(model.specs()),
                         _shapes_of(model.specs()), mesh, rules)
    micro_abs = jax.tree.map(
        lambda s: jax.ShapeDtypeStruct(
            (s.shape[0] // accum,) + s.shape[1:], s.dtype), batch_abs)

    def micro_fn(params, mb):
        (loss, _), grads = jax.value_and_grad(
            lambda p: model.loss(p, mb), has_aux=True)(params)
        return loss, grads
    # grads land in the carry with the params' sharding, forcing the same
    # per-microbatch DP reduction the real scan body performs.
    return LoopSpec("micro", _wrap_act(micro_fn, mesh, rules),
                    (params_abs, micro_abs),
                    (params_sh, batch_sh), accum, ("flops", "coll"),
                    out_shardings=(None, params_sh))


def loops_for(model, cfg, shape, mesh, rules, kind: str,
              accum: int = 1) -> Tuple[Any, ...]:
    if cfg.family == "encdec":
        loops = encdec_loops(model, cfg, shape, mesh, rules, kind, accum)
    else:
        loops = lm_loops(model, cfg, shape, mesh, rules, kind, accum)
    for lp in loops:
        lp.fn = _wrap_act(lp.fn, mesh, rules)
    return loops
