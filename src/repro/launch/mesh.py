"""Production mesh construction.

A function (not a module-level constant) so importing this module never
touches jax device state — the dry-run sets
``XLA_FLAGS=--xla_force_host_platform_device_count=512`` before first jax
init, and everything else must see the real single device.
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 (one v5e pod, 256 chips) or 2x16x16 (two pods, 512 chips)."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)
