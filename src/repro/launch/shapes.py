"""Assigned input shapes x applicability rules (40 cells)."""
from __future__ import annotations

import dataclasses
from typing import Optional

from repro.configs import get_config


@dataclasses.dataclass(frozen=True)
class ShapeSpec:
    name: str
    seq_len: int
    global_batch: int
    kind: str            # "train" | "prefill" | "decode"


SHAPES = {
    "train_4k": ShapeSpec("train_4k", 4_096, 256, "train"),
    "prefill_32k": ShapeSpec("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": ShapeSpec("decode_32k", 32_768, 128, "decode"),
    "long_500k": ShapeSpec("long_500k", 524_288, 1, "decode"),
}

# long_500k needs sub-quadratic attention: run for SSM/hybrid (+ gemma2,
# whose local layers are O(window) and whose 23 global layers shard their
# 500k KV over the data axis); skip for pure full-attention archs.
LONG_OK = {"recurrentgemma-9b", "mamba2-130m", "gemma2-27b"}


def applicable(arch: str, shape: str) -> tuple[bool, str]:
    cfg = get_config(arch)
    if shape == "long_500k" and cfg.name not in LONG_OK:
        return False, "pure full-attention arch: 500k KV has no sub-quadratic escape"
    return True, ""


def all_cells():
    from repro.configs import list_archs
    for arch in list_archs():
        for shape in SHAPES:
            yield arch, shape
