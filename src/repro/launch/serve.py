"""Serving launcher: batched decode for any assigned arch.

  PYTHONPATH=src python -m repro.launch.serve --arch smollm-135m --reduced
"""
import argparse
import dataclasses
import sys
import time

import jax
import numpy as np


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="smollm-135m")
    ap.add_argument("--reduced", action="store_true", default=True)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--new-tokens", type=int, default=16)
    ap.add_argument("--max-len", type=int, default=256)
    args = ap.parse_args()

    from repro.models import build_model, get_model, reduced_config
    from repro.runtime import Request, Server

    _, cfg = get_model(args.arch)
    if args.reduced:
        cfg = dataclasses.replace(reduced_config(cfg), dtype="float32")
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    server = Server(model, params, batch=args.batch, max_len=args.max_len)
    rng = np.random.default_rng(0)
    reqs = [Request(rid=i, prompt=rng.integers(0, cfg.vocab_size, 8),
                    max_new_tokens=args.new_tokens)
            for i in range(args.requests)]
    t0 = time.perf_counter()
    done = server.run(reqs)
    dt = time.perf_counter() - t0
    tokens = sum(len(v) for v in done.values())
    print(f"{cfg.name}: {tokens} tokens, {len(done)} requests, "
          f"{tokens/dt:.1f} tok/s")
    return 0


if __name__ == "__main__":
    sys.exit(main())
