import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

The two lines above MUST stay first: jax locks the device count at first
init, and the production meshes need 512 host placeholder devices.  Only
this entry point sets the flag — tests and benches see 1 device.

For every live cell this driver:
  1. builds the production mesh (16x16 single-pod / 2x16x16 multi-pod),
  2. lowers + compiles the cell's step with ShapeDtypeStruct inputs,
  3. prints memory_analysis() (fits-in-HBM proof) and cost_analysis()
     (FLOPs/bytes for the roofline),
  4. parses collective bytes from the optimized HLO,
  5. writes a JSON artifact consumed by benchmarks/roofline_report.py.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch all --mesh both \
      --out artifacts/dryrun [--shape train_4k] [--skip-existing]
"""
import argparse     # noqa: E402
import json         # noqa: E402
import pathlib      # noqa: E402
import time         # noqa: E402
import traceback    # noqa: E402

import jax          # noqa: E402

from repro.launch.cells import build_cell, rules_for            # noqa: E402
from repro.launch.mesh import make_production_mesh              # noqa: E402
from repro.launch.shapes import SHAPES, applicable              # noqa: E402
from repro.roofline.analysis import (cost_summary, memory_summary,  # noqa: E402
                                     parse_collectives, roofline_terms)


def run_cell(arch: str, shape: str, multi_pod: bool, out_dir: pathlib.Path,
             verbose: bool = True, rules=None, cfg_overrides=None,
             accum=None, opt_cfg=None, tag: str = "") -> dict:
    mesh_name = "pod2x16x16" if multi_pod else "pod16x16"
    rec = {"arch": arch, "shape": shape, "mesh": mesh_name,
           "chips": 512 if multi_pod else 256, "status": "ok", "tag": tag}
    ok, why = applicable(arch, shape)
    if not ok:
        rec.update(status="skipped", reason=why)
        return rec
    try:
        mesh = make_production_mesh(multi_pod=multi_pod)
        t0 = time.perf_counter()
        kw = {} if opt_cfg is None else {"opt_cfg": opt_cfg}
        cell = build_cell(arch, shape, mesh, rules=rules,
                          cfg_overrides=cfg_overrides, accum=accum, **kw)
        with mesh:
            jitted = jax.jit(cell.fn, in_shardings=cell.in_shardings,
                             out_shardings=cell.out_shardings,
                             donate_argnums=cell.donate)
            lowered = jitted.lower(*cell.args)
            t1 = time.perf_counter()
            compiled = lowered.compile()
            t2 = time.perf_counter()
        mem = memory_summary(compiled)
        cost = cost_summary(compiled)
        coll = parse_collectives(compiled.as_text())
        # Loop-unit extrapolation: cost_analysis counts while bodies once.
        flops, nbytes = cost["flops"], cost["bytes"]
        coll_total = float(sum(coll.values()))
        unit_recs = []
        for lp in cell.loops:
            with mesh:
                kw = {}
                if lp.out_shardings is not None:
                    kw["out_shardings"] = lp.out_shardings
                uc = jax.jit(lp.fn, in_shardings=lp.in_shardings,
                             **kw).lower(*lp.args).compile()
            u_cost = cost_summary(uc)
            u_coll = parse_collectives(uc.as_text())
            u_coll_total = float(sum(u_coll.values()))
            if "flops" in lp.use:
                flops += (lp.trips - 1) * u_cost["flops"]
                nbytes += (lp.trips - 1) * u_cost["bytes"]
            if "coll" in lp.use:
                coll_total += (lp.trips - 1) * u_coll_total
                for k, v in u_coll.items():
                    coll[k] = coll.get(k, 0) + (lp.trips - 1) * v
            unit_recs.append({"name": lp.name, "trips": lp.trips,
                              "use": list(lp.use),
                              "flops": u_cost["flops"],
                              "bytes": u_cost["bytes"],
                              "coll": u_coll_total})
        cost = dict(cost, flops=flops, bytes=nbytes, units=unit_recs)
        chips = rec["chips"]
        rl = roofline_terms(
            per_device_flops=flops,
            per_device_bytes=nbytes,
            per_device_coll_bytes=coll_total,
            chips=chips, model_flops=cell.model_flops)
        rec.update(lower_s=round(t1 - t0, 2), compile_s=round(t2 - t1, 2),
                   memory=mem, cost=cost, collectives=coll,
                   roofline=rl.as_dict(), tokens=cell.tokens)
        if verbose:
            print(f"[{arch} x {shape} x {mesh_name}] "
                  f"lower {t1-t0:.1f}s compile {t2-t1:.1f}s")
            print(f"  memory_analysis: args={mem['argument_size_in_bytes']/1e9:.2f}GB "
                  f"out={mem['output_size_in_bytes']/1e9:.2f}GB "
                  f"temp={mem['temp_size_in_bytes']/1e9:.2f}GB "
                  f"(per device; HBM 16GB)")
            print(f"  cost_analysis: flops/dev={cost['flops']:.3e} "
                  f"bytes/dev={cost['bytes']:.3e}")
            print(f"  collectives/dev: " + (", ".join(
                f"{k}={v/1e6:.1f}MB" for k, v in sorted(coll.items()))
                or "none"))
            print(f"  roofline: compute={rl.compute_s*1e3:.2f}ms "
                  f"memory={rl.memory_s*1e3:.2f}ms "
                  f"collective={rl.collective_s*1e3:.2f}ms "
                  f"-> dominant={rl.dominant} mfu={rl.mfu:.3f} "
                  f"useful={rl.useful_ratio:.2f}")
    except Exception as e:  # noqa: BLE001
        rec.update(status="error", error=f"{type(e).__name__}: {e}",
                   traceback=traceback.format_exc()[-2000:])
        if verbose:
            print(f"[{arch} x {shape} x {mesh_name}] FAILED: {rec['error']}")
    if out_dir is not None:
        out_dir.mkdir(parents=True, exist_ok=True)
        suffix = f"__{tag}" if tag else ""
        path = out_dir / f"{arch}__{shape}__{mesh_name}{suffix}.json"
        path.write_text(json.dumps(rec, indent=1, default=str))
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="all")
    ap.add_argument("--shape", default="all")
    ap.add_argument("--mesh", default="both",
                    choices=["single", "multi", "both"])
    ap.add_argument("--out", default="artifacts/dryrun")
    ap.add_argument("--skip-existing", action="store_true")
    args = ap.parse_args()

    from repro.configs import list_archs
    archs = list_archs() if args.arch == "all" else [args.arch]
    shapes = list(SHAPES) if args.shape == "all" else [args.shape]
    meshes = {"single": [False], "multi": [True],
              "both": [False, True]}[args.mesh]
    out = pathlib.Path(args.out)

    n_ok = n_skip = n_err = 0
    for arch in archs:
        for shape in shapes:
            for multi in meshes:
                mesh_name = "pod2x16x16" if multi else "pod16x16"
                path = out / f"{arch}__{shape}__{mesh_name}.json"
                if args.skip_existing and path.exists():
                    prev = json.loads(path.read_text())
                    if prev.get("status") == "ok":
                        n_ok += 1
                        continue
                rec = run_cell(arch, shape, multi, out)
                n_ok += rec["status"] == "ok"
                n_skip += rec["status"] == "skipped"
                n_err += rec["status"] == "error"
    print(f"\ndry-run complete: {n_ok} ok, {n_skip} skipped (documented), "
          f"{n_err} errors")
    if n_err:
        raise SystemExit(1)


if __name__ == "__main__":
    main()
