"""Cell builder: (arch x shape x mesh) -> lowerable jitted program.

A *cell* is one entry of the dry-run matrix.  ``build_cell`` returns the
jitted step function (train_step / prefill_step / serve_step), its abstract
arguments (ShapeDtypeStruct stand-ins — no allocation), and the in/out
shardings resolved from the logical-axis rules.  The same builder backs the
dry-run, the roofline report and the perf hillclimb, so an optimization
changes every consumer at once.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.core.sharding import (LONG_CONTEXT_RULES, TP_DP_RULES,
                                 ShardingRules, activation_rules)
from repro.models import build_model, get_model
from repro.models.layers import abstract_params, is_spec, logical_tree
from repro.optim import AdamWConfig, apply_updates, init_state, state_logical
from repro.launch.shapes import SHAPES, ShapeSpec, applicable


@dataclasses.dataclass
class LoopSpec:
    """A scanned loop whose body XLA's cost_analysis counts only once.

    The dry-run compiles ``fn`` separately and extrapolates:
    corrected = full + (trips - 1) * unit.  ``use`` selects which terms the
    unit corrects ("flops" for flops+bytes, "coll" for collective bytes —
    train cells use a grad-wrt-x-only unit for collectives so the stacked
    param-grad all-reduce, already fully counted in the main HLO, is not
    double-counted).
    """
    name: str
    fn: Callable
    args: Tuple[Any, ...]
    in_shardings: Tuple[Any, ...]
    trips: int
    use: Tuple[str, ...] = ("flops", "coll")
    out_shardings: Any = None


@dataclasses.dataclass
class Cell:
    arch: str
    shape: str
    fn: Callable
    args: Tuple[Any, ...]
    in_shardings: Tuple[Any, ...]
    out_shardings: Any
    donate: Tuple[int, ...]
    model_flops: float
    tokens: int
    loops: Tuple[LoopSpec, ...] = ()
    note: str = ""


def _shapes_of(specs):
    return jax.tree.map(lambda s: s.shape, specs, is_leaf=is_spec)


def _sharding_tree(logical, shapes, mesh, rules):
    return jax.tree.map(
        lambda lg, sh: NamedSharding(mesh, rules.spec_for(lg, sh, mesh)),
        logical, shapes, is_leaf=lambda x: isinstance(x, tuple))


def with_act_rules(fn, mesh, rules):
    """Run ``fn``'s trace under the activation-constraint context."""
    def wrapped(*args):
        with activation_rules(mesh, rules):
            return fn(*args)
    return wrapped


def _batch_abstract(cfg, shape: ShapeSpec):
    b, s = shape.global_batch, shape.seq_len
    if cfg.family == "encdec":
        text = s // 2
        return {"tokens": jax.ShapeDtypeStruct((b, text), jnp.int32),
                "labels": jax.ShapeDtypeStruct((b, text), jnp.int32),
                "frontend": jax.ShapeDtypeStruct((b, s - text, cfg.d_model),
                                                 jnp.float32)}
    out = {}
    text = s - cfg.frontend_tokens
    out["tokens"] = jax.ShapeDtypeStruct((b, text), jnp.int32)
    out["labels"] = jax.ShapeDtypeStruct((b, text), jnp.int32)
    if cfg.frontend:
        out["frontend"] = jax.ShapeDtypeStruct(
            (b, cfg.frontend_tokens, cfg.d_model), jnp.float32)
    return out


def _batch_logical(cfg):
    out = {"tokens": ("batch", "seq"), "labels": ("batch", "seq")}
    if cfg.family == "encdec" or cfg.frontend:
        out["frontend"] = ("batch", "seq", "embed")
    return out


def _train_state(model, mesh, rules, zero1=True):
    specs = model.specs()
    params_abs = abstract_params(specs, jnp.float32)
    params_logical = model.logical()
    params_shapes = _shapes_of(specs)
    state_abs = {
        "params": params_abs,
        "opt": {"mu": params_abs, "nu": params_abs,
                "step": jax.ShapeDtypeStruct((), jnp.int32)},
        "rng": jax.ShapeDtypeStruct((2,), jnp.uint32),
        "step": jax.ShapeDtypeStruct((), jnp.int32),
    }
    logical = {
        "params": params_logical,
        "opt": state_logical(params_logical, params_shapes, mesh, rules,
                             zero1=zero1),
        "rng": (None,),
        "step": (),
    }
    shapes = {
        "params": params_shapes,
        "opt": {"mu": params_shapes, "nu": params_shapes, "step": ()},
        "rng": (2,),
        "step": (),
    }
    shardings = _sharding_tree(logical, shapes, mesh, rules)
    return state_abs, shardings


def _cache_state(model, cfg, batch, max_len, mesh, rules):
    cspecs = model.cache_specs(batch, max_len)
    logical = logical_tree(cspecs)
    shapes = _shapes_of(cspecs)

    def abs_of(spec):
        last = spec.logical[-1] if spec.logical else ""
        if last == "kv_seq":           # ring-buffer position index
            dt = jnp.int32
        elif last == "state":          # fp32 recurrent state
            dt = jnp.float32
        else:
            dt = jnp.dtype(cfg.dtype)
        return jax.ShapeDtypeStruct(spec.shape, dt)

    cache_abs = jax.tree.map(abs_of, cspecs, is_leaf=is_spec)
    shardings = _sharding_tree(logical, shapes, mesh, rules)
    return cache_abs, shardings


def rules_for(shape: ShapeSpec, mesh: Mesh,
              base: ShardingRules = TP_DP_RULES) -> ShardingRules:
    data_ways = 1
    for ax in ("pod", "data"):
        if ax in mesh.shape:
            data_ways *= mesh.shape[ax]
    if shape.global_batch < data_ways:
        return LONG_CONTEXT_RULES
    return base


# -- per-cell deployment configuration ----------------------------------------
#
# Chunk sizes trade HLO op count / VMEM tile size against sequence length;
# grad-accumulation bounds the live activation footprint (scan carries) per
# device.  These are the *deployment defaults* a production config would
# ship; §Perf in EXPERIMENTS.md hillclimbs them per cell.

TRAIN_ACCUM = {
    "smollm-135m": 1, "granite-3-2b": 4, "qwen3-4b": 8, "gemma2-27b": 8,
    "recurrentgemma-9b": 4, "deepseek-moe-16b": 4,
    "phi3.5-moe-42b-a6.6b": 8, "seamless-m4t-medium": 1,
    "mamba2-130m": 2, "paligemma-3b": 4,
}

# Train cells whose fp32 params+grads exceed ~1/3 of HBM under pure TP get
# FSDP (weights sharded over `data` on their embed dim, gathered at use).
FSDP_BYTES_THRESHOLD = 6e9


def cell_config(cfg, shape: ShapeSpec):
    """Deployment-config overrides for one cell."""
    updates = {}
    if shape.seq_len >= 32_768 and shape.kind != "decode":
        updates["attn_chunk"] = 1024
        if cfg.family == "ssm":
            updates["ssd_chunk"] = 512
    return dataclasses.replace(cfg, **updates) if updates else cfg


def train_rules(cfg, mesh: Mesh) -> ShardingRules:
    from repro.core.sharding import FSDP_RULES
    model_ways = mesh.shape.get("model", 1)
    per_dev = cfg.param_count() * 4 * 2 / model_ways   # params + grads fp32
    return FSDP_RULES if per_dev > FSDP_BYTES_THRESHOLD else TP_DP_RULES


def build_cell(arch: str, shape_name: str, mesh: Mesh,
               rules: Optional[ShardingRules] = None,
               opt_cfg: AdamWConfig = AdamWConfig(),
               cfg_overrides: Optional[dict] = None,
               accum: Optional[int] = None) -> Cell:
    ok, why = applicable(arch, shape_name)
    if not ok:
        raise ValueError(f"{arch} x {shape_name} skipped: {why}")
    shape = SHAPES[shape_name]
    _, cfg = get_model(arch)
    cfg = cell_config(cfg, shape)
    if cfg_overrides:
        cfg = dataclasses.replace(cfg, **cfg_overrides)
    model = build_model(cfg)
    n_active = cfg.active_param_count()

    if shape.kind == "train":
        if rules is None:
            rules = train_rules(cfg, mesh)
        if accum is None:
            accum = TRAIN_ACCUM.get(cfg.name, 1)
        state_abs, state_sh = _train_state(model, mesh, rules,
                                           zero1=opt_cfg.zero1)
        batch_abs = _batch_abstract(cfg, shape)
        batch_sh = _sharding_tree(
            _batch_logical(cfg),
            jax.tree.map(lambda s: s.shape, batch_abs), mesh, rules)

        def grads_of(params, batch):
            def loss_fn(p):
                loss, parts = model.loss(p, batch)
                return loss, parts
            return jax.value_and_grad(loss_fn, has_aux=True)(params)

        params_sh_tree = state_sh["params"]

        def train_step(state, batch):
            if accum > 1:
                # microbatch scan bounds live activations to 1/accum;
                # the accumulator carry is pinned to the params' sharding so
                # each microbatch's grads reduce-scatter (ZeRO flow) instead
                # of all-reducing a replicated buffer.
                mbs = jax.tree.map(
                    lambda x: x.reshape((accum, x.shape[0] // accum)
                                        + x.shape[1:]), batch)

                def micro(carry, mb):
                    g_acc, l_acc = carry
                    (loss, _), grads = grads_of(state["params"], mb)
                    if opt_cfg.grad_reduce_dtype:
                        # reduce across slices in low precision; accumulate
                        # in fp32 (error stays below bf16 rounding of one
                        # microbatch gradient)
                        grads = jax.tree.map(
                            lambda g: g.astype(opt_cfg.grad_reduce_dtype),
                            grads)
                    grads = jax.lax.with_sharding_constraint(
                        grads, params_sh_tree)
                    return (jax.tree.map(
                        lambda a, g: a + g.astype(jnp.float32),
                        g_acc, grads), l_acc + loss), None
                zeros = jax.lax.with_sharding_constraint(
                    jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32),
                                 state["params"]), params_sh_tree)
                (grads, loss), _ = jax.lax.scan(
                    micro, (zeros, jnp.zeros((), jnp.float32)), mbs)
                grads = jax.tree.map(lambda g: g / accum, grads)
                loss = loss / accum
            else:
                (loss, _), grads = grads_of(state["params"], batch)
            params, opt, metrics = apply_updates(
                opt_cfg, state["params"], grads, state["opt"])
            new_state = {"params": params, "opt": opt,
                         "rng": jax.random.fold_in(state["rng"], 0),
                         "step": state["step"] + 1}
            return new_state, dict(metrics, loss=loss)

        tokens = shape.global_batch * shape.seq_len
        from repro.launch.units import loops_for, micro_loop
        loops = list(loops_for(model, cfg, shape, mesh, rules, "train",
                               accum))
        if accum > 1:
            # compose corrections: layer unit runs accum*reps times total;
            # full counts it once, micro adds (accum-1) more.
            for lp in loops:
                lp.trips = accum * (lp.trips - 1) + 1
            loops.append(micro_loop(model, cfg, shape, mesh, rules, accum,
                                    batch_abs, batch_sh))
        return Cell(arch, shape_name, with_act_rules(train_step, mesh,
                                                      rules),
                    (state_abs, batch_abs), (state_sh, batch_sh),
                    (state_sh, None), donate=(0,),
                    model_flops=6.0 * n_active * tokens, tokens=tokens,
                    loops=tuple(loops), note=f"accum={accum}")

    if rules is None:
        rules = rules_for(shape, mesh)
    params_abs = abstract_params(model.specs(), jnp.dtype(cfg.param_dtype))
    params_sh = _sharding_tree(model.logical(), _shapes_of(model.specs()),
                               mesh, rules)

    if shape.kind == "prefill":
        batch_abs = _batch_abstract(cfg, shape)
        b = shape.global_batch
        cache_abs, cache_sh = _cache_state(model, cfg, b, shape.seq_len,
                                           mesh, rules)
        batch_sh = _sharding_tree(
            _batch_logical(cfg),
            jax.tree.map(lambda s: s.shape, batch_abs), mesh, rules)
        if cfg.family == "encdec":
            def prefill_step(params, batch):
                return model.prefill(params, batch["frontend"],
                                     batch["tokens"], shape.seq_len // 2)
        elif cfg.frontend:
            def prefill_step(params, batch):
                return model.prefill(params, batch["tokens"],
                                     shape.seq_len,
                                     extra_embeds=batch["frontend"])
        else:
            def prefill_step(params, batch):
                return model.prefill(params, batch["tokens"],
                                     shape.seq_len)
        tokens = shape.global_batch * shape.seq_len
        from repro.launch.units import loops_for
        loops = loops_for(model, cfg, shape, mesh, rules, "prefill")
        return Cell(arch, shape_name, with_act_rules(prefill_step, mesh,
                                                      rules),
                    (params_abs, batch_abs), (params_sh, batch_sh),
                    (None, cache_sh), donate=(),
                    model_flops=2.0 * n_active * tokens, tokens=tokens,
                    loops=loops)

    # decode: one new token against a seq_len-deep cache
    b = shape.global_batch
    max_len = shape.seq_len if cfg.family != "encdec" else shape.seq_len // 2
    cache_abs, cache_sh = _cache_state(model, cfg, b, max_len, mesh, rules)
    tok_abs = jax.ShapeDtypeStruct((b, 1), jnp.int32)
    tok_sh = NamedSharding(mesh, rules.spec_for(("batch", None), (b, 1),
                                                mesh))
    pos_abs = jax.ShapeDtypeStruct((), jnp.int32)
    pos_sh = NamedSharding(mesh, P())

    def serve_step(params, cache, token, pos):
        return model.decode_step(params, cache, token, pos)

    tokens = b
    from repro.launch.units import loops_for
    loops = loops_for(model, cfg, shape, mesh, rules, "decode")
    return Cell(arch, shape_name, with_act_rules(serve_step, mesh, rules),
                (params_abs, cache_abs, tok_abs, pos_abs),
                (params_sh, cache_sh, tok_sh, pos_sh),
                (None, cache_sh), donate=(1,),
                model_flops=2.0 * n_active * tokens, tokens=tokens,
                loops=loops)
