"""Training launcher: any assigned arch, optional elasticity.

  PYTHONPATH=src python -m repro.launch.train --arch smollm-135m \
      --steps 100 [--reduced] [--slices 4 --devices 8 --elastic]

With --devices N the launcher requests N CPU host devices (like the
dry-run) so multi-slice elasticity runs for real on one host; on TPU the
flag is unnecessary.
"""
import argparse
import os
import sys


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="smollm-135m")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--reduced", action="store_true",
                    help="reduced config (CPU-friendly)")
    ap.add_argument("--seq-len", type=int, default=128)
    ap.add_argument("--global-batch", type=int, default=16)
    ap.add_argument("--grad-accum", type=int, default=1)
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--slices", type=int, default=1)
    ap.add_argument("--model-ways", type=int, default=1)
    ap.add_argument("--devices", type=int, default=0,
                    help="request N CPU host devices before jax init")
    ap.add_argument("--elastic", action="store_true",
                    help="attach a LocalRMS and honour DMR decisions")
    ap.add_argument("--ckpt-dir", default=None)
    args = ap.parse_args()

    if args.devices:
        os.environ["XLA_FLAGS"] = (
            f"--xla_force_host_platform_device_count={args.devices}")

    from repro.data import DataConfig
    from repro.models import build_model, get_model, reduced_config
    from repro.optim import AdamWConfig
    from repro.rms.job import Job
    from repro.runtime import ElasticTrainer, LocalRMS, TrainerConfig

    _, cfg = get_model(args.arch)
    if args.reduced:
        cfg = reduced_config(cfg)
    model = build_model(cfg)
    data = DataConfig(vocab_size=cfg.vocab_size, seq_len=args.seq_len,
                      global_batch=args.global_batch,
                      frontend=cfg.frontend,
                      frontend_tokens=cfg.frontend_tokens,
                      d_model=cfg.d_model, enc_dec=cfg.family == "encdec")
    opt = AdamWConfig(lr=args.lr, warmup_steps=max(args.steps // 20, 1),
                      total_steps=args.steps)
    rms = None
    if args.elastic:
        rms = LocalRMS(num_nodes=max(args.devices // args.model_ways, 1))
        rms.submit(Job(job_id=0, app=f"lm:{cfg.name}", submit_time=0.0,
                       work=args.steps, min_nodes=1,
                       max_nodes=rms.cluster.num_nodes, preferred=None,
                       requested_nodes=args.slices), start=True)
    trainer = ElasticTrainer(
        model, opt, data,
        TrainerConfig(steps=args.steps, model_ways=args.model_ways,
                      max_slices=max(args.slices, 1),
                      log_period=max(args.steps // 10, 1),
                      ckpt_dir=args.ckpt_dir),
        rms=rms, job_id=0)
    trainer.train()
    for m in trainer.metrics:
        print(f"step {m['step']:5d} loss {m['loss']:.4f} "
              f"slices {m['slices']}")
    if trainer.resize_log:
        print("resizes:", trainer.resize_log)
    return 0


if __name__ == "__main__":
    sys.exit(main())
